"""Serving example: async front-end, paged continuous batching, prefix reuse.

The same engine that backs RL rollout (``repro.rl.engine``) is the serving
decode loop: requests carry their own token budgets, rows retire at EOS or
budget, and freed slots are immediately re-prefilled from the queue — short
requests never wait on long neighbours (DESIGN.md §3).

Part 1 runs the real server path (DESIGN.md §10): ``AsyncLMServer`` over a
radix-prefix-cached paged engine, two tenants sharing a system prompt whose
KV pages are prefilled once and matched from the trie by every later
request, tokens streamed back through each request's ``TokenStream``.
Part 2 serves an n-best sampling workload (G samples per prompt — the
serving twin of a GRPO group) through the PAGED arena (DESIGN.md §8): each
prompt's KV is prefilled once into refcounted shared pages, every sample
only pays private decode pages, and retirement returns pages to a free
list.  Part 3 keeps the legacy fixed-shape prefill+decode smoke across
attention families (dense GQA, MLA, SSM) — the same ``decode_step`` the
dry-run lowers at scale.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data import PromptPipeline
from repro.models import decode_step, init_params, model_decl, prefill
from repro.rl import (
    PagedEngineConfig, PagedRolloutEngine, Request, RolloutConfig, make_env,
)
from repro.serve import AsyncLMServer, ServeConfig

# ------------------------- 1. async serving with the radix prefix cache
S_ARCH, S_NEW, S_PAGE = "mistral-nemo-12b", 16, 16
scfg_model = get_smoke(S_ARCH)
s_params = init_params(jax.random.PRNGKey(0), model_decl(scfg_model))
s_rcfg = RolloutConfig(max_new_tokens=S_NEW, temperature=1.0, eos_id=-1)
s_engine = PagedRolloutEngine(
    scfg_model, s_rcfg,
    PagedEngineConfig(num_slots=4, max_prompt_len=64, steps_per_sync=4,
                      page_len=S_PAGE, max_group=1, prefix_cache=True))
system_prompt = np.arange(3, 3 + 3 * S_PAGE, dtype=np.int32) % 29 + 3


async def serve_demo():
    server = AsyncLMServer(
        s_engine, s_params, jax.random.PRNGKey(7),
        ServeConfig(max_queue=32, max_backlog=2, quantum=128),
        tenant_weights={"alice": 2.0, "bob": 1.0})
    await server.start()

    async def ask(tenant, i):
        user = np.int32([40 + i, 41 + i, 9, 10])
        stream = server.submit(np.concatenate([system_prompt, user]),
                               tenant=tenant, max_new=S_NEW)
        n = 0
        async for delta in stream:            # tokens arrive per round
            n += len(delta)
        comp = await stream.result()
        return tenant, stream.uid, n, stream.ttft, comp

    t0 = time.perf_counter()
    outs = await asyncio.gather(*[ask("alice" if i % 2 else "bob", i)
                                  for i in range(8)])
    dt = time.perf_counter() - t0
    await server.stop()
    st, est = server.stats, s_engine.stats
    print(f"{S_ARCH}: async-served {st['completed']} requests "
          f"({st['tokens_out']} streamed tokens) in {dt:.2f}s incl. compile")
    print(f"  cache_hit_rate="
          f"{est['prefix_hit_tokens'] / max(est['prompt_tokens'], 1):.2f} "
          f"(prefilled {est['prefill_tokens']} of {est['prompt_tokens']} "
          f"prompt tokens)  mean_ttft={server.mean_ttft * 1e3:.0f}ms")
    for tenant, uid, n, ttft, comp in outs[:4]:
        print(f"  uid={uid} tenant={tenant:5s} streamed={n:2d} "
              f"completed={comp.completed}")

asyncio.run(serve_demo())

# ------------------------------------------- 2. paged n-best-of-G serving
ARCH = "mistral-nemo-12b"
SLOTS, TP, MAX_NEW = 8, 32, 48
N_PROMPTS, G = 6, 4          # 24 samples served through 8 slots
PAGE_LEN = 16

cfg = get_smoke(ARCH)
key = jax.random.PRNGKey(0)
params = init_params(key, model_decl(cfg))
rng = np.random.default_rng(0)

rcfg = RolloutConfig(max_new_tokens=MAX_NEW, temperature=1.0, eos_id=-1)
engine = PagedRolloutEngine(
    cfg, rcfg, PagedEngineConfig(num_slots=SLOTS, max_prompt_len=TP,
                                 steps_per_sync=4, page_len=PAGE_LEN,
                                 max_group=G))

# prompts stream one-at-a-time from the data pipeline; each is sampled G
# times (n-best serving), with a straggler-heavy budget mix per group:
# most samples short, one long-form
stream = PromptPipeline(make_env("copy_calc"), batch_size=SLOTS,
                        max_prompt_len=TP, seed=0).iter_prompts()
budgets = {}
groups = []
for p in range(N_PROMPTS):
    _, toks, _n = next(stream)
    group = []
    for j in range(G):
        uid = p * G + j
        budgets[uid] = MAX_NEW if j == 0 else int(rng.integers(4, 12))
        group.append(Request(uid=uid, tokens=toks, budget=budgets[uid]))
    groups.append(group)

t0 = time.perf_counter()
completions = engine.run_groups(params, groups, key)
t1 = time.perf_counter()

st = engine.stats
tok = st["tokens_generated"]
n_req = N_PROMPTS * G
prompt_pages = -(-TP // PAGE_LEN)
print(f"{ARCH}: served {n_req} samples ({N_PROMPTS} prompts x G={G}, "
      f"{tok} tokens) on {SLOTS} slots in {t1 - t0:.2f}s incl. compile")
print(f"  rounds={st['rounds']} prompt_prefills={st['prompt_prefills']} "
      f"(dense would prefill {n_req}) "
      f"slot_util={tok / max(st['slot_substeps'], 1):.2f}")
print(f"  peak_pages={st['peak_pages_in_use']}/{engine.num_pages} "
      f"(prompt KV per group: {prompt_pages} shared pages, "
      f"not {G * prompt_pages})")
for c in completions[:4]:
    print(f"  uid={c.uid:2d} prompt={c.prompt_len:2d} "
          f"generated={c.response_len:2d}/{budgets[c.uid]:2d}")

# ----------------------------------------- 3. fixed-shape decode-step smoke
ARCHS = ["deepseek-v2-236b", "h2o-danube-3-4b", "mamba2-130m"]
B, TPS, NEW = 4, 32, 16

for arch in ARCHS:
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    prompts = jax.random.randint(key, (B, TPS), 3, cfg.vocab_size)
    plens = jnp.full((B,), TPS, jnp.int32)

    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, t, l: prefill(p, cfg, t, cache_len=TPS + NEW, prefill_len=l)
    )(params, prompts, plens)
    t1 = time.perf_counter()

    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    toks = jnp.argmax(logits, axis=-1)
    out = [toks]
    for i in range(NEW - 1):
        pos = jnp.full((B,), TPS + i, jnp.int32)
        logits, cache = step(params, toks, cache, pos)
        toks = jnp.argmax(logits, axis=-1)
        out.append(toks)
    jax.block_until_ready(out[-1])
    t2 = time.perf_counter()
    print(f"{arch:24s} prefill({B}x{TPS})={t1 - t0:6.2f}s  "
          f"decode {NEW} steps={t2 - t1:6.2f}s  "
          f"({B * (NEW - 1) / (t2 - t1):6.1f} tok/s incl. compile)")
print("OK")
