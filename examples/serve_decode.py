"""Serving example: batched prefill + decode with KV caches.

Loads a smoke-scale config from each attention family (dense GQA, MLA,
sliding-window, SSM) and serves a batch of prompts: prefill builds the
cache, then tokens stream out one decode step at a time — the same
``serve_step`` the dry-run lowers at (arch × decode_32k/long_500k) scale.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import decode_step, init_params, model_decl, prefill

ARCHS = ["mistral-nemo-12b", "deepseek-v2-236b", "h2o-danube-3-4b", "mamba2-130m"]
B, TP, NEW = 4, 32, 16

for arch in ARCHS:
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    prompts = jax.random.randint(key, (B, TP), 3, cfg.vocab_size)
    plens = jnp.full((B,), TP, jnp.int32)

    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, t, l: prefill(p, cfg, t, cache_len=TP + NEW, prefill_len=l)
    )(params, prompts, plens)
    t1 = time.perf_counter()

    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    toks = jnp.argmax(logits, axis=-1)
    out = [toks]
    for i in range(NEW - 1):
        pos = jnp.full((B,), TP + i, jnp.int32)
        logits, cache = step(params, toks, cache, pos)
        toks = jnp.argmax(logits, axis=-1)
        out.append(toks)
    jax.block_until_ready(out[-1])
    t2 = time.perf_counter()
    print(f"{arch:24s} prefill({B}x{TP})={t1 - t0:6.2f}s  "
          f"decode {NEW} steps={t2 - t1:6.2f}s  "
          f"({B * (NEW - 1) / (t2 - t1):6.1f} tok/s incl. compile)")
print("OK")
