"""End-to-end driver: GRPO+NAT RL training on a verifiable task.

Trains a small decoder on modular arithmetic with exact-match rewards,
comparing full-token GRPO against RPC at ~50% token budget — the paper's
Figure 1 setup, hermetic on CPU.

Run:  PYTHONPATH=src python examples/train_rl.py --steps 120
      (add --selector urs / det_trunc / entropy to switch schemes;
       --arch nat-qwen3-8b --preset full is the real Qwen3-8B config a TPU
       job would train.)
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--selector", default="rpc")
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--arch", default="nat-qwen3-8b")
    args = ap.parse_args()
    train_main([
        "--arch", args.arch, "--preset", args.preset,
        "--selector", args.selector, "--steps", str(args.steps),
        "--prompts-per-step", "8", "--group-size", "8", "--max-new", "12",
        "--lr", "1e-3", "--log-every", "10",
    ])
