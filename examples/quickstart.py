"""Quickstart: NAT in ~60 lines.

Shows the paper's core mechanism end to end on synthetic data:
  1. draw a token selection (RPC) over a fake rollout batch,
  2. form Horvitz-Thompson weights,
  3. verify the masked loss matches the full-token loss in expectation
     (Proposition 1) by Monte Carlo over masks.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    RPCSelector, full_token_loss_reference, nat_grpo_loss,
)

B, T = 8, 64
key = jax.random.PRNGKey(0)
k1, k2, k3, k4 = jax.random.split(key, 4)

# a fake scored rollout batch: logprobs of realized tokens under pi / pi_old
logp = -jnp.abs(jax.random.normal(k1, (B, T))) * 0.5
old_logp = logp + 0.1 * jax.random.normal(k2, (B, T))
advantages = jax.random.normal(k3, (B,))
response_mask = (jnp.arange(T)[None, :] < 48).astype(jnp.float32)  # 48-token responses

# full-token GRPO loss (the oracle NAT must match in expectation)
full_loss = full_token_loss_reference(logp, old_logp, advantages, response_mask)

# NAT: random prefix cutting with min retained prefix C=8, HT reweighting
selector = RPCSelector(min_cut=8)
losses, kept = [], []
for i in range(512):
    sel = selector(jax.random.fold_in(k4, i), response_mask)
    loss, metrics = nat_grpo_loss(
        logp, old_logp, advantages, sel.ht_weights,
        orig_lengths=response_mask.sum(-1))
    losses.append(loss)
    kept.append(metrics["selected_ratio"])

mc = jnp.mean(jnp.stack(losses))
print(f"full-token GRPO loss      : {full_loss:+.6f}")
print(f"NAT-RPC loss (MC over mask): {mc:+.6f}  (512 draws)")
print(f"mean selected-token ratio  : {jnp.mean(jnp.stack(kept)):.3f} "
      f"(paper predicts ~0.5 + C/2T = {0.5 + 8 / (2 * 48):.3f})")
assert abs(mc - full_loss) < 0.02, "HT estimator should be unbiased"
print("OK: unbiased partial-token loss with ~half the tokens.")
