"""Ablation: the compute-variance trade-off of NAT selectors (paper §3.1).

For each selector, estimates over many mask draws:
  * expected kept-token fraction (compute budget),
  * gradient-estimator variance around the full-token gradient,
  * bias (should be ~0 for HT schemes; systematically non-zero for
    deterministic truncation — the paper's Table 1 story).

Run:  PYTHONPATH=src python examples/selector_ablation.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    DetTruncSelector, FullSelector, RPCSelector, URSSelector,
    nat_grpo_loss,
)

B, T, DRAWS = 16, 96, 400
key = jax.random.PRNGKey(7)
k1, k2, k3, km = jax.random.split(key, 4)
theta = jax.random.normal(k1, (B, T)) * 0.1          # toy "parameters"
old_logp = -jnp.abs(jax.random.normal(k2, (B, T)))
adv = jax.random.normal(k3, (B,))
rmask = (jnp.arange(T)[None] < 80).astype(jnp.float32)
lengths = rmask.sum(-1)


def loss_with(sel_weights, theta):
    logp = old_logp + theta                           # d logp / d theta = 1
    loss, _ = nat_grpo_loss(logp, old_logp, adv, sel_weights, lengths)
    return loss


g_full = jax.grad(loss_with, argnums=1)(rmask, theta)

rows = []
for name, sel in [
    ("full", FullSelector()),
    ("urs p=0.5", URSSelector(p=0.5)),
    ("rpc C=8", RPCSelector(min_cut=8)),
    ("det_trunc", DetTruncSelector(frac=0.5)),
]:
    grads, kept = [], []
    for i in range(DRAWS):
        s = sel(jax.random.fold_in(km, i), rmask)
        grads.append(jax.grad(loss_with, argnums=1)(s.ht_weights, theta))
        kept.append(s.mask.sum() / rmask.sum())
    g = jnp.stack(grads)
    bias = jnp.linalg.norm(jnp.mean(g, 0) - g_full) / jnp.linalg.norm(g_full)
    var = jnp.mean(jnp.var(g, axis=0))
    rows.append((name, float(jnp.mean(jnp.stack(kept))), float(bias), float(var)))

print(f"{'selector':12s} {'kept%':>7s} {'rel-bias':>9s} {'grad-var':>9s}")
for name, kept, bias, var in rows:
    print(f"{name:12s} {kept * 100:6.1f}% {bias:9.4f} {var:9.2e}")
print("\nHT schemes (urs/rpc) are unbiased at ~half the tokens;")
print("deterministic truncation is cheaper but biased — matching Table 1.")
