"""Paged decode-attention kernel vs the jnp oracle: GQA/MQA shapes, shared
prompt pages, unallocated-page skips, partial-page gaps, inactive slots
(interpret mode) — plus the fused paged *prefill* kernel (pool + packed
suffix under one softmax) and its custom-vjp backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import (
    paged_attention,
    paged_attention_ref,
    paged_decode_pallas,
    paged_prefill_attention,
    paged_prefill_attention_ref,
    paged_prefill_fwd_pallas,
)

SWEEP = [
    # (S, KV, G, D, P, page_len, M)
    (4, 2, 2, 32, 12, 8, 4),
    (2, 4, 1, 16, 8, 4, 5),     # MHA
    (3, 1, 8, 32, 16, 16, 3),   # MQA
    (5, 2, 3, 64, 20, 8, 6),
]


def data(s, kv, g, d, p, pl, m, seed=0):
    """Random pool + block tables shaped like the engine's: a shared prompt
    page run, slot-private decode pages, a partial-page gap, one inactive
    slot, and unallocated table tails."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (s, kv, g, d), jnp.float32) * 0.3
    kp = jax.random.normal(jax.random.fold_in(key, 1), (p, pl, kv, d)) * 0.3
    vp = jax.random.normal(jax.random.fold_in(key, 2), (p, pl, kv, d)) * 0.3
    rng = np.random.default_rng(seed)
    pos = np.full((p, pl), -1, np.int32)
    bt = np.full((s, m), -1, np.int32)
    # pages 0..1 shared prompt (partial second page: the gap)
    plen = pl + max(1, pl // 2)
    pos[0] = np.arange(pl)
    pos[1, :plen - pl] = np.arange(pl, plen)
    q_pos = np.full((s,), -1, np.int32)
    nxt = 2
    for si in range(s - 1):  # last slot stays inactive
        bt[si, 0], bt[si, 1] = 0, 1
        ndec = int(rng.integers(0, m - 2)) if m > 2 else 0
        tok = 0
        for pi in range(ndec):
            if nxt >= p:
                break
            bt[si, 2 + pi] = nxt
            fill = int(rng.integers(1, pl + 1))
            pos[nxt, :fill] = 2 * pl + tok + np.arange(fill)
            tok += fill
            nxt += 1
        q_pos[si] = 2 * pl + max(tok - 1, 0)
    return q, kp, vp, jnp.asarray(pos), jnp.asarray(bt), jnp.asarray(q_pos)


@pytest.mark.parametrize("s,kv,g,d,p,pl,m", SWEEP)
def test_kernel_vs_ref(s, kv, g, d, p, pl, m):
    q, kp, vp, pos, bt, qp = data(s, kv, g, d, p, pl, m)
    o = paged_decode_pallas(q, kp, vp, pos, bt, qp)
    oref = paged_attention_ref(q, kp, vp, pos, bt, qp)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)
    # the inactive slot (q_pos = -1) outputs exactly zero
    assert np.all(np.asarray(o)[-1] == 0)


def test_flat_head_wrapper_matches_gqa_grouping():
    s, kv, g, d, p, pl, m = SWEEP[0]
    q, kp, vp, pos, bt, qp = data(s, kv, g, d, p, pl, m)
    o4 = paged_attention_ref(q, kp, vp, pos, bt, qp)
    of = paged_attention(q.reshape(s, kv * g, d), kp, vp, pos, bt, qp)
    np.testing.assert_allclose(np.asarray(of),
                               np.asarray(o4).reshape(s, kv * g, d),
                               rtol=2e-5, atol=2e-5)


def test_unallocated_pages_do_not_contribute():
    """Poisoning every page NOT named by a slot's block table must not
    change its output — the gather-isolation invariant at kernel level."""
    s, kv, g, d, p, pl, m = SWEEP[0]
    q, kp, vp, pos, bt, qp = data(s, kv, g, d, p, pl, m)
    o1 = paged_decode_pallas(q, kp, vp, pos, bt, qp)
    owned = set(np.asarray(bt)[0][np.asarray(bt)[0] >= 0].tolist())
    kp2, vp2, pos2 = (np.array(x) for x in (kp, vp, pos))
    for page in range(p):
        if page not in owned:
            kp2[page] = 1e3
            vp2[page] = -1e3
            # stale-but-plausible positions: visibility must still come
            # only through the block table
            pos2[page] = np.arange(pl)
    o2 = paged_decode_pallas(q, jnp.asarray(kp2), jnp.asarray(vp2),
                             jnp.asarray(pos2), bt, qp)
    np.testing.assert_array_equal(np.asarray(o1)[0], np.asarray(o2)[0])


# ------------------------------------------------------------- MLA variant
MLA_SWEEP = [
    # (S, H, R, Dr, P, page_len, M)
    (4, 4, 16, 8, 12, 8, 4),
    (2, 8, 32, 16, 8, 4, 5),
    (3, 1, 8, 4, 16, 16, 3),
]


def mla_data(s, h, r, dr, p, pl, m, seed=0):
    """Latent pool shaped like ``mla_paged_cache_decl``: value operand IS
    the latent page; same shared-prompt/partial-page/inactive-slot
    structure as ``data``."""
    key = jax.random.PRNGKey(seed)
    qa = jax.random.normal(key, (s, h, r), jnp.float32) * 0.3
    qr = jax.random.normal(jax.random.fold_in(key, 1), (s, h, dr)) * 0.3
    cp = jax.random.normal(jax.random.fold_in(key, 2), (p, pl, r)) * 0.3
    krp = jax.random.normal(jax.random.fold_in(key, 3), (p, pl, dr)) * 0.3
    _, _, _, pos, bt, qp = data(s, 1, 1, 8, p, pl, m, seed=seed)
    return qa, qr, cp, krp, pos, bt, qp


@pytest.mark.parametrize("s,h,r,dr,p,pl,m", MLA_SWEEP)
def test_mla_kernel_vs_ref(s, h, r, dr, p, pl, m):
    from repro.kernels.paged_attn import (
        paged_mla_attention_ref, paged_mla_decode_pallas,
    )

    qa, qr, cp, krp, pos, bt, qp = mla_data(s, h, r, dr, p, pl, m)
    scale = 1.0 / np.sqrt(r + dr)
    o = paged_mla_decode_pallas(qa, qr, cp, krp, pos, bt, qp, scale=scale)
    oref = paged_mla_attention_ref(qa, qr, cp, krp, pos, bt, qp, scale=scale)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(o)[-1] == 0)   # inactive slot -> exact zero


def test_mla_unallocated_pages_do_not_contribute():
    s, h, r, dr, p, pl, m = MLA_SWEEP[0]
    from repro.kernels.paged_attn import paged_mla_decode_pallas

    qa, qr, cp, krp, pos, bt, qp = mla_data(s, h, r, dr, p, pl, m)
    scale = 1.0 / np.sqrt(r + dr)
    o1 = paged_mla_decode_pallas(qa, qr, cp, krp, pos, bt, qp, scale=scale)
    owned = set(np.asarray(bt)[0][np.asarray(bt)[0] >= 0].tolist())
    cp2, krp2, pos2 = (np.array(x) for x in (cp, krp, pos))
    for page in range(p):
        if page not in owned:
            cp2[page] = 1e3
            krp2[page] = -1e3
            pos2[page] = np.arange(pl)
    o2 = paged_mla_decode_pallas(qa, qr, jnp.asarray(cp2), jnp.asarray(krp2),
                                 jnp.asarray(pos2), bt, qp, scale=scale)
    np.testing.assert_array_equal(np.asarray(o1)[0], np.asarray(o2)[0])


# ----------------------------------------------------- fused prefill kernel
BQ = 16
PAD = np.int32(2 ** 30)

PREFILL_SWEEP = [
    # (H, KVH, D): GQA, MHA, MQA
    (4, 2, 8),
    (4, 4, 8),
    (8, 1, 8),
]


def prefill_data(h, kvh, d, seed=0, dtype=np.float32):
    """A PagedLayout-shaped problem: 4 segments packed into 2 rows (with
    qblock-aligned gaps + PAD tails), a pool whose page 0 is poisoned with
    NaN (the kernel must never read a page its block tables don't name),
    and per-segment prompt pages written through the duplicate
    last-prompt token — exactly what the engine's prefill leaves behind."""
    rng = np.random.default_rng(seed)
    r, t, plen, npg, m = 2, 64, 16, 9, 3
    seg_rows = [(0, 0, 32), (0, 32, 20), (1, 0, 48), (1, 48, 10)]
    seg_start = np.array([15, 7, 31, 3], np.int32)
    segment_ids = np.full((r, t), PAD, np.int32)
    positions = np.zeros((r, t), np.int32)
    for s, (row, off, slen) in enumerate(seg_rows):
        segment_ids[row, off:off + slen] = s
        positions[row, off:off + slen] = np.arange(
            seg_start[s], seg_start[s] + slen)
    block_tables = np.full((len(seg_rows), m), -1, np.int32)
    pos_pages = np.full((npg, plen), -1, np.int32)
    k_pages = rng.standard_normal((npg, plen, kvh, d)).astype(dtype)
    v_pages = rng.standard_normal((npg, plen, kvh, d)).astype(dtype)
    k_pages[0] = np.nan
    v_pages[0] = np.nan
    nxt = 1
    for s in range(len(seg_rows)):
        ntok = int(seg_start[s]) + 1    # prompt incl. duplicate last token
        for pi in range(-(-ntok // plen)):
            block_tables[s, pi] = nxt
            n = min(plen, ntok - pi * plen)
            pos_pages[nxt, :n] = np.arange(pi * plen, pi * plen + n)
            nxt += 1
    q = rng.standard_normal((r, h, t, d)).astype(np.float32)
    k = rng.standard_normal((r, kvh, t, d)).astype(np.float32)
    v = rng.standard_normal((r, kvh, t, d)).astype(np.float32)
    return (tuple(jnp.asarray(a) for a in
                  (q, k, v, segment_ids, seg_start, block_tables,
                   k_pages, v_pages, pos_pages)),
            segment_ids != PAD)


@pytest.mark.parametrize("h,kvh,d", PREFILL_SWEEP)
def test_prefill_kernel_vs_ref(h, kvh, d):
    args, live = prefill_data(h, kvh, d)
    # the ref's dense gather clamps unallocated entries to page 0 and hits
    # 0 * nan — feed it cleaned pages; the kernel runs on the poison and
    # must stay finite (it must never READ page 0)
    clean = tuple(jnp.nan_to_num(a) for a in args[6:8])
    o_ref, lse_ref = paged_prefill_attention_ref(
        *args[:6], clean[0], clean[1], args[8])
    o_k, lse_k = paged_prefill_fwd_pallas(*args, bq=BQ, bk=BQ)
    lv = live[:, None, :]
    np.testing.assert_allclose(
        np.asarray(jnp.where(lv[..., None], o_k, 0.0)),
        np.asarray(jnp.where(lv[..., None], o_ref, 0.0)),
        rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(jnp.where(lv, lse_k, 0.0)),
        np.asarray(jnp.where(lv, lse_ref, 0.0)),
        rtol=2e-5, atol=2e-5)
    assert np.isfinite(np.asarray(o_k)[
        np.broadcast_to(lv[..., None], o_k.shape)]).all()


def test_prefill_kernel_bf16_pool():
    """Production pool dtype: bf16 pages under f32 activations — the
    kernel and oracle upcast identically, so they still agree tightly."""
    args, live = prefill_data(4, 2, 8, dtype=np.float32)
    args = args[:6] + (args[6].astype(jnp.bfloat16),
                       args[7].astype(jnp.bfloat16), args[8])
    clean = tuple(jnp.nan_to_num(a.astype(jnp.float32)).astype(jnp.bfloat16)
                  for a in args[6:8])
    o_ref, _ = paged_prefill_attention_ref(
        *args[:6], clean[0], clean[1], args[8])
    o_k, _ = paged_prefill_fwd_pallas(*args, bq=BQ, bk=BQ)
    lv = live[:, None, :, None]
    np.testing.assert_allclose(
        np.asarray(jnp.where(lv, o_k, 0.0)),
        np.asarray(jnp.where(lv, o_ref, 0.0)), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("h,kvh,d", PREFILL_SWEEP)
def test_prefill_grad_parity(h, kvh, d):
    """custom_vjp (dq over pages + dkv scattered through the block table)
    vs autodiff through the oracle, for all five operands including the
    pool pages (GRPO siblings sharing a page must SUM their grads)."""
    args, live = prefill_data(h, kvh, d)
    kp_c, vp_c = (jnp.nan_to_num(a) for a in args[6:8])
    rng = np.random.default_rng(1)
    mask = jnp.asarray(live[:, None, :, None], jnp.float32)
    dout = jnp.asarray(rng.standard_normal(
        (2, h, 64, d)).astype(np.float32)) * mask

    def loss_kernel(q_, k_, v_, kp_, vp_):
        o = paged_prefill_attention(q_, k_, v_, args[3], args[4], args[5],
                                    kp_, vp_, args[8], BQ, BQ, True)
        return jnp.sum(o * dout)

    def loss_ref(q_, k_, v_, kp_, vp_):
        o, _ = paged_prefill_attention_ref(q_, k_, v_, args[3], args[4],
                                           args[5], kp_, vp_, args[8])
        return jnp.sum(o * dout)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(
        args[0], args[1], args[2], kp_c, vp_c)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(
        args[0], args[1], args[2], kp_c, vp_c)
    for name, a, b in zip(("dq", "dk", "dv", "dk_pool", "dv_pool"), gk, gr):
        diff = float(jnp.max(jnp.abs(a - b)))
        ref = float(jnp.max(jnp.abs(b)))
        assert diff < 2e-4 * max(ref, 1.0), (name, diff, ref)


def test_prefill_unnamed_pages_do_not_contribute():
    """Scaling every page NOT named by any block table must not change the
    output — visibility flows only through the tables."""
    args, live = prefill_data(4, 2, 8)
    clean = tuple(jnp.nan_to_num(a) for a in args[6:8])
    o1, _ = paged_prefill_fwd_pallas(*args[:6], clean[0], clean[1],
                                     args[8], bq=BQ, bk=BQ)
    named = set(np.asarray(args[5])[np.asarray(args[5]) >= 0].tolist())
    kp2, vp2 = np.array(clean[0]), np.array(clean[1])
    for pg in range(kp2.shape[0]):
        if pg not in named:
            kp2[pg], vp2[pg] = 1e3, -1e3
    o2, _ = paged_prefill_fwd_pallas(*args[:6], jnp.asarray(kp2),
                                     jnp.asarray(vp2), args[8], bq=BQ, bk=BQ)
    lv = np.broadcast_to(live[:, None, :, None], o1.shape)
    np.testing.assert_array_equal(np.asarray(o1)[lv], np.asarray(o2)[lv])
