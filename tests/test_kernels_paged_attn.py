"""Paged decode-attention kernel vs the jnp oracle: GQA/MQA shapes, shared
prompt pages, unallocated-page skips, partial-page gaps, inactive slots
(interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import (
    paged_attention,
    paged_attention_ref,
    paged_decode_pallas,
)

SWEEP = [
    # (S, KV, G, D, P, page_len, M)
    (4, 2, 2, 32, 12, 8, 4),
    (2, 4, 1, 16, 8, 4, 5),     # MHA
    (3, 1, 8, 32, 16, 16, 3),   # MQA
    (5, 2, 3, 64, 20, 8, 6),
]


def data(s, kv, g, d, p, pl, m, seed=0):
    """Random pool + block tables shaped like the engine's: a shared prompt
    page run, slot-private decode pages, a partial-page gap, one inactive
    slot, and unallocated table tails."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (s, kv, g, d), jnp.float32) * 0.3
    kp = jax.random.normal(jax.random.fold_in(key, 1), (p, pl, kv, d)) * 0.3
    vp = jax.random.normal(jax.random.fold_in(key, 2), (p, pl, kv, d)) * 0.3
    rng = np.random.default_rng(seed)
    pos = np.full((p, pl), -1, np.int32)
    bt = np.full((s, m), -1, np.int32)
    # pages 0..1 shared prompt (partial second page: the gap)
    plen = pl + max(1, pl // 2)
    pos[0] = np.arange(pl)
    pos[1, :plen - pl] = np.arange(pl, plen)
    q_pos = np.full((s,), -1, np.int32)
    nxt = 2
    for si in range(s - 1):  # last slot stays inactive
        bt[si, 0], bt[si, 1] = 0, 1
        ndec = int(rng.integers(0, m - 2)) if m > 2 else 0
        tok = 0
        for pi in range(ndec):
            if nxt >= p:
                break
            bt[si, 2 + pi] = nxt
            fill = int(rng.integers(1, pl + 1))
            pos[nxt, :fill] = 2 * pl + tok + np.arange(fill)
            tok += fill
            nxt += 1
        q_pos[si] = 2 * pl + max(tok - 1, 0)
    return q, kp, vp, jnp.asarray(pos), jnp.asarray(bt), jnp.asarray(q_pos)


@pytest.mark.parametrize("s,kv,g,d,p,pl,m", SWEEP)
def test_kernel_vs_ref(s, kv, g, d, p, pl, m):
    q, kp, vp, pos, bt, qp = data(s, kv, g, d, p, pl, m)
    o = paged_decode_pallas(q, kp, vp, pos, bt, qp)
    oref = paged_attention_ref(q, kp, vp, pos, bt, qp)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)
    # the inactive slot (q_pos = -1) outputs exactly zero
    assert np.all(np.asarray(o)[-1] == 0)


def test_flat_head_wrapper_matches_gqa_grouping():
    s, kv, g, d, p, pl, m = SWEEP[0]
    q, kp, vp, pos, bt, qp = data(s, kv, g, d, p, pl, m)
    o4 = paged_attention_ref(q, kp, vp, pos, bt, qp)
    of = paged_attention(q.reshape(s, kv * g, d), kp, vp, pos, bt, qp)
    np.testing.assert_allclose(np.asarray(of),
                               np.asarray(o4).reshape(s, kv * g, d),
                               rtol=2e-5, atol=2e-5)


def test_unallocated_pages_do_not_contribute():
    """Poisoning every page NOT named by a slot's block table must not
    change its output — the gather-isolation invariant at kernel level."""
    s, kv, g, d, p, pl, m = SWEEP[0]
    q, kp, vp, pos, bt, qp = data(s, kv, g, d, p, pl, m)
    o1 = paged_decode_pallas(q, kp, vp, pos, bt, qp)
    owned = set(np.asarray(bt)[0][np.asarray(bt)[0] >= 0].tolist())
    kp2, vp2, pos2 = (np.array(x) for x in (kp, vp, pos))
    for page in range(p):
        if page not in owned:
            kp2[page] = 1e3
            vp2[page] = -1e3
            # stale-but-plausible positions: visibility must still come
            # only through the block table
            pos2[page] = np.arange(pl)
    o2 = paged_decode_pallas(q, jnp.asarray(kp2), jnp.asarray(vp2),
                             jnp.asarray(pos2), bt, qp)
    np.testing.assert_array_equal(np.asarray(o1)[0], np.asarray(o2)[0])


# ------------------------------------------------------------- MLA variant
MLA_SWEEP = [
    # (S, H, R, Dr, P, page_len, M)
    (4, 4, 16, 8, 12, 8, 4),
    (2, 8, 32, 16, 8, 4, 5),
    (3, 1, 8, 4, 16, 16, 3),
]


def mla_data(s, h, r, dr, p, pl, m, seed=0):
    """Latent pool shaped like ``mla_paged_cache_decl``: value operand IS
    the latent page; same shared-prompt/partial-page/inactive-slot
    structure as ``data``."""
    key = jax.random.PRNGKey(seed)
    qa = jax.random.normal(key, (s, h, r), jnp.float32) * 0.3
    qr = jax.random.normal(jax.random.fold_in(key, 1), (s, h, dr)) * 0.3
    cp = jax.random.normal(jax.random.fold_in(key, 2), (p, pl, r)) * 0.3
    krp = jax.random.normal(jax.random.fold_in(key, 3), (p, pl, dr)) * 0.3
    _, _, _, pos, bt, qp = data(s, 1, 1, 8, p, pl, m, seed=seed)
    return qa, qr, cp, krp, pos, bt, qp


@pytest.mark.parametrize("s,h,r,dr,p,pl,m", MLA_SWEEP)
def test_mla_kernel_vs_ref(s, h, r, dr, p, pl, m):
    from repro.kernels.paged_attn import (
        paged_mla_attention_ref, paged_mla_decode_pallas,
    )

    qa, qr, cp, krp, pos, bt, qp = mla_data(s, h, r, dr, p, pl, m)
    scale = 1.0 / np.sqrt(r + dr)
    o = paged_mla_decode_pallas(qa, qr, cp, krp, pos, bt, qp, scale=scale)
    oref = paged_mla_attention_ref(qa, qr, cp, krp, pos, bt, qp, scale=scale)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(o)[-1] == 0)   # inactive slot -> exact zero


def test_mla_unallocated_pages_do_not_contribute():
    s, h, r, dr, p, pl, m = MLA_SWEEP[0]
    from repro.kernels.paged_attn import paged_mla_decode_pallas

    qa, qr, cp, krp, pos, bt, qp = mla_data(s, h, r, dr, p, pl, m)
    scale = 1.0 / np.sqrt(r + dr)
    o1 = paged_mla_decode_pallas(qa, qr, cp, krp, pos, bt, qp, scale=scale)
    owned = set(np.asarray(bt)[0][np.asarray(bt)[0] >= 0].tolist())
    cp2, krp2, pos2 = (np.array(x) for x in (cp, krp, pos))
    for page in range(p):
        if page not in owned:
            cp2[page] = 1e3
            krp2[page] = -1e3
            pos2[page] = np.arange(pl)
    o2 = paged_mla_decode_pallas(qa, qr, jnp.asarray(cp2), jnp.asarray(krp2),
                                 jnp.asarray(pos2), bt, qp, scale=scale)
    np.testing.assert_array_equal(np.asarray(o1)[0], np.asarray(o2)[0])
