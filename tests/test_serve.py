"""Serving stack: engine-level radix prefix-cache parity (cached == uncached
greedy tokens, sublinear prefill, epoch flush on weight swap, capability
gate), streaming contract (deltas precede completion, concatenate to it),
and the async front-end (DRR fairness, graceful shedding, eviction instead
of PagePoolExhausted under a saturating system-prompt mix) — DESIGN.md §10.

Async tests run via ``asyncio.run`` inside plain sync tests: the container
has no pytest-asyncio, and the server's pump is an ordinary task."""
import asyncio
import time
import types

import numpy as np
import jax
import pytest

from repro.models import init_params, model_decl
from repro.models.capabilities import CapabilityError
from repro.models.config import ModelConfig, dense_blocks
from repro.rl import (
    Completion,
    PagePoolExhausted,
    Request,
    RolloutConfig,
    VOCAB_SIZE,
)
from repro.rl.engine import make_paged_engine
from repro.serve import AsyncLMServer, ServeConfig, ServerSaturated

PAGE = 8
SYS = (np.arange(1, 25, dtype=np.int32) % 29 + 3)   # 24-tok shared prefix


def tiny_cfg(**kw):
    return ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                       blocks=dense_blocks(2), seq_parallel=False,
                       remat_policy="none", scan_layers=False, **kw)


def prompt(i):
    """System prompt (3 full pages) + a short per-request user suffix that
    crosses into a partial page."""
    return np.concatenate([SYS, np.int32([30 + i, 31 + i, 6, 7])])


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), model_decl(cfg))
    rcfg = RolloutConfig(max_new_tokens=10, temperature=0.0, group_size=1)
    mk = lambda **kw: make_paged_engine(
        cfg, rcfg, num_slots=4, max_prompt_len=32, page_len=PAGE, **kw)
    groups = [[Request(uid=i, tokens=prompt(i % 3), budget=8)]
              for i in range(6)]
    key = jax.random.PRNGKey(1)
    eng_off, eng_on = mk(), mk(prefix_cache=True)
    base = eng_off.run_groups(params, groups, key)
    cached = eng_on.run_groups(params, groups, key)
    return types.SimpleNamespace(
        cfg=cfg, params=params, rcfg=rcfg, mk=mk, groups=groups, key=key,
        eng_on=eng_on, base=base, cached=cached,
        stats_off=dict(eng_off.stats), stats_on=dict(eng_on.stats))


# ----------------------------------------------- engine-level prefix cache
def test_prefix_cache_greedy_parity(setup):
    """Resuming prefill from cached pages is numerically the same model:
    greedy tokens match the uncached engine exactly, logps to tolerance."""
    assert len(setup.base) == len(setup.cached) == 6
    for a, b in zip(setup.base, setup.cached):
        assert a.uid == b.uid
        assert np.array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logp, b.logp, atol=2e-4,
                                   equal_nan=True)


def test_prefix_cache_prefill_is_counter_sublinear(setup):
    """Six requests over three distinct prompts: the cache prefills each
    shared chunk once, so prefill_tokens collapses well below the uncached
    engine's (which prefills every prompt in full)."""
    off, on = setup.stats_off, setup.stats_on
    assert off["prompt_tokens"] == on["prompt_tokens"]
    assert off["prefill_tokens"] == off["prompt_tokens"]
    assert on["prefix_hit_tokens"] > 0
    assert on["prefill_tokens"] == (
        on["prompt_tokens"] - on["prefix_hit_tokens"])
    # 3 distinct prompts x 28 tokens: a fresh engine prefills >= the three
    # full prompts; every later arrival pays only its non-shared suffix
    assert on["prefill_tokens"] < off["prefill_tokens"] * 0.65
    assert on["prefix_hit_tokens"] / on["prompt_tokens"] >= 0.5


def test_prefix_cache_requires_pure_attention_stack():
    cfg = ModelConfig(name="tiny-local", d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                      blocks=dense_blocks(2, mixer="local"),
                      seq_parallel=False, remat_policy="none",
                      scan_layers=False)
    rcfg = RolloutConfig(max_new_tokens=8, temperature=0.0, group_size=1)
    with pytest.raises(CapabilityError, match="radix prefix cache"):
        make_paged_engine(cfg, rcfg, num_slots=2, max_prompt_len=16,
                          page_len=8, prefix_cache=True)


def test_weight_swap_flushes_cached_prefixes(setup):
    """set_params bumps the trie epoch: KV cached under the old weights
    never matches again, and a rerun under new params equals an uncached
    run under those params."""
    eng = setup.eng_on
    params2 = init_params(jax.random.PRNGKey(9), model_decl(setup.cfg))
    eng.begin(setup.params, setup.key)
    for g in setup.groups[:2]:
        eng.submit_group(g)
    eng.drain()
    hits_before = eng.stats["prefix_hit_tokens"]
    eng.set_params(params2)
    for g in setup.groups[:2]:
        eng.submit_group(g)
    out = {c.uid: c for c in eng.drain()}
    # same prompts again, but the old epoch's pages must NOT have matched;
    # chunks re-prefilled under params2 MAY match between the two groups
    assert eng.stats["prefix_hit_tokens"] <= hits_before + 3 * PAGE
    base2 = setup.mk().run_groups(params2, setup.groups[:2], setup.key)
    for b in base2:
        assert np.array_equal(b.tokens, out[b.uid].tokens)


def test_streaming_deltas_precede_completion(setup):
    """on_token deltas for a uid always arrive before its Completion, and
    their concatenation is exactly the completion's token array."""
    events = []
    eng = setup.eng_on
    eng.begin(setup.params, setup.key,
              on_finish=lambda c: events.append(("fin", c.uid, c)),
              on_token=lambda u, t: events.append(("tok", u, t.copy())))
    for g in setup.groups[:4]:
        eng.submit_group(g)
    while not eng.idle:
        eng.drive()
    fins = {u: c for k, u, c in events if k == "fin"}
    assert len(fins) == 4
    for uid, comp in fins.items():
        fin_at = next(i for i, e in enumerate(events)
                      if e[0] == "fin" and e[1] == uid)
        deltas = [t for i, (k, u, t) in enumerate(events)
                  if k == "tok" and u == uid]
        late = [i for i, (k, u, _t) in enumerate(events)
                if k == "tok" and u == uid and i > fin_at]
        assert not late, f"uid {uid}: delta after completion"
        got = (np.concatenate(deltas) if deltas
               else np.zeros((0,), np.int32))
        assert np.array_equal(got, comp.tokens)


# -------------------------------------------------- DRR fairness (no jax)
class FakeEngine:
    """Just enough engine for the scheduler tests: placement order is
    recorded, drive() hands every live request one token per round and
    retires it at its budget."""

    def __init__(self, max_new=4):
        self.rcfg = types.SimpleNamespace(max_new_tokens=max_new)
        self.order = []
        self._live = []
        self.stats = {}

    def begin(self, params, key, *, on_finish=None, on_token=None):
        self._fin, self._tok = on_finish, on_token

    def submit_group(self, reqs):
        (r,) = reqs
        self.order.append(r.uid)
        self._live.append([r, 0])

    @property
    def backlog(self):
        return 0          # placement is immediate; fairness stays upstream

    @property
    def idle(self):
        return not self._live

    def drive(self):
        done = []
        for ent in self._live:
            r, n = ent
            self._tok(r.uid, np.int32([n]))
            ent[1] = n + 1
            if ent[1] >= (r.budget or self.rcfg.max_new_tokens):
                done.append(ent)
        for ent in done:
            self._live.remove(ent)
            r, n = ent
            self._fin(Completion(uid=r.uid, prompt_len=len(r.tokens),
                                 tokens=np.arange(n, dtype=np.int32),
                                 logp=np.zeros(n), entropy=np.zeros(n),
                                 completed=True))
        return []


def _uid_tenants(server, streams):
    return {s.uid: s.tenant for s in streams}


def test_drr_interleaves_equal_tenants():
    """Two tenants flooding equally: admissions alternate (any prefix of
    the admission order is within one request of balanced), so neither
    tenant's head-of-line latency depends on the other's queue depth."""
    async def main():
        eng = FakeEngine()
        # cost = 8 prompt + 56 budget = 64 = quantum -> one admission per
        # tenant per DRR sweep
        srv = AsyncLMServer(eng, None, None,
                            ServeConfig(max_queue=64, max_backlog=8,
                                        quantum=64, default_budget=56))
        streams = [srv.submit(np.arange(8), tenant=t)
                   for t in ["a"] * 6 for _ in range(1)]
        streams += [srv.submit(np.arange(8), tenant="b") for _ in range(6)]
        await srv.start()
        await srv.drain()
        await srv.stop()
        tenants = _uid_tenants(srv, streams)
        seq = [tenants[u] for u in eng.order]
        assert sorted(seq) == ["a"] * 6 + ["b"] * 6
        for i in range(1, len(seq) + 1):
            na, nb = seq[:i].count("a"), seq[:i].count("b")
            assert abs(na - nb) <= 1, f"unfair prefix {seq[:i]}"
    asyncio.run(main())


def test_drr_weights_bias_admission():
    """weight 2.0 drains a tenant about twice as fast: with equal queues,
    the heavy tenant's last admission lands well before the light one's,
    but the light tenant is never starved out of the early admissions."""
    async def main():
        eng = FakeEngine()
        srv = AsyncLMServer(eng, None, None,
                            ServeConfig(max_queue=64, max_backlog=8,
                                        quantum=32, default_budget=56),
                            tenant_weights={"heavy": 2.0, "light": 1.0})
        streams = [srv.submit(np.arange(8), tenant="heavy")
                   for _ in range(6)]
        streams += [srv.submit(np.arange(8), tenant="light")
                    for _ in range(6)]
        await srv.start()
        await srv.drain()
        await srv.stop()
        tenants = _uid_tenants(srv, streams)
        seq = [tenants[u] for u in eng.order]
        last_heavy = max(i for i, t in enumerate(seq) if t == "heavy")
        last_light = max(i for i, t in enumerate(seq) if t == "light")
        assert last_heavy < last_light
        assert "light" in seq[:4], f"light tenant starved: {seq}"
    asyncio.run(main())


def test_shedding_is_graceful_and_recovers():
    """Past max_queue, submit sheds with ServerSaturated; admitted work
    still completes, and the queue accepts again once it drains."""
    async def main():
        eng = FakeEngine()
        srv = AsyncLMServer(eng, None, None,
                            ServeConfig(max_queue=3, max_backlog=2,
                                        quantum=64, default_budget=4))
        streams = [srv.submit(np.arange(4)) for _ in range(3)]
        with pytest.raises(ServerSaturated):
            srv.submit(np.arange(4))
        assert srv.stats["shed"] == 1
        await srv.start()
        await srv.drain()
        streams.append(srv.submit(np.arange(4)))   # recovered
        await srv.drain()
        await srv.stop()
        for s in streams:
            comp = await s.result()
            assert comp.completed
        assert srv.stats["completed"] == 4
    asyncio.run(main())


def test_saturated_carries_drain_rate_retry_hint():
    """ServerSaturated tells the caller WHEN to retry: retry_after_s is
    the mean gap between recent completions (0.1s fallback before any
    completion data exists)."""
    async def main():
        eng = FakeEngine()
        srv = AsyncLMServer(eng, None, None,
                            ServeConfig(max_queue=2, max_backlog=2,
                                        quantum=64, default_budget=4))
        srv.submit(np.arange(4)), srv.submit(np.arange(4))
        with pytest.raises(ServerSaturated) as ei:
            srv.submit(np.arange(4))
        assert ei.value.retry_after_s == pytest.approx(0.1)  # no drain data
        # seed a measured drain rate: 4 completions 0.25s apart
        now = time.perf_counter()
        srv._finish_times = [now - 0.75, now - 0.5, now - 0.25, now]
        with pytest.raises(ServerSaturated) as ei:
            srv.submit(np.arange(4))
        assert ei.value.retry_after_s == pytest.approx(0.25, rel=0.05)
        assert srv.stats["shed"] == 2
    asyncio.run(main())


def test_submit_with_retry_bounded_then_succeeds():
    """submit_with_retry paces itself by the server's own hint: bounded
    attempts raise the final ServerSaturated when the queue stays full,
    and a draining queue lets a later attempt through."""
    async def main():
        eng = FakeEngine()
        srv = AsyncLMServer(eng, None, None,
                            ServeConfig(max_queue=2, max_backlog=2,
                                        quantum=64, default_budget=4))
        held = [srv.submit(np.arange(4)) for _ in range(2)]
        with pytest.raises(ValueError, match="attempts"):
            await srv.submit_with_retry(np.arange(4), attempts=0)
        # server stopped: every attempt sheds, the last one re-raises
        with pytest.raises(ServerSaturated):
            await srv.submit_with_retry(np.arange(4), attempts=3,
                                        max_sleep_s=0.01)
        assert srv.stats["shed"] == 3
        # pump running: the queue drains underneath the retry loop
        await srv.start()
        stream = await srv.submit_with_retry(np.arange(4), attempts=50,
                                             max_sleep_s=0.05)
        await srv.drain()
        await srv.stop()
        comp = await stream.result()
        assert comp.completed
        for s in held:
            assert (await s.result()).completed
        assert srv.stats["completed"] == 3
    asyncio.run(main())


# ------------------------------------------- full-stack serving (real jax)
@pytest.fixture(scope="module")
def small_pool(setup):
    """2-slot engine over a deliberately tight 12-page pool: placement
    pressure MUST be absorbed by radix eviction (one compile, reused by
    both saturation tests — engines re-``begin`` cleanly)."""
    return make_paged_engine(setup.cfg, setup.rcfg, num_slots=2,
                             max_prompt_len=32, page_len=PAGE, num_pages=12,
                             prefix_cache=True)


def test_server_over_paged_engine_shares_and_evicts(setup, small_pool):
    """System-prompt-heavy mix through the real engine with a pool sized
    to force eviction: every admitted request completes and streams its
    exact completion, the trie serves >= 50% of prompt tokens, and
    PagePoolExhausted never surfaces."""
    eng = small_pool

    async def main():
        srv = AsyncLMServer(
            eng, setup.params, setup.key,
            ServeConfig(max_queue=16, max_backlog=2, quantum=64))
        await srv.start()
        streams = [srv.submit(prompt(i % 3), tenant=f"t{i % 2}", max_new=6)
                   for i in range(8)]

        async def consume(st):
            parts = []
            async for d in st:
                parts.append(d)
            comp = await st.result()
            got = (np.concatenate(parts) if parts
                   else np.zeros((0,), np.int32))
            assert np.array_equal(got, comp.tokens)
            return comp

        comps = await asyncio.gather(*[consume(s) for s in streams])
        await srv.stop()
        return comps, dict(srv.stats)

    comps, stats = asyncio.run(main())
    assert len(comps) == 8 and stats["completed"] == 8
    assert stats["shed"] == 0
    st = eng.stats
    assert st["prefix_hit_tokens"] / st["prompt_tokens"] >= 0.5
    assert st["prefill_tokens"] < st["prompt_tokens"]
    assert srv_ttft_ok(stats)


def srv_ttft_ok(stats):
    # TTFT samples were collected for every completion (monotone sanity —
    # wall-clock bounds belong to the benchmark gates, not unit tests)
    return stats["ttft_sum"] > 0.0 and stats["ttft_max"] > 0.0


def test_small_pool_evicts_instead_of_raising(setup, small_pool):
    """Saturating the pool with distinct prompts evicts cold radix
    branches (stats say so) rather than raising PagePoolExhausted."""
    eng = small_pool
    # 8 DISTINCT 28-token prompts: 3 full pages each + partial + decode
    # pages >> 12-page pool -> the trie must shed cold branches
    groups = [[Request(uid=i, tokens=np.roll(prompt(i), i), budget=4)]
              for i in range(8)]
    try:
        comps = eng.run_groups(setup.params, groups, setup.key)
    except PagePoolExhausted as e:   # pragma: no cover - the bug this pins
        pytest.fail(f"eviction should have absorbed pool pressure: {e}")
    assert len(comps) == 8
    assert eng.stats["evicted_pages"] > 0
