"""Tiny deterministic stand-in for the slice of `hypothesis` this suite
uses, so the property tests still *run* (seeded random examples, no
shrinking) when hypothesis isn't installed.  The real library is declared
in pyproject.toml and is used automatically when present."""
from __future__ import annotations


import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: rng.choice(items))


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def settings(max_examples: int = 100, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        # no functools.wraps: pytest must see a zero-arg signature, not the
        # strategy parameters (which it would treat as fixtures)
        def runner():
            rng = random.Random(0)
            # read from `runner` so `settings` composes in either order
            for _ in range(getattr(runner, "_max_examples", 100)):
                args = [s.example(rng) for s in arg_strategies]
                drawn = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, **drawn)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner._max_examples = getattr(fn, "_max_examples", 100)
        return runner
    return deco


class _St:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)


st = _St()
