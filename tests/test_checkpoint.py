"""Checkpoint manager: roundtrip (incl. bf16 + QTensor), async writes,
keep-last-k GC, atomicity, elastic restore with explicit shardings."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import AdamWConfig, init_opt_state


def tree_eq(a, b):
    ok = True
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        ok &= bool(jnp.all(jnp.asarray(x) == jnp.asarray(y)))
    return ok


@pytest.fixture()
def tree(key):
    params = {"w": jax.random.normal(key, (8, 16), jnp.bfloat16),
              "b": jnp.arange(5, dtype=jnp.float32),
              "nested": {"s": jnp.float32(3.5)}}
    opt = init_opt_state({"w": params["w"]},
                         AdamWConfig(moment_dtype="int8"))
    return {"params": params, "opt": opt}


def test_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, tree, extra={"note": "hi", "pipeline": {"step": 3}})
    assert mgr.latest_step() == 7
    restored, extra = mgr.restore(7, tree)
    assert tree_eq(tree, restored)
    assert extra["note"] == "hi" and extra["pipeline"]["step"] == 3


def test_async_and_keep_last(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=False)
        mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_atomicity_tmp_ignored(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree)
    os.makedirs(tmp_path / ".tmp-9")  # simulated dead partial write
    assert mgr.latest_step() == 5


def test_elastic_restore_with_shardings(tmp_path, tree):
    """Restore placing leaves with explicit (trivial-mesh) NamedShardings —
    the code path a restarted job with a different mesh uses."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree["params"])
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), tree["params"])
    restored, _ = mgr.restore(1, tree["params"], shardings=sh)
    assert tree_eq(tree["params"], restored)
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf.sharding, NamedSharding)


def test_torn_newest_checkpoint_falls_back(tmp_path, tree):
    """Crash-safety regression (DESIGN.md §13): a truncated shard in the
    newest checkpoint makes latest_step() skip it with a loud warning and
    return the previous valid step; restore() of the torn step refuses."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    mgr.save(2, tree)
    # tear the newest: truncate one leaf's payload behind its npy header
    step_dir = tmp_path / "step_000000002"
    leaf = sorted(p for p in os.listdir(step_dir) if p.startswith("leaf_"))[0]
    fp = step_dir / leaf
    with open(fp, "r+b") as f:
        f.truncate(os.path.getsize(fp) - 8)
    assert not mgr.is_valid(2) and mgr.is_valid(1)
    with pytest.warns(RuntimeWarning, match="torn or corrupt"):
        assert mgr.latest_step() == 1
    with pytest.raises(ValueError, match="torn or corrupt"):
        mgr.restore(2, tree)
    restored, _ = mgr.restore(1, tree)
    assert tree_eq(tree, restored)


def test_unparseable_manifest_falls_back(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, tree)
    mgr.save(4, tree)
    with open(tmp_path / "step_000000004" / "manifest.json", "w") as f:
        f.write('{"step": 4, "leaves": {')  # torn mid-write
    with pytest.warns(RuntimeWarning, match="torn or corrupt"):
        assert mgr.latest_step() == 3


def test_restore_latest_after_overwrite(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    t2 = jax.tree.map(lambda x: x if not hasattr(x, "dtype")
                      else jnp.zeros_like(x), tree)
    mgr.save(1, t2)  # same step overwritten atomically
    restored, _ = mgr.restore(1, tree)
    assert tree_eq(t2, restored)
