"""Stream-overlapped trainer (DESIGN.md §6): token-exact serial parity at
max_staleness=0, the sample queue's staleness contract, importance-correction
metrics under forced staleness, and quiesce-checkpoint resume.  Plus the
multi-producer reassembly contract (DESIGN.md §12): N racing producers,
ordered delivery, first-error-wins failure, deadlock-free reservations."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grpo import group_advantages
from repro.core.repack import bucket_ladder, pick_bucket
from repro.core.selectors import make_selector
from repro.data import PromptPipeline
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    AsyncNATGRPOTrainer,
    ContinuousRolloutEngine,
    EngineConfig,
    NATGRPOTrainer,
    NATTrainerConfig,
    RolloutConfig,
    SampleQueue,
    TaggedGroup,
    VOCAB_SIZE,
    make_env,
    make_train_step,
    rollout_group_continuous,
)


def tiny_cfg():
    return ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                       blocks=dense_blocks(2), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


def trainer_cfg(**kw):
    base = dict(
        selector="rpc", selector_kwargs=(("min_cut", 4),),
        prompts_per_step=2, max_prompt_len=16,
        rollout=RolloutConfig(max_new_tokens=8, group_size=4,
                              overprovision=1.5),
        steps_per_sync=2,
        adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        bucket_align=8, seed=0)
    base.update(kw)
    return NATTrainerConfig(**base)


def serial_reference_run(cfg, tc, num_steps):
    """Independent re-implementation of the historical serial train loop
    (pre-async-refactor NATGRPOTrainer.train_step), built from the same
    primitives: the parity oracle for the staleness-0 pipeline."""
    env = make_env(tc.env, **dict(tc.env_kwargs))
    pipeline = PromptPipeline(env, batch_size=tc.prompts_per_step,
                              max_prompt_len=tc.max_prompt_len, seed=tc.seed)
    key = jax.random.PRNGKey(tc.seed)
    key, k = jax.random.split(key)
    params = init_params(k, model_decl(cfg))
    from repro.optim.adamw import init_opt_state

    opt_state = init_opt_state(params, tc.adamw)
    selector = make_selector(tc.selector, **dict(tc.selector_kwargs))
    engine = ContinuousRolloutEngine(
        cfg, tc.rollout, EngineConfig(
            num_slots=tc.num_slots
            or tc.prompts_per_step * tc.rollout.group_size,
            max_prompt_len=tc.max_prompt_len,
            steps_per_sync=tc.steps_per_sync))
    train_step = jax.jit(make_train_step(cfg, tc.grpo, tc.adamw,
                                         vocab_chunks=1))
    t_max = tc.max_prompt_len + tc.rollout.max_new_tokens
    ladder = bucket_ladder(t_max, tc.num_buckets, tc.bucket_align)

    p, g = tc.prompts_per_step, tc.rollout.group_size
    steps = []
    for _ in range(num_steps):
        pb = next(pipeline)
        key, k_roll, k_sel = jax.random.split(key, 3)
        rb = rollout_group_continuous(
            params, cfg, tc.rollout, pb.tokens, pb.prompt_lens, k_roll,
            engine=engine)
        rewards = np.zeros((p, g), np.float32)
        for i in range(p):
            for j in range(g):
                r = i * g + j
                pl, rl = int(rb.prompt_lens[r]), int(rb.response_lens[r])
                rewards[i, j] = env.reward(pb.prompts[i],
                                           rb.tokens[r, pl:pl + rl])
        adv = np.asarray(group_advantages(jnp.asarray(rewards),
                                          tc.grpo.adv_eps)).reshape(-1)
        sel = selector(k_sel, jnp.asarray(rb.response_mask))
        batch = {
            "tokens": rb.tokens,
            "response_mask": rb.response_mask,
            "old_logp": rb.old_logp,
            "advantages": adv.astype(np.float32),
            "ht_weights": np.asarray(sel.ht_weights, np.float32),
            "orig_lengths": rb.response_lens.astype(np.float32),
            "lengths": (rb.prompt_lens + rb.response_lens).astype(np.int32),
            "behavior_logp": rb.old_logp,
            "staleness": np.zeros((rb.tokens.shape[0],), np.float32),
        }
        if tc.repack and sel.prefix_structured:
            keep_total = rb.prompt_lens + np.minimum(
                np.asarray(sel.keep_len), rb.response_lens)
            t_new = min(pick_bucket(int(keep_total.max()), ladder),
                        rb.tokens.shape[1])
            batch = {k: (v[:, :t_new] if getattr(v, "ndim", 0) >= 2 else v)
                     for k, v in batch.items()}
            batch["lengths"] = keep_total.astype(np.int32)
        params, opt_state, metrics = train_step(
            params, opt_state, {k: jnp.asarray(v) for k, v in batch.items()})
        steps.append({
            "tokens": np.asarray(batch["tokens"]).copy(),
            "loss": float(metrics["loss"]),
            "reward_mean": float(rewards.mean()),
        })
    return params, steps


def test_staleness0_token_and_metric_exact():
    """The async pipeline at max_staleness=0 reproduces the serial loop
    token-for-token (learner batches), metric-for-metric (loss, rewards),
    and parameter-for-parameter (bitwise after N updates)."""
    cfg, tc = tiny_cfg(), trainer_cfg()
    n = 3
    ref_params, ref_steps = serial_reference_run(cfg, tc, n)

    tr = NATGRPOTrainer(cfg, tc)
    consumed = []
    orig_pop = tr.queue.pop

    def spy_pop(version, timeout=None):
        g = orig_pop(version, timeout=timeout)
        consumed.append(g)
        return g

    tr.queue.pop = spy_pop
    metrics = [tr.train_step() for _ in range(n)]
    tr.close()

    for i in range(n):
        assert metrics[i]["staleness"] == 0
        # the learner consumed exactly the serial rollout's token grid
        rb = consumed[i].batch
        b = ref_steps[i]["tokens"].shape[0]
        assert rb.tokens.shape[0] == b
        np.testing.assert_array_equal(
            rb.tokens[:, :ref_steps[i]["tokens"].shape[1]],
            ref_steps[i]["tokens"])
        assert metrics[i]["loss"] == ref_steps[i]["loss"]
        assert metrics[i]["reward_mean"] == ref_steps[i]["reward_mean"]
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        tr.params, ref_params)


def _dummy_group(version, index=0):
    return TaggedGroup(index=index, behavior_version=version, batch=None,
                       prompt_batch=None, key_sel=None, t_rollout=0.0)


def test_sample_queue_staleness_contract():
    """pop() never serves a group staler than max_staleness versions: the
    over-stale head is dropped (and counted), fresh groups still flow."""
    q = SampleQueue(capacity=4, max_staleness=1)
    q.put(_dummy_group(version=0, index=0))
    assert q.pop(current_version=1).behavior_version == 0  # staleness 1: ok

    q.put(_dummy_group(version=0, index=1))
    q.put(_dummy_group(version=2, index=2))
    g = q.pop(current_version=3)  # v0 is 3 stale -> dropped, v2 served
    assert g.behavior_version == 2
    assert q.dropped_stale == 1

    with pytest.raises(TimeoutError):
        q.pop(current_version=3, timeout=0.05)


def test_sample_queue_propagates_actor_errors():
    q = SampleQueue(capacity=1, max_staleness=0)
    q.fail(RuntimeError("actor died"))
    with pytest.raises(RuntimeError, match="actor died"):
        q.pop(current_version=0, timeout=1.0)


def test_sample_queue_fail_first_error_wins():
    """A second fail() (e.g. close()'s poison pill racing a real actor
    crash) must not mask the original exception — regression for the
    fail/put race that used to surface the *last* error."""
    q = SampleQueue(capacity=1, max_staleness=0)
    q.put(_dummy_group(version=0, index=0))  # full: next put blocks

    raised = []

    def blocked_put():
        try:
            q.put(_dummy_group(version=0, index=1), timeout=30.0)
        except BaseException as e:  # noqa: BLE001 - recording for assert
            raised.append(e)

    t = threading.Thread(target=blocked_put, daemon=True)
    t.start()
    time.sleep(0.2)  # let the put block on the full queue
    q.fail(RuntimeError("root cause"))
    q.fail(RuntimeError("poison pill"))
    t.join(timeout=10.0)
    assert not t.is_alive()
    assert len(raised) == 1 and str(raised[0]) == "root cause"
    with pytest.raises(RuntimeError, match="root cause"):
        q.pop(current_version=0, timeout=1.0)  # consumer sees it too


def test_sample_queue_reassembles_index_order():
    """Out-of-order deposits from racing producers are served in serial
    index order, and a reserved gap holds younger groups back."""
    q = SampleQueue(capacity=4, max_staleness=3)
    q.reserve(0)
    q.put(_dummy_group(version=0, index=2), producer="f1")
    q.put(_dummy_group(version=1, index=1), producer="f1")
    with pytest.raises(TimeoutError):
        q.pop(current_version=1, timeout=0.05)  # index 0 still in flight
    q.put(_dummy_group(version=1, index=0), producer="f0")
    got = [q.pop(current_version=1).index for _ in range(3)]
    assert got == [0, 1, 2]
    assert q.watermarks == {"f0": 1, "f1": 1}


def test_sample_queue_cancel_unblocks_gap():
    """A producer abandoning its reservation (rollout raised) must not
    wedge the consumer waiting on the gap."""
    q = SampleQueue(capacity=4, max_staleness=0)
    q.reserve(0)
    q.put(_dummy_group(version=0, index=1))
    q.cancel(0)
    assert q.pop(current_version=0, timeout=5.0).index == 1
    assert q.inflight() == 0


# --- multi-producer property (hypothesis when installed; seeded fallback) ---
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st


@settings(max_examples=15, deadline=None)
@given(num_producers=st.integers(1, 4), max_staleness=st.integers(0, 3),
       num_groups=st.integers(4, 14), drop_mod=st.integers(0, 5),
       seed=st.integers(0, 999))
def test_sample_queue_multi_producer_property(num_producers, max_staleness,
                                              num_groups, drop_mod, seed):
    """N producers race the trainer's claim/reserve/roll/put protocol while
    a learner pops and bumps its version; some claims are abandoned
    (cancel).  Invariants: delivery is the serial index order minus the
    abandoned indices, nothing served is staler than ``max_staleness``,
    and the system quiesces — no deadlock, no leaked reservations."""
    import random

    rng = random.Random(seed)
    q = SampleQueue(capacity=max_staleness + 1, max_staleness=max_staleness)
    lock = threading.Lock()
    state = {"next": 0, "version": 0}
    dropped, errors = set(), []

    def producer(name):
        try:
            while True:
                with lock:
                    i = state["next"]
                    if i >= num_groups:
                        return
                    # the trainer's staleness gate: claim only when the
                    # learner is close enough, reserve INSIDE the claim
                    # lock so the queue knows the gap before anyone
                    # younger deposits.  Cancelled indices never reach the
                    # learner, so the gate counts them as consumed —
                    # otherwise a drop wedges it permanently.
                    gated = (i - state["version"] - len(dropped)
                             > max_staleness)
                    if not gated:
                        state["next"] = i + 1
                        version = state["version"]
                        q.reserve(i, timeout=30.0)
                if gated:
                    time.sleep(0.001)
                    continue
                time.sleep(rng.random() * 0.003)  # racy rollout
                if drop_mod and i % drop_mod == drop_mod - 1:
                    with lock:
                        dropped.add(i)
                    q.cancel(i)
                    continue
                q.put(_dummy_group(version=version, index=i),
                      timeout=30.0, producer=name)
        except BaseException as e:  # noqa: BLE001 - surface in main thread
            errors.append(e)
            q.fail(e)

    threads = [threading.Thread(target=producer, args=(f"p{k}",),
                                daemon=True)
               for k in range(num_producers)]
    for t in threads:
        t.start()

    served = []
    while True:
        with lock:
            done = (state["next"] >= num_groups and q.inflight() == 0
                    and q.qsize() == 0)
        if done:
            break
        try:
            g = q.pop(state["version"], timeout=0.2)
        except TimeoutError:
            continue
        served.append(g)
        with lock:
            state["version"] += 1
    for t in threads:
        t.join(timeout=10.0)
        assert not t.is_alive(), "producer deadlocked"
    assert not errors, errors

    expect = [i for i in range(num_groups) if i not in dropped]
    assert [g.index for g in served] == expect, "serial order violated"
    # the gate bounds staleness at claim time and drops known then are a
    # subset of drops below the index, so nothing ever goes over-stale:
    # the queue must have served everything within the bound, dropped none
    for pos, g in enumerate(served):
        assert pos - g.behavior_version <= max_staleness
    assert q.dropped_stale == 0
    assert q.inflight() == 0 and q.qsize() == 0


@pytest.mark.parametrize("overprovision", [1.0, 1.5])
def test_forced_staleness_importance_metrics(overprovision):
    """With max_staleness=1 and a held learner, the second group is
    guaranteed one version stale: its step must report the truncated-IS
    correction metrics and stay finite."""
    cfg = tiny_cfg()
    tc = trainer_cfg(
        max_staleness=1,
        rollout=RolloutConfig(max_new_tokens=8, group_size=4,
                              overprovision=overprovision))
    tr = AsyncNATGRPOTrainer(cfg, tc)
    try:
        tr._ensure_actor()
        # both groups roll under version 0 before the learner moves
        deadline = time.monotonic() + 120
        while tr.queue.qsize() < 2:
            assert time.monotonic() < deadline, "actor stalled"
            time.sleep(0.01)
        m0 = tr.train_step()
        m1 = tr.train_step()
    finally:
        tr.close()

    assert m0["staleness"] == 0 and m0["stale_frac"] == 0.0
    assert m1["staleness"] == 1 and m1["stale_frac"] == 1.0
    assert m1["behavior_version"] == 0 and m1["policy_version"] == 2
    assert np.isfinite(m1["loss"])
    assert m1["is_ratio_mean"] > 0.0
    assert 0.0 <= m1["is_clip_frac"] <= 1.0
    assert m1["dropped_stale"] == 0


def test_streaming_rollout_stats_accounting():
    """Streaming groups surface the rollout token cost: generated tokens
    never exceed the budget, utilization stays in (0, 1]."""
    cfg = tiny_cfg()
    tc = trainer_cfg(max_staleness=2)
    tr = AsyncNATGRPOTrainer(cfg, tc)
    try:
        ms = [tr.train_step() for _ in range(3)]
    finally:
        tr.close()
    for m in ms:
        assert m["tokens_budget"] == 2 * 6 * 8
        assert 0 < m["tokens_generated"] <= m["tokens_budget"]
        assert m["staleness"] <= 2


@pytest.mark.slow
def test_quiesce_checkpoint_resume_exact(tmp_path):
    """save_checkpoint quiesces at a group boundary; a fresh trainer that
    restores it continues the exact parameter stream."""
    from repro.checkpoint import CheckpointManager

    cfg, tc = tiny_cfg(), trainer_cfg()
    mgr = CheckpointManager(str(tmp_path), keep_last=2)

    a = NATGRPOTrainer(cfg, tc)
    a.train_step()
    a.train_step()
    saved = a.save_checkpoint(mgr)
    assert mgr.latest_step() == saved
    while a.step_count < saved + 2:
        a.train_step()
    a.close()

    b = NATGRPOTrainer(cfg, tc)
    extra = b.restore_checkpoint(mgr)
    assert b.step_count == saved == int(extra["learner_version"])
    b.train_step()
    b.train_step()
    b.close()

    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)),
        a.params, b.params)
