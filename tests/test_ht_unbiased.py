"""Proposition 1 and the paper's variance analysis, tested numerically.

* HT-masked loss is an unbiased estimator of the full-token loss (value AND
  gradient) for URS, RPC, and entropy-based designs.
* URS inflates the per-token second moment by exactly 1/p (§3.1).
* RPC covariance Cov(m_s, m_t) = p_t (1 - p_s) for s <= t (§4).
* Deterministic truncation is systematically biased (§4, Table 1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grpo import full_token_loss_reference, nat_grpo_loss
from repro.core.selectors import (
    DetTruncSelector, RPCSelector, URSSelector, rpc_survival,
)

B, T = 6, 40


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(42)
    k1, k2, k3 = jax.random.split(key, 3)
    logp = -jnp.abs(jax.random.normal(k1, (B, T))) * 0.4
    old_logp = logp + 0.15 * jax.random.normal(k2, (B, T))
    adv = jax.random.normal(k3, (B,))
    rm = np.zeros((B, T), np.float32)
    lengths = [40, 32, 24, 16, 40, 8]
    for i, l in enumerate(lengths):
        rm[i, :l] = 1.0
    return logp, old_logp, adv, jnp.asarray(rm)


def mc_loss(selector, batch, n, key, grad=False):
    logp, old_logp, adv, rm = batch
    lengths = rm.sum(-1)

    def loss(lp, w):
        out, _ = nat_grpo_loss(lp, old_logp, adv, w, lengths)
        return out

    @jax.jit
    def one(k):
        w = selector(k, rm).ht_weights
        return jax.grad(loss)(logp, w) if grad else loss(logp, w)

    total = one(jax.random.fold_in(key, 0))
    for i in range(1, n):
        total = jax.tree.map(lambda a, b: a + b, total,
                             one(jax.random.fold_in(key, i)))
    return jax.tree.map(lambda a: a / n, total)


@pytest.mark.parametrize("selector,tol", [
    (URSSelector(p=0.5), 0.02),
    (URSSelector(p=0.25), 0.04),
    (RPCSelector(min_cut=4), 0.03),
    (RPCSelector(min_cut=1), 0.05),
])
def test_prop1_value_unbiased(selector, tol, batch, key):
    logp, old_logp, adv, rm = batch
    full = full_token_loss_reference(logp, old_logp, adv, rm)
    mc = mc_loss(selector, batch, 800, key)
    assert abs(float(mc - full)) < tol, (float(mc), float(full))


def test_prop1_gradient_unbiased(batch, key):
    logp, old_logp, adv, rm = batch
    g_full = jax.grad(
        lambda lp: full_token_loss_reference(lp, old_logp, adv, rm))(logp)
    for sel in (URSSelector(p=0.5), RPCSelector(min_cut=4)):
        g_mc = mc_loss(sel, batch, 1200, key, grad=True)
        rel = float(jnp.linalg.norm(g_mc - g_full) / jnp.linalg.norm(g_full))
        assert rel < 0.12, (type(sel).__name__, rel)


def test_det_trunc_biased(batch, key):
    """The negative control: deterministic truncation must NOT match."""
    logp, old_logp, adv, rm = batch
    g_full = jax.grad(
        lambda lp: full_token_loss_reference(lp, old_logp, adv, rm))(logp)
    g_det = mc_loss(DetTruncSelector(frac=0.5), batch, 4, key, grad=True)
    rel = float(jnp.linalg.norm(g_det - g_full) / jnp.linalg.norm(g_full))
    assert rel > 0.3, "deterministic truncation should be visibly biased"


def test_urs_second_moment_inflation(key):
    """E||w g||^2 = ||g||^2 / p exactly (paper §3.1)."""
    for p in (0.2, 0.5, 0.8):
        g = 1.7  # any fixed per-token score
        n = 20000
        m = jax.random.bernoulli(key, p, (n,)).astype(jnp.float32)
        w = m / p
        emp = float(jnp.mean((w * g) ** 2))
        np.testing.assert_allclose(emp, g * g / p, rtol=0.05)


def test_rpc_mask_covariance(key):
    """Cov(m_s, m_t) = p_t (1 - p_s), s <= t (§4)."""
    t_len, c = 24, 3
    rm = jnp.ones((1, t_len), jnp.float32)
    sel = RPCSelector(min_cut=c)
    draw = jax.jit(lambda k: sel(k, rm).mask[0])
    m = np.asarray(jax.vmap(draw)(jax.random.split(key, 6000)))
    pos = jnp.arange(t_len)[None, :]
    p = np.asarray(rpc_survival(pos, jnp.array([t_len]), c))[0]
    for s, t in [(4, 10), (5, 20), (10, 23), (3, 4)]:
        emp = np.cov(m[:, s], m[:, t])[0, 1]
        expect = p[t] * (1 - p[s])
        np.testing.assert_allclose(emp, expect, atol=0.02)


def test_rpc_variance_exceeds_independent(key):
    """App. B.4: positively-correlated RPC masks give variance >= the
    matched independent design (same marginal p_t) for positive losses."""
    t_len, c = 16, 2
    rm = jnp.ones((1, t_len), jnp.float32)
    pos = jnp.arange(t_len)[None, :]
    p = rpc_survival(pos, jnp.array([t_len]), c)
    losses = jnp.abs(jax.random.normal(key, (t_len,))) + 0.5

    def ht_est(w):
        return jnp.sum(w * losses) / t_len

    sel = RPCSelector(min_cut=c)

    @jax.jit
    def both(k):
        s = sel(k, rm)
        m = jax.random.uniform(k, (t_len,)) < p[0]
        return ht_est(s.ht_weights[0]), ht_est(m / p[0])

    rpc_vals, ind_vals = jax.vmap(both)(jax.random.split(key, 4000))
    assert np.var(np.asarray(rpc_vals)) > np.var(np.asarray(ind_vals)) * 0.9


def test_grpo_special_case_full_tokens(batch):
    """w == response_mask reproduces vanilla GRPO exactly."""
    logp, old_logp, adv, rm = batch
    loss, metrics = nat_grpo_loss(logp, old_logp, adv, rm, rm.sum(-1))
    ref = full_token_loss_reference(logp, old_logp, adv, rm)
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-6)
    np.testing.assert_allclose(float(metrics["selected_ratio"]), 1.0)


# ---------------------------------------- arbitrary-design property test
# (hypothesis when installed; deterministic seeded fallback otherwise)
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st


@jax.jit
def _mc_value_and_grad(logp, old_logp, adv, rm, p, keys):
    """MC mean of (loss, grad) for independent Bernoulli(p_t) masks with
    HT weights w_t = m_t / p_t (Eq. 6), vmapped over draw keys."""
    lengths = rm.sum(-1)

    def loss(lp, w):
        out, _ = nat_grpo_loss(lp, old_logp, adv, w, lengths)
        return out

    def one(k):
        m = (jax.random.uniform(k, rm.shape) < p).astype(jnp.float32) * rm
        w = m / p
        return loss(logp, w), jax.grad(loss)(logp, w)

    vals, grads = jax.vmap(one)(keys)
    return vals, grads.mean(0)


@settings(max_examples=15)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.floats(min_value=0.15, max_value=0.9))
def test_ht_unbiased_for_random_inclusion_probabilities(seed, p_min):
    """Eq. 6 pins w_t = m_t / p_t as unbiased for ANY inclusion-probability
    field p_t in (0, 1] — not just the shipped URS/RPC designs.  Draw a
    random per-token field, estimate by MC, and check the mean matches the
    full-token loss AND gradient within standard-error tolerance."""
    key = jax.random.PRNGKey(seed)
    kp, kb, k1, k2, k3 = jax.random.split(key, 5)
    logp = -jnp.abs(jax.random.normal(k1, (B, T))) * 0.4
    old_logp = logp + 0.15 * jax.random.normal(k2, (B, T))
    adv = jax.random.normal(k3, (B,))
    rm = np.zeros((B, T), np.float32)
    for i, l in enumerate([40, 32, 24, 16, 40, 8]):
        rm[i, :l] = 1.0
    rm = jnp.asarray(rm)
    # arbitrary inclusion probabilities in [p_min, 1]; 1 off-response so
    # the reweighting never divides by a vanishing p outside the support
    u = jax.random.uniform(kp, (B, T))
    p = jnp.where(rm > 0, p_min + (1.0 - p_min) * u, 1.0)

    full = full_token_loss_reference(logp, old_logp, adv, rm)
    g_full = jax.grad(
        lambda lp: full_token_loss_reference(lp, old_logp, adv, rm))(logp)

    n = 512
    vals, g_mc = _mc_value_and_grad(logp, old_logp, adv, rm, p,
                                    jax.random.split(kb, n))
    se = float(jnp.std(vals)) / np.sqrt(n)
    assert abs(float(jnp.mean(vals)) - float(full)) < 6 * se + 2e-3, \
        (float(jnp.mean(vals)), float(full), se)
    rel = float(jnp.linalg.norm(g_mc - g_full) / jnp.linalg.norm(g_full))
    assert rel < 0.25, (rel, p_min)
