"""Disaggregated actor/learner (DESIGN.md §12): slice carving, d2d weight
publication, fleet parity, and prefill/decode disaggregation.

The load-bearing gates:

* fleet-of-1 at staleness 0 is **bit-exact** against the serial
  ``NATGRPOTrainer`` — same tokens, same metrics, same params — for both
  the continuous and the disaggregated paged engine;
* a fleet of N produces per-group **token-exact** rollouts against a
  single-engine oracle walking the same indices (the shared KeyChain);
* publication moves **zero bytes through the host** — asserted on the
  publisher's counter (``jax.transfer_guard`` is belt-and-braces on real
  backends but inert on the CPU backend, so the counter is the gate).

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
dist lane) the same suite exercises real cross-device placement; on a
1-device host the carving degenerates and only the placement collapses.
"""
import jax
import numpy as np
import pytest

from repro.dist import WeightPublisher, carve, tree_bytes
from repro.launch.mesh import slice_mesh
from repro.launch.step_specs import publication_shardings
from repro.models.capabilities import CapabilityError, check_slice_handoff
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    AsyncNATGRPOTrainer,
    DisaggPagedRolloutEngine,
    DistNATGRPOTrainer,
    KeyChain,
    NATGRPOTrainer,
    NATTrainerConfig,
    RolloutConfig,
    VOCAB_SIZE,
    make_dist_trainer,
)


def tiny_cfg(**kw):
    base = dict(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                blocks=dense_blocks(2), seq_parallel=False,
                remat_policy="none", scan_layers=False)
    base.update(kw)
    return ModelConfig(**base)


def trainer_cfg(**kw):
    base = dict(
        selector="rpc", selector_kwargs=(("min_cut", 4),),
        prompts_per_step=2, max_prompt_len=16,
        rollout=RolloutConfig(max_new_tokens=8, group_size=4,
                              overprovision=1.5, temperature=1.0),
        steps_per_sync=2,
        adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        bucket_align=8, num_buckets=1, seed=0)
    base.update(kw)
    return NATTrainerConfig(**base)


# ------------------------------------------------------------- placement
def test_carve_topology_math():
    """Pure placement arithmetic (device identity is irrelevant): learner
    keeps the head, fleet roles round-robin the tail."""
    devs = list(range(8))
    topo = carve(devs, fleet=2, disagg=True)
    assert topo.learner == (0, 1, 2, 3)
    assert [fs.decode for fs in topo.fleets] == [(4,), (6,)]
    assert [fs.prefill for fs in topo.fleets] == [(5,), (7,)]
    assert [fs.name for fs in topo.fleets] == ["fleet0", "fleet1"]
    assert topo.num_fleets == 2 and topo.disagg

    topo = carve(devs, fleet=3, disagg=False)
    assert topo.learner == tuple(devs[:5])
    assert [fs.decode for fs in topo.fleets] == [(5,), (6,), (7,)]
    assert all(fs.prefill == () for fs in topo.fleets)


def test_carve_degenerate_single_device():
    """On a 1-device host every role lands on that device — the
    orchestration still runs, only the placement collapses."""
    topo = carve([0], fleet=2, disagg=True)
    assert topo.learner == (0,)
    for fs in topo.fleets:
        assert fs.decode == (0,) and fs.prefill == (0,)
        assert fs.devices == (0,)


def test_carve_errors():
    with pytest.raises(ValueError, match="fleet"):
        carve([0, 1], fleet=0)
    with pytest.raises(ValueError, match="learner_devices"):
        carve([0, 1], fleet=1, learner_devices=3)


def test_carve_real_devices_distinct():
    """With >= 4 real devices (the CI dist lane forces 8 virtual ones)
    the learner slice and fleet slices are disjoint."""
    devs = jax.devices()
    if len(devs) < 4:
        pytest.skip("needs >= 4 devices (CI dist lane)")
    topo = carve(devs, fleet=2)
    roles = [d for fs in topo.fleets for d in fs.devices]
    assert len(set(roles)) == len(roles)
    assert not (set(topo.learner) & set(roles))


# ------------------------------------------------------------ publication
def test_weight_publisher_counters_and_epochs():
    params = {"w": np.ones((4, 4), np.float32),
              "b": np.zeros((4,), np.float32)}
    dev = jax.devices()[0]
    pub = WeightPublisher({"fleet0": dev, "fleet1": dev})
    out = pub.publish(params, epoch=0)
    assert set(out) == {"fleet0", "fleet1"}
    per_copy = tree_bytes(params)
    assert per_copy == 4 * 4 * 4 + 4 * 4
    assert pub.stats == {"publishes": 1, "bytes_published": 2 * per_copy,
                         "host_bytes": 0, "publish_retries": 0, "epoch": 0}
    out = pub.publish(params)  # epoch auto-increments
    assert pub.stats["epoch"] == 1 and pub.stats["publishes"] == 2
    tree, epoch = pub.latest("fleet1")
    assert epoch == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), params["w"])
    with pytest.raises(KeyError):
        pub.latest("fleet9")


def test_publication_shardings_replicated():
    """The dry-run-facing helper: every param leaf replicates over the
    fleet slice mesh (a replica runs the whole model)."""
    mesh = slice_mesh(jax.devices())
    abs_p, sh = publication_shardings(tiny_cfg(), mesh)
    leaves = jax.tree_util.tree_leaves(sh)
    assert leaves and all(
        s.spec == jax.sharding.PartitionSpec() for s in leaves)
    assert (jax.tree_util.tree_structure(sh)
            == jax.tree_util.tree_structure(abs_p))


# --------------------------------------------------------------- keychain
def test_keychain_matches_serial_walk():
    """keys_for(i) reproduces the serial split walk even when indices are
    claimed out of order (the fleet race)."""
    key0 = jax.random.PRNGKey(7)
    serial, state = [], key0
    for _ in range(5):
        state, k_roll, k_sel = jax.random.split(state, 3)
        serial.append((k_roll, k_sel))
    chain = KeyChain(key0)
    for i in (3, 0, 4, 2, 1):
        base, k_roll, k_sel = chain.keys_for(i)
        np.testing.assert_array_equal(np.asarray(k_roll),
                                      np.asarray(serial[i][0]))
        np.testing.assert_array_equal(np.asarray(k_sel),
                                      np.asarray(serial[i][1]))
    np.testing.assert_array_equal(np.asarray(chain.state_before(0)),
                                  np.asarray(key0))


# ------------------------------------------------------- capability gates
def test_disagg_capability_gate_config_time():
    """Configs whose prompt state can't hand off across slices fail at
    construction (models/capabilities.py), never mid-run."""
    local = tiny_cfg(name="loc", blocks=((("attn", "local"), 2),), window=8)
    with pytest.raises(CapabilityError, match="pool-resident"):
        check_slice_handoff(local)
    audio = tiny_cfg(name="audio", num_codebooks=2)
    with pytest.raises(CapabilityError, match="num_codebooks"):
        check_slice_handoff(audio)
    # the trainer surfaces the same gate from its constructor
    with pytest.raises(CapabilityError, match="pool-resident"):
        DistNATGRPOTrainer(local, trainer_cfg(
            fleet=1, disagg="prefill,decode", rollout_engine="paged"))
    with pytest.raises(ValueError, match="rollout_engine"):
        DistNATGRPOTrainer(tiny_cfg(), trainer_cfg(
            fleet=1, disagg="prefill,decode"))  # continuous can't disagg
    with pytest.raises(ValueError, match="disagg"):
        DistNATGRPOTrainer(tiny_cfg(), trainer_cfg(
            fleet=1, disagg="prefill", rollout_engine="paged"))


def test_make_dist_trainer_dispatch():
    tr = make_dist_trainer(tiny_cfg(), trainer_cfg())
    try:
        assert type(tr) is AsyncNATGRPOTrainer
    finally:
        tr.close()
    tr = make_dist_trainer(tiny_cfg(), trainer_cfg(fleet=1))
    try:
        assert isinstance(tr, DistNATGRPOTrainer)
    finally:
        tr.close()


# ---------------------------------------------------------------- parity
@pytest.mark.slow
def test_fleet1_staleness0_bitexact_continuous():
    """THE parity gate: a fleet of 1 at staleness 0 reproduces the serial
    trainer bit-for-bit — metrics each step, params after N steps — and
    publication moved zero bytes through the host."""
    cfg, n = tiny_cfg(), 3
    serial = NATGRPOTrainer(cfg, trainer_cfg())
    ref = [serial.train_step() for _ in range(n)]
    serial.close()

    dist = DistNATGRPOTrainer(cfg, trainer_cfg(fleet=1))
    got = [dist.train_step() for _ in range(n)]
    for a, b in zip(ref, got):
        assert a["loss"] == b["loss"]
        assert a["reward_mean"] == b["reward_mean"]
        assert a["resp_len_mean"] == b["resp_len_mean"]
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)),
        serial.params, dist.params)

    stats = dist.publication_stats()
    assert stats["host_bytes"] == 0          # the zero-host-bytes gate
    assert stats["publishes"] == n + 1       # init + one per train step
    assert stats["epoch"] == n
    assert stats["bytes_published"] > 0
    dist.close()


@pytest.mark.slow
def test_fleet1_disagg_bitexact_paged():
    """Prefill/decode disaggregation is a pure placement change: the
    disaggregated paged trainer is bit-exact against the fused serial
    paged trainer, and the handoff counters show cross-slice traffic."""
    cfg, n = tiny_cfg(), 3
    serial = NATGRPOTrainer(cfg, trainer_cfg(rollout_engine="paged"))
    ref = [serial.train_step() for _ in range(n)]
    serial.close()

    dist = DistNATGRPOTrainer(cfg, trainer_cfg(
        rollout_engine="paged", fleet=1, disagg="prefill,decode"))
    assert isinstance(dist.engine, DisaggPagedRolloutEngine)
    got = [dist.train_step() for _ in range(n)]
    for a, b in zip(ref, got):
        assert a["loss"] == b["loss"]
        assert a["reward_mean"] == b["reward_mean"]
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)),
        serial.params, dist.params)

    stats = dist.publication_stats()
    assert stats["host_bytes"] == 0
    assert stats["handoffs"] >= n            # one prefill handoff per group
    assert stats["handoff_bytes"] > 0
    dist.close()


@pytest.mark.slow
def test_fleet2_groups_token_exact_vs_oracle():
    """Under a frozen learner, a racing fleet of 2 produces the exact
    rollouts a single-engine oracle produces for the same indices — the
    shared KeyChain pins group i's keys regardless of which replica
    claims it."""
    cfg, k = tiny_cfg(), 4

    def collect(tc):
        tr = DistNATGRPOTrainer(cfg, tc)
        groups = {}
        try:
            tr._ensure_actor()
            while len(groups) < k:
                g = tr.queue.pop(0, timeout=120.0)
                groups[g.index] = g
        finally:
            tr.close()
        return groups

    oracle = collect(trainer_cfg(fleet=1, max_staleness=k))
    fleet = collect(trainer_cfg(fleet=2, max_staleness=k))
    assert set(oracle) == set(fleet) == set(range(k))
    for i in range(k):
        np.testing.assert_array_equal(fleet[i].batch.tokens,
                                      oracle[i].batch.tokens)
        np.testing.assert_array_equal(fleet[i].batch.response_lens,
                                      oracle[i].batch.response_lens)
        np.testing.assert_array_equal(np.asarray(fleet[i].key_sel),
                                      np.asarray(oracle[i].key_sel))
        assert fleet[i].behavior_version == 0


@pytest.mark.slow
def test_fleet2_staleness_pipeline_runs():
    """The full overlapped fleet pipeline: threads race, the queue
    reassembles, the learner steps, watermarks advance, no host bytes."""
    dist = DistNATGRPOTrainer(
        tiny_cfg(), trainer_cfg(fleet=2, max_staleness=2))
    try:
        ms = [dist.train_step() for _ in range(4)]
    finally:
        dist.close()
    for m in ms:
        assert m["staleness"] <= 2
        assert np.isfinite(m["loss"])
    stats = dist.publication_stats()
    assert stats["host_bytes"] == 0
    assert set(stats["watermarks"]) <= {"fleet0", "fleet1"}
    assert stats["watermarks"], "no fleet ever deposited"


@pytest.mark.slow
def test_dist_checkpoint_resume_exact(tmp_path):
    """quiesce-checkpoint + restore continues the exact parameter stream
    (the restored trainer re-publishes onto its fleet slices)."""
    from repro.checkpoint import CheckpointManager

    cfg, tc = tiny_cfg(), trainer_cfg(fleet=1)
    mgr = CheckpointManager(str(tmp_path), keep_last=2)

    a = DistNATGRPOTrainer(cfg, tc)
    a.train_step()
    a.train_step()
    saved = a.save_checkpoint(mgr)
    while a.step_count < saved + 2:
        a.train_step()
    a.close()

    b = DistNATGRPOTrainer(cfg, tc)
    b.restore_checkpoint(mgr)
    assert b.step_count == saved
    b.train_step()
    b.train_step()
    b.close()

    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)),
        a.params, b.params)


@pytest.mark.slow
def test_eight_device_fleet_placement():
    """The CI dist lane's 8-virtual-device run: replicas actually land on
    distinct devices, rollouts execute there, and parity still holds."""
    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip("needs 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    cfg = tiny_cfg()
    dist = DistNATGRPOTrainer(
        cfg, trainer_cfg(rollout_engine="paged", fleet=2,
                         disagg="prefill,decode"), devices=devs)
    placed = {d for fs in dist.topology.fleets for d in fs.devices}
    assert len(placed) == 4 and not (set(dist.topology.learner) & placed)
    try:
        m = dist.train_step()
    finally:
        dist.close()
    assert np.isfinite(m["loss"])
    assert dist.publication_stats()["host_bytes"] == 0
