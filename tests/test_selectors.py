"""Selector invariants (unit + hypothesis property tests).

Invariants from the paper (§3.1, §4):
  * masks live only on response tokens,
  * inclusion probabilities are in (0, 1] wherever the mask can be 1,
  * E[m] = p (checked by Monte Carlo for URS and analytically for RPC),
  * RPC masks are contiguous prefixes with the minimum-cutoff survival
    function p_t = 1 (t<=C), (T-t+1)/(T-C+1) (t>C),
  * Det-Trunc keeps exactly floor(frac*T) tokens with p == 1 (the biased
    baseline).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st

from repro.core.selectors import (
    DetTruncSelector, EntropySelector, FullSelector, RPCSelector,
    URSSelector, make_selector, rpc_survival,
)


def make_mask(lengths, prompt_lens, t):
    b = len(lengths)
    rm = np.zeros((b, t), np.float32)
    for i, (p, l) in enumerate(zip(prompt_lens, lengths)):
        rm[i, p:p + l] = 1.0
    return jnp.asarray(rm)


@pytest.mark.parametrize("name,kwargs", [
    ("full", {}), ("urs", {"p": 0.5}), ("rpc", {"min_cut": 4}),
    ("det_trunc", {}),
])
def test_mask_only_on_response(name, kwargs, key):
    rm = make_mask([10, 20, 1], [3, 0, 5], 32)
    sel = make_selector(name, **kwargs)(key, rm)
    assert np.all(np.asarray(sel.mask) <= np.asarray(rm))
    assert np.all(np.asarray(sel.inclusion) > 0)
    assert np.all(np.asarray(sel.inclusion) <= 1)
    w = np.asarray(sel.ht_weights)
    assert np.all(w[np.asarray(rm) == 0] == 0)


def test_full_selector_identity(key):
    rm = make_mask([10, 5], [2, 4], 24)
    sel = FullSelector()(key, rm)
    np.testing.assert_array_equal(np.asarray(sel.mask), np.asarray(rm))
    np.testing.assert_array_equal(np.asarray(sel.ht_weights), np.asarray(rm))


def test_urs_expectation(key):
    rm = make_mask([40], [4], 64)
    sel = URSSelector(p=0.3)
    draw = jax.jit(lambda k: sel(k, rm).mask)
    total = np.zeros((1, 64))
    n = 400
    for i in range(n):
        total += np.asarray(draw(jax.random.fold_in(key, i)))
    emp = total / n
    resp = np.asarray(rm) > 0
    assert abs(emp[resp].mean() - 0.3) < 0.03


def test_rpc_survival_formula():
    pos = jnp.arange(20)[None, :]
    length = jnp.array([20])
    p = np.asarray(rpc_survival(pos, length, min_cut=5))[0]
    np.testing.assert_allclose(p[:5], 1.0)
    for t in range(6, 21):  # 1-based t
        expect = (20 - t + 1) / (20 - 5 + 1)
        np.testing.assert_allclose(p[t - 1], expect, rtol=1e-6)


def test_rpc_prefix_structure_and_expectation(key):
    rm = make_mask([30, 12], [2, 6], 48)
    sel = RPCSelector(min_cut=4)
    draw = jax.jit(lambda k: sel(k, rm))
    kept = []
    for i in range(500):
        s = draw(jax.random.fold_in(key, i))
        m = np.asarray(s.mask)
        # contiguity: within response, mask is a prefix
        for b in range(2):
            resp = np.where(np.asarray(rm)[b] > 0)[0]
            vals = m[b, resp]
            assert np.all(np.diff(vals) <= 0), "mask must be a prefix"
        kept.append(np.asarray(s.keep_len))
    kept = np.stack(kept)  # (500, 2)
    # E[L] = (C + T)/2
    np.testing.assert_allclose(kept[:, 0].mean(), (4 + 30) / 2, atol=1.0)
    np.testing.assert_allclose(kept[:, 1].mean(), (4 + 12) / 2, atol=0.6)


def test_rpc_ht_mean_one(key):
    """E[m/p] = 1 per position — the HT identity that drives Prop. 1."""
    rm = make_mask([24], [0], 24)
    sel = RPCSelector(min_cut=2)
    draw = jax.jit(lambda k: sel(k, rm).ht_weights)
    n = 3000
    ws = jax.vmap(draw)(jax.random.split(key, n))
    np.testing.assert_allclose(np.asarray(ws).mean(0)[0], 1.0, atol=0.15)


def test_det_trunc_is_deterministic_biased(key):
    rm = make_mask([20], [3], 32)
    sel = DetTruncSelector(frac=0.5)
    s1 = sel(key, rm)
    s2 = sel(jax.random.fold_in(key, 1), rm)
    np.testing.assert_array_equal(np.asarray(s1.mask), np.asarray(s2.mask))
    assert np.asarray(s1.mask).sum() == 10
    # p == 1 on kept prefix -> weights don't compensate: that's the bias
    np.testing.assert_array_equal(np.asarray(s1.ht_weights), np.asarray(s1.mask))


def test_entropy_selector_respects_floor(key):
    rm = make_mask([30], [2], 40)
    ent = jnp.abs(jax.random.normal(key, (1, 40)))
    sel = EntropySelector(p_floor=0.25, budget=0.5)
    s = sel(key, rm, ent)
    p = np.asarray(s.inclusion)
    resp = np.asarray(rm) > 0
    assert np.all(p[resp] >= 0.25 - 1e-6)
    assert np.all(p[resp] <= 1.0 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(8, 64),
    prompt=st.integers(0, 8),
    min_cut=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_rpc_properties_hypothesis(t, prompt, min_cut, seed):
    length = t - prompt
    rm = make_mask([length], [prompt], t)
    sel = RPCSelector(min_cut=min_cut)
    s = sel(jax.random.PRNGKey(seed), rm)
    m = np.asarray(s.mask)[0]
    p = np.asarray(s.inclusion)[0]
    keep = int(np.asarray(s.keep_len)[0])
    # keep length within [min(C, T), T]
    assert min(min_cut, length) <= keep <= length
    # mask matches keep_len
    assert int(m.sum()) == keep
    # survival monotone non-increasing on the response
    resp = slice(prompt, prompt + length)
    assert np.all(np.diff(p[resp]) <= 1e-7)
    # HT weights bounded by the minimum-cutoff guarantee
    c = min(min_cut, length)
    w = np.asarray(s.ht_weights)[0][resp]
    bound = (length - c + 1) / 1.0
    assert np.all(w <= bound + 1e-4)


@settings(max_examples=15, deadline=None)
@given(
    p=st.floats(0.05, 1.0),
    t=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_urs_properties_hypothesis(p, t, seed):
    rm = make_mask([t], [0], t)
    s = URSSelector(p=p)(jax.random.PRNGKey(seed), rm)
    incl = np.asarray(s.inclusion)[0]
    np.testing.assert_allclose(incl, p, rtol=1e-6)
    w = np.asarray(s.ht_weights)[0]
    # every weight is 0 or 1/p (float32 tolerance)
    assert np.all((np.abs(w) < 1e-6) | (np.abs(w - 1.0 / p) < 1e-4))
