"""Radix prefix cache: trie semantics (ready-next-round, longest match,
page-aligned insert, epoch flush) and the ownership protocol under random
insert/match/evict/flush interleavings — never double-free, never leak:
the allocator free list, live handles, and trie residents partition the
pool, and eviction never reclaims a page a live reader still names
(DESIGN.md §10)."""
import numpy as np
import pytest

from repro.rl import PageAllocator, RadixPrefixCache

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

PL = 4  # page_len for every trie in this file


def make(num_pages=32):
    a = PageAllocator(num_pages)
    return a, RadixPrefixCache(a, PL)


def toks(*chunks):
    """Concatenate per-page chunks given as ints: toks(1, 2) -> the 8-token
    prompt [1]*4 + [2]*4 (distinct chunk per int keeps keys readable)."""
    return np.concatenate([np.full((PL,), c, np.int32) for c in chunks])


def prefill_insert(a, cache, tokens, parent=None, start=0):
    """Engine-side insert: alloc fresh pages for the uncached full chunks
    (caller = the group holds ref 1), chain them into the trie (trie takes
    its own ref).  Returns (pages, nodes)."""
    n = (len(tokens) - start) // PL
    pages = a.alloc(n)
    nodes = cache.insert(parent, tokens, start, pages)
    return pages, nodes


# ------------------------------------------------------------- trie basics
def test_lookup_empty_and_partial_pages():
    _, cache = make()
    assert cache.lookup(toks(1, 2)) == []
    assert cache.lookup(np.int32([1, 2])) == []  # shorter than one page


def test_nodes_ready_only_after_step():
    """Pages inserted this round are still being written by this round's
    prefill dispatch — same-round lookups must not match them."""
    a, cache = make()
    prefill_insert(a, cache, toks(1, 2))
    assert cache.lookup(toks(1, 2)) == []          # same round: not ready
    cache.step()
    assert [n.page for n in cache.lookup(toks(1, 2))] == [0, 1]


def test_longest_match_is_chunkwise_and_prefix_only():
    a, cache = make()
    prefill_insert(a, cache, toks(1, 2, 3))
    cache.step()
    assert len(cache.lookup(toks(1, 2, 3))) == 3
    assert len(cache.lookup(toks(1, 2, 9))) == 2   # diverges at chunk 3
    assert len(cache.lookup(toks(9, 2, 3))) == 0   # diverges at chunk 1
    # a trailing partial page never extends the match
    assert len(cache.lookup(np.concatenate([toks(1, 2), [3, 3]]))) == 2


def test_insert_keeps_incumbent_and_branches():
    """Re-inserting a cached chunk keeps the incumbent node (the duplicate
    page stays caller-owned); new suffixes branch below the shared chain."""
    a, cache = make()
    p1, _ = prefill_insert(a, cache, toks(1, 2))
    cache.step()
    # second group with the same first chunk, diverging second chunk
    dup = a.alloc(2)
    nodes = cache.insert(None, toks(1, 9), 0, dup)
    assert len(nodes) == 1 and nodes[0].page == dup[1]
    # incumbent kept: dup[0] was NOT adopted, trie still points at p1[0]
    cache.step()
    assert [n.page for n in cache.lookup(toks(1, 2))] == p1
    assert [n.page for n in cache.lookup(toks(1, 9))] == [p1[0], dup[1]]
    # the un-adopted duplicate page carries only its caller reference
    assert int(a.refcount[dup[0]]) == 1


def test_insert_start_must_be_page_aligned():
    a, cache = make()
    with pytest.raises(AssertionError):
        cache.insert(None, toks(1, 2), 2, a.alloc(1))


# --------------------------------------------------------------- eviction
def test_evict_lru_leaves_first_and_cascades():
    a, cache = make()
    pA, _ = prefill_insert(a, cache, toks(1, 2))
    pB, _ = prefill_insert(a, cache, toks(5))
    cache.step()
    a.release(pA), a.release(pB)        # groups retire; trie refs remain
    cache.touch(cache.lookup(toks(1, 2)))   # A is now hotter than B
    freed = cache.evict(1)
    assert freed == [pB[0]]             # coldest leaf goes first
    # cascading: evicting 2 more frees A's leaf then its parent
    assert sorted(cache.evict(2)) == sorted(pA)
    assert cache.num_resident == 0
    assert a.in_use == 0


def test_evict_never_touches_pages_with_live_readers():
    a, cache = make()
    pages, _ = prefill_insert(a, cache, toks(1, 2))
    cache.step()
    # a second group matches the chain and retains it (engine commit path)
    a.retain(pages)
    a.release(pages)                    # first group retires
    assert cache.evict(8) == []         # reader still holds both pages
    a.release(pages)                    # reader retires
    assert sorted(cache.evict(8)) == sorted(pages)


def test_flush_starts_epoch_and_reaps_stragglers():
    a, cache = make()
    pA, _ = prefill_insert(a, cache, toks(1, 2))
    cache.step()
    a.release([pA[1]])                  # leaf is trie-only; root still read
    freed = cache.flush()
    assert freed == [pA[1]]             # evictable stale branch freed now
    assert cache.lookup(toks(1, 2)) == []   # stale epoch never matches
    # a fresh insert of the same tokens shadows the stale incumbent
    pB, _ = prefill_insert(a, cache, toks(1))
    cache.step()
    assert [n.page for n in cache.lookup(toks(1))] == pB
    a.release([pA[0]])                  # the straggler's reader drains
    assert cache.reap() == [pA[0]]
    assert cache.reap() == []           # stale fully drained -> cheap no-op
    assert cache.num_resident == 1


# ------------------------------------------------- property: ownership law
def _check_cache_partition(a, cache, live_handles):
    """Free list, live pages, and trie residents obey the ownership law:
    free/live partition the pool exactly, every trie resident is live, and
    every live page is reachable from a handle and/or the trie with the
    right multiplicity (trie holds exactly one ref per resident page)."""
    free = a._free
    assert len(free) == len(set(free)), "free list holds a page twice"
    live = set(np.flatnonzero(a.refcount > 0).tolist())
    assert live.isdisjoint(free), "page simultaneously free and live"
    assert len(live) + len(free) == a.num_pages, "pages leaked"
    resident = cache.resident_pages
    assert resident <= live, "trie names a freed page"
    expected = np.zeros((a.num_pages,), np.int32)
    for pages in live_handles:
        for p in pages:
            expected[p] += 1
    for p in resident:
        expected[p] += 1
    assert np.array_equal(expected, a.refcount), (
        "refcounts drifted from handles + trie residency")


@settings(max_examples=25)
@given(st.integers(min_value=6, max_value=24),
       st.lists(st.integers(min_value=0, max_value=9),
                min_size=20, max_size=60),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_radix_random_interleavings_never_leak_or_double_free(
        num_pages, script, seed):
    """Random insert/match-retain/retire/evict/flush/step interleavings:
    after every op the pool partitions exactly (no leak, no double-free)
    and eviction never frees a page a live group still reads.  At the end,
    retiring every group and evicting everything returns the whole pool."""
    rng = np.random.default_rng(seed)
    a = PageAllocator(num_pages)
    cache = RadixPrefixCache(a, PL)
    handles = []   # live groups: lists of pages each holds one ref on

    def new_prompt():
        n = int(rng.integers(1, 4))
        return np.asarray(rng.integers(0, 3, size=n * PL), np.int32)

    for op in script:
        if op <= 4:                       # place a group (engine commit)
            t = new_prompt()
            nodes = cache.lookup(t)
            m_pages = [nd.page for nd in nodes]
            n_fresh = len(t) // PL - len(m_pages)
            if n_fresh > a.num_free:
                cache.evict(n_fresh - a.num_free)
            if n_fresh > a.num_free:
                continue                  # saturated: shed, nothing leaked
            if m_pages:
                a.retain(m_pages)
                cache.touch(nodes)
            fresh = a.alloc(n_fresh)
            cache.insert(nodes[-1] if nodes else None, t,
                         len(m_pages) * PL, fresh)
            handles.append(m_pages + fresh)
        elif op <= 6 and handles:         # a group retires
            a.release(handles.pop(int(rng.integers(len(handles)))))
        elif op == 7:                     # pool pressure
            cache.evict(int(rng.integers(1, 4)))
        elif op == 8:                     # weight swap
            cache.flush()
        else:                             # drive round boundary
            cache.step()
            cache.reap()
        _check_cache_partition(a, cache, handles)

    while handles:
        a.release(handles.pop())
    cache.step()
    cache.evict(num_pages)
    _check_cache_partition(a, cache, [])
    assert cache.num_resident == 0
    assert a.num_free == num_pages, "drained pool did not return whole"


# ---------------------------------------- learner retention (DESIGN.md §11)
def test_learner_retention_survives_eviction_and_flush():
    """The zero re-prefill handoff: the learner takes its own ref on a
    harvested response's prompt pages.  Neither pool-pressure eviction nor
    the set_params epoch flush may reclaim them while that ref lives —
    only the learner's release makes them evictable."""
    a, cache = make()
    pages, _ = prefill_insert(a, cache, toks(1, 2))
    cache.step()
    a.retain(pages)                     # learner retains at harvest
    a.release(pages)                    # the rollout group retires
    assert cache.evict(8) == []         # pressure: retained pages survive
    assert cache.flush() == []          # weight swap: ditto
    assert all(int(a.refcount[p]) >= 1 for p in pages)
    a.release(pages)                    # learner releases after the step
    assert sorted(cache.reap() + cache.evict(8)) == sorted(pages)
    assert a.in_use == 0


@settings(max_examples=25)
@given(st.integers(min_value=6, max_value=24),
       st.lists(st.integers(min_value=0, max_value=11),
                min_size=20, max_size=60),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_retention_interleavings_never_leak_or_reclaim(
        num_pages, script, seed):
    """The ownership property test with the learner in the loop: groups
    place/retire as before, and harvests hand the group's pages to a
    learner handle (extra ref) that outlives eviction and flush.  After
    every op the pool still partitions exactly, and no retained page is
    ever on the free list.  Draining groups AND learner handles returns
    the whole pool."""
    rng = np.random.default_rng(seed)
    a = PageAllocator(num_pages)
    cache = RadixPrefixCache(a, PL)
    handles = []     # live rollout groups
    retained = []    # learner-retained page sets (one ref each)

    def new_prompt():
        n = int(rng.integers(1, 4))
        return np.asarray(rng.integers(0, 3, size=n * PL), np.int32)

    for op in script:
        if op <= 3:                       # place a group (engine commit)
            t = new_prompt()
            nodes = cache.lookup(t)
            m_pages = [nd.page for nd in nodes]
            n_fresh = len(t) // PL - len(m_pages)
            if n_fresh > a.num_free:
                cache.evict(n_fresh - a.num_free)
            if n_fresh > a.num_free:
                continue                  # saturated: shed, nothing leaked
            if m_pages:
                a.retain(m_pages)
                cache.touch(nodes)
            fresh = a.alloc(n_fresh)
            cache.insert(nodes[-1] if nodes else None, t,
                         len(m_pages) * PL, fresh)
            handles.append(m_pages + fresh)
        elif op <= 5 and handles:         # harvest: learner retains, group
            pages = handles.pop(int(rng.integers(len(handles))))
            a.retain(pages)               # retires in the same breath
            retained.append(pages)
            a.release(pages)
        elif op == 6 and handles:         # a group retires unharvested
            a.release(handles.pop(int(rng.integers(len(handles)))))
        elif op == 7 and retained:        # learner grad step done
            a.release(retained.pop(int(rng.integers(len(retained)))))
        elif op == 8:                     # pool pressure
            cache.evict(int(rng.integers(1, 4)))
        elif op == 9:                     # weight swap
            cache.flush()
        else:                             # drive round boundary
            cache.step()
            cache.reap()
        _check_cache_partition(a, cache, handles + retained)
        free = set(a._free)
        for pages in retained:
            assert free.isdisjoint(pages), "retained page was reclaimed"

    for pages in retained + handles:
        a.release(pages)
    cache.step()
    cache.flush()
    cache.reap()
    cache.evict(num_pages)
    _check_cache_partition(a, cache, [])
    assert a.num_free == num_pages, "drained pool did not return whole"
