"""Batch layouts (core/layout.py, DESIGN.md §7): packing invariants, the
bucketed layout's bit-exactness vs the historical inline slicing, and the
acceptance contract — packed-layout loss/grads match the padded reference
for both URS and RPC selectors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core.grpo import GRPOConfig
from repro.core.layout import (
    PAD_SEGMENT,
    BucketedLayout,
    PackedLayout,
    PaddedLayout,
    make_layout,
    plan_pack,
)
from repro.core.repack import bucket_ladder, pick_bucket
from repro.core.selectors import make_selector
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.rl import VOCAB_SIZE
from repro.rl.learner import make_loss_fn, make_train_step


def tiny_cfg():
    return ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                       blocks=dense_blocks(2), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


def synth_batch(b=8, t=64, seed=0):
    """A synthetic rollout-shaped learner batch with ragged lengths."""
    rng = np.random.default_rng(seed)
    prompt_lens = rng.integers(4, 10, b).astype(np.int32)
    response_lens = rng.integers(5, t - 12, b).astype(np.int32)
    tokens = rng.integers(1, VOCAB_SIZE, (b, t)).astype(np.int32)
    rmask = np.zeros((b, t), np.float32)
    for r in range(b):
        rmask[r, prompt_lens[r]:prompt_lens[r] + response_lens[r]] = 1
        tokens[r, prompt_lens[r] + response_lens[r]:] = 0
    old_logp = (rng.standard_normal((b, t)) * 0.1 - 2).astype(np.float32) * rmask
    batch = {
        "tokens": tokens,
        "response_mask": rmask,
        "old_logp": old_logp,
        "advantages": rng.standard_normal(b).astype(np.float32),
        "orig_lengths": response_lens.astype(np.float32),
        "lengths": (prompt_lens + response_lens).astype(np.int32),
        "behavior_logp": old_logp,
        "staleness": np.zeros((b,), np.float32),
    }
    return batch, prompt_lens, response_lens, rmask


def select(batch, rmask, name, seed=3, **kw):
    sel = make_selector(name, **kw)(jax.random.PRNGKey(seed),
                                    jnp.asarray(rmask))
    batch = dict(batch)
    batch["ht_weights"] = np.asarray(sel.ht_weights, np.float32)
    return batch, sel


# ------------------------------------------------------------- plan_pack
def test_plan_pack_partitions_and_fits():
    rng = np.random.default_rng(0)
    hull = rng.integers(0, 33, 50)
    rows = plan_pack(hull, 32)
    placed = [s for row in rows for s in row]
    # every nonzero hull exactly once, zero hulls skipped
    assert sorted(placed) == sorted(np.flatnonzero(hull).tolist())
    for row in rows:
        assert sum(int(hull[s]) for s in row) <= 32


def test_plan_pack_rejects_oversized_hull():
    with pytest.raises(ValueError, match="exceeds pack_len"):
        plan_pack(np.array([40]), 32)


def test_plan_pack_deterministic():
    hull = np.array([10, 10, 20, 5, 5, 32])
    assert plan_pack(hull, 32) == plan_pack(hull, 32)


# ------------------------------------------------------ packing invariants
@pytest.mark.parametrize("sel_name,kw", [
    ("rpc", {"min_cut": 4}), ("urs", {"p": 0.5})])
def test_packed_layout_invariants(sel_name, kw):
    batch, pl_, rl_, rmask = synth_batch()
    batch, sel = select(batch, rmask, sel_name, **kw)
    b, t = batch["tokens"].shape
    lb = make_layout("packed").build(
        batch, prompt_lens=pl_, response_lens=rl_,
        keep_len=np.asarray(sel.keep_len),
        keep_mask=batch["ht_weights"] > 0,
        prefix_structured=sel.prefix_structured,
        ladder=bucket_ladder(t, 4, 8))
    d = lb.data
    seg = d["segment_ids"]
    resp = d["resp_ids"]
    pos = d["positions"]
    real = seg < int(PAD_SEGMENT)

    # per-row monotone segment ids (the kernel block-skip contract)
    assert (np.diff(seg, axis=1) >= 0).all()
    # positions restart per segment and count the original grid position
    keep_mask = batch["ht_weights"] > 0
    hull = np.where(keep_mask.any(1), t - np.argmax(keep_mask[:, ::-1], 1), 0)
    seen = np.zeros((b, t), bool)
    for r in range(seg.shape[0]):
        for c in range(seg.shape[1]):
            if real[r, c]:
                src, p = int(resp[r, c]), int(pos[r, c])
                assert not seen[src, p], "token packed twice"
                seen[src, p] = True
                assert d["tokens"][r, c] == batch["tokens"][src, p]
                assert d["old_logp"][r, c] == batch["old_logp"][src, p]
                assert d["ht_weights"][r, c] == batch["ht_weights"][src, p]
    # exactly each response's hull [0, h) is packed, once
    for src in range(b):
        np.testing.assert_array_equal(
            seen[src], np.arange(t) < hull[src])
    # padding is inert: zero weight everywhere it isn't a real token
    assert (d["ht_weights"][~real] == 0).all()
    # accounting
    assert lb.tokens_scored == seg.shape[0] * seg.shape[1]
    assert lb.kept_tokens == int((batch["ht_weights"] > 0).sum())
    assert lb.tokens_scored <= b * t
    assert 0 < lb.pack_efficiency <= 1


def test_packed_layout_row_quant():
    batch, pl_, rl_, rmask = synth_batch()
    batch, sel = select(batch, rmask, "rpc", min_cut=4)
    t = batch["tokens"].shape[1]
    kw = dict(prompt_lens=pl_, response_lens=rl_,
              keep_len=np.asarray(sel.keep_len),
              keep_mask=batch["ht_weights"] > 0,
              prefix_structured=sel.prefix_structured,
              ladder=bucket_ladder(t, 4, 8))
    base = make_layout("packed").build(batch, **kw)
    quant = make_layout("packed", row_quant=4).build(batch, **kw)
    assert quant.num_rows % 4 == 0
    assert quant.num_rows >= base.num_rows


def test_packed_layout_no_kept_tokens():
    batch, pl_, rl_, rmask = synth_batch()
    batch = dict(batch)
    batch["ht_weights"] = np.zeros_like(rmask)
    t = batch["tokens"].shape[1]
    lb = make_layout("packed").build(
        batch, prompt_lens=pl_, response_lens=rl_,
        keep_len=np.zeros(8, np.int32), keep_mask=batch["ht_weights"] > 0,
        prefix_structured=True, ladder=bucket_ladder(t, 4, 8))
    assert lb.kept_tokens == 0
    assert (lb.data["segment_ids"] == int(PAD_SEGMENT)).all()


# --------------------------------------------- bucketed/padded equivalence
def test_bucketed_layout_matches_historical_slicing():
    batch, pl_, rl_, rmask = synth_batch()
    batch, sel = select(batch, rmask, "rpc", min_cut=4)
    t = batch["tokens"].shape[1]
    ladder = bucket_ladder(t, 4, 8)
    lb = BucketedLayout().build(
        batch, prompt_lens=pl_, response_lens=rl_,
        keep_len=np.asarray(sel.keep_len), keep_mask=batch["ht_weights"] > 0,
        prefix_structured=True, ladder=ladder)
    keep_total = pl_ + np.minimum(np.asarray(sel.keep_len), rl_)
    t_new = min(pick_bucket(int(keep_total.max()), ladder), t)
    assert lb.row_len == t_new
    for k, v in batch.items():
        ref = v[:, :t_new] if getattr(v, "ndim", 0) >= 2 else v
        if k == "lengths":
            ref = keep_total.astype(np.int32)
        np.testing.assert_array_equal(lb.data[k], ref)


def test_bucketed_layout_unstructured_falls_back_to_padded():
    batch, pl_, rl_, rmask = synth_batch()
    batch, sel = select(batch, rmask, "urs", p=0.5)
    t = batch["tokens"].shape[1]
    lb = BucketedLayout().build(
        batch, prompt_lens=pl_, response_lens=rl_,
        keep_len=np.asarray(sel.keep_len), keep_mask=batch["ht_weights"] > 0,
        prefix_structured=False, ladder=bucket_ladder(t, 4, 8))
    assert lb.row_len == t
    np.testing.assert_array_equal(lb.data["tokens"], batch["tokens"])


def test_padded_layout_is_identity():
    batch, pl_, rl_, rmask = synth_batch()
    batch, sel = select(batch, rmask, "rpc", min_cut=4)
    t = batch["tokens"].shape[1]
    lb = PaddedLayout().build(
        batch, prompt_lens=pl_, response_lens=rl_,
        keep_len=np.asarray(sel.keep_len), keep_mask=batch["ht_weights"] > 0,
        prefix_structured=True, ladder=bucket_ladder(t, 4, 8))
    assert lb.tokens_scored == batch["tokens"].size
    for k, v in batch.items():
        np.testing.assert_array_equal(lb.data[k], v)


def test_make_layout_unknown():
    with pytest.raises(ValueError, match="unknown layout"):
        make_layout("zigzag")


# --------------------------------------------- the token-exactness contract
@pytest.mark.parametrize("sel_name,kw", [
    ("rpc", {"min_cut": 4}), ("urs", {"p": 0.5})])
def test_packed_loss_and_grads_match_padded(sel_name, kw):
    """ISSUE 4 acceptance: the packed learner step reproduces the padded
    reference loss and gradients within tolerance for URS and RPC — the
    HT estimator (Eq. 6) is layout-invariant."""
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), model_decl(cfg))
    batch, pl_, rl_, rmask = synth_batch()
    batch, sel = select(batch, rmask, sel_name, **kw)
    t = batch["tokens"].shape[1]
    gcfg = GRPOConfig()

    loss_pad = make_loss_fn(cfg, gcfg, vocab_chunks=1)
    loss_pk = make_loss_fn(cfg, gcfg, vocab_chunks=1, packed=True)
    (lp, mp), gp = jax.value_and_grad(loss_pad, has_aux=True)(
        params, {k: jnp.asarray(v) for k, v in batch.items()})

    lb = make_layout("packed").build(
        batch, prompt_lens=pl_, response_lens=rl_,
        keep_len=np.asarray(sel.keep_len), keep_mask=batch["ht_weights"] > 0,
        prefix_structured=sel.prefix_structured,
        ladder=bucket_ladder(t, 4, 8))
    (lk, mk), gk = jax.value_and_grad(loss_pk, has_aux=True)(
        params, {k: jnp.asarray(v) for k, v in lb.data.items()})

    assert lb.tokens_scored < batch["tokens"].size  # it actually saved work
    np.testing.assert_allclose(float(lk), float(lp), rtol=1e-6, atol=1e-7)
    # per-token loss metrics agree too (same selected set either way)
    assert float(mk["selected_tokens"]) == float(mp["selected_tokens"])
    np.testing.assert_allclose(float(mk["clip_frac"]), float(mp["clip_frac"]),
                               atol=1e-6)
    flat_p, _ = ravel_pytree(gp)
    flat_k, _ = ravel_pytree(gk)
    scale = float(jnp.abs(flat_p).max())
    np.testing.assert_allclose(np.asarray(flat_k), np.asarray(flat_p),
                               atol=5e-3 * scale, rtol=0)


def _packed_learner_inputs(m):
    """Build (full LayoutBatch, list of m per-microbatch LayoutBatches) for
    the same selection — split on the response axis BEFORE packing."""
    from repro.core.layout import build_microbatches

    batch, pl_, rl_, rmask = synth_batch(b=8, t=64)
    batch, sel = select(batch, rmask, "rpc", min_cut=4)
    ladder = bucket_ladder(64, 4, 8)
    layout = make_layout("packed")
    kw = dict(prompt_lens=pl_, response_lens=rl_,
              keep_len=np.asarray(sel.keep_len),
              keep_mask=np.asarray(sel.ht_weights) > 0,
              prefix_structured=sel.prefix_structured, ladder=ladder)
    return layout.build(batch, **kw), build_microbatches(layout, batch, m,
                                                         **kw)


def test_packed_microbatch_accumulation_matches_single_step():
    """packed + num_microbatches > 1: split responses into microbatches
    BEFORE packing (per-microbatch BatchLayout.build), accumulate grads —
    the updated params match num_microbatches=1 within reassociation
    tolerance (the estimator is identical; only the pack plans differ)."""
    from repro.optim import AdamWConfig, init_opt_state

    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), model_decl(cfg))
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, oc)
    lb1, mbs = _packed_learner_inputs(2)
    step1 = jax.jit(make_train_step(cfg, GRPOConfig(), oc, vocab_chunks=1,
                                    packed=True))
    step2 = jax.jit(make_train_step(cfg, GRPOConfig(), oc, vocab_chunks=1,
                                    packed=True, num_microbatches=2))

    def dev(d):
        return {k: jnp.asarray(v) for k, v in d.items()}

    p1, _, m1 = step1(params, opt, dev(lb1.data))
    p2, _, m2 = step2(params, opt, tuple(dev(mb.data) for mb in mbs))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-5)
    flat1, _ = ravel_pytree(p1)
    flat2, _ = ravel_pytree(p2)
    # params are bf16: allow one-ulp rounding on the handful of entries
    # whose accumulated grad lands on a rounding boundary
    np.testing.assert_allclose(np.asarray(flat2, np.float32),
                               np.asarray(flat1, np.float32),
                               rtol=1e-2, atol=1e-3)


def test_packed_microbatch_step_requires_prebuilt_tuple():
    """The packed accumulation path refuses a single flat dict: packed rows
    cannot be split after packing, so the caller must pre-split (the shape
    of the old num_microbatches>1 rejection, now with an escape hatch)."""
    from repro.optim import AdamWConfig, init_opt_state

    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), model_decl(cfg))
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, oc)
    lb1, _ = _packed_learner_inputs(2)
    step = make_train_step(cfg, GRPOConfig(), oc, vocab_chunks=1,
                           packed=True, num_microbatches=2)
    with pytest.raises(ValueError, match="pre-packed"):
        step(params, opt, {k: jnp.asarray(v) for k, v in lb1.data.items()})


def test_build_microbatches_requires_even_split():
    from repro.core.layout import build_microbatches

    batch, pl_, rl_, rmask = synth_batch(b=8, t=64)
    batch, sel = select(batch, rmask, "rpc", min_cut=4)
    with pytest.raises(ValueError, match="does not split"):
        build_microbatches(
            make_layout("packed"), batch, 3, prompt_lens=pl_,
            response_lens=rl_, keep_len=np.asarray(sel.keep_len),
            keep_mask=np.asarray(sel.ht_weights) > 0,
            prefix_structured=sel.prefix_structured,
            ladder=bucket_ladder(64, 4, 8))


def test_packed_accepts_ssm_rejects_xattn():
    """The capability table (models/capabilities.py) now admits ssm/rec
    under the packed layout (segment-boundary state resets) and rejects
    only mixers whose row says packed_ok=False (xattn)."""
    from repro.models.capabilities import CapabilityError
    from repro.models.model import score_tokens

    from repro.models.config import SSMConfig

    cfg = ModelConfig(name="ssm-tiny", d_model=32, n_heads=0, n_kv_heads=0,
                      head_dim=0, d_ff=0, vocab_size=VOCAB_SIZE,
                      blocks=dense_blocks(1, mixer="ssm"), seq_parallel=False,
                      remat_policy="none", scan_layers=False,
                      ssm=SSMConfig(state_dim=16, head_dim=16, expand=2,
                                    conv_width=4, chunk=8))
    params = init_params(jax.random.PRNGKey(0), model_decl(cfg))
    toks = jnp.zeros((2, 16), jnp.int32)
    seg = jnp.zeros((2, 16), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 16))
    logp, _ = score_tokens(params, cfg, toks, positions=pos, segment_ids=seg,
                           vocab_chunks=1)
    assert np.all(np.isfinite(np.asarray(logp, np.float32)))

    xcfg = ModelConfig(name="xattn-tiny", d_model=32, n_heads=2, n_kv_heads=2,
                       head_dim=16, d_ff=64, vocab_size=VOCAB_SIZE,
                       blocks=((("attn", "xattn"), 1),), seq_parallel=False,
                       remat_policy="none", scan_layers=False,
                       num_image_tokens=4)
    xparams = init_params(jax.random.PRNGKey(1), model_decl(xcfg))
    img = jnp.zeros((2, 4, 32), jnp.bfloat16)
    with pytest.raises(CapabilityError, match="xattn"):
        score_tokens(xparams, xcfg, toks, positions=pos, segment_ids=seg,
                     image_embeds=img, vocab_chunks=1)


def test_train_inputs_packed_spec():
    """launch/step_specs.py lowers the packed batch: id planes present,
    per-response leaves sized by num_segments, no padded-grid lengths."""
    from repro.configs.shapes import ShapeSpec
    from repro.launch.step_specs import train_inputs

    cfg = tiny_cfg()
    shape = ShapeSpec("t", "train", 64, 16)
    batch, shards = train_inputs(cfg, shape, mesh=None, layout="packed",
                                 num_segments=24)
    assert set(batch) >= {"tokens", "positions", "segment_ids", "resp_ids"}
    assert "lengths" not in batch
    for key in ("positions", "segment_ids", "resp_ids"):
        assert batch[key].shape == (16, 64)
    for key in ("advantages", "orig_lengths", "staleness"):
        assert batch[key].shape == (24,)
    assert set(shards) == set(batch)
    with pytest.raises(ValueError, match="unknown step-spec layout"):
        train_inputs(cfg, shape, mesh=None, layout="zigzag")
