"""Architecture-coverage matrix (DESIGN.md §9): every config in configs/
x {layout: padded/bucketed/packed} x {engine: legacy/continuous/paged}.

Three contracts, all keyed off the capability table
(``models/capabilities.py``):

1. **Fastest legal path, no silent fallback** — each config's
   ``(fastest_layout, fastest_engine)`` equals the hand-written EXPECTED
   table below.  If a future edit quietly demotes deepseek-v2 (MLA) off the
   paged engine or mamba2/recurrentgemma off the packed learner, this file
   fails by name.
2. **Layout parity** — for every legal layout, per-token logp matches the
   padded-grid reference token-for-token (attention kinds bitwise-level;
   ssm/rec within reassociation tolerance — the chunked scans re-run at
   different offsets inside packed rows).
3. **Engine parity** — for every legal arena engine, greedy completions
   match the legacy scan token-exactly, and illegal cells raise
   ``CapabilityError`` at construction time, never mid-run.

The sweep instantiates each family's REDUCED (smoke) config; the
capability verdicts are computed on the FULL config (same mixer rows).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_config, get_smoke
from repro.core.layout import PAD_SEGMENT, make_layout
from repro.core.repack import bucket_ladder
from repro.core.selectors import make_selector
from repro.models import capabilities as caps
from repro.models import init_params, model_decl
from repro.models.capabilities import CapabilityError
from repro.models.model import score_tokens
from repro.rl import (
    ContinuousRolloutEngine,
    EngineConfig,
    PagedEngineConfig,
    PagedRolloutEngine,
    Request,
    RolloutConfig,
)
from repro.rl.rollout import generate

# full-zoo sweep: breadth coverage, runs in the dedicated config-matrix CI
# job (-m slow), not the fast tier
pytestmark = pytest.mark.slow

# The committed coverage table.  Changing a capability row is allowed —
# but it must be done HERE, visibly, not by a fallback deep in a trainer.
EXPECTED = {
    "llama-3.2-vision-90b": ("bucketed", None),
    "nemotron-4-340b": ("packed", "paged"),
    "h2o-danube-3-4b": ("packed", "paged"),
    "mistral-nemo-12b": ("packed", "paged"),
    "gemma3-27b": ("packed", "paged"),
    "recurrentgemma-9b": ("packed", "paged"),
    "deepseek-v2-236b": ("packed", "paged"),
    "qwen3-moe-235b-a22b": ("packed", "paged"),
    "mamba2-130m": ("packed", "paged"),
    "musicgen-large": ("bucketed", "legacy"),
    "nat-qwen3-8b": ("packed", "paged"),
}

B, T = 6, 48


def _synth(cfg, seed=0):
    """Ragged rollout-shaped batch in the config's vocab (+ codebook planes
    / image embeds where the config wants them)."""
    rng = np.random.default_rng(seed)
    pl = rng.integers(4, 10, B).astype(np.int32)
    rl = rng.integers(5, T - 12, B).astype(np.int32)
    shape = (B, T, cfg.num_codebooks) if cfg.num_codebooks else (B, T)
    toks = rng.integers(1, cfg.vocab_size, shape).astype(np.int32)
    rmask = np.zeros((B, T), np.float32)
    for r in range(B):
        rmask[r, pl[r]:pl[r] + rl[r]] = 1
        toks[r, pl[r] + rl[r]:] = 0
    img = (rng.standard_normal(
        (B, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
        if cfg.num_image_tokens else None)
    return toks, pl, rl, rmask, img


def test_expected_table_is_exhaustive():
    assert sorted(EXPECTED) == sorted(ALL_ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_fastest_legal_path(arch):
    """The no-silent-fallback pin: fastest layout/engine per config equals
    the committed table, and the legal lists are ordered fastest-first."""
    cfg = get_config(arch)
    want_layout, want_engine = EXPECTED[arch]
    assert caps.fastest_layout(cfg) == want_layout, arch
    assert caps.fastest_engine(cfg) == want_engine, arch
    layouts, engines = caps.legal_layouts(cfg), caps.legal_engines(cfg)
    assert layouts and layouts[0] == want_layout
    assert list(layouts) == [n for n in ("packed", "bucketed", "padded")
                             if n in layouts]
    assert list(engines) == [n for n in ("paged", "continuous", "legacy")
                             if n in engines]
    # padded grid + legacy scan are universal fallbacks for non-vision
    assert "padded" in layouts
    if "xattn" not in caps.config_mixers(cfg):
        assert "legacy" in engines


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_layout_logp_parity(arch):
    """Every legal layout reproduces the padded grid's per-token logp for
    the tokens it scores — cell (arch, layout) in the coverage matrix."""
    cfg = get_smoke(arch)
    full = get_config(arch)
    params = init_params(jax.random.PRNGKey(0), model_decl(cfg))
    toks, pl, rl, rmask, img = _synth(cfg)
    imgj = None if img is None else jnp.asarray(img, jnp.bfloat16)
    sel = make_selector("rpc", min_cut=4)(jax.random.PRNGKey(3),
                                          jnp.asarray(rmask))
    hw = np.asarray(sel.ht_weights, np.float32)
    batch = {"tokens": toks, "ht_weights": hw}
    kw = dict(prompt_lens=pl, response_lens=rl,
              keep_len=np.asarray(sel.keep_len), keep_mask=hw > 0,
              prefix_structured=sel.prefix_structured,
              ladder=bucket_ladder(T, 4, 8))
    lp_pad, _ = score_tokens(params, cfg, jnp.asarray(toks),
                             lengths=jnp.asarray(pl + rl),
                             image_embeds=imgj, vocab_chunks=1)
    lp_pad = np.asarray(lp_pad, np.float64)

    layouts = caps.legal_layouts(full)
    for name in layouts:
        lb = make_layout(name).build(batch, **kw)
        d = lb.data
        if name == "packed":
            lp, _ = score_tokens(params, cfg, jnp.asarray(d["tokens"]),
                                 positions=jnp.asarray(d["positions"]),
                                 segment_ids=jnp.asarray(d["segment_ids"]),
                                 vocab_chunks=1)
            lp = np.asarray(lp, np.float64)
            real = d["segment_ids"] < int(PAD_SEGMENT)
            got = lp[real]
            ref = lp_pad[d["resp_ids"][real], d["positions"][real]]
        else:
            t_new = d["tokens"].shape[1]
            lp, _ = score_tokens(params, cfg, jnp.asarray(d["tokens"]),
                                 image_embeds=imgj, vocab_chunks=1)
            lp = np.asarray(lp, np.float64)
            # compare the kept tokens (the estimator's support); bucketed
            # slicing only drops the all-cut tail, a causal no-op upstream
            keep = d["ht_weights"][:, :t_new] > 0
            got, ref = lp[keep], lp_pad[:, :t_new][keep]
        # attention kinds mask (bitwise-level); ssm/rec zero state at
        # segment starts — exact math, ULP-level reassociation (the
        # chunked scans re-run at shifted offsets inside packed rows)
        np.testing.assert_allclose(got, ref, atol=1e-2, rtol=0,
                                   err_msg=f"{arch}/{name}")
        assert np.all(np.isfinite(got)), f"{arch}/{name}"


@pytest.mark.parametrize(
    "arch", [a for a in ALL_ARCHS if EXPECTED[a][1] is not None])
def test_engine_greedy_parity(arch):
    """Every legal arena engine reproduces the legacy scan's greedy
    completions token-exactly — cell (arch, engine) in the matrix."""
    cfg = get_smoke(arch)
    full = get_config(arch)
    engines = caps.legal_engines(full)
    assert engines[0] == EXPECTED[arch][1]
    if engines == ("legacy",):     # codebooks: the scan IS the only cell
        assert cfg.num_codebooks
        return
    params = init_params(jax.random.PRNGKey(0), model_decl(cfg))
    rng = np.random.default_rng(1)
    prompts = rng.integers(3, cfg.vocab_size, size=(3, 10)).astype(np.int32)
    plens = np.full((3,), 10, np.int32)
    n = 8
    key = jax.random.PRNGKey(0)
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    ref, ref_logp, _, _, _ = generate(
        params, cfg, rcfg, jnp.asarray(prompts), jnp.asarray(plens), key)
    ref, ref_logp = np.asarray(ref), np.asarray(ref_logp)
    reqs = [Request(uid=i, tokens=prompts[i], budget=n) for i in range(3)]
    tp = prompts.shape[1]

    for name in engines:
        if name == "legacy":
            continue
        if name == "continuous":
            eng = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
                num_slots=2, max_prompt_len=10, steps_per_sync=3,
                refill_lanes=1))
        else:
            eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
                num_slots=2, max_prompt_len=10, steps_per_sync=3,
                page_len=4, max_group=2))
        comps = {c.uid: c for c in eng.run(params, reqs, key)}
        assert len(comps) == 3, f"{arch}/{name}"
        for i in range(3):
            c = comps[i]
            np.testing.assert_array_equal(
                c.tokens, ref[i, tp:tp + c.response_len],
                err_msg=f"{arch}/{name}")
            np.testing.assert_allclose(
                c.logp, ref_logp[i, :c.response_len], atol=1e-5,
                err_msg=f"{arch}/{name}")
        if name == "paged":
            assert eng._alloc.in_use == 0


def test_illegal_cells_raise_at_construction():
    """Illegal matrix cells fail loudly at config time, naming the
    capability row — never a silent fallback, never a mid-run error."""
    vis = get_smoke("llama-3.2-vision-90b")
    rcfg = RolloutConfig(max_new_tokens=4, temperature=0.0, eos_id=-1)
    with pytest.raises(CapabilityError, match="xattn"):
        PagedRolloutEngine(vis, rcfg, PagedEngineConfig(
            num_slots=2, max_prompt_len=8, page_len=4, max_group=2))
    with pytest.raises(CapabilityError, match="xattn"):
        ContinuousRolloutEngine(vis, rcfg, EngineConfig(
            num_slots=2, max_prompt_len=8))
    with pytest.raises(CapabilityError, match="packed"):
        caps.check_packed(vis)
    music = get_smoke("musicgen-large")
    with pytest.raises(CapabilityError, match="num_codebooks"):
        caps.check_packed(music)


def test_trainer_packed_layout_rejected_at_config_time():
    """Satellite regression: NATTrainerConfig(layout='packed') on an
    unsupported mixer raises CapabilityError from the trainer constructor
    (formerly it silently built and failed steps later in-jit)."""
    from repro.rl import NATGRPOTrainer, NATTrainerConfig

    vis = get_smoke("llama-3.2-vision-90b")
    tcfg = NATTrainerConfig(layout="packed", rollout_engine="legacy",
                            prompts_per_step=1, max_prompt_len=8)
    with pytest.raises(CapabilityError, match="capability row 'xattn'"):
        NATGRPOTrainer(vis, tcfg)


def test_coverage_cells_cover_every_arch():
    cells = caps.coverage_cells()
    archs = {a for a, _, _ in cells}
    assert archs == set(ALL_ARCHS)
    # the three headline rows the issue names
    assert ("deepseek-v2-236b", "packed", "paged") in cells
    assert ("mamba2-130m", "packed", "paged") in cells
    assert ("recurrentgemma-9b", "packed", "paged") in cells
    # vision has no engine cells but still has layout coverage
    assert ("llama-3.2-vision-90b", "bucketed", None) in cells
