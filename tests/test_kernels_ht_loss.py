"""Fused HT-loss head kernel vs the pure-jnp oracle: shape/dtype sweeps for
forward, logz/entropy, and both backward kernels (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ht_loss import (
    fused_score_grid, fused_token_logprobs, logprob_ref,
)
from repro.kernels.ht_loss import kernel as K

SWEEP = [
    # (N, D, V, block_n, block_v)
    (256, 64, 512, 128, 128),
    (256, 128, 1024, 256, 512),
    (512, 96, 768, 128, 256),
    (128, 256, 2048, 128, 1024),
]


def data(n, d, v, dtype, seed=0):
    k = jax.random.PRNGKey(seed)
    h = (jax.random.normal(k, (n, d), jnp.float32) * 0.4).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(k, 1), (d, v), jnp.float32)
         * 0.05).astype(dtype)
    tok = jax.random.randint(jax.random.fold_in(k, 2), (n,), 0, v)
    return h, w, tok


@pytest.mark.parametrize("n,d,v,bn,bv", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_sweep(n, d, v, bn, bv, dtype):
    h, w, tok = data(n, d, v, dtype)
    logp, logz, ent = K.fwd_pallas(h, w, tok, block_n=bn, block_v=bv)
    rl, rz, re = logprob_ref(h, w, tok)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(logp), np.asarray(rl), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(logz), np.asarray(rz), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(re), rtol=3e-2,
                               atol=3e-2)


@pytest.mark.parametrize("n,d,v,bn,bv", SWEEP[:2])
def test_bwd_sweep(n, d, v, bn, bv):
    h, w, tok = data(n, d, v, jnp.float32)

    def loss_k(h, w):
        lp, _ = fused_token_logprobs(h, w, tok, bn, bv, True)
        return jnp.sum(jnp.sin(lp))

    def loss_r(h, w):
        lp, _, _ = logprob_ref(h, w, tok)
        return jnp.sum(jnp.sin(lp))

    gk = jax.grad(loss_k, argnums=(0, 1))(h, w)
    gr = jax.grad(loss_r, argnums=(0, 1))(h, w)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]),
                               rtol=1e-4, atol=1e-5)


def test_entropy_cotangent_dropped():
    """Entropy is metrics-only: its cotangent must not produce grads."""
    h, w, tok = data(256, 64, 512, jnp.float32)
    g = jax.grad(
        lambda h: jnp.sum(fused_token_logprobs(h, w, tok, 128, 128, True)[1])
    )(h)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-7)


def test_grid_wrapper_matches_score_grid():
    b, t, d, v = 2, 33, 64, 512
    k = jax.random.PRNGKey(5)
    hidden = jax.random.normal(k, (b, t, d), jnp.float32) * 0.3
    w = jax.random.normal(jax.random.fold_in(k, 1), (d, v)) * 0.05
    toks = jax.random.randint(jax.random.fold_in(k, 2), (b, t), 0, v)
    logp, ent = fused_score_grid(hidden, w, toks, block_n=64, block_v=128)
    assert logp.shape == (b, t)
    rl, _, re = logprob_ref(hidden[:, :-1].reshape(-1, d), w,
                            toks[:, 1:].reshape(-1))
    np.testing.assert_allclose(np.asarray(logp[:, 1:]).reshape(-1),
                               np.asarray(rl), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(logp[:, 0]), 0.0)


def test_under_jit():
    h, w, tok = data(256, 64, 512, jnp.bfloat16)
    f = jax.jit(lambda a, b: fused_token_logprobs(a, b, tok, 128, 128, True))
    lp, _ = f(h, w)
    rl, _, _ = logprob_ref(h, w, tok)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(rl), rtol=3e-2,
                               atol=3e-2)
