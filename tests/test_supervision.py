"""Chaos-hardened elastic fleets (DESIGN.md §13): failure detection,
token-exact group reclaim, and the fault-injection harness.

The load-bearing gates:

* **kill-one-replica recovery** — a fleet of 2 with one replica killed by
  an injected death produces per-group tokens identical to the no-fault
  fleet: the reclaimed index re-derives the dead claimer's exact keys
  from the shared KeyChain;
* **property test** — random seeded fault schedules (kills, stalls,
  put-failures across N replicas) either complete token-exactly or raise
  a clean structured ``SupervisorError``; never a deadlock, never a lost
  or double-consumed group;
* **dead-producer unblock** — removing a dead producer's watermark and
  cancelling its orphaned reservations lets a blocked ``pop`` proceed.

Fast tests drive the real trainer orchestration with a *fake* per-group
roll (``_roll_group`` overridden with a pure function of the chain keys):
the claim/reserve/reclaim/deposit concurrency under test is byte-for-byte
the production path, only the jax compute is skipped.  The slow tests at
the bottom run real engines end to end.
"""
import threading
import time
import types

import jax
import numpy as np
import pytest

from repro.dist import PublicationError, WeightPublisher
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    DistNATGRPOTrainer,
    NATTrainerConfig,
    QuiesceTimeout,
    RetryPolicy,
    ReplicaSupervisor,
    RolloutConfig,
    SampleQueue,
    SupervisorError,
    TaggedGroup,
    VOCAB_SIZE,
    retry_call,
)
from repro.testing import FaultPlan, FaultSpec, InjectedActorDeath, InjectedFault

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st


def tiny_cfg(**kw):
    base = dict(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                blocks=dense_blocks(2), seq_parallel=False,
                remat_policy="none", scan_layers=False)
    base.update(kw)
    return ModelConfig(**base)


def fleet_cfg(**kw):
    base = dict(
        selector="rpc", selector_kwargs=(("min_cut", 4),),
        prompts_per_step=2, max_prompt_len=16,
        rollout=RolloutConfig(max_new_tokens=8, group_size=4,
                              overprovision=1.5, temperature=1.0),
        steps_per_sync=2,
        adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        bucket_align=8, num_buckets=1, seed=0,
        supervise=True, supervise_interval=0.02)
    base.update(kw)
    return NATTrainerConfig(**base)


def _fake_tokens(i, k_roll):
    """The fake roll's output: a pure function of (index, chain key) — two
    claimers of the same index must produce identical 'tokens'."""
    return np.asarray(k_roll).astype(np.int64) + i


class _FakeRollFleet(DistNATGRPOTrainer):
    """Fleet trainer whose per-group roll is the cheap pure function above:
    the claim/reserve/reclaim/deposit protocol is the production code, the
    jax rollout is not exercised (keeps chaos examples sub-second)."""

    def _roll_group(self, engine, params, pb, k_roll, i):
        time.sleep(0.01)  # widen the race window between replicas
        return types.SimpleNamespace(tokens=_fake_tokens(i, k_roll))


def _collect(tr, k, timeout=60.0):
    got = {}
    tr._ensure_actor()
    while len(got) < k:
        g = tr.queue.pop(0, timeout=timeout)
        assert g.index not in got, f"group {g.index} served twice"
        got[g.index] = g
    return got


def _wait_for(pred, timeout=10.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while not pred():
        if time.monotonic() > deadline:
            pytest.fail(f"timed out waiting for {msg}")
        time.sleep(0.01)


# --------------------------------------------------------- chaos harness
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(site="actor", kind="explode")
    with pytest.raises(ValueError, match="delay"):
        FaultSpec(site="actor", kind="stall")
    FaultSpec(site="actor", kind="stall", delay=0.1)  # ok


def test_fault_plan_matching_after_times_replica_at():
    plan = FaultPlan([
        FaultSpec(site="actor", replica="r1", at=2, after=1, times=2,
                  exc=InjectedActorDeath),
    ])
    # wrong site / replica / index: pass through
    plan.fire("queue_put", replica="r1", index=2)
    plan.fire("actor", replica="r0", index=2)
    plan.fire("actor", replica="r1", index=3)
    assert plan.total_fired() == 0
    # first matching occurrence is skipped by after=1
    plan.fire("actor", replica="r1", index=2)
    assert plan.total_fired() == 0 and not plan.exhausted()
    # then fires exactly `times` times
    for _ in range(2):
        with pytest.raises(InjectedActorDeath, match="replica=r1"):
            plan.fire("actor", replica="r1", index=2)
    plan.fire("actor", replica="r1", index=2)  # budget exhausted: pass
    assert plan.fired == {"actor": 2}
    assert plan.total_fired() == 2 and plan.exhausted()


def test_fault_plan_stall_sleeps_not_raises():
    plan = FaultPlan([FaultSpec(site="drive", kind="stall", delay=0.1)])
    t0 = time.monotonic()
    plan.fire("drive")           # stalls
    assert time.monotonic() - t0 >= 0.09
    t0 = time.monotonic()
    plan.fire("drive")           # budget spent: pass-through
    assert time.monotonic() - t0 < 0.05
    assert plan.fired == {"drive": 1}


def test_fault_plan_random_is_deterministic():
    a = FaultPlan.random(7, replicas=["fleet0", "fleet1"])
    b = FaultPlan.random(7, replicas=["fleet0", "fleet1"])
    assert [dataclass_tuple(s) for s in a.specs] \
        == [dataclass_tuple(s) for s in b.specs]
    c = FaultPlan.random(8, replicas=["fleet0", "fleet1"])
    assert len(c.specs) != len(a.specs) or (
        [dataclass_tuple(s) for s in c.specs]
        != [dataclass_tuple(s) for s in a.specs]) or not a.specs


def dataclass_tuple(s):
    return (s.site, s.kind, s.replica, s.at, s.after, s.times, s.delay,
            s.exc.__name__)


# ------------------------------------------------------- bounded retries
def test_retry_call_bounded_and_escalates():
    calls, retries = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise InjectedFault("transient")
        return "ok"

    out = retry_call(flaky, RetryPolicy(max_attempts=3, backoff_s=0.001),
                     (InjectedFault,),
                     lambda attempt, exc: retries.append(attempt))
    assert out == "ok" and len(calls) == 3 and retries == [1, 2]

    # exhausting the budget re-raises the last retryable error
    with pytest.raises(InjectedFault):
        retry_call(lambda: (_ for _ in ()).throw(InjectedFault("x")),
                   RetryPolicy(max_attempts=2, backoff_s=0.001),
                   (InjectedFault,))

    # non-retryable escalates immediately (one attempt)
    calls.clear()

    def wrong():
        calls.append(1)
        raise KeyError("not transient")

    with pytest.raises(KeyError):
        retry_call(wrong, RetryPolicy(max_attempts=5, backoff_s=0.001),
                   (InjectedFault,))
    assert len(calls) == 1


def test_publisher_retries_transient_and_escalates_persistent():
    params = {"w": np.ones((4, 4), np.float32)}
    dev = jax.devices()[0]

    pub = WeightPublisher({"fleet0": dev}, max_attempts=3, backoff_s=0.001)
    pub.chaos = FaultPlan([FaultSpec(site="publish", at=1)])
    pub.publish(params, epoch=0)                  # clean
    out = pub.publish(params, epoch=1)            # one injected failure
    assert set(out) == {"fleet0"}
    assert pub.stats["publish_retries"] == 1
    assert pub.stats["epoch"] == 1 and pub.stats["publishes"] == 2

    pub2 = WeightPublisher({"fleet0": dev}, max_attempts=3, backoff_s=0.001)
    pub2.chaos = FaultPlan([FaultSpec(site="publish", times=99)])
    with pytest.raises(PublicationError, match="after 3 attempts"):
        pub2.publish(params, epoch=0)
    assert pub2.stats["publish_retries"] == 2     # bounded, then escalate
    assert pub2.stats["publishes"] == 0


def test_publisher_add_remove_target():
    params = {"w": np.ones((2, 2), np.float32)}
    dev = jax.devices()[0]
    pub = WeightPublisher({"fleet0": dev})
    pub.publish(params, epoch=3)
    tree = pub.add_target("fleet1", dev, params=params, epoch=3)
    assert tree is not None
    _, epoch = pub.latest("fleet1")
    assert epoch == 3
    with pytest.raises(ValueError, match="already registered"):
        pub.add_target("fleet1", dev)
    pub.remove_target("fleet1")
    with pytest.raises(KeyError):
        pub.latest("fleet1")


# --------------------------------------------------- queue-level recovery
def _group(i, version=0):
    return TaggedGroup(index=i, behavior_version=version, batch=None,
                       prompt_batch=None, key_sel=jax.random.PRNGKey(i),
                       t_rollout=0.0)


def test_queue_remove_producer_unblocks_pop():
    """Regression: a dead producer's reservation used to wedge pop forever
    (the queue held younger groups for a gap nobody would ever fill)."""
    q = SampleQueue(capacity=4, max_staleness=99)
    q.reserve(0)                            # dead producer's claim
    q.put(_group(1), producer="b")
    q.watermarks["a"] = 0                   # its earlier deposit's watermark
    with pytest.raises(TimeoutError):
        q.pop(0, timeout=0.2)               # index 0 gap blocks the head
    q.remove_producer("a", cancel=(0,))
    assert q.pop(0, timeout=5.0).index == 1
    assert "a" not in q.watermarks and "b" in q.watermarks


def test_queue_drops_duplicate_deposits():
    """At-most-once per index: a condemned replica waking up late and
    re-depositing a reclaimed (or already-served) group is dropped."""
    q = SampleQueue(capacity=4, max_staleness=99)
    q.reserve(0)
    q.put(_group(0), producer="a")          # survivor's re-roll lands first
    q.put(_group(0), producer="b")          # late duplicate while queued
    assert q.dropped_dup == 1 and q.qsize() == 1
    assert q.pop(0, timeout=5.0).index == 0
    q.put(_group(0), producer="b")          # duplicate of a served index
    assert q.dropped_dup == 2 and q.qsize() == 0
    # a stale reservation attached to the duplicate is released too
    q.reserve(0)
    q.put(_group(0), producer="b")
    assert q.inflight() == 0


# ------------------------------------------------------------ supervisor
def test_supervisor_detects_death_reclaims_and_dewatermarks():
    q = SampleQueue(capacity=4, max_staleness=99)
    sup = ReplicaSupervisor(q, hang_timeout=5.0, interval=0.02)
    die = threading.Event()
    victim = threading.Thread(target=die.wait, daemon=True)
    survivor = threading.Thread(target=lambda: time.sleep(30), daemon=True)
    victim.start(), survivor.start()
    sup.register("a", thread=victim)
    sup.register("b", thread=survivor)
    q.reserve(3)
    sup.claim("a", 3)
    q.watermarks["a"] = 0                   # deposit-then-die: ghost entry
    sup.start()
    try:
        die.set()                           # the thread exits silently
        _wait_for(lambda: sup.stats["replicas_failed"] == 1,
                  msg="death detection")
        assert sup.stats["groups_reclaimed"] == 1
        assert "a" not in q.watermarks      # ghost watermark removed
        assert q.inflight() == 1            # reservation SURVIVES for reclaim
        assert sup.should_stop("a") and not sup.should_stop("b")
        assert sup.reclaim_pending()
        assert sup.take_reclaim("b") == 3   # survivor adopts the orphan
        assert sup.take_reclaim("b") is None
        snap = {s.name: s for s in sup.status()}
        assert snap["a"].dead and not snap["b"].dead
        assert "state=dead" in snap["a"].describe()
        assert snap["b"].claimed == 3       # take_reclaim assigned it
    finally:
        sup.stop()


def test_supervisor_tolerates_registered_but_unstarted_thread():
    """Join-race regression: replicas register BEFORE their thread starts
    (so the first heartbeat/claim always finds them), and the monitor
    must not book the not-yet-started thread (is_alive() False, ident
    None) as dead-without-reporting."""
    q = SampleQueue(capacity=4, max_staleness=99)
    sup = ReplicaSupervisor(q, hang_timeout=5.0, interval=0.01)
    go = threading.Event()
    t = threading.Thread(target=go.wait, daemon=True)
    sup.register("late", thread=t)      # registered, NOT started
    sup.start()
    try:
        time.sleep(0.1)                 # many monitor polls
        assert sup.stats["replicas_failed"] == 0
        assert not sup.should_stop("late")
        t.start()                       # now it lives...
        time.sleep(0.05)
        assert sup.stats["replicas_failed"] == 0
        go.set()                        # ...and exits silently -> dead
        _wait_for(lambda: sup.stats["replicas_failed"] == 1,
                  msg="death detection after a real start+exit")
    finally:
        sup.stop()


def test_supervisor_hang_detection_respects_progress_watermark():
    q = SampleQueue(capacity=4, max_staleness=99)
    prog = {"v": 0}
    sup = ReplicaSupervisor(q, hang_timeout=0.5, interval=0.02)
    t = threading.Thread(target=lambda: time.sleep(30), daemon=True)
    t.start()
    sup.register("w", thread=t, progress=lambda: prog["v"])
    q.reserve(2)
    sup.claim("w", 2)
    sup.start()
    try:
        # a long-but-ADVANCING rollout is never condemned: the progress
        # watermark refreshes activity even with no explicit heartbeat
        for _ in range(14):
            prog["v"] += 1
            time.sleep(0.05)
        assert sup.stats["replicas_condemned"] == 0
        # freeze the watermark: now it is a hang
        _wait_for(lambda: sup.stats["replicas_condemned"] == 1,
                  msg="hang condemnation")
        assert sup.take_reclaim("other") == 2
        # all replicas condemned -> the queue is failed with a structured
        # error naming the victim (first-error-wins on the consumer side)
        with pytest.raises(SupervisorError, match="dead or condemned"):
            q.pop(0, timeout=5.0)
    finally:
        sup.stop()


def test_supervisor_all_dead_fails_queue_with_statuses():
    q = SampleQueue(capacity=2, max_staleness=99)
    sup = ReplicaSupervisor(q, hang_timeout=5.0, interval=0.02)
    t = threading.Thread(target=lambda: None)
    t.start(), t.join()
    sup.register("solo", thread=t)
    sup.report_failure("solo", InjectedActorDeath("boom"))
    assert sup.all_dead()
    with pytest.raises(SupervisorError) as ei:
        q.pop(0, timeout=5.0)
    err = ei.value
    assert "all fleet replicas" in str(err)
    assert [s.name for s in err.statuses] == ["solo"]
    assert err.statuses[0].dead
    assert "InjectedActorDeath" in err.statuses[0].describe()
    # first error wins: a later poison pill never masks the root cause
    q.fail(RuntimeError("trainer closed"))
    with pytest.raises(SupervisorError):
        q.pop(0, timeout=5.0)


# --------------------------------------- fleet recovery (fake roll, fast)
def test_fleet2_kill_one_token_exact_fake_roll():
    """An injected actor death after fleet1's claim: the supervisor
    reclaims its group, fleet0 re-rolls it off the shared chain, and every
    delivered group matches the chain oracle exactly."""
    k = 4
    plan = FaultPlan([FaultSpec(site="actor", replica="fleet1",
                                exc=InjectedActorDeath)])
    tr = _FakeRollFleet(tiny_cfg(), fleet_cfg(fleet=2, max_staleness=k),
                        chaos=plan)
    try:
        oracle = {i: _fake_tokens(i, tr._key_chain.keys_for(i)[1])
                  for i in range(k)}
        got = _collect(tr, k)
        assert sorted(got) == list(range(k))
        for i in range(k):
            np.testing.assert_array_equal(got[i].batch.tokens, oracle[i])
        stats = tr.publication_stats()
        sup = stats["supervisor"]
        assert sup["replicas_failed"] == 1
        assert sup["groups_reclaimed"] == 1   # death fires after the claim
        assert plan.exhausted()
        assert "fleet1" not in stats["watermarks"]
    finally:
        tr.close()


def test_fleet2_stall_condemned_then_duplicate_dropped():
    """A stalled replica is condemned past hang_timeout, its group is
    re-rolled by the survivor; when the stalled thread wakes its late
    deposit is dropped as a duplicate and its loop exits."""
    k = 4
    plan = FaultPlan([FaultSpec(site="actor", kind="stall", delay=1.5,
                                replica="fleet1")])
    tr = _FakeRollFleet(
        tiny_cfg(), fleet_cfg(fleet=2, max_staleness=k, hang_timeout=0.3),
        chaos=plan)
    try:
        oracle = {i: _fake_tokens(i, tr._key_chain.keys_for(i)[1])
                  for i in range(k)}
        got = _collect(tr, k)
        for i in range(k):
            np.testing.assert_array_equal(got[i].batch.tokens, oracle[i])
        sup = tr.supervisor.stats
        assert sup["replicas_condemned"] == 1
        assert sup["groups_reclaimed"] == 1
        # exactly one of the two deposits for the stalled index survives
        _wait_for(lambda: tr.queue.dropped_dup == 1,
                  msg="late duplicate deposit")
    finally:
        tr.close()


def test_elastic_replacement_after_death():
    """Kill one of two replicas, join a replacement mid-run: the newcomer
    gets the current publication epoch, claims from a clean boundary, and
    the stream stays token-exact throughout."""
    plan = FaultPlan([FaultSpec(site="actor", replica="fleet1",
                                exc=InjectedActorDeath)])
    tr = _FakeRollFleet(tiny_cfg(), fleet_cfg(fleet=2, max_staleness=8),
                        chaos=plan)
    try:
        oracle = {i: _fake_tokens(i, tr._key_chain.keys_for(i)[1])
                  for i in range(8)}
        got = _collect(tr, 3)
        _wait_for(lambda: tr.supervisor.stats["replicas_failed"] == 1,
                  msg="injected death")
        name = tr.add_replica()
        assert name == "fleet2"
        _, epoch = tr.publisher.latest("fleet2")
        assert epoch == tr._learner_version        # current epoch, no wait
        got.update(_collect(tr, 5))     # five MORE groups: 3..7
        assert sorted(got) == list(range(8))
        for i in range(8):
            np.testing.assert_array_equal(got[i].batch.tokens, oracle[i])
        sup = tr.supervisor.stats
        assert sup["joins"] == 1 and sup["replicas_failed"] == 1
        assert set(tr.queue.watermarks) <= {"fleet0", "fleet2"}
    finally:
        tr.close()


def test_quiesce_timeout_names_replica_watermark_heartbeat():
    """A wedged quiesce raises a structured QuiesceTimeout naming each
    replica's state, claimed group, queue watermark, and heartbeat age."""
    plan = FaultPlan([FaultSpec(site="actor", kind="stall", delay=1.5,
                                replica="fleet0")])
    tr = _FakeRollFleet(tiny_cfg(), fleet_cfg(fleet=1, max_staleness=2),
                        chaos=plan)
    try:
        tr._ensure_actor()
        _wait_for(lambda: plan.total_fired() == 1, msg="stall injection")
        with pytest.raises(QuiesceTimeout) as ei:
            tr._quiesce(timeout=0.3)
        msg = str(ei.value)
        assert "fleet0" in msg
        assert "claimed=" in msg and "watermark=" in msg
        assert "heartbeat_age=" in msg and "state=alive" in msg
        tr._resume_admission()
    finally:
        tr.close()


def test_quiesce_all_dead_raises_supervisor_error():
    plan = FaultPlan([FaultSpec(site="actor", exc=InjectedActorDeath)])
    tr = _FakeRollFleet(tiny_cfg(), fleet_cfg(fleet=1, max_staleness=2),
                        chaos=plan)
    try:
        tr._ensure_actor()
        _wait_for(lambda: tr.supervisor.all_dead(), msg="sole replica death")
        with pytest.raises(SupervisorError, match="dead or condemned") as ei:
            tr._quiesce(timeout=5.0)
        assert ei.value.statuses and ei.value.statuses[0].dead
    finally:
        tr.close()


# --------------------------------------------- property: random schedules
K_PROP = 4


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6), fleet=st.integers(2, 3))
def test_chaos_property_random_schedules(seed, fleet):
    """Any seeded FaultPlan over N replicas (kills, stalls, put-failures):
    the run either delivers a token-exact serial prefix of groups or
    raises a clean structured SupervisorError — never a deadlock, never a
    lost or double-consumed group."""
    replicas = [f"fleet{i}" for i in range(fleet)]
    plan = FaultPlan.random(seed, replicas=replicas, max_index=K_PROP,
                            max_faults=3, stall_delay=0.8)
    tr = _FakeRollFleet(
        tiny_cfg(), fleet_cfg(fleet=fleet, max_staleness=K_PROP,
                              hang_timeout=0.3),
        chaos=plan)
    got, err = {}, None
    try:
        oracle = {i: _fake_tokens(i, tr._key_chain.keys_for(i)[1])
                  for i in range(K_PROP)}
        tr._ensure_actor()
        try:
            while len(got) < K_PROP:
                # a timeout here IS the deadlock the supervision layer
                # promises cannot happen — fail loudly, not silently
                g = tr.queue.pop(0, timeout=30.0)
                assert g.index not in got, "group double-served"
                got[g.index] = g
        except SupervisorError as e:
            err = e
    finally:
        tr.close()
    # delivered groups form a gapless serial prefix, each token-exact
    assert sorted(got) == list(range(len(got)))
    for i, g in got.items():
        np.testing.assert_array_equal(g.batch.tokens, oracle[i])
    if err is not None:
        assert err.statuses, "SupervisorError must carry replica statuses"
        assert all(s.dead or s.condemned for s in err.statuses)
    else:
        assert len(got) == K_PROP


# ----------------------------------------- real engines (slow, CI chaos lane)
@pytest.mark.slow
def test_fleet2_kill_one_replica_token_exact_vs_oracle():
    """THE recovery gate: a fleet of 2 with fleet1 killed by an injected
    death produces the same per-group rollouts as the no-fault fleet of 2
    — recovery is invisible in the sample stream."""
    cfg, k = tiny_cfg(), 3

    def collect(chaos):
        tr = DistNATGRPOTrainer(
            cfg, fleet_cfg(fleet=2, max_staleness=k, hang_timeout=300.0),
            chaos=chaos)
        got = {}
        try:
            tr._ensure_actor()
            while len(got) < k:
                g = tr.queue.pop(0, timeout=120.0)
                got[g.index] = g
            stats = tr.publication_stats()
        finally:
            tr.close()
        return got, stats

    oracle, _ = collect(None)
    plan = FaultPlan([FaultSpec(site="actor", replica="fleet1",
                                exc=InjectedActorDeath)])
    got, stats = collect(plan)
    assert set(got) == set(oracle) == set(range(k))
    for i in range(k):
        np.testing.assert_array_equal(got[i].batch.tokens,
                                      oracle[i].batch.tokens)
        np.testing.assert_array_equal(got[i].batch.response_lens,
                                      oracle[i].batch.response_lens)
        np.testing.assert_array_equal(np.asarray(got[i].key_sel),
                                      np.asarray(oracle[i].key_sel))
        assert got[i].behavior_version == 0
    sup = stats["supervisor"]
    assert sup["replicas_failed"] == 1 and sup["groups_reclaimed"] == 1
    assert plan.exhausted()
    assert "fleet1" not in stats["watermarks"]


@pytest.mark.slow
def test_placement_retry_under_pool_pressure():
    """Transient PagePoolExhausted at engine drive is retried on a fresh
    per-group session (bounded) instead of killing the replica."""
    from repro.rl.engine import PagePoolExhausted

    plan = FaultPlan([FaultSpec(site="placement", exc=PagePoolExhausted,
                                times=2)])
    tr = DistNATGRPOTrainer(
        tiny_cfg(),
        fleet_cfg(fleet=1, max_staleness=1, rollout_engine="paged",
                  hang_timeout=300.0, placement_retries=3,
                  placement_backoff=0.01),
        chaos=plan)
    try:
        tr._ensure_actor()
        g = tr.queue.pop(0, timeout=180.0)
        assert g.index == 0
        stats = tr.publication_stats()
        assert stats["placement_retries"] == 2
        assert stats["supervisor"]["replicas_failed"] == 0
        assert plan.exhausted()
    finally:
        tr.close()
