"""Shared fixtures.  NOTE: no xla_force_host_platform_device_count here —
tests and benches must see the real single CPU device; only the dry-run
(launch/dryrun.py) overrides the device count, in its own process."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
