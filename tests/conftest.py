"""Shared fixtures.  NOTE: no xla_force_host_platform_device_count here —
the suite must pass at whatever device count the environment provides:
the real single CPU device locally, and the 8 virtual devices CI forces
(.github/workflows/ci.yml) to exercise multi-device sharding paths.  Only
the dry-run (launch/dryrun.py) forces a count itself, in its own process;
tests must not depend on jax.device_count() being 1."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
