"""Paged KV arena: greedy parity with the legacy scan AND the dense slot
arena, group-level prompt-prefix sharing, page lifecycle (refcount drop on
retire/cancel -> free list), gather isolation, allocator exhaustion, and
the learner-batch contract on the paged path (DESIGN.md §8)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    ContinuousRolloutEngine,
    EngineConfig,
    NATGRPOTrainer,
    NATTrainerConfig,
    PageAllocator,
    PagedEngineConfig,
    PagedRolloutEngine,
    PagePoolExhausted,
    Request,
    RolloutConfig,
    VOCAB_SIZE,
)
from repro.rl.rollout import generate, rollout_group_continuous


def tiny_cfg():
    return ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                       blocks=dense_blocks(2), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    rng = np.random.default_rng(0)
    prompts = rng.integers(3, VOCAB_SIZE, size=(5, 10)).astype(np.int32)
    plens = np.full((5,), 10, np.int32)
    return cfg, params, prompts, plens, key


# ------------------------------------------------------------ allocator unit
def test_page_allocator_refcounts_and_free_list():
    a = PageAllocator(6)
    p1 = a.alloc(2)
    assert a.in_use == 2 and a.num_free == 4
    a.retain(p1)           # second sibling holds the prompt pages
    a.retain(p1)           # third
    assert a.release(p1) == []          # 2 refs left: nothing freed
    assert a.release(p1) == []          # 1 ref left
    assert sorted(a.release(p1)) == sorted(p1)  # last ref: back to free list
    assert a.in_use == 0 and a.num_free == 6
    d = a.alloc(1)
    assert a.release(d) == d            # refcount-1 decode page frees at once
    assert a.peak_in_use == 2           # max concurrent in_use ever observed


def test_page_allocator_exhaustion_raises():
    a = PageAllocator(2)
    a.alloc(2)
    with pytest.raises(PagePoolExhausted, match="2/2 pages in use"):
        a.alloc(1)


# ------------------------------------------------------------- greedy parity
def test_greedy_parity_with_legacy_and_dense(setup):
    """Acceptance gate: the paged engine reproduces legacy dense-arena
    completions token-exactly under greedy decoding, with recycling (fewer
    slots than requests) and a partial last prompt page (10 % 4 != 0)."""
    cfg, params, prompts, plens, key = setup
    n = 8
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    full, logps, ents, _, _ = generate(
        params, cfg, rcfg, jnp.asarray(prompts), jnp.asarray(plens), key)
    full, logps, ents = map(np.asarray, (full, logps, ents))

    dense = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=3, refill_lanes=1))
    paged = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=3, page_len=4,
        max_group=2))
    reqs = [Request(uid=i, tokens=prompts[i], budget=n) for i in range(5)]
    comps_d = {c.uid: c for c in dense.run(params, reqs, key)}
    comps_p = {c.uid: c for c in paged.run(params, reqs, key)}
    assert len(comps_p) == 5
    tp = prompts.shape[1]
    for i in range(5):
        c = comps_p[i]
        rl = c.response_len
        np.testing.assert_array_equal(c.tokens, full[i, tp:tp + rl])
        np.testing.assert_allclose(c.logp, logps[i, :rl], atol=1e-5)
        np.testing.assert_allclose(c.entropy, ents[i, :rl], atol=1e-5)
        np.testing.assert_array_equal(c.tokens, comps_d[i].tokens)
    # every page returned to the free list once the session drained
    assert paged._alloc.in_use == 0


def test_group_prefix_sharing_prefills_once(setup):
    """One prompt prefill per group; under greedy every sibling reproduces
    the legacy completion; prompt pages are shared (peak pages well under
    the dense-equivalent private-prompt budget)."""
    cfg, params, prompts, plens, key = setup
    n, g = 8, 4
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    full, logps, _, _, _ = generate(
        params, cfg, rcfg, jnp.asarray(prompts[:2]),
        jnp.asarray(plens[:2]), key)
    full, logps = np.asarray(full), np.asarray(logps)

    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=2 * g, max_prompt_len=10, steps_per_sync=2, page_len=4,
        max_group=g, group_lanes=2))
    eng.begin(params, key)
    for pi in range(2):
        eng.submit_group([Request(uid=pi * g + j, tokens=prompts[pi],
                                  budget=n) for j in range(g)])
    comps = {c.uid: c for c in eng.drain()}
    assert len(comps) == 2 * g
    tp = prompts.shape[1]
    for pi in range(2):
        for j in range(g):
            c = comps[pi * g + j]
            np.testing.assert_array_equal(
                c.tokens, full[pi, tp:tp + c.response_len])
            np.testing.assert_allclose(c.logp, logps[pi, :c.response_len],
                                       atol=1e-5)
    st = eng.stats
    assert st["prompt_prefills"] == 2          # one prefill per group
    # prompt pages per group: ceil(10/4) = 3, counted ONCE per group;
    # decode pages: ceil(8/4) = 2 per sibling
    assert st["peak_pages_in_use"] <= 2 * (3 + g * 2)
    # dense-equivalent (private prompts) would hold 2 * g * (3 + 2) pages
    assert st["peak_pages_in_use"] < 2 * g * (3 + 2)


def test_parked_siblings_resume_without_reprefill(setup):
    """A group wider than the arena: siblings beyond the free slots park
    and later RESUME into freed slots from the shared prompt pages + saved
    prompt logits — still exactly one prefill, still legacy-exact greedy
    completions (group width never serializes the arena)."""
    cfg, params, prompts, plens, key = setup
    n, g = 8, 4
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    full, logps, _, _, _ = generate(
        params, cfg, rcfg, jnp.asarray(prompts[:1]), jnp.asarray(plens[:1]),
        key)
    full, logps = np.asarray(full), np.asarray(logps)

    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=3, page_len=4,
        max_group=g))
    eng.begin(params, key)
    eng.submit_group([Request(uid=j, tokens=prompts[0], budget=n)
                      for j in range(g)])
    assert not eng.idle
    comps = {c.uid: c for c in eng.drain()}
    assert len(comps) == g and eng.idle
    tp = prompts.shape[1]
    for j in range(g):
        c = comps[j]
        np.testing.assert_array_equal(c.tokens, full[0, tp:tp + c.response_len])
        np.testing.assert_allclose(c.logp, logps[0, :c.response_len],
                                   atol=1e-5)
    assert eng.stats["prompt_prefills"] == 1  # parked siblings never re-prefill
    assert eng._alloc.in_use == 0


def test_stateful_mixer_places_atomically(setup):
    """Per-slot-state mixers (local rings here) run the paged arena with
    atomic group placement — non-attention states broadcast to sibling
    slots on device — and reproduce the legacy scan under greedy; the
    default num_slots in rollout_group_continuous covers one G' group."""
    _, _, prompts, plens, key = setup
    local_cfg = ModelConfig(name="tiny-local", d_model=64, n_heads=4,
                            n_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=VOCAB_SIZE, window=8,
                            blocks=dense_blocks(2, mixer="local"),
                            seq_parallel=False, remat_policy="none",
                            scan_layers=False)
    params = init_params(jax.random.PRNGKey(1), model_decl(local_cfg))
    n, g = 6, 2
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    full, logps, _, _, _ = generate(
        params, local_cfg, rcfg, jnp.asarray(prompts[:2]),
        jnp.asarray(plens[:2]), key)
    full, logps = np.asarray(full), np.asarray(logps)

    eng = PagedRolloutEngine(local_cfg, rcfg, PagedEngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=2, page_len=4,
        max_group=g))
    assert not eng._pure_pool
    groups = [[Request(uid=pi * g + j, tokens=prompts[pi], budget=n)
               for j in range(g)] for pi in range(2)]
    comps = {c.uid: c for c in eng.run_groups(params, groups, key)}
    assert len(comps) == 2 * g
    tp = prompts.shape[1]
    for pi in range(2):
        for j in range(g):
            c = comps[pi * g + j]
            np.testing.assert_array_equal(
                c.tokens, full[pi, tp:tp + c.response_len])
            np.testing.assert_allclose(c.logp, logps[pi, :c.response_len],
                                       atol=1e-5)
    # overprovisioned default sizing must not under-provision max_group
    rcfg2 = RolloutConfig(max_new_tokens=4, group_size=2, overprovision=1.5)
    rb = rollout_group_continuous(params, local_cfg, rcfg2, prompts[:1],
                                  plens[:1], key, steps_per_sync=2,
                                  paged=True, page_len=4)
    assert rb.tokens.shape[0] == 2  # G kept rows from a G'=3 group


# ------------------------------------------------------------ page lifecycle
def test_retire_returns_pages_and_recycles(setup):
    """Refcount drop on retirement returns pages to the free list, and a
    recycled page serves a later request without leaking its previous
    occupant (the arena is sized so reuse is forced)."""
    cfg, params, prompts, plens, key = setup
    n = 8
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    full, _, _, _, _ = generate(
        params, cfg, rcfg, jnp.asarray(prompts), jnp.asarray(plens), key)
    full = np.asarray(full)
    # 5 sequential requests, pool sized for ~one request: ceil(10/4) +
    # ceil(8/4) = 5 pages needed per request; give it 6 so every
    # placement must recycle freed pages
    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=1, max_prompt_len=10, steps_per_sync=4, page_len=4,
        num_pages=6, max_group=1))
    reqs = [Request(uid=i, tokens=prompts[i], budget=n) for i in range(5)]
    comps = {c.uid: c for c in eng.run(params, reqs, key)}
    tp = prompts.shape[1]
    for i in range(5):
        np.testing.assert_array_equal(
            comps[i].tokens, full[i, tp:tp + comps[i].response_len])
    assert eng._alloc.in_use == 0
    assert eng._alloc.peak_in_use <= 6


def test_cancel_frees_pages_immediately(setup):
    """APRIL cancellation: the straggler's pages return to the free list in
    the same round the host learns of the cancellation."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=32, temperature=1.0, eos_id=-1)
    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=2, page_len=4,
        max_group=1))
    reqs = [Request(uid=0, tokens=prompts[0], budget=2),
            Request(uid=1, tokens=prompts[1], budget=32),
            Request(uid=2, tokens=prompts[2], budget=32)]

    def on_finish(c):
        return [1, 2] if c.uid == 0 else None

    comps = {c.uid: c for c in eng.run(params, reqs, key, on_finish=on_finish)}
    assert comps[1].cancelled and comps[1].response_len < 32
    assert comps[2].cancelled and comps[2].response_len == 0  # never placed
    assert eng.stats["cancelled"] == 2
    assert eng.stats["decode_steps"] < 32
    assert eng._alloc.in_use == 0  # cancellation released everything


def test_deferred_group_cancellation_emits_once(setup):
    """A cancelled sibling of a group stuck at the queue head (waiting on
    pages/slots) must emit exactly ONE Completion, however many rounds the
    group waits before placing."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=8, temperature=1.0, eos_id=-1)
    # pool sized so group B cannot place while group A decodes: A needs
    # 3 prompt + up to 2 decode pages of the 7-page pool, leaving < the
    # 3 + 1 pages B's placement needs
    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=2, page_len=4,
        num_pages=7, max_group=1))
    eng.begin(params, key)
    eng.submit_group([Request(uid=0, tokens=prompts[0], budget=8)])
    eng.submit_group([Request(uid=1, tokens=prompts[1], budget=8)])
    eng.drive()               # A places; B waits on pages
    eng.cancel([1])
    comps = eng.drain()
    assert sorted(c.uid for c in comps) == [0, 1]  # exactly one each
    by_uid = {c.uid: c for c in comps}
    assert by_uid[1].cancelled and by_uid[1].response_len == 0
    assert eng.stats["cancelled"] == 1


def test_gather_isolation_across_groups(setup):
    """No slot can read another group's decode pages: per-slot decode pages
    are disjoint, prompt pages are shared only within a group, and zeroing
    every page OUTSIDE one slot's block table leaves its next-token logits
    untouched."""
    cfg, params, prompts, plens, key = setup
    n = 8
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=4, max_prompt_len=10, steps_per_sync=2, page_len=4,
        max_group=2, group_lanes=2))
    eng.begin(params, key)
    eng.submit_group([Request(uid=j, tokens=prompts[0], budget=n)
                      for j in range(2)])
    eng.submit_group([Request(uid=2 + j, tokens=prompts[1], budget=n)
                      for j in range(2)])
    eng.drive()
    eng.drive()
    # host invariants: decode pages pairwise disjoint; prompt pages shared
    # within a group, disjoint across groups
    dec = [set(eng._slot_decode_pages[s]) for s in range(4)]
    for a in range(4):
        for b in range(a + 1, 4):
            assert not dec[a] & dec[b], (a, b)
    pp = [tuple(eng._slot_prompt_pages[s]) for s in range(4)]
    assert pp[0] == pp[1] and pp[2] == pp[3] and set(pp[0]).isdisjoint(pp[2])

    # device invariant: pages outside slot 0's table are invisible to it
    state = eng._state
    bt = np.full((4, eng._max_pages), -1, np.int32)
    for s in range(4):
        n_pp_s = -(-int(eng._slot_plen[s]) // 4)
        bt[s, :n_pp_s] = eng._slot_prompt_pages[s]
        dp = eng._slot_decode_pages[s]
        bt[s, n_pp_s:n_pp_s + len(dp)] = dp
    owned = {p for p in bt[0] if p >= 0}

    def poison(leaf):
        if leaf.ndim >= 3 and leaf.shape[1] == eng.num_pages:
            mask = np.ones((eng.num_pages,), bool)
            mask[sorted(owned)] = False
            shape = (1, eng.num_pages) + (1,) * (leaf.ndim - 2)
            return jnp.where(jnp.asarray(mask).reshape(shape), 0, leaf)
        return leaf

    from repro.models.model import decode_step
    poisoned = jax.tree.map(poison, state["cache"])
    tok = jnp.argmax(state["logits"], axis=-1).astype(jnp.int32)
    wp = jnp.full((4,), eng.num_pages, jnp.int32)  # read-only probe
    wo = jnp.zeros((4,), jnp.int32)
    logits_a, _ = decode_step(params, cfg, tok, state["cache"], state["pos"],
                              block_tables=jnp.asarray(bt), write_page=wp,
                              write_off=wo)
    logits_b, _ = decode_step(params, cfg, tok, poisoned, state["pos"],
                              block_tables=jnp.asarray(bt), write_page=wp,
                              write_off=wo)
    np.testing.assert_array_equal(np.asarray(logits_a)[0],
                                  np.asarray(logits_b)[0])


def test_allocator_exhaustion_surfaces_clearly(setup):
    """An undersized pool raises PagePoolExhausted (with occupancy in the
    message) instead of silently corrupting the arena: two long-budget
    slots outgrow a pool sized for their placement but not their decode."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=32, temperature=1.0, eos_id=-1)
    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=4, page_len=4,
        num_pages=11, max_group=1))
    # each slot: 3 prompt pages + up to ceil(32/4)=8 decode pages; two
    # slots can place (3+1 + 3+1 = 8 <= 11) but cannot both run to budget
    reqs = [Request(uid=i, tokens=prompts[i], budget=32) for i in range(2)]
    with pytest.raises(PagePoolExhausted, match="pages in use"):
        eng.run(params, reqs, key)
    # a group that can NEVER fit is rejected at submit time
    eng2 = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=4, page_len=4,
        num_pages=8, max_group=2))
    eng2.begin(params, key)
    with pytest.raises(PagePoolExhausted, match="grow PagedEngineConfig"):
        eng2.submit_group([Request(uid=i, tokens=prompts[0], budget=32)
                           for i in range(2)])


def test_submit_group_validates_siblings(setup):
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=8, temperature=1.0, eos_id=-1)
    eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
        num_slots=4, max_prompt_len=10, page_len=4, max_group=2))
    eng.begin(params, key)
    with pytest.raises(ValueError, match="share one prompt"):
        eng.submit_group([Request(uid=0, tokens=prompts[0]),
                          Request(uid=1, tokens=prompts[1])])
    with pytest.raises(ValueError, match="max_group"):
        eng.submit_group([Request(uid=i, tokens=prompts[0])
                          for i in range(3)])
    # per-slot-state mixers (here: local rings) cannot park siblings, so
    # their groups must fit the arena atomically
    local_cfg = ModelConfig(name="tiny-local", d_model=64, n_heads=4,
                            n_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=VOCAB_SIZE, window=8,
                            blocks=dense_blocks(2, mixer="local"),
                            seq_parallel=False, remat_policy="none",
                            scan_layers=False)
    with pytest.raises(ValueError, match="max_group cannot exceed"):
        PagedRolloutEngine(local_cfg, rcfg, PagedEngineConfig(
            num_slots=2, max_prompt_len=10, max_group=4))


def test_kernel_impl_matches_ref(setup):
    """attn_impl='kernel' (Pallas block-table gather) reproduces the jnp
    gather path: greedy tokens exact; logp within the cross-structure
    reassociation tolerance (cf. the teacher-forced parity note)."""
    cfg, params, prompts, plens, key = setup
    n = 6
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    outs = {}
    for impl in ("ref", "kernel"):
        eng = PagedRolloutEngine(cfg, rcfg, PagedEngineConfig(
            num_slots=2, max_prompt_len=10, steps_per_sync=2, page_len=5,
            max_group=2, attn_impl=impl))
        eng.begin(params, key)
        eng.submit_group([Request(uid=j, tokens=prompts[0], budget=n)
                          for j in range(2)])
        outs[impl] = {c.uid: c for c in eng.drain()}
    for uid, c in outs["ref"].items():
        np.testing.assert_array_equal(c.tokens, outs["kernel"][uid].tokens)
        np.testing.assert_allclose(c.logp, outs["kernel"][uid].logp,
                                   atol=2e-2)


# --------------------------------------------------------- learner contract
def test_rollout_group_continuous_paged_contract(setup):
    """rollout_group_continuous(paged=True) produces the same learner-batch
    contract as the dense path, with group prefills counted."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=8, group_size=4, overprovision=1.5)
    rb = rollout_group_continuous(params, cfg, rcfg, prompts[:3], plens[:3],
                                  key, num_slots=6, steps_per_sync=2,
                                  paged=True, page_len=4)
    b = 3 * 4
    assert rb.tokens.shape == (b, 10 + 8)
    for i in range(b):
        pl, rl = int(rb.prompt_lens[i]), int(rb.response_lens[i])
        row = rb.response_mask[i]
        assert row[:pl].sum() == 0
        assert row[pl:pl + rl].sum() == rl
        assert np.all(rb.old_logp[i][row == 0] == 0)
    st = rb.stats
    assert st["tokens_budget"] == 3 * 6 * 8
    assert 0 < st["tokens_generated"] <= st["tokens_budget"]
    assert st["prompt_prefills"] == 3  # one per prompt, not per sibling


def test_trainer_paged_rollout_metrics():
    """End-to-end: NATGRPOTrainer on rollout_engine='paged' trains and
    surfaces the rollout token accounting."""
    cfg = tiny_cfg()
    tc = NATTrainerConfig(
        selector="rpc", selector_kwargs=(("min_cut", 4),),
        prompts_per_step=2, max_prompt_len=16,
        rollout=RolloutConfig(max_new_tokens=8, group_size=4,
                              overprovision=1.5),
        rollout_engine="paged", page_len=8, steps_per_sync=2,
        adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
        bucket_align=8, seed=0)
    tr = NATGRPOTrainer(cfg, tc)
    m = tr.train_step()
    assert np.isfinite(m["loss"])
    assert m["tokens_budget"] == 2 * 6 * 8
    assert 0 < m["tokens_generated"] <= m["tokens_budget"]


# ------------------------------------------- allocator property tests
# (hypothesis when installed; deterministic seeded fallback otherwise)
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st


def _check_partition(a):
    """The free list and the live refcounts partition the pool exactly:
    every page is either on the free list (refcount 0) or live
    (refcount > 0), never both, never neither, never twice."""
    free = a._free
    assert len(free) == len(set(free)), "free list holds a page twice"
    live = set(np.flatnonzero(a.refcount > 0).tolist())
    assert live.isdisjoint(free), "page simultaneously free and live"
    assert len(live) + len(free) == a.num_pages, "pages leaked"
    assert a.in_use == len(live)
    assert np.all(a.refcount >= 0)


@settings(max_examples=60)
@given(st.integers(min_value=2, max_value=12),
       st.lists(st.integers(min_value=0, max_value=10 ** 6),
                min_size=1, max_size=80))
def test_page_allocator_interleavings_never_double_free_or_leak(
        num_pages, ops):
    """Arbitrary alloc/retain/release interleavings keep the free list +
    refcounts an exact partition of the pool (the invariant that makes
    retire a free-list push and cancellation safe mid-group)."""
    a = PageAllocator(num_pages)
    handles = []          # (pages, model_refs) for every live allocation
    for op in ops:
        kind = op % 3
        if kind == 0:                       # alloc 1..3 pages
            n = 1 + (op // 3) % 3
            if n > a.num_free:
                with pytest.raises(PagePoolExhausted):
                    a.alloc(n)
            else:
                pages = a.alloc(n)
                assert len(pages) == n
                assert all(a.refcount[p] == 1 for p in pages)
                handles.append([pages, 1])
        elif kind == 1 and handles:         # retain (another sibling)
            h = handles[(op // 3) % len(handles)]
            a.retain(h[0])
            h[1] += 1
        elif kind == 2 and handles:         # release one reference
            i = (op // 3) % len(handles)
            h = handles[i]
            freed = a.release(h[0])
            h[1] -= 1
            # pages free exactly when the LAST reference drops
            if h[1] == 0:
                assert sorted(freed) == sorted(h[0])
                handles.pop(i)
            else:
                assert freed == []
        _check_partition(a)
    # drain: dropping every remaining reference returns the whole pool
    for pages, refs in handles:
        for _ in range(refs):
            a.release(pages)
    _check_partition(a)
    assert a.in_use == 0 and a.num_free == num_pages
    assert np.all(a.refcount == 0)


@settings(max_examples=40)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=8))
def test_page_allocator_exhaustion_reports_exact_occupancy(pool, held):
    """PagePoolExhausted names the exact in-use/free occupancy at the
    moment of failure — the numbers operators size num_pages from."""
    held = min(held, pool)
    a = PageAllocator(pool)
    if held:
        a.alloc(held)
    want = a.num_free + 1               # always one more than is free
    with pytest.raises(
            PagePoolExhausted,
            match=rf"allocating {want} page\(s\): {held}/{pool} pages "
                  rf"in use \({pool - held} free\)"):
        a.alloc(want)
    # a failed alloc must not perturb the pool
    _check_partition(a)
    assert a.in_use == held
