"""Per-assigned-architecture smoke: instantiate the REDUCED config of each
family, run one forward and one NAT-GRPO train step on CPU, assert output
shapes and finiteness.  (The FULL configs are exercised via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_smoke, shapes_for
from repro.core.grpo import GRPOConfig
from repro.models import forward_hidden, init_params, model_decl
from repro.optim import AdamWConfig, init_opt_state
from repro.rl.learner import make_train_step

# the full model-zoo sweep is breadth coverage, not a fast-tier gate:
# CI's jax matrix skips it (-m 'not slow'); a non-blocking job runs it
pytestmark = pytest.mark.slow

B, T = 2, 32


def _inputs(cfg, key):
    shape = (B, T, cfg.num_codebooks) if cfg.num_codebooks else (B, T)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    img = (jax.random.normal(key, (B, cfg.num_image_tokens, cfg.d_model),
                             jnp.bfloat16) if cfg.num_image_tokens else None)
    return toks, img


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    toks, img = _inputs(cfg, key)
    h, _, aux = forward_hidden(params, cfg, toks, image_embeds=img)
    assert h.shape == (B, T, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32))), arch
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, model_decl(cfg))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, GRPOConfig(), opt_cfg, vocab_chunks=1))
    toks, img = _inputs(cfg, key)
    rm = np.zeros((B, T), np.float32)
    rm[:, 4:28] = 1.0
    batch = {
        "tokens": toks,
        "response_mask": jnp.asarray(rm),
        "old_logp": -jnp.abs(jax.random.normal(key, (B, T))) * jnp.asarray(rm),
        "advantages": jnp.array([1.0, -1.0]),
        "ht_weights": jnp.asarray(rm) * 2.0,
        "orig_lengths": jnp.asarray(rm.sum(-1)),
        "lengths": jnp.full((B,), T, jnp.int32),
    }
    if img is not None:
        batch["image_embeds"] = img
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert float(metrics["grad_norm"]) > 0, arch
    # params actually changed
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                                        - b.astype(jnp.float32)))),
                     params, new_params))
    assert moved > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_decl_only(arch):
    """Full configs build their declaration tree (no allocation) and expose
    the assigned dims."""
    cfg = get_config(arch)
    decl = model_decl(cfg)
    assert decl is not None
    shapes = [s.name for s in shapes_for(cfg)]
    assert "train_4k" in shapes and "decode_32k" in shapes
    if arch in ("h2o-danube-3-4b", "gemma3-27b", "recurrentgemma-9b",
                "mamba2-130m"):
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes
