"""Logical-axis sharding resolution: best-effort divisibility, axis-conflict
handling, mesh-absence handling (property-based)."""
import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover — CI installs hypothesis
    from hypothesis_fallback import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    DEFAULT_RULES,
    RULE_PROFILES,
    ShardingRules,
    best_effort_spec,
    is_axes_tuple,
    logical_to_sharding,
    shard_constraint,
    tree_shardings,
)


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


class FakeMesh:
    """Shape-only stand-in so properties can exercise many mesh shapes
    without building device meshes."""

    def __init__(self, shape: dict):
        self.shape = shape


def test_basic_resolution():
    m = FakeMesh({"data": 16, "model": 16})
    spec = best_effort_spec((128, 256), ("embed", "heads"), m)
    assert spec == P("data", "model")


def test_indivisible_dropped():
    m = FakeMesh({"data": 16, "model": 16})
    # 8 kv heads cannot split 16 ways -> replicated
    spec = best_effort_spec((1024, 8, 128), ("embed", "kv_heads", "head_dim"), m)
    assert spec == P("data", None, None)


def test_tuple_rule_prefix():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # batch -> ("pod", "data"): 4 rows divide pod(2) and pod*data(32)? 4 % 32
    # != 0, so only the "pod" prefix applies
    spec = best_effort_spec((4, 64), ("batch", None), m)
    assert spec == P("pod", None)
    spec = best_effort_spec((64, 64), ("batch", None), m)
    assert spec == P(("pod", "data"), None)


def test_absent_axis_dropped():
    m = FakeMesh({"data": 16, "model": 16})  # no "pod"
    spec = best_effort_spec((64,), ("batch",), m)
    assert spec == P(("data",)) or spec == P("data")


def test_axis_used_once():
    m = FakeMesh({"data": 4, "model": 4})
    # two dims both wanting "model": only the first gets it
    rules = ShardingRules(rules=(("a", "model"), ("b", "model")))
    spec = best_effort_spec((8, 8), ("a", "b"), m, rules)
    assert spec == P("model", None)


@settings(max_examples=100, deadline=None)
@given(
    dims=st.lists(st.integers(1, 512), min_size=1, max_size=4),
    data=st.sampled_from([1, 2, 4, 8, 16]),
    model=st.sampled_from([1, 2, 4, 8, 16]),
)
def test_always_valid_spec(dims, data, model):
    """Resolved spec always divides: product of assigned axis sizes divides
    the dim — for any shape and any mesh."""
    m = FakeMesh({"data": data, "model": model})
    names = ["embed", "heads", "vocab", "mlp"][: len(dims)]
    spec = best_effort_spec(tuple(dims), tuple(names), m)
    for dim, entry in zip(dims, tuple(spec)):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        prod = int(np.prod([m.shape[a] for a in axes]))
        assert dim % prod == 0


def test_override():
    r = DEFAULT_RULES.override(kv_seq=("data", "model"))
    assert r.get("kv_seq") == ("data", "model")
    assert r.get("heads") == DEFAULT_RULES.get("heads")
    r2 = DEFAULT_RULES.override(brand_new="model")
    assert r2.get("brand_new") == "model"


def test_rule_profiles_membership():
    for name in ("default", "fsdp", "tensor_parallel", "sequence_parallel",
                 "small_model"):
        assert name in RULE_PROFILES, name
        assert isinstance(RULE_PROFILES[name], ShardingRules)
    assert RULE_PROFILES["default"] is DEFAULT_RULES
    # small_model = replicated weights, full DP
    assert RULE_PROFILES["small_model"].get("embed") is None
    assert "model" in RULE_PROFILES["small_model"].get("batch")


def test_logical_to_sharding_no_mesh():
    # mesh=None -> None (jit treats unspecified as replicated); CPU paths
    # use the exact production code with no special-casing
    assert logical_to_sharding((8, 16), ("batch", "embed"), None) is None


def test_logical_to_sharding_real_mesh(mesh):
    sh = logical_to_sharding((8, 16), ("batch", None), mesh)
    assert isinstance(sh, jax.sharding.NamedSharding)
    assert sh.spec == P("data", None)
    scalar = logical_to_sharding((), (), mesh)
    assert scalar.spec == P()


def test_shard_constraint_noop_without_mesh():
    import jax.numpy as jnp

    x = jnp.arange(12.0).reshape(3, 4)
    assert shard_constraint(x, ("batch", "embed")) is x


def test_tree_shardings_and_leaf_predicate(mesh):
    import jax.numpy as jnp

    abs_tree = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    axes = {"w": ("embed", "mlp"), "b": (None,)}
    sh = tree_shardings(abs_tree, axes, mesh)
    assert set(sh) == {"w", "b"}
    assert all(isinstance(s, jax.sharding.NamedSharding) for s in sh.values())
    assert is_axes_tuple(("embed", None)) and is_axes_tuple(())
    assert not is_axes_tuple((1, 2))
