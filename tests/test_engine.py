"""Continuous-batching engine: slot-recycling invariants, logprob parity
with the legacy rollout path, per-request budgets, quota cancellation, and
the learner-batch contract (DESIGN.md §3)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import (
    init_params, invalidate_cache_rows, merge_cache, model_decl, prefill,
)
from repro.models.config import ModelConfig, dense_blocks
from repro.models.model import score_tokens
from repro.optim import AdamWConfig
from repro.rl import (
    ContinuousRolloutEngine,
    EngineConfig,
    NATGRPOTrainer,
    NATTrainerConfig,
    Request,
    RolloutConfig,
    VOCAB_SIZE,
)
from repro.rl.rollout import generate, rollout_group_continuous


def tiny_cfg():
    return ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                       blocks=dense_blocks(2), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    rng = np.random.default_rng(0)
    prompts = rng.integers(3, VOCAB_SIZE, size=(5, 10)).astype(np.int32)
    plens = np.full((5,), 10, np.int32)
    return cfg, params, prompts, plens, key


def test_greedy_parity_with_legacy(setup):
    """Token-for-token and logprob parity: the slot arena (with recycling —
    fewer slots than requests) must reproduce the legacy scan exactly under
    greedy decoding."""
    cfg, params, prompts, plens, key = setup
    n = 8
    rcfg = RolloutConfig(max_new_tokens=n, temperature=0.0, eos_id=-1)
    full, logps, ents, _, _ = generate(
        params, cfg, rcfg, jnp.asarray(prompts), jnp.asarray(plens), key)
    full, logps, ents = map(np.asarray, (full, logps, ents))

    eng = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=3, refill_lanes=1))
    reqs = [Request(uid=i, tokens=prompts[i], budget=n) for i in range(5)]
    comps = eng.run(params, reqs, key)
    assert len(comps) == 5
    for i, c in enumerate(comps):
        rl = c.response_len
        tp = prompts.shape[1]
        np.testing.assert_array_equal(c.tokens, full[i, tp:tp + rl])
        np.testing.assert_allclose(c.logp, logps[i, :rl], atol=1e-5)
        np.testing.assert_allclose(c.entropy, ents[i, :rl], atol=1e-5)


def test_teacher_forced_logprob_parity(setup):
    """Behaviour logprobs collected in-flight must match the learner's
    teacher-forced scoring path (score_tokens) on the same tokens.

    Tolerance note: incremental KV decode and the full-sequence forward
    accumulate in different orders, so f32 logprobs differ at the ~1e-2
    level on this model — the legacy scan shows the same gap vs
    score_tokens.  Exact token-for-token parity engine-vs-legacy is covered
    by test_greedy_parity_with_legacy (atol 1e-5)."""
    cfg, params, prompts, plens, key = setup
    n = 8
    rcfg = RolloutConfig(max_new_tokens=n, temperature=1.0, eos_id=-1)
    eng = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
        num_slots=3, max_prompt_len=10, steps_per_sync=4))
    reqs = [Request(uid=i, tokens=prompts[i], budget=n) for i in range(5)]
    comps = eng.run(params, reqs, key)

    tp = prompts.shape[1]
    grid = np.full((5, tp + n), 0, np.int32)
    for i, c in enumerate(comps):
        grid[i, :tp] = prompts[i]
        grid[i, tp:tp + c.response_len] = c.tokens
    lengths = jnp.asarray([tp + c.response_len for c in comps], jnp.int32)
    logp, _ = score_tokens(params, cfg, jnp.asarray(grid), lengths=lengths,
                           vocab_chunks=1)
    logp = np.asarray(logp)
    for i, c in enumerate(comps):
        np.testing.assert_allclose(
            c.logp, logp[i, tp:tp + c.response_len], atol=2e-2)


def test_slot_recycling_overwrites_kv(setup):
    """A retired slot's KV rows are fully overwritten by the next prefill:
    after a long occupant is recycled into a short one, no cache position
    beyond the short trajectory survives in the arena."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=8, temperature=1.0, eos_id=-1)
    eng = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
        num_slots=1, max_prompt_len=10, steps_per_sync=2))
    # occupant A: 10-token prompt + 8 generated (positions up to 17);
    # occupant B (same slot, after recycling): 4 + 2 (positions <= 6)
    reqs = [Request(uid=0, tokens=prompts[0], budget=8),
            Request(uid=1, tokens=prompts[1][:4], budget=2)]
    comps = eng.run(params, reqs, key)
    assert comps[0].response_len == 8 and comps[1].response_len == 2

    # the refill must leave nothing of A behind:
    # 1. no cache position beyond B's trajectory (A reached position 17;
    #    B spans [0, 6) plus one admissible masked post-retirement write),
    # 2. the decode region past the prompt width is zeroed (A's generated
    #    KV lived there),
    # 3. the prompt region is exactly B's fresh prefill.
    tp = 10
    horizon = 4 + 2 + 1
    padded_b = np.zeros((1, tp), np.int32)
    padded_b[0, :4] = prompts[1][:4]
    _, fresh = prefill(params, cfg, jnp.asarray(padded_b),
                       cache_len=eng.cache_len,
                       prefill_len=jnp.asarray([4], jnp.int32))
    arena = eng.last_state["cache"]
    for gname in arena:
        for lname in arena[gname]:
            entry, ref = arena[gname][lname], fresh[gname][lname]
            pos = np.asarray(entry["pos"])[:, 0]
            assert pos.max() <= horizon - 1, (gname, lname, pos)
            k = np.asarray(entry["k"], np.float32)[:, 0]  # (repeat, S, KV, D)
            assert np.all(k[:, tp:] == 0), (gname, lname)
            # B's prompt rows match a standalone prefill of B to within one
            # bf16 ulp (the fused step and the standalone executable may
            # round reductions differently)
            np.testing.assert_allclose(
                k[:, :4], np.asarray(ref["k"], np.float32)[:, 0, :4],
                rtol=1e-2, atol=1e-2, err_msg=lname)


def test_merge_and_invalidate_cache_rows(setup):
    """Primitive level: merge_cache swaps exactly the masked rows;
    invalidate_cache_rows zeroes KV and poisons pos with -1."""
    cfg, params, prompts, plens, key = setup
    cache_len = 16
    _, ca = prefill(params, cfg, jnp.asarray(prompts[:2]),
                    cache_len=cache_len, prefill_len=jnp.asarray(plens[:2]))
    _, cb = prefill(params, cfg, jnp.asarray(prompts[2:4]),
                    cache_len=cache_len, prefill_len=jnp.asarray(plens[2:4]))
    mask = jnp.asarray([True, False])
    merged = merge_cache(cb, ca, mask)

    def rows(tree, i):
        return jax.tree.map(lambda a: np.asarray(a)[:, i], tree)

    jax.tree.map(np.testing.assert_array_equal, rows(merged, 0), rows(cb, 0))
    jax.tree.map(np.testing.assert_array_equal, rows(merged, 1), rows(ca, 1))

    inv = invalidate_cache_rows(merged, jnp.asarray([True, False]))
    for group in inv.values():
        for entry in group.values():
            assert np.all(np.asarray(entry["pos"])[:, 0] == -1)
            assert np.all(np.asarray(entry["k"])[:, 0] == 0)
    # non-masked rows untouched by invalidation
    jax.tree.map(np.testing.assert_array_equal, rows(inv, 1), rows(merged, 1))


def test_per_request_budgets(setup):
    """Rows stop at their own budget — the serving contract that lets short
    requests stop paying for long neighbours."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=16, temperature=1.0, eos_id=-1)
    eng = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=4))
    budgets = [3, 16, 1, 7]
    reqs = [Request(uid=i, tokens=prompts[i % 5], budget=b)
            for i, b in enumerate(budgets)]
    comps = eng.run(params, reqs, key)
    assert [c.response_len for c in comps] == budgets
    assert all(not c.completed for c in comps)  # eos_id=-1: budget exits


def test_quota_cancellation(setup):
    """on_finish cancellations retire in-flight rows at the next sync and
    drop queued ones before they start."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=32, temperature=1.0, eos_id=-1)
    eng = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
        num_slots=2, max_prompt_len=10, steps_per_sync=2))
    reqs = [Request(uid=0, tokens=prompts[0], budget=2),
            Request(uid=1, tokens=prompts[1], budget=32),
            Request(uid=2, tokens=prompts[2], budget=32)]

    def on_finish(c):
        return [1, 2] if c.uid == 0 else None

    comps = eng.run(params, reqs, key, on_finish=on_finish)
    by_uid = {c.uid: c for c in comps}
    assert by_uid[0].response_len == 2 and not by_uid[0].cancelled
    assert by_uid[1].cancelled and by_uid[1].response_len < 32
    assert by_uid[2].cancelled and by_uid[2].response_len == 0  # never placed
    assert eng.stats["cancelled"] == 2
    # cancelling the stragglers must end the run early
    assert eng.stats["decode_steps"] < 32


def test_same_round_natural_retirement_is_not_cancelled(setup):
    """A row that retires on its own (budget/EOS) in the same sync round as
    the completion whose callback cancels it must keep cancelled=False —
    the cancellation arrived after the row had already finished."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=8, temperature=1.0, eos_id=-1)
    eng = ContinuousRolloutEngine(cfg, rcfg, EngineConfig(
        num_slots=3, max_prompt_len=10, steps_per_sync=4, refill_lanes=3))
    # all three rows start together (3 lanes) and exhaust their budgets
    # inside the same sync window
    reqs = [Request(uid=i, tokens=prompts[i], budget=2) for i in range(3)]

    def on_finish(c):
        return [1, 2] if c.uid == 0 else None

    comps = eng.run(params, reqs, key, on_finish=on_finish)
    assert [c.response_len for c in comps] == [2, 2, 2]
    assert not any(c.cancelled for c in comps)
    assert eng.stats["cancelled"] == 0


def test_rollout_group_continuous_contract(setup):
    """The continuous path produces the same learner-batch contract as the
    legacy rollout_group (masks aligned, logp only on response tokens)."""
    cfg, params, prompts, plens, key = setup
    rcfg = RolloutConfig(max_new_tokens=8, group_size=4, overprovision=1.5)
    rb = rollout_group_continuous(params, cfg, rcfg, prompts[:3], plens[:3],
                                  key, num_slots=4, steps_per_sync=2)
    b = 3 * 4
    assert rb.tokens.shape == (b, 10 + 8)
    assert rb.response_mask.shape == rb.tokens.shape
    for i in range(b):
        pl, rl = int(rb.prompt_lens[i]), int(rb.response_lens[i])
        row = rb.response_mask[i]
        assert row[:pl].sum() == 0
        assert row[pl:pl + rl].sum() == rl
        assert row[pl + rl:].sum() == 0
        assert np.all(rb.old_logp[i][row == 0] == 0)
        assert np.all(rb.old_logp[i][row == 1] <= 1e-5)
    st = rb.stats
    assert st["tokens_budget"] == 3 * 6 * 8
    assert 0 < st["tokens_generated"] <= st["tokens_budget"]


def test_trainer_continuous_rollout_metrics():
    """End-to-end: the trainer on the slot arena surfaces the rollout token
    cost (tokens_generated vs tokens_budget) in its metrics."""
    cfg = tiny_cfg()
    tc = NATTrainerConfig(
        selector="rpc", selector_kwargs=(("min_cut", 4),),
        prompts_per_step=2, max_prompt_len=16,
        rollout=RolloutConfig(max_new_tokens=8, group_size=4,
                              overprovision=1.5),
        steps_per_sync=2,
        adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
        bucket_align=8, seed=0)
    tr = NATGRPOTrainer(cfg, tc)
    m = tr.train_step()
    assert np.isfinite(m["loss"])
    assert m["tokens_budget"] == 2 * 6 * 8
    assert 0 < m["tokens_generated"] <= m["tokens_budget"]
    assert 0 < m["rollout_utilization"] <= 1.0
