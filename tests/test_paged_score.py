"""Zero re-prefill teacher forcing (DESIGN.md §11), end to end: paged
engine rollout with ``learner_retain`` -> ``export_learner_pages`` ->
``core.layout.PagedLayout`` -> ``score_tokens(paged_prefix=...)``.

Parity contracts:
  * both paged impls ("ref" | "kernel") match the DENSE padded-grid logp
    per response token within the pool's bf16 KV storage rounding — the
    tolerance is the pool dtype, not kernel error (at staleness 0 the
    forward is otherwise exact),
  * kernel matches ref tightly under f32 activations (with bf16 params
    the ref rounds softmax probabilities to bf16 like the dense path,
    while the kernel keeps f32 probabilities — a dtype-policy gap, so
    the tight comparison casts params to f32; the pool stays bf16),
  * segment-head slots (the re-forwarded last prompt token) score
    exactly 0 — the response's first token gets the true logp,
  * parameter grads match between impls (response-side grads are exact;
    prompt-KV paths are dropped by ``stop_gradient`` in both),
  * released pages drain the allocator back to empty,
  * the capability gate rejects non-attn stacks by name, and
    ``PAGED_SCORE_BLOCK`` stays pinned to ``PagedLayout.qblock``.
"""
import functools

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layout import PagedLayout, make_layout
from repro.models import attention as attn
from repro.models import capabilities as caps
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.models.model import score_tokens
from repro.rl import Request, RolloutConfig, VOCAB_SIZE
from repro.rl.engine import make_paged_engine

B, TP, N = 6, 10, 12
T = TP + N


def tiny_cfg(**kw):
    kw.setdefault("blocks", dense_blocks(2))
    return ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                       seq_parallel=False, remat_policy="none",
                       scan_layers=False, **kw)


@functools.lru_cache(maxsize=1)
def setup():
    """One rollout shared by the module: 3 GRPO groups x 2 siblings."""
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    rng = np.random.default_rng(0)
    prompts = rng.integers(3, VOCAB_SIZE, size=(3, TP)).astype(np.int32)
    rcfg = RolloutConfig(max_new_tokens=N, temperature=1.0, eos_id=-1,
                         group_size=2)
    eng = make_paged_engine(cfg, rcfg, num_slots=4, max_prompt_len=TP,
                            steps_per_sync=3, page_len=16,
                            learner_retain=True)
    groups = [[Request(uid=pi * 2 + j, tokens=prompts[pi], budget=N)
               for j in range(2)] for pi in range(3)]
    comps = {c.uid: c for c in eng.run_groups(params, groups, key)}
    uids = sorted(comps)
    export = eng.export_learner_pages(uids)

    grid = np.zeros((B, T), np.int32)
    rlens = np.zeros((B,), np.int32)
    for i, u in enumerate(uids):
        c = comps[u]
        grid[i, :TP] = prompts[u // 2]
        grid[i, TP:TP + c.response_len] = c.tokens
        rlens[i] = c.response_len
    logp_dense, _ = score_tokens(params, cfg, jnp.asarray(grid),
                                 lengths=jnp.asarray(TP + rlens),
                                 vocab_chunks=1)
    keep = np.zeros((B, T), bool)
    for i in range(B):
        keep[i, TP:TP + rlens[i]] = True
    lb = make_layout("paged").build(
        {"tokens": grid}, prompt_lens=np.full((B,), TP, np.int32),
        response_lens=rlens, keep_len=rlens, keep_mask=keep,
        prefix_structured=True, ladder=[16, 32, 48, 64])
    return dict(cfg=cfg, params=params, eng=eng, export=export,
                logp_dense=np.asarray(logp_dense), lb=lb)


def paged_logp(params, impl):
    s = setup()
    d = s["lb"].data
    logp, _ = score_tokens(
        params, s["cfg"], jnp.asarray(d["tokens"]),
        positions=jnp.asarray(d["positions"]),
        segment_ids=jnp.asarray(d["segment_ids"]),
        paged_prefix=s["export"]["pool"],
        page_tables={"block_tables": s["export"]["block_tables"],
                     "seg_start": jnp.asarray(d["seg_start"])},
        paged_impl=impl, vocab_chunks=1)
    return np.asarray(logp)


def f32_params():
    return jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        setup()["params"])


def test_qblock_pinned_to_layout():
    assert attn.PAGED_SCORE_BLOCK == PagedLayout().qblock


def test_export_compacts_shared_prompt_pages():
    ex = setup()["export"]
    # 6 siblings, 3 shared prompts of <= 1 page each -> 3 compacted pages
    assert ex["pool"]["group0"]["l0"]["k"].shape[1] == 3
    assert ex["block_tables"].shape[0] == B
    assert np.array_equal(np.asarray(ex["prompt_lens"]), np.full((B,), TP))


@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_paged_logp_matches_dense(impl):
    s = setup()
    d = s["lb"].data
    lp = paged_logp(s["params"], impl)
    seg = np.asarray(d["segment_ids"])
    pos = np.asarray(d["positions"])
    worst = 0.0
    for r in range(s["lb"].num_rows):
        for t in range(s["lb"].row_len):
            if seg[r, t] >= B:
                continue
            if pos[r, t] <= TP - 1:      # segment head slot: exactly 0
                assert lp[r, t] == 0.0
                continue
            worst = max(worst, abs(lp[r, t] - s["logp_dense"][seg[r, t],
                                                              pos[r, t]]))
    # bound = the pool's bf16 KV storage rounding, NOT kernel error
    assert worst < 2e-2, worst


def test_kernel_matches_ref_tightly_in_f32():
    p32 = f32_params()
    a = paged_logp(p32, "ref")
    b = paged_logp(p32, "kernel")
    live = np.asarray(setup()["lb"].data["segment_ids"]) < B
    assert float(np.abs(np.where(live, a - b, 0.0)).max()) < 2e-4


def test_param_grad_parity():
    s = setup()
    d = s["lb"].data
    mask = jnp.asarray(np.asarray(d["segment_ids"]) < B)

    def loss(p, impl):
        lp, _ = score_tokens(
            p, s["cfg"], jnp.asarray(d["tokens"]),
            positions=jnp.asarray(d["positions"]),
            segment_ids=jnp.asarray(d["segment_ids"]),
            paged_prefix=s["export"]["pool"],
            page_tables={"block_tables": s["export"]["block_tables"],
                         "seg_start": jnp.asarray(d["seg_start"])},
            paged_impl=impl, vocab_chunks=1)
        return jnp.sum(jnp.where(mask, lp, 0.0) ** 2)

    p32 = f32_params()
    gr, _ = jax.flatten_util.ravel_pytree(
        jax.grad(lambda p: loss(p, "ref"))(p32))
    gk, _ = jax.flatten_util.ravel_pytree(
        jax.grad(lambda p: loss(p, "kernel"))(p32))
    diff = float(jnp.max(jnp.abs(gr - gk)))
    scale = float(jnp.max(jnp.abs(gr)))
    assert diff < 2e-4 * max(scale, 1.0), (diff, scale)


def test_capability_gate_names_offender():
    ok = tiny_cfg()
    caps.check_paged_score(ok)
    bad = tiny_cfg(blocks=((("attn", "ssm"), 1),))
    assert not caps.paged_score_ok(bad)
    with pytest.raises(caps.CapabilityError, match="ssm"):
        caps.check_paged_score(bad)


def test_release_drains_allocator():
    """Runs last by name-independent design: release is idempotent on the
    shared engine, and a full release drains every retained ref."""
    s = setup()
    s["eng"].release_learner_pages()
    assert s["eng"]._alloc.in_use == 0
    # releasing again is a no-op, not a double free
    s["eng"].release_learner_pages()
    assert s["eng"]._alloc.in_use == 0
