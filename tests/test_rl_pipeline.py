"""RL substrate integration: envs, data pipeline, rollout engine, trainer."""
import numpy as np
import pytest

from repro.data import Prefetcher, PromptPipeline
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    NATGRPOTrainer, NATTrainerConfig, RolloutConfig, VOCAB_SIZE, decode_tokens,
    encode, make_env,
)
from repro.rl.env import EOS, ModArithEnv
from repro.rl.rollout import rollout_group
from repro.models import init_params, model_decl


def tiny_cfg():
    return ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                       blocks=dense_blocks(2), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


def test_env_rewards():
    env = ModArithEnv(max_val=20, mod=97)
    rng = np.random.default_rng(0)
    p = env.sample(rng)
    full = np.array(encode(p.answer) + [EOS], np.int32)
    assert env.reward(p, full) == 1.0
    assert env.reward(p, np.array(encode("99999"), np.int32)) <= 0.2
    # partial credit for a correct prefix
    if len(p.answer) > 1:
        part = np.array(encode(p.answer[:1]), np.int32)
        assert 0 < env.reward(p, part) < 1.0


def test_tokenizer_roundtrip():
    s = "12+34%97=?"
    assert decode_tokens(encode(s)) == s


def test_pipeline_determinism_and_host_sharding():
    env = make_env("mod_arith")
    a = PromptPipeline(env, batch_size=8, max_prompt_len=24, seed=3)
    b = PromptPipeline(env, batch_size=8, max_prompt_len=24, seed=3)
    ba, bb = a.batch_at(5), b.batch_at(5)
    np.testing.assert_array_equal(ba.tokens, bb.tokens)
    # two hosts partition the same global batch
    h0 = PromptPipeline(env, batch_size=8, max_prompt_len=24, seed=3,
                        host_id=0, num_hosts=2)
    h1 = PromptPipeline(env, batch_size=8, max_prompt_len=24, seed=3,
                        host_id=1, num_hosts=2)
    g0, g1 = h0.batch_at(5), h1.batch_at(5)
    np.testing.assert_array_equal(
        np.concatenate([g0.tokens, g1.tokens]), ba.tokens)
    # checkpoint cursor roundtrip
    a.step = 17
    st = a.state_dict()
    c = PromptPipeline(env, batch_size=8, max_prompt_len=24, seed=0)
    c.load_state_dict(st)
    np.testing.assert_array_equal(next(c).tokens, a.batch_at(17).tokens)


def test_iter_prompts_streams_batches_without_advancing_cursor():
    """iter_prompts yields the same prompts batch_at produces, unpadded, and
    leaves the pipeline cursor untouched (checkpoint resume unaffected)."""
    env = make_env("mod_arith")
    pipe = PromptPipeline(env, batch_size=4, max_prompt_len=24, seed=7)
    stream = pipe.iter_prompts()
    got = [next(stream) for _ in range(10)]  # spans three batches
    assert pipe.step == 0
    for j, (prompt, toks, n) in enumerate(got):
        ref = pipe.batch_at(j // 4)
        i = j % 4
        assert n == int(ref.prompt_lens[i])
        np.testing.assert_array_equal(toks, ref.tokens[i, :n])
        assert prompt.answer == ref.prompts[i].answer


def test_prefetcher():
    out = list(Prefetcher(iter(range(7)), depth=2))
    assert out == list(range(7))

    def boom():
        yield 1
        raise RuntimeError("boom")

    it = Prefetcher(boom(), depth=1)
    assert next(it) == 1
    with pytest.raises(RuntimeError):
        for _ in it:
            pass


def test_rollout_shapes_and_masks(key):
    cfg = tiny_cfg()
    params = init_params(key, model_decl(cfg))
    env = make_env("mod_arith")
    pipe = PromptPipeline(env, batch_size=3, max_prompt_len=16)
    pb = next(pipe)
    rcfg = RolloutConfig(max_new_tokens=8, group_size=4, overprovision=1.5)
    rb = rollout_group(params, cfg, rcfg, pb.tokens, pb.prompt_lens, key)
    b = 3 * 4
    assert rb.tokens.shape == (b, 16 + 8)
    assert rb.response_mask.shape == rb.tokens.shape
    # responses start exactly at prompt_lens and run response_lens tokens
    for i in range(b):
        pl, rl = int(rb.prompt_lens[i]), int(rb.response_lens[i])
        row = rb.response_mask[i]
        assert row[:pl].sum() == 0
        assert row[pl:pl + rl].sum() == rl
        assert row[pl + rl:].sum() == 0
        # behaviour logp only on response tokens, <= 0
        assert np.all(rb.old_logp[i][row == 0] == 0)
        assert np.all(rb.old_logp[i][row == 1] <= 1e-5)


def test_trainer_selectors_one_step():
    cfg = tiny_cfg()
    for sel, kw in [("rpc", (("min_cut", 4),)), ("urs", (("p", 0.5),)),
                    ("full", ()), ("det_trunc", ()), ("entropy", ())]:
        tc = NATTrainerConfig(
            selector=sel, selector_kwargs=kw, prompts_per_step=2,
            max_prompt_len=16,
            rollout=RolloutConfig(max_new_tokens=8, group_size=4),
            adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
            bucket_align=8, seed=0)
        tr = NATGRPOTrainer(cfg, tc)
        m = tr.train_step()
        assert np.isfinite(m["loss"]), sel
        assert 0 < m["selected_ratio"] <= 1.0 + 1e-6, sel
        if sel == "det_trunc":
            assert m["bucket_len"] <= 16 + 8


def test_rpc_repack_shrinks_learner_tokens():
    """With long responses, RPC's physical repack processes fewer learner
    tokens than full-token GRPO on the same rollouts."""
    cfg = tiny_cfg()
    common = dict(prompts_per_step=2, max_prompt_len=16,
                  rollout=RolloutConfig(max_new_tokens=32, group_size=4,
                                        eos_id=-1),  # never stop early
                  adamw=AdamWConfig(lr=1e-4, warmup_steps=2, total_steps=10),
                  bucket_align=8, seed=1)
    full = NATGRPOTrainer(cfg, NATTrainerConfig(selector="full", **common))
    rpc = NATGRPOTrainer(cfg, NATTrainerConfig(
        selector="rpc", selector_kwargs=(("min_cut", 2),), **common))
    mf = full.train_step()
    toks_rpc = [rpc.train_step()["learner_tokens"] for _ in range(6)]
    assert np.mean(toks_rpc) < mf["learner_tokens"]
