"""Optimizer substrate: AdamW semantics, int8 moment compression, clipping,
schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st

from repro.optim import (
    AdamWConfig, adamw_update, clip_by_global_norm, dequantize, global_norm,
    init_opt_state, quantize, schedule,
)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0, end_lr_frac=1.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        g = {"x": 2 * (params["x"] - target)}
        params, state, _ = adamw_update(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_int8_matches_fp32_closely():
    k = jax.random.PRNGKey(0)
    p0 = {"w": jax.random.normal(k, (64, 128)) * 0.1}
    tgt = jax.random.normal(jax.random.fold_in(k, 1), (64, 128)) * 0.1
    out = {}
    for mode in ("fp32", "int8"):
        cfg = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=50,
                          clip_norm=100.0, moment_dtype=mode, end_lr_frac=1.0)
        p = dict(p0)
        s = init_opt_state(p, cfg)
        for _ in range(50):
            g = {"w": 2 * (p["w"] - tgt)}
            p, s, _ = adamw_update(p, g, s, cfg)
        out[mode] = np.asarray(p["w"])
    # int8-compressed moments track the fp32 trajectory and both converge
    err = np.abs(out["int8"] - out["fp32"]).max()
    assert err < 0.06, err
    np.testing.assert_allclose(out["int8"], np.asarray(tgt), atol=0.06)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3))
def test_quantize_roundtrip(seed, nd):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 9, size=nd))
    x = jnp.asarray(rng.normal(size=shape) * (10.0 ** (seed % 5 - 2)),
                    jnp.float32)
    q = quantize(x)
    back = dequantize(q)
    assert back.shape == x.shape
    scale = float(jnp.max(jnp.abs(x))) or 1.0
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=scale / 100.0)


def test_quantize_block_structure():
    x = jnp.ones((4, 300))  # 300 pads to 3 blocks of 128
    q = quantize(x)
    assert q.q.shape == (4, 3, 128)
    assert q.scale.shape == (4, 3, 1)
    np.testing.assert_allclose(np.asarray(dequantize(q)), 1.0)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        float(global_norm(clipped)), 1.0, rtol=1e-5)
    # below threshold: untouched
    c2, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), np.asarray(g["a"]))


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      end_lr_frac=0.1)
    s = [float(schedule(cfg, jnp.asarray(i))) for i in range(101)]
    assert s[0] == 0.0
    np.testing.assert_allclose(s[10], 1.0, rtol=1e-5)
    assert all(a >= b - 1e-9 for a, b in zip(s[10:], s[11:]))  # decays
    np.testing.assert_allclose(s[100], 0.1, rtol=1e-4)
