"""Prefix-aware flash attention kernel vs the jnp oracle: shape/dtype/GQA/
window/cut sweeps for forward and both backward kernels (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.prefix_attn import attention_ref, prefix_flash_attention
from repro.kernels.prefix_attn.kernel import fwd_pallas

SWEEP = [
    # (B, H, KV, T, D, bq, bk, window)
    (2, 4, 2, 256, 32, 64, 64, 0),
    (1, 4, 4, 128, 64, 64, 64, 0),      # MHA
    (2, 8, 1, 256, 32, 128, 128, 0),    # MQA
    (2, 4, 2, 256, 32, 64, 64, 64),     # sliding window
    (1, 2, 2, 512, 16, 128, 64, 128),   # rectangular blocks + window
]


def data(b, h, kv, t, d, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    q = (jax.random.normal(k, (b, h, t, d), jnp.float32) * 0.3).astype(dtype)
    kk = (jax.random.normal(jax.random.fold_in(k, 1), (b, kv, t, d)) * 0.3
          ).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(k, 2), (b, kv, t, d)) * 0.3
         ).astype(dtype)
    cut = jnp.asarray(
        np.linspace(t // 3, t, b).astype(np.int32))  # mixed cut positions
    return q, kk, v, cut


@pytest.mark.parametrize("b,h,kv,t,d,bq,bk,window", SWEEP)
def test_fwd_sweep(b, h, kv, t, d, bq, bk, window):
    q, k, v, cut = data(b, h, kv, t, d)
    o, lse = fwd_pallas(q, k, v, cut, window=window, bq=bq, bk=bk)
    oref, lref = attention_ref(q, k, v, cut, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("b,h,kv,t,d,bq,bk,window", SWEEP[:3])
def test_bwd_sweep(b, h, kv, t, d, bq, bk, window):
    q, k, v, cut = data(b, h, kv, t, d)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(
            prefix_flash_attention(q, k, v, cut, window, bq, bk, True)))

    def loss_r(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v, cut, window=window)[0]))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b_, nm in zip(gk, gr, "dq dk dv".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=3e-4,
                                   atol=3e-4, err_msg=nm)


def test_bf16():
    q, k, v, cut = data(2, 4, 2, 256, 32, dtype=jnp.bfloat16)
    o = prefix_flash_attention(q, k, v, cut, 0, 128, 128, True)
    oref, _ = attention_ref(q, k, v, cut)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=5e-2,
                               atol=5e-2)


def test_cut_zero_block_rows():
    """Rows entirely past the cut emit zeros and zero grads (no NaN)."""
    b, h, kv, t, d = 2, 2, 2, 128, 16
    q, k, v, _ = data(b, h, kv, t, d)
    cut = jnp.array([32, 128], jnp.int32)  # row 0: 3/4 of rows invalid

    o, lse = fwd_pallas(q, k, v, cut, bq=64, bk=64)
    o = np.asarray(o)
    assert np.all(np.isfinite(o))
    assert np.all(o[0, :, 64:, :] == 0.0)  # q blocks past the cut skipped

    g = jax.grad(lambda q: jnp.sum(
        prefix_flash_attention(q, k, v, cut, 0, 64, 64, True)))(q)
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.all(np.asarray(g)[0, :, 64:, :] == 0.0)


def test_compute_savings_structure():
    """Block skipping is structural: with cut=T/4 only the first quarter of
    q-blocks can contribute — verified via output sparsity per block."""
    b, h, kv, t, d = 1, 2, 2, 256, 16
    q, k, v, _ = data(b, h, kv, t, d)
    cut = jnp.array([64], jnp.int32)
    o, _ = fwd_pallas(q, k, v, cut, bq=64, bk=64)
    o = np.asarray(o)
    assert np.any(o[0, :, :64, :] != 0)
    assert np.all(o[0, :, 64:, :] == 0)
