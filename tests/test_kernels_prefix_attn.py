"""Prefix-aware flash attention kernel vs the jnp oracle: shape/dtype/GQA/
window/cut sweeps for forward and both backward kernels (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.prefix_attn import attention_ref, prefix_flash_attention
from repro.kernels.prefix_attn.kernel import fwd_pallas

SWEEP = [
    # (B, H, KV, T, D, bq, bk, window)
    (2, 4, 2, 256, 32, 64, 64, 0),
    (1, 4, 4, 128, 64, 64, 64, 0),      # MHA
    (2, 8, 1, 256, 32, 128, 128, 0),    # MQA
    (2, 4, 2, 256, 32, 64, 64, 64),     # sliding window
    (1, 2, 2, 512, 16, 128, 64, 128),   # rectangular blocks + window
]


def data(b, h, kv, t, d, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    q = (jax.random.normal(k, (b, h, t, d), jnp.float32) * 0.3).astype(dtype)
    kk = (jax.random.normal(jax.random.fold_in(k, 1), (b, kv, t, d)) * 0.3
          ).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(k, 2), (b, kv, t, d)) * 0.3
         ).astype(dtype)
    cut = jnp.asarray(
        np.linspace(t // 3, t, b).astype(np.int32))  # mixed cut positions
    return q, kk, v, cut


@pytest.mark.parametrize("b,h,kv,t,d,bq,bk,window", SWEEP)
def test_fwd_sweep(b, h, kv, t, d, bq, bk, window):
    q, k, v, cut = data(b, h, kv, t, d)
    o, lse = fwd_pallas(q, k, v, cut, window=window, bq=bq, bk=bk)
    oref, lref = attention_ref(q, k, v, cut, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("b,h,kv,t,d,bq,bk,window", SWEEP[:3])
def test_bwd_sweep(b, h, kv, t, d, bq, bk, window):
    q, k, v, cut = data(b, h, kv, t, d)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(
            prefix_flash_attention(q, k, v, cut, window, bq, bk, True)))

    def loss_r(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v, cut, window=window)[0]))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b_, nm in zip(gk, gr, "dq dk dv".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=3e-4,
                                   atol=3e-4, err_msg=nm)


def test_bf16():
    q, k, v, cut = data(2, 4, 2, 256, 32, dtype=jnp.bfloat16)
    o = prefix_flash_attention(q, k, v, cut, 0, 128, 128, True)
    oref, _ = attention_ref(q, k, v, cut)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), rtol=5e-2,
                               atol=5e-2)


def test_cut_zero_block_rows():
    """Rows entirely past the cut emit zeros and zero grads (no NaN)."""
    b, h, kv, t, d = 2, 2, 2, 128, 16
    q, k, v, _ = data(b, h, kv, t, d)
    cut = jnp.array([32, 128], jnp.int32)  # row 0: 3/4 of rows invalid

    o, lse = fwd_pallas(q, k, v, cut, bq=64, bk=64)
    o = np.asarray(o)
    assert np.all(np.isfinite(o))
    assert np.all(o[0, :, 64:, :] == 0.0)  # q blocks past the cut skipped

    g = jax.grad(lambda q: jnp.sum(
        prefix_flash_attention(q, k, v, cut, 0, 64, 64, True)))(q)
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.all(np.asarray(g)[0, :, 64:, :] == 0.0)


def test_compute_savings_structure():
    """Block skipping is structural: with cut=T/4 only the first quarter of
    q-blocks can contribute — verified via output sparsity per block."""
    b, h, kv, t, d = 1, 2, 2, 256, 16
    q, k, v, _ = data(b, h, kv, t, d)
    cut = jnp.array([64], jnp.int32)
    o, _ = fwd_pallas(q, k, v, cut, bq=64, bk=64)
    o = np.asarray(o)
    assert np.any(o[0, :, :64, :] != 0)
    assert np.all(o[0, :, 64:, :] == 0)


# ------------------------------------------------------ packed (segment-id)
from repro.kernels.prefix_attn import (  # noqa: E402
    packed_attention_ref, packed_flash_attention,
)
from repro.kernels.prefix_attn.kernel import (  # noqa: E402
    packed_fwd_pallas, seg_block_ranges,
)

PAD = np.int32(2**30)


def packed_ids(b, t, seed=0, pad_tail=True):
    """Synthetic per-row-monotone segment ids with occasional tail padding
    — the exact shape core/layout.py emits."""
    rng = np.random.default_rng(seed)
    out = np.full((b, t), PAD, np.int32)
    sid = 0
    for r in range(b):
        off = 0
        while off < t:
            ln = min(int(rng.integers(3, max(4, t // 3))), t - off)
            out[r, off:off + ln] = sid
            sid += 1
            off += ln
            if pad_tail and rng.random() < 0.3:
                break
    return jnp.asarray(out)


PACKED_SWEEP = [
    # (B, H, KV, T, D, blk)
    (2, 4, 2, 256, 32, 64),
    (1, 4, 4, 128, 64, 64),      # MHA
    (2, 8, 1, 256, 32, 128),     # MQA
]


@pytest.mark.parametrize("b,h,kv,t,d,blk", PACKED_SWEEP)
def test_packed_fwd_sweep(b, h, kv, t, d, blk):
    q, k, v, _ = data(b, h, kv, t, d)
    seg = packed_ids(b, t)
    o, lse = packed_fwd_pallas(q, k, v, seg, bq=blk, bk=blk)
    oref, lref = packed_attention_ref(q, k, v, seg)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("b,h,kv,t,d,blk", PACKED_SWEEP)
def test_packed_bwd_sweep(b, h, kv, t, d, blk):
    q, k, v, _ = data(b, h, kv, t, d)
    seg = packed_ids(b, t)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(
            packed_flash_attention(q, k, v, seg, blk, blk, True)))

    def loss_r(q, k, v):
        return jnp.sum(jnp.sin(packed_attention_ref(q, k, v, seg)[0]))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b_, nm in zip(gk, gr, "dq dk dv".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=3e-4,
                                   atol=3e-4, err_msg=nm)


def test_packed_no_cross_segment_attention():
    """The packed invariant itself: outputs for a packed row equal the
    outputs of each segment attended in isolation — packed neighbors are
    invisible."""
    b, h, kv, t, d = 1, 2, 2, 128, 16
    q, k, v, _ = data(b, h, kv, t, d)
    seg = np.zeros((1, t), np.int32)
    seg[0, 48:] = 1  # two segments: [0, 48) and [48, T)
    o, _ = packed_fwd_pallas(q, k, v, jnp.asarray(seg), bq=64, bk=64)

    # segment 1 in isolation: slice it out and run full causal attention
    q1, k1, v1 = (x[:, :, 48:, :] for x in (q, k, v))
    cut = jnp.array([t - 48], jnp.int32)
    o1, _ = fwd_pallas(jnp.asarray(q1), jnp.asarray(k1), jnp.asarray(v1),
                       cut, bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(o)[:, :, 48:, :], np.asarray(o1),
                               rtol=2e-5, atol=2e-5)


def test_packed_block_skip_is_structural():
    """Blocks whose segment ranges cannot intersect are skipped: with one
    segment per block-aligned span, a query block never reads other
    blocks' K/V — verified against the per-block range summaries."""
    b, t, blk = 1, 256, 64
    seg = np.repeat(np.arange(t // blk, dtype=np.int32), blk)[None]
    lo, hi = seg_block_ranges(jnp.asarray(seg), blk)
    lo, hi = np.asarray(lo), np.asarray(hi)
    nb = t // blk
    needed = np.zeros((nb, nb), bool)
    for qi in range(nb):
        for ki in range(nb):
            needed[qi, ki] = (ki * blk <= qi * blk + blk - 1
                              and lo[0, ki] <= hi[0, qi]
                              and lo[0, qi] <= hi[0, ki])
    np.testing.assert_array_equal(needed, np.eye(nb, dtype=bool))


def test_packed_padding_rows_finite():
    """All-padding rows (sentinel segment ids) self-attend: outputs and
    grads stay finite, never NaN."""
    b, h, kv, t, d = 1, 2, 2, 128, 16
    q, k, v, _ = data(b, h, kv, t, d)
    seg = jnp.full((b, t), PAD, jnp.int32)
    o, lse = packed_fwd_pallas(q, k, v, seg, bq=64, bk=64)
    assert np.all(np.isfinite(np.asarray(o)))
    g = jax.grad(lambda q: jnp.sum(
        packed_flash_attention(q, k, v, seg, 64, 64, True)))(q)
    assert np.all(np.isfinite(np.asarray(g)))
