"""Bucket ladder / physical repacking properties."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st

from repro.core.repack import (
    bucket_ladder, expected_token_savings, pick_bucket, plan_microbatches,
)


def test_ladder_alignment():
    lad = bucket_ladder(4096, num_buckets=4, align=128)
    assert all(l % 128 == 0 for l in lad)
    assert lad[-1] >= 4096
    assert lad == tuple(sorted(set(lad)))


@settings(max_examples=50, deadline=None)
@given(max_len=st.integers(64, 8192), need=st.integers(1, 8192))
def test_pick_bucket_covers(max_len, need):
    lad = bucket_ladder(max_len, 4, 64)
    b = pick_bucket(min(need, max_len), lad)
    assert b >= min(need, max_len) or b == lad[-1]


def test_plan_microbatches_sorted_buckets():
    keep = np.array([100, 900, 50, 800, 120, 60, 70, 1000])
    plans = plan_microbatches(keep, 4, bucket_ladder(1024, 4, 64))
    # all rows covered exactly once
    rows = np.sort(np.concatenate([p.row_order for p in plans]))
    np.testing.assert_array_equal(rows, np.arange(8))
    # long rows grouped first -> later plans get smaller buckets
    lens = [p.bucket_len for p in plans]
    assert lens == sorted(lens, reverse=True)
    # each plan's bucket covers its rows
    for p in plans:
        assert keep[p.row_order].max() <= p.bucket_len


def test_expected_token_savings_formula():
    lengths = np.array([100, 200, 400])
    # E[kept per row] = (C + T)/2
    expect = ((8 + lengths) / 2).sum() / lengths.sum()
    got = expected_token_savings(lengths, min_cut=8)
    np.testing.assert_allclose(got, expect, rtol=1e-9)
    assert 0.5 < got < 0.55
