"""Bucket ladder / physical repacking properties."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from hypothesis_fallback import given, settings, st

from repro.core.repack import (
    bucket_ladder, expected_token_savings, pick_bucket, plan_microbatches,
)


def test_ladder_alignment():
    lad = bucket_ladder(4096, num_buckets=4, align=128)
    assert all(l % 128 == 0 for l in lad)
    assert lad[-1] >= 4096
    assert lad == tuple(sorted(set(lad)))


@settings(max_examples=50, deadline=None)
@given(max_len=st.integers(64, 8192), need=st.integers(1, 8192))
def test_pick_bucket_covers(max_len, need):
    lad = bucket_ladder(max_len, 4, 64)
    b = pick_bucket(min(need, max_len), lad)
    assert b >= min(need, max_len) or b == lad[-1]


def test_plan_microbatches_sorted_buckets():
    keep = np.array([100, 900, 50, 800, 120, 60, 70, 1000])
    plans = plan_microbatches(keep, 4, bucket_ladder(1024, 4, 64))
    # all rows covered exactly once
    rows = np.sort(np.concatenate([p.row_order for p in plans]))
    np.testing.assert_array_equal(rows, np.arange(8))
    # long rows grouped first -> later plans get smaller buckets
    lens = [p.bucket_len for p in plans]
    assert lens == sorted(lens, reverse=True)
    # each plan's bucket covers its rows
    for p in plans:
        assert keep[p.row_order].max() <= p.bucket_len


def test_expected_token_savings_formula():
    lengths = np.array([100, 200, 400])
    # E[kept per row] = (C + T)/2
    expect = ((8 + lengths) / 2).sum() / lengths.sum()
    got = expected_token_savings(lengths, min_cut=8)
    np.testing.assert_allclose(got, expect, rtol=1e-9)
    assert 0.5 < got < 0.55


def test_pick_bucket_overflow_raises():
    """Regression (ISSUE 4): needed > ladder[-1] used to silently return the
    last bucket, truncating kept tokens; it must be a hard error."""
    lad = bucket_ladder(256, num_buckets=4, align=64)
    import pytest

    with pytest.raises(ValueError, match="exceeds the bucket ladder"):
        pick_bucket(lad[-1] + 1, lad)
    # boundary: exactly the top bucket is fine
    assert pick_bucket(lad[-1], lad) == lad[-1]


def test_plan_microbatches_all_equal_lengths():
    keep = np.full(8, 100)
    plans = plan_microbatches(keep, 4, bucket_ladder(256, 4, 64))
    assert all(p.bucket_len == plans[0].bucket_len for p in plans)
    rows = np.sort(np.concatenate([p.row_order for p in plans]))
    np.testing.assert_array_equal(rows, np.arange(8))


def test_plan_microbatches_single_row():
    plans = plan_microbatches(np.array([37]), 1, bucket_ladder(128, 4, 32))
    assert len(plans) == 1
    np.testing.assert_array_equal(plans[0].row_order, [0])
    assert plans[0].bucket_len >= 37


def test_plan_microbatches_zero_keep_rows():
    """keep_len == 0 rows (nothing selected) still land in exactly one
    microbatch, padded to the smallest bucket."""
    keep = np.array([0, 0, 0, 0, 90, 80, 10, 0])
    ladder = bucket_ladder(128, 4, 32)
    plans = plan_microbatches(keep, 4, ladder)
    rows = np.sort(np.concatenate([p.row_order for p in plans]))
    np.testing.assert_array_equal(rows, np.arange(8))
    # the all-zero microbatches sit in the smallest bucket
    assert plans[-1].bucket_len == ladder[0]
    for p in plans:
        assert keep[p.row_order].max(initial=0) <= p.bucket_len


@settings(max_examples=50, deadline=None)
@given(
    lens=st.lists(st.integers(0, 512), min_size=1, max_size=32),
    nmb=st.integers(1, 8),
)
def test_plan_microbatches_unions_partition_batch(lens, nmb):
    """Property: microbatch row sets are disjoint and their union is the
    whole batch, for every divisible split."""
    keep = np.asarray(lens)
    if len(keep) % nmb:
        nmb = 1
    plans = plan_microbatches(keep, nmb, bucket_ladder(512, 4, 64))
    all_rows = np.concatenate([p.row_order for p in plans])
    assert len(all_rows) == len(set(all_rows.tolist())) == len(keep)
    np.testing.assert_array_equal(np.sort(all_rows), np.arange(len(keep)))
    for p in plans:
        if len(p.row_order):
            assert keep[p.row_order].max() <= p.bucket_len
