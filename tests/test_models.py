"""Model-zoo correctness: for EVERY mixer family, the chunked scorer matches
full logits, and prefill+decode matches the teacher-forced forward (the
strongest cross-check of cache semantics: rings, MLA latents, SSD states,
RG-LRU recurrence, cross-attn K/V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    MLAConfig, MoEConfig, ModelConfig, RGLRUConfig, SSMConfig, decode_step,
    dense_blocks, forward_hidden, full_logits, init_params, model_decl,
    prefill, score_tokens,
)


def mk(name, **kw):
    base = dict(name=name, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=97, seq_parallel=False)
    base.update(kw)
    return ModelConfig(**base)


CFGS = {
    "dense": mk("dense", blocks=dense_blocks(3)),
    "local": mk("local", blocks=((("local", "local", "attn"), 2),), window=8),
    "moe": mk("moe", blocks=((("attn:moe",), 3),),
              # capacity_factor 4.0 => cap >= T: no capacity drops, so the
              # teacher-forced cross-checks below are exact (drop patterns
              # differ between the T and T+1 forwards otherwise)
              moe=MoEConfig(num_experts=4, top_k=2, num_shared=1,
                            d_ff_expert=32, capacity_factor=4.0)),
    "mla": mk("mla", blocks=((("mla:dense",), 1), (("mla",), 2)),
              mla=MLAConfig(q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=16,
                            qk_rope_dim=8, v_head_dim=16)),
    "ssm": mk("ssm", blocks=((("ssm",), 3),), d_ff=0, n_heads=0, n_kv_heads=0,
              head_dim=0,
              ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                            chunk=8)),
    "rec": mk("rec", blocks=((("rec", "rec", "local"), 2),), window=8,
              rglru=RGLRUConfig(lru_width=64, conv_width=4)),
    "vlm": mk("vlm", blocks=((("attn", "attn", "xattn"), 2),),
              num_image_tokens=5),
    "audio": mk("audio", blocks=dense_blocks(3), num_codebooks=2,
                vocab_size=17),
}

B, T = 2, 32


def setup(name):
    cfg = CFGS[name]
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    shape = (B, T, cfg.num_codebooks) if cfg.num_codebooks else (B, T)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    img = (jax.random.normal(key, (B, 5, cfg.d_model), jnp.bfloat16)
           if cfg.num_image_tokens else None)
    return cfg, params, toks, img


@pytest.mark.parametrize("name", list(CFGS))
def test_forward_shapes_no_nan(name):
    cfg, params, toks, img = setup(name)
    hidden, _, aux = forward_hidden(params, cfg, toks, image_embeds=img)
    assert hidden.shape == (B, T, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", list(CFGS))
def test_chunked_scoring_matches_full_logits(name):
    cfg, params, toks, img = setup(name)
    logp, _ = score_tokens(params, cfg, toks, image_embeds=img, vocab_chunks=1)
    fl = full_logits(params, cfg, toks, image_embeds=img)
    if cfg.num_codebooks:
        ref = sum(
            np.take_along_axis(
                np.asarray(jax.nn.log_softmax(fl[:, :-1, k], -1)),
                np.asarray(toks)[:, 1:, k][..., None], -1)[..., 0]
            for k in range(cfg.num_codebooks))
    else:
        ref = np.take_along_axis(
            np.asarray(jax.nn.log_softmax(fl[:, :-1], -1)),
            np.asarray(toks)[:, 1:][..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(logp)[:, 1:], ref, rtol=2e-2,
                               atol=2e-2)
    assert np.all(np.asarray(logp) <= 1e-4)


@pytest.mark.parametrize("name", list(CFGS))
def test_prefill_decode_matches_teacher_forcing(name):
    cfg, params, toks, img = setup(name)
    pl = jnp.full((B,), T, jnp.int32)
    last_logits, cache = prefill(params, cfg, toks, cache_len=T + 8,
                                 prefill_len=pl, image_embeds=img)
    fl = full_logits(params, cfg, toks, image_embeds=img)
    np.testing.assert_allclose(np.asarray(last_logits, np.float32),
                               np.asarray(fl[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)
    if cfg.num_codebooks:
        nxt = jax.random.randint(jax.random.PRNGKey(1),
                                 (B, cfg.num_codebooks), 0, cfg.vocab_size)
        toks2 = jnp.concatenate([toks, nxt[:, None, :]], axis=1)
    else:
        nxt = jax.random.randint(jax.random.PRNGKey(1), (B,), 0, cfg.vocab_size)
        toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    dl, _ = decode_step(params, cfg, nxt, cache, jnp.full((B,), T, jnp.int32))
    fl2 = full_logits(params, cfg, toks2, image_embeds=img)
    np.testing.assert_allclose(np.asarray(dl, np.float32),
                               np.asarray(fl2[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.slow
def test_variable_prefill_lengths():
    """Rows with different prompt lengths decode correctly (padding never
    leaks into caches — incl. recurrent states)."""
    for name in ("dense", "ssm", "rec", "local"):
        cfg, params, toks, img = setup(name)
        if cfg.num_codebooks:
            continue
        pl = jnp.array([T, T // 2], jnp.int32)
        _, cache = prefill(params, cfg, toks, cache_len=T + 8, prefill_len=pl)
        nxt = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, cfg.vocab_size)
        dl, _ = decode_step(params, cfg, nxt, cache, pl)
        # row 1 reference: forward on its true prefix + the new token
        short = jnp.concatenate([toks[1:2, :T // 2], nxt[1:2][:, None]], axis=1)
        fl = full_logits(params, cfg, short)
        np.testing.assert_allclose(
            np.asarray(dl[1], np.float32), np.asarray(fl[0, -1], np.float32),
            rtol=6e-2, atol=6e-2, err_msg=name)


def test_banded_local_attention_exact():
    """The O(T·w) banded path must equal the masked O(T^2) path."""
    from repro.models import attention as A
    key = jax.random.PRNGKey(3)
    b, t, h, d, w = 2, 64, 4, 16, 16
    q = jax.random.normal(key, (b, t, h, d), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, h, d)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, h, d)) * 0.3
    lengths = jnp.array([64, 40])
    scale = 1.0 / np.sqrt(d)
    banded = A._banded_local_attention(q, k, v, w, scale, lengths)
    mask = A.causal_window_mask(t, t, w)[None, None]
    mask = mask & (jnp.arange(t)[None, None, None, :] < lengths[:, None, None, None])
    full = A.sdpa(q, k, v, mask, scale)
    valid_q = np.arange(t)[None, :] < np.asarray(lengths)[:, None]
    np.testing.assert_allclose(
        np.asarray(banded)[valid_q], np.asarray(full)[valid_q],
        rtol=1e-4, atol=1e-5)
