"""GRPO objective components (Eqs. 1-5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grpo import (
    GRPOConfig, clipped_surrogate, group_advantages, kl_k3, nat_grpo_loss,
    token_entropy_from_logits, token_logprobs_from_logits,
)


def test_group_advantages_normalization():
    r = jnp.array([[1.0, 0.0, 1.0, 0.0], [1.0, 1.0, 1.0, 1.0]])
    a = np.asarray(group_advantages(r))
    np.testing.assert_allclose(a[0].mean(), 0.0, atol=1e-6)
    np.testing.assert_allclose(a[0].std(), 1.0, atol=1e-3)
    # degenerate group (all equal): advantages ~ 0, no NaN
    assert np.all(np.isfinite(a[1]))
    np.testing.assert_allclose(a[1], 0.0, atol=1e-3)


def test_token_logprobs_and_entropy(key):
    logits = jax.random.normal(key, (2, 5, 11))
    toks = jax.random.randint(key, (2, 5), 0, 11)
    lp = token_logprobs_from_logits(logits, toks)
    ref = np.take_along_axis(
        np.asarray(jax.nn.log_softmax(logits, -1)),
        np.asarray(toks)[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(np.asarray(lp), ref, rtol=1e-5, atol=1e-6)
    ent = token_entropy_from_logits(logits)
    p = np.asarray(jax.nn.softmax(logits, -1))
    ref_e = -(p * np.log(p)).sum(-1)
    np.testing.assert_allclose(np.asarray(ent), ref_e, rtol=1e-4, atol=1e-5)


def f(x):
    return float(jnp.asarray(x).reshape(()))


def test_clipping_behavior():
    adv = jnp.array([[1.0]])
    # ratio above 1+eps with positive advantage -> clipped
    s_hi, clipped = clipped_surrogate(jnp.array([[1.0]]), jnp.array([[0.0]]),
                                      adv, clip_eps=0.2)
    np.testing.assert_allclose(f(s_hi), 1.2, rtol=1e-5)
    assert f(clipped) == 1.0
    # ratio inside the trust region -> untouched
    s_in, cl2 = clipped_surrogate(jnp.array([[0.05]]), jnp.array([[0.0]]),
                                  adv, clip_eps=0.2)
    np.testing.assert_allclose(f(s_in), float(jnp.exp(0.05)), rtol=1e-5)
    assert f(cl2) == 0.0
    # negative advantage: min() takes the unclipped (more pessimistic) branch
    s_neg, _ = clipped_surrogate(jnp.array([[1.0]]), jnp.array([[0.0]]),
                                 -adv, clip_eps=0.2)
    np.testing.assert_allclose(f(s_neg), -float(jnp.exp(1.0)), rtol=1e-5)


def test_kl_k3_nonnegative(key):
    a = jax.random.normal(key, (100,))
    b = jax.random.normal(jax.random.fold_in(key, 1), (100,))
    kl = kl_k3(a, b)
    assert np.all(np.asarray(kl) >= 0)
    np.testing.assert_allclose(np.asarray(kl_k3(a, a)), 0.0, atol=1e-6)


def test_kl_regularizer_enters_loss(key):
    logp = -jnp.abs(jax.random.normal(key, (2, 8)))
    rm = jnp.ones((2, 8))
    adv = jnp.array([1.0, -1.0])
    ref_logp = logp - 0.5
    l0, _ = nat_grpo_loss(logp, logp, adv, rm, rm.sum(-1),
                          GRPOConfig(kl_beta=0.0), ref_logp=ref_logp)
    l1, m1 = nat_grpo_loss(logp, logp, adv, rm, rm.sum(-1),
                           GRPOConfig(kl_beta=0.5), ref_logp=ref_logp)
    assert float(l1) > float(l0)  # KL penalty reduces the objective
    assert m1["kl"] > 0


def test_dapo_clip_higher():
    adv = jnp.array([[1.0]])
    s, _ = clipped_surrogate(jnp.array([[1.0]]), jnp.array([[0.0]]), adv,
                             clip_eps=0.2, clip_eps_high=0.5)
    np.testing.assert_allclose(f(s), 1.5, rtol=1e-5)
