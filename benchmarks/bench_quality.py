"""Table 2 / Figures 1-2 analog: accuracy parity of NAT schemes with
full-token GRPO on a verifiable task, multi-seed with 95% CIs.

Trains the same tiny model with GRPO / URS / Det-Trunc / RPC on modular
arithmetic; reports greedy accuracy, final reward, behaviour entropy, and
mean learner tokens per step.  The paper's claim to reproduce: URS and RPC
within CI of GRPO; Det-Trunc directionally worse / less stable.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ci95, emit
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import NATGRPOTrainer, NATTrainerConfig, RolloutConfig, VOCAB_SIZE

ALGOS = [
    ("grpo", "full", ()),
    ("urs", "urs", (("p", 0.5),)),
    ("det_trunc", "det_trunc", ()),
    ("rpc", "rpc", (("min_cut", 4),)),
]


def model():
    return ModelConfig(name="q", d_model=128, n_heads=4, n_kv_heads=2,
                       head_dim=32, d_ff=384, vocab_size=VOCAB_SIZE,
                       blocks=dense_blocks(3), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


def run(steps: int = 60, seeds=(0, 1, 2), eval_prompts: int = 48) -> dict:
    print("# bench_quality (Table 2 / Fig 1-2): NAT vs GRPO on mod-arith")
    print(f"{'algo':10s} {'acc@greedy':>16s} {'reward':>14s} "
          f"{'entropy':>13s} {'tokens/step':>12s}")
    out = {}
    for name, sel, kw in ALGOS:
        accs, rewards, ents, toks = [], [], [], []
        t0 = time.perf_counter()
        for seed in seeds:
            tc = NATTrainerConfig(
                selector=sel, selector_kwargs=kw,
                prompts_per_step=8, max_prompt_len=16,
                rollout=RolloutConfig(max_new_tokens=8, group_size=8,
                                      overprovision=1.0),
                adamw=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps),
                grpo=__import__("repro.core.grpo", fromlist=["GRPOConfig"]
                                ).GRPOConfig(clip_eps=0.2),
                bucket_align=8, seed=seed,
                env_kwargs=(("max_val", 9), ("mod", 10)),  # single-digit task
            )
            tr = NATGRPOTrainer(model(), tc)
            hist = tr.run(steps)
            ev = tr.evaluate(eval_prompts)
            accs.append(ev["accuracy"])
            rewards.append(np.mean([m["reward_mean"] for m in hist[-10:]]))
            ents.append(np.mean([m["entropy_behavior"] for m in hist[-10:]]))
            toks.append(np.mean([m["learner_tokens"] for m in hist]))
        dt = time.perf_counter() - t0
        (am, ah), (rm_, rh), (em, eh) = ci95(accs), ci95(rewards), ci95(ents)
        print(f"{name:10s} {am:8.3f}±{ah:<6.3f} {rm_:8.3f}±{rh:<4.3f} "
              f"{em:8.3f}±{eh:<4.3f} {np.mean(toks):11.0f}")
        out[name] = dict(acc=am, acc_ci=ah, reward=rm_, entropy=em,
                         tokens=float(np.mean(toks)))
        emit(f"quality/{name}", dt / (len(seeds) * steps),
             f"acc={am:.3f}+-{ah:.3f};tok={np.mean(toks):.0f}")
    return out


if __name__ == "__main__":
    run()
