"""Table 1 analog: measured properties of each token-efficient method.

For each selector we MEASURE (not assert) on a small learner:
  * forward FLOPs and backward+forward FLOPs of the learner step
    (XLA cost analysis; RPC/Det-Trunc get their physical repack, so their
    forward shrinks — URS only zeroes loss terms),
  * gradient bias vs full-token GRPO (MC),
giving the Unbiased? / Forward savings / Backward savings matrix.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.grpo import GRPOConfig
from repro.launch.hlo_stats import cost_stats
from repro.core.selectors import make_selector
from repro.models.config import ModelConfig, dense_blocks
from repro.models import init_params, model_decl
from repro.rl.learner import make_loss_fn

B, T = 8, 256


def flops_of(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile()
    return cost_stats(c)["flops"]


def run(draws: int = 150) -> None:
    cfg = ModelConfig(name="bench", d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=512,
                      blocks=dense_blocks(2), seq_parallel=False,
                      remat_policy="none", scan_layers=False)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    rm = (jnp.arange(T)[None] >= 16).astype(jnp.float32) * jnp.ones((B, 1))
    lengths = rm.sum(-1)
    loss_fn = make_loss_fn(cfg, GRPOConfig(), vocab_chunks=1)

    def batch_for(w, t_phys):
        return {
            "tokens": toks[:, :t_phys],
            "old_logp": -jnp.abs(jax.random.normal(key, (B, t_phys))) * rm[:, :t_phys],
            "advantages": jax.random.normal(key, (B,)),
            "ht_weights": w[:, :t_phys],
            "orig_lengths": lengths,
            "lengths": jnp.full((B,), t_phys, jnp.int32),
            "response_mask": rm[:, :t_phys],
        }

    # reference: full tokens
    full_w = rm
    f_fwd = flops_of(lambda p, b: loss_fn(p, b)[0], params, batch_for(full_w, T))
    f_all = flops_of(jax.grad(lambda p, b: loss_fn(p, b)[0]), params,
                     batch_for(full_w, T))

    # reference gradient for bias measurement
    grad_fn = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))

    def flat_grad(batch):
        g = grad_fn(params, batch)
        return jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                                for x in jax.tree.leaves(g)])

    g_ref_v = flat_grad(batch_for(full_w, T))

    print("# bench_method_matrix (Table 1): measured method properties")
    print(f"{'method':11s} {'fwd_flops%':>10s} {'fwd+bwd%':>9s} "
          f"{'grad_bias':>10s} {'unbiased?':>9s}")
    rows = [("full", "full", {}, T),
            ("urs", "urs", {"p": 0.5}, T),
            ("det_trunc", "det_trunc", {}, T // 2 + 16),
            ("rpc", "rpc", {"min_cut": 16}, None)]
    for name, sel_name, kw, t_phys in rows:
        sel = make_selector(sel_name, **kw)
        t0 = time.perf_counter()
        # expected physical length for RPC: bucket at ~E[L] + prompt
        if t_phys is None:
            t_phys = 16 + ((T - 16) + 16) // 2 + 32  # prompt + E[L] + slack
        gsum_a = gsum_b = None
        for i in range(draws):
            s = sel(jax.random.fold_in(key, i), rm)
            g = flat_grad(batch_for(s.ht_weights, T))
            if i % 2 == 0:
                gsum_a = g if gsum_a is None else gsum_a + g
            else:
                gsum_b = g if gsum_b is None else gsum_b + g
        na, nb = (draws + 1) // 2, draws // 2
        gmc = (gsum_a + gsum_b) / draws
        ref_norm = float(jnp.linalg.norm(g_ref_v))
        bias = float(jnp.linalg.norm(gmc - g_ref_v)) / ref_norm
        # split-half MC noise floor: ||mean_a - mean_b||/2 estimates the
        # sampling error of gmc — "biased" means bias >> noise
        noise = float(jnp.linalg.norm(gsum_a / na - gsum_b / nb)) / (2 * ref_norm)
        m_fwd = flops_of(lambda p, b: loss_fn(p, b)[0], params,
                         batch_for(sel(key, rm).ht_weights, t_phys))
        m_all = flops_of(jax.grad(lambda p, b: loss_fn(p, b)[0]), params,
                         batch_for(sel(key, rm).ht_weights, t_phys))
        unb = "yes" if bias < max(3 * noise, 0.05) else "NO"
        print(f"{name:11s} {100 * m_fwd / f_fwd:9.1f}% {100 * m_all / f_all:8.1f}% "
              f"{bias:10.4f} (noise {noise:.3f}) {unb:>4s}")
        emit(f"method_matrix/{name}", (time.perf_counter() - t0) / draws,
             f"fwd={m_fwd / f_fwd:.3f};fwdbwd={m_all / f_all:.3f};"
             f"bias={bias:.4f};noise={noise:.4f}")


if __name__ == "__main__":
    run()
