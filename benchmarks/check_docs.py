"""Docs citation lint (blocking in the CI lint job).

Two classes of rot this catches:

* **Dead section citations.**  Source docstrings, tests, benchmarks and
  the README cite design decisions as ``DESIGN.md §N`` (including list
  forms like ``§3, §6`` and ``§8/§11``).  Every cited §N must resolve to
  a real ``## §N`` heading in DESIGN.md — a renumbered or deleted
  section breaks the citation, and a broken citation is worse than
  none.
* **Absent path references.**  README.md and ROADMAP.md must only name
  repo paths that exist (backquoted ``src/...``-style tokens and
  relative markdown-link targets), and must not reference absolute
  machine-local paths (``/root/...``) that mean nothing to a reader of
  the repo.

Run it from the repo root:

    python -m benchmarks.check_docs
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# one citation may carry several sections: "DESIGN.md §8/§11",
# "DESIGN.md §3, §6, §8" — capture the whole span, then each number
CITE_RE = re.compile(r"DESIGN\.md\s+(§\d+(?:\s*[,/]\s*§\d+)*)")
HEADING_RE = re.compile(r"^## §(\d+)\b", re.M)

# repo-relative path tokens inside backticks; a trailing ::Symbol names
# a member inside the file and is not part of the path
PATH_TOKEN_RE = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples|configs)/[\w./-]+)"
    r"(?:::[\w.]+)?`")
MD_LINK_RE = re.compile(r"\]\(([^)#]+?)(?:#[^)]*)?\)")
ABS_PATH_RE = re.compile(r"/root/[\w./-]+")

CITE_GLOBS = ("src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py",
              "docs/**/*.md", "README.md")
PATH_FILES = ("README.md", "ROADMAP.md")


def design_sections() -> set[int]:
    return {int(n) for n in
            HEADING_RE.findall((ROOT / "DESIGN.md").read_text())}


def check_citations(errors: list[str]) -> int:
    known = design_sections()
    seen = 0
    for pattern in CITE_GLOBS:
        for path in sorted(ROOT.glob(pattern)):
            rel = path.relative_to(ROOT)
            for i, line in enumerate(path.read_text().splitlines(), 1):
                for span in CITE_RE.findall(line):
                    for num in re.findall(r"\d+", span):
                        seen += 1
                        if int(num) not in known:
                            errors.append(
                                f"{rel}:{i}: cites DESIGN.md §{num} but "
                                f"DESIGN.md has no '## §{num}' heading")
    return seen


def check_paths(errors: list[str]) -> int:
    seen = 0
    for name in PATH_FILES:
        path = ROOT / name
        for i, line in enumerate(path.read_text().splitlines(), 1):
            for target in ABS_PATH_RE.findall(line):
                errors.append(
                    f"{name}:{i}: references machine-local path "
                    f"'{target}' — use a repo-relative path or drop it")
            tokens = PATH_TOKEN_RE.findall(line)
            if name.endswith(".md"):
                tokens += [t for t in MD_LINK_RE.findall(line)
                           if "://" not in t and not t.startswith("/")]
            for target in tokens:
                seen += 1
                if not (ROOT / target).exists():
                    errors.append(
                        f"{name}:{i}: references '{target}' which does "
                        f"not exist in the repo")
    return seen


def main() -> int:
    errors: list[str] = []
    n_cites = check_citations(errors)
    n_paths = check_paths(errors)
    if errors:
        for e in errors:
            print(f"check_docs: {e}", file=sys.stderr)
        print(f"check_docs: FAIL ({len(errors)} problem(s))",
              file=sys.stderr)
        return 1
    print(f"check_docs: OK — {n_cites} section citations resolve "
          f"({sorted(design_sections())} known), {n_paths} path "
          f"references exist")
    return 0


if __name__ == "__main__":
    sys.exit(main())
