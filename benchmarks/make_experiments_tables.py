"""Assemble the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONL artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments_tables \
        --in experiments/dryrun.jsonl --out experiments/tables.md
"""
from __future__ import annotations

import argparse
import os

from benchmarks.roofline import load, markdown, table


def dryrun_table(recs) -> str:
    out = ["| arch | shape | mesh | status | compile | peak GiB/dev | "
           "micro (rows×n) |",
           "|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(recs.items()):
        mem = r.get("memory", {})
        micro = (f"{r.get('micro_rows','-')}×{r.get('num_micro','-')}"
                 if "micro_rows" in r else "—")
        out.append(
            f"| {arch} | {shape} | {mesh} | {r['status']} | "
            f"{r.get('compile_s', float('nan')):.0f}s | "
            f"{mem.get('peak_bytes', 0) / 2**30:.1f} | {micro} |")
    return "\n".join(out)


def collective_table(recs, mesh="single") -> str:
    out = ["| arch | shape | HLO flops/dev | bytes/dev | coll bytes/dev | "
           "AG | AR | RS | A2A | CP |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or "probe_total_per_dev" not in r:
            continue
        t = r["probe_total_per_dev"]
        sc = r.get("scan_cost", {}).get("coll", {})
        gb = 1e9

        def f(k):
            return f"{sc.get(k, 0) / gb:.1f}"

        out.append(
            f"| {arch} | {shape} | {t['flops']:.2e} | {t['bytes']:.2e} | "
            f"{t['coll']:.2e} | {f('all-gather')} | {f('all-reduce')} | "
            f"{f('reduce-scatter')} | {f('all-to-all')} | "
            f"{f('collective-permute')} |")
    out.append("")
    out.append("(per-op columns in GB/device from the SCANNED compile — "
               "per-iteration costs, not totals; totals come from the "
               "probe extrapolation column.)")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inputs", nargs="*",
                    default=["experiments/dryrun.jsonl"])
    ap.add_argument("--out", default="experiments/tables.md")
    args = ap.parse_args()
    recs = load(args.inputs)
    parts = [
        "## Dry-run cells (compile + memory)\n", dryrun_table(recs),
        "\n\n## Roofline (single-pod, probe-extrapolated)\n",
        markdown(table(recs, "single")),
        "\n\n## Collective detail\n", collective_table(recs),
    ]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(parts) + "\n")
    print(f"wrote {args.out} ({len(recs)} records)")


if __name__ == "__main__":
    main()
