"""§Roofline report generator: reads the dry-run JSONL artifacts and emits
the per-(arch × shape) roofline table (markdown) with the three terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and a what-would-move-it
note per row."""
from __future__ import annotations

import argparse
import json
import os
from collections import OrderedDict

NOTES = {
    ("compute", "train"): "raise arithmetic intensity: fuse HT head, larger "
                          "microbatch, bf16 remat",
    ("compute", "prefill"): "attention-bound: banded/flash kernels, shorter "
                            "effective T via RPC",
    ("compute", "decode"): "batch more concurrent sequences per chip",
    ("memory", "train"): "cut optimizer/grad traffic: int8 moments, fewer "
                         "microbatch weight re-reads",
    ("memory", "prefill"): "KV/activation layout; fuse QKV; wider tiles",
    ("memory", "decode"): "weight-bound: quantize weights / multi-token "
                          "speculation to amortize reads",
    ("collective", "train"): "shrink FSDP all-gathers: replicate small "
                             "weights, overlap with compute, 2D-shard",
    ("collective", "prefill"): "reshard activations less; overlap collectives",
    ("collective", "decode"): "replicate params over idle axes; shrink "
                              "all-reduce payloads",
}


def load(paths):
    recs = OrderedDict()
    for p in paths:
        if not os.path.exists(p):
            continue
        for line in open(p):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return recs


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(recs, mesh="single"):
    rows = []
    for (arch, shape, m), r in recs.items():
        if m != mesh or r.get("status") != "ok":
            continue
        rl = r.get("roofline")
        if not rl:
            continue
        kind = ("train" if shape.startswith("train") else
                "prefill" if shape.startswith("prefill") else "decode")
        rows.append({
            "arch": arch, "shape": shape,
            "compute": rl["compute_s"], "memory": rl["memory_s"],
            "collective": rl["collective_s"], "dominant": rl["dominant"],
            "frac": rl["roofline_fraction"],
            "useful": r.get("useful_ratio", float("nan")),
            "note": NOTES.get((rl["dominant"], kind), ""),
            "mem_gib": r.get("memory", {}).get("peak_bytes", 0) / 2**30,
        })
    return rows


def markdown(rows):
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "roofline-frac | useful (6ND/HLO) | peak GiB/dev | move it down by |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute'])} | "
            f"{fmt_s(r['memory'])} | {fmt_s(r['collective'])} | "
            f"**{r['dominant']}** | {r['frac']:.2f} | {r['useful']:.2f} | "
            f"{r['mem_gib']:.1f} | {r['note']} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inputs", nargs="*",
                    default=["experiments/dryrun.jsonl"])
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.inputs)
    rows = table(recs, args.mesh)
    if not rows:
        print("# roofline: no probe records found (run the dry-run with "
              "--probes first)")
        return
    print(markdown(rows))


if __name__ == "__main__":
    main()
