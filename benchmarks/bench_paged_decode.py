"""Paged vs dense arena at G-sibling GRPO groups: decode throughput and
KV-arena memory (DESIGN.md §8).

The workload is the rollout engine's steady state: P prompts, G = 8
samples each, served through the same slot width on both arenas.  The
dense arena duplicates every prompt's KV into each sibling's private rows
and re-prefills it G times; the paged arena prefills once per group into
refcounted shared pages, so

  * prompt-KV bytes per group scale O(1) in G instead of O(G) — gated as
    ``paged/prompt_kv_bytes_ratio <= 1/G + slack``,
  * decode throughput must stay within 5% of the dense arena
    (``paged/decode_tps_ratio``): the block-table gather buys memory, not
    time, and must not cost time either.

Peak arena bytes are exact bookkeeping, not an allocator estimate: every
KV byte of both arenas is a static buffer (dense: slots x cache_len rows;
paged: the page pool), and the paged engine additionally reports its peak
pages in use.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.rl.engine import (
    ContinuousRolloutEngine,
    EngineConfig,
    PagedEngineConfig,
    PagedRolloutEngine,
    Request,
)
from repro.rl.rollout import RolloutConfig

SLOTS = 8           # device batch width for BOTH arenas
P_PROMPTS = 8       # distinct prompts
G = 8               # siblings per group (the paper's GRPO group size)
MAX_NEW = 64        # decode budget
TP = 32             # prompt width (full prompts: sharing is the point)
PAGE_LEN = 16
STEPS_PER_SYNC = 8
ITERS = 2           # best-of-N wall times (CI runners are noisy)


def _model():
    return ModelConfig(name="bench-paged", d_model=256, n_heads=8,
                       n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
                       blocks=dense_blocks(4), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


def _groups(rng, cfg):
    prompts = rng.integers(3, cfg.vocab_size, size=(P_PROMPTS, TP)).astype(
        np.int32)
    # straggler mix inside each group: most siblings short, one full-budget
    budgets = np.array(
        [[MAX_NEW if j == 0 else int(rng.integers(8, 25)) for j in range(G)]
         for _ in range(P_PROMPTS)], np.int32)
    return prompts, budgets


def _requests(prompts, budgets):
    return [[Request(uid=p * G + j, tokens=prompts[p], budget=int(budgets[p, j]))
             for j in range(G)] for p in range(P_PROMPTS)]


def _serve(engine, params, groups, key) -> float:
    engine.run_groups(params, groups[:1], key)  # compile prefill + step
    best = float("inf")
    for _ in range(ITERS):
        t0 = time.perf_counter()
        engine.run_groups(params, groups, key)
        best = min(best, time.perf_counter() - t0)
    return best


def _kv_bytes_per_token(cfg) -> int:
    # k + v, bf16 storage dtype (2 bytes), per layer
    return 2 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers


def run() -> dict:
    cfg = _model()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    rng = np.random.default_rng(0)
    prompts, budgets = _groups(rng, cfg)
    groups = _requests(prompts, budgets)
    rcfg = RolloutConfig(max_new_tokens=MAX_NEW, temperature=1.0, eos_id=-1)

    dense = ContinuousRolloutEngine(
        cfg, rcfg, EngineConfig(num_slots=SLOTS, max_prompt_len=TP,
                                steps_per_sync=STEPS_PER_SYNC))
    paged = PagedRolloutEngine(
        cfg, rcfg, PagedEngineConfig(num_slots=SLOTS, max_prompt_len=TP,
                                     steps_per_sync=STEPS_PER_SYNC,
                                     page_len=PAGE_LEN, max_group=G))

    t_dense = _serve(dense, params, groups, key)
    t_paged = _serve(paged, params, groups, key)
    tokens = int(budgets.sum())
    tps_dense = tokens / t_dense
    tps_paged = tokens / t_paged
    tps_ratio = tps_paged / tps_dense

    bpt = _kv_bytes_per_token(cfg)
    # prompt KV held per group while it decodes: dense gives every sibling
    # a private copy of the prompt rows; paged holds one refcounted set of
    # prompt pages (page-quantized).  MEASURED from the engine's prefill
    # counter, not restated from config: if prefix sharing ever regresses
    # to per-sibling prefills, prompt_prefills grows G-fold and the gate
    # fails — a constant formula could never catch that
    n_pp = -(-TP // PAGE_LEN)
    n_req = P_PROMPTS * G
    dense_prompt_bytes = G * TP * bpt
    paged_prompt_bytes = (paged.stats["prompt_prefills"] * n_pp * PAGE_LEN
                          * bpt // P_PROMPTS)
    prompt_ratio = (paged.stats["prompt_prefills"] * n_pp * PAGE_LEN
                    / (n_req * TP))
    # whole-arena peaks: dense commits slots x cache_len rows up front;
    # paged commits only the pages actually in flight at the peak
    dense_arena_bytes = SLOTS * (TP + MAX_NEW) * bpt
    paged_peak_bytes = paged.stats["peak_pages_in_use"] * PAGE_LEN * bpt

    print(f"# bench_paged_decode: {P_PROMPTS} prompts x G={G}, "
          f"{SLOTS} slots, prompt {TP}, budget {MAX_NEW}, "
          f"page_len {PAGE_LEN}")
    print(f"{'arena':8s} {'time(s)':>8s} {'tok/s':>8s} "
          f"{'prompt KV/group':>16s} {'peak arena':>12s}")
    print(f"{'dense':8s} {t_dense:8.2f} {tps_dense:8.1f} "
          f"{dense_prompt_bytes:16,d} {dense_arena_bytes:12,d}")
    print(f"{'paged':8s} {t_paged:8.2f} {tps_paged:8.1f} "
          f"{paged_prompt_bytes:16,d} {paged_peak_bytes:12,d}")
    print(f"prompt_kv_bytes_ratio={prompt_ratio:.3f} (1/G={1 / G:.3f}), "
          f"decode_tps_ratio={tps_ratio:.2f}, "
          f"paged peak pages {paged.stats['peak_pages_in_use']}"
          f"/{paged.num_pages}")

    emit("paged/dense_decode", t_dense,
         f"tok_s={tps_dense:.1f};arena_bytes={dense_arena_bytes}")
    emit("paged/paged_decode", t_paged,
         f"tok_s={tps_paged:.1f};peak_arena_bytes={paged_peak_bytes};"
         f"prompt_prefills={paged.stats['prompt_prefills']}")
    emit("paged/decode_tps_ratio", abs(t_dense - t_paged),
         f"tps_ratio={tps_ratio:.3f}")
    emit("paged/prompt_kv_bytes_ratio", 0.0,
         f"prompt_kv_bytes_ratio={prompt_ratio:.4f}")
    return {"tps_ratio": tps_ratio, "prompt_kv_bytes_ratio": prompt_ratio,
            "paged_peak_bytes": paged_peak_bytes,
            "dense_arena_bytes": dense_arena_bytes}


if __name__ == "__main__":
    run()
