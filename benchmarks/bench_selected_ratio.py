"""Figure 3 analog: RPC selected-token ratio across training steps.

The paper observes ~0.54-0.56 with C=100 on ~E[T]-length responses; the
prediction is 0.5 + C/(2 E[T]).  We run the real trainer and compare the
measured per-step ratio with the prediction for our response lengths.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ci95, emit
from repro.core.repack import expected_token_savings
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import NATGRPOTrainer, NATTrainerConfig, RolloutConfig, VOCAB_SIZE


def run(steps: int = 12, min_cut: int = 6) -> None:
    cfg = ModelConfig(name="tiny", d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=VOCAB_SIZE,
                      blocks=dense_blocks(2), seq_parallel=False,
                      remat_policy="none", scan_layers=False)
    tc = NATTrainerConfig(
        selector="rpc", selector_kwargs=(("min_cut", min_cut),),
        prompts_per_step=4, max_prompt_len=16,
        rollout=RolloutConfig(max_new_tokens=24, group_size=4, eos_id=-1),
        adamw=AdamWConfig(lr=3e-4, warmup_steps=5, total_steps=steps),
        bucket_align=8, seed=0)
    tr = NATGRPOTrainer(cfg, tc)
    t0 = time.perf_counter()
    hist = tr.run(steps)
    dt = time.perf_counter() - t0
    ratios = [m["selected_ratio"] for m in hist]
    lens = [m["resp_len_mean"] for m in hist]
    pred = expected_token_savings(np.full(16, np.mean(lens)), min_cut)
    m, h = ci95(ratios)
    print("# bench_selected_ratio (Fig. 3): RPC kept-token ratio per step")
    print(f"  measured ratio = {m:.3f} ± {h:.3f}   "
          f"prediction 0.5 + C/2E[T] = {pred:.3f}")
    print(f"  per-step: {['%.2f' % r for r in ratios]}")
    emit("selected_ratio/rpc", dt / steps, f"ratio={m:.3f};pred={pred:.3f}")


if __name__ == "__main__":
    run()
