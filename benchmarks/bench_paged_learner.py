"""Zero re-prefill teacher forcing: the learner scores straight from the
rollout engine's paged KV pool (DESIGN.md §11).

The packed learner (bench_packed_learner.py) already stopped scoring dead
PAD tokens — but it still RE-FORWARDS every prompt token to rebuild KV
the rollout engine just computed.  This bench closes that loop: the paged
engine rolls out with ``learner_retain=True``, ``export_learner_pages``
hands the learner a compacted pool + block tables, and
``core.layout.PagedLayout`` packs only ``[P-1, hull)`` suffixes — one
re-forwarded token per response (the segment head, so the response's
first token gets a true logp) instead of P.

The workload is the GRPO steady state the paged arena is built for:
P prompts x G siblings with the 80/20 straggler mix; siblings share
prompt pages, so the exported pool is O(P), not O(B).

Emitted rows (BENCH_* perf trajectory, gated in benchmarks/check_gates.py):
  paged_learner/step                — paged train-step wall time + speedup
                                      vs the packed baseline
  paged_learner/prefill_token_ratio — prompt tokens the learner forwards,
                                      paged / packed.  Ideal 1/P; CI gates
                                      <= 0.05 (learner re-prefill ~ 0)
  paged_learner/tokens_scored_ratio — scored tokens vs the padded grid;
                                      gates <= 0.65 like the packed lane
  paged_learner/logp_parity         — max |paged - dense| per-token logp
                                      (bounded by the pool's bf16 KV
                                      storage rounding; reported, the
                                      hard parity pins live in
                                      tests/test_paged_score.py)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.grpo import GRPOConfig
from repro.core.layout import make_layout
from repro.core.repack import bucket_ladder
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.models.model import score_tokens
from repro.optim import AdamWConfig, init_opt_state
from repro.rl import VOCAB_SIZE, Request, RolloutConfig
from repro.rl.engine import make_paged_engine
from repro.rl.learner import make_train_step

P_PROMPTS = 8        # distinct prompts
G = 4                # GRPO siblings per prompt -> B = 32 responses
B = P_PROMPTS * G
PROMPT = 24          # prompt length (what zero re-prefill eliminates)
MAX_NEW = 64         # decode budget
T = PROMPT + MAX_NEW
PAGE_LEN = 16
SEED = 0


def _model():
    return ModelConfig(name="bench-paged-learner", d_model=128, n_heads=8,
                       n_kv_heads=4, head_dim=16, d_ff=256,
                       vocab_size=VOCAB_SIZE, blocks=dense_blocks(2),
                       seq_parallel=False, remat_policy="none",
                       scan_layers=False)


def _budgets() -> np.ndarray:
    """80/20 straggler mix per group: sibling 0 runs the full budget, the
    rest stop early (deterministic, mirrors the other perf benches)."""
    out = np.zeros((B,), np.int32)
    for r in range(B):
        out[r] = MAX_NEW if r % G == 0 else 16 + (r * 7919) % 17
    return out


def run():
    cfg = _model()
    gcfg = GRPOConfig()
    ocfg = AdamWConfig(lr=1e-4, warmup_steps=5, total_steps=1000)
    params = init_params(jax.random.PRNGKey(SEED), model_decl(cfg))
    opt = init_opt_state(params, ocfg)
    rng = np.random.default_rng(SEED)

    prompts = rng.integers(3, VOCAB_SIZE, size=(P_PROMPTS, PROMPT)).astype(
        np.int32)
    budgets = _budgets()
    rcfg = RolloutConfig(max_new_tokens=MAX_NEW, temperature=1.0,
                         eos_id=-1, group_size=G)
    eng = make_paged_engine(cfg, rcfg, num_slots=8, max_prompt_len=PROMPT,
                            steps_per_sync=8, page_len=PAGE_LEN,
                            learner_retain=True)
    groups = [[Request(uid=pi * G + j, tokens=prompts[pi],
                       budget=int(budgets[pi * G + j]))
               for j in range(G)] for pi in range(P_PROMPTS)]
    comps = {c.uid: c for c in eng.run_groups(params, groups,
                                              jax.random.PRNGKey(SEED + 1))}
    uids = sorted(comps)
    export = eng.export_learner_pages(uids)
    pool_bytes = sum(int(a.nbytes) for a in
                     jax.tree.leaves(export["pool"]))

    # rollout-shaped dense batch (full-keep teacher forcing)
    grid = np.zeros((B, T), np.int32)
    rlens = np.zeros((B,), np.int32)
    for i, u in enumerate(uids):
        c = comps[u]
        grid[i, :PROMPT] = prompts[u // G]
        grid[i, PROMPT:PROMPT + c.response_len] = c.tokens
        rlens[i] = c.response_len
    rmask = np.zeros((B, T), np.float32)
    for r in range(B):
        rmask[r, PROMPT:PROMPT + rlens[r]] = 1
    old_logp = (rng.standard_normal((B, T)) * 0.1 - 2).astype(np.float32)
    old_logp *= rmask
    batch = {
        "tokens": grid,
        "response_mask": rmask,
        "old_logp": old_logp,
        "advantages": rng.standard_normal(B).astype(np.float32),
        "ht_weights": rmask,          # full keep: every response token
        "orig_lengths": rlens.astype(np.float32),
        "behavior_logp": old_logp,
        "staleness": np.zeros((B,), np.float32),
    }
    prompt_lens = np.full((B,), PROMPT, np.int32)
    ladder = bucket_ladder(T, 4, 128)

    # packed baseline: full hull (prompt + response) per row
    lb_pk = make_layout("packed").build(
        batch, prompt_lens=prompt_lens, response_lens=rlens,
        keep_len=rlens, keep_mask=rmask > 0, prefix_structured=True,
        ladder=ladder)
    step_pk = jax.jit(make_train_step(cfg, gcfg, ocfg, vocab_chunks=1,
                                      packed=True))
    jpk = {k: jnp.asarray(v) for k, v in lb_pk.data.items()}
    t_pk = time_call(lambda bb: step_pk(params, opt, bb), jpk)

    # paged: suffix-only rows + the engine's pool
    lb_pg = make_layout("paged").build(
        batch, prompt_lens=prompt_lens, response_lens=rlens,
        keep_len=rlens, keep_mask=rmask > 0, prefix_structured=True,
        ladder=ladder)
    step_pg = jax.jit(make_train_step(cfg, gcfg, ocfg, vocab_chunks=1,
                                      paged=True))
    jpg = {k: jnp.asarray(v) for k, v in lb_pg.data.items()}
    jpg["pool"] = export["pool"]
    jpg["block_tables"] = export["block_tables"]
    t_pg = time_call(lambda bb: step_pg(params, opt, bb), jpg)
    eng.release_learner_pages()

    # prompt tokens each learner forwards (positions < prompt_len, live)
    def prompt_tokens(d):
        seg = np.asarray(d["segment_ids"])
        pos = np.asarray(d["positions"])
        live = seg < B
        return int((live & (pos < PROMPT)).sum())

    pt_pk = prompt_tokens(lb_pk.data)         # = B * PROMPT
    pt_pg = prompt_tokens(lb_pg.data)         # = B (segment heads only)
    prefill_ratio = pt_pg / max(pt_pk, 1)
    scored_ratio = lb_pg.tokens_scored / (B * T)

    # parity vs the dense grid (bf16 pool rounding bound; hard pins in
    # tests/test_paged_score.py)
    logp_dense, _ = score_tokens(params, cfg, jnp.asarray(grid),
                                 lengths=jnp.asarray(prompt_lens + rlens),
                                 vocab_chunks=1)
    logp_paged, _ = score_tokens(
        params, cfg, jnp.asarray(lb_pg.data["tokens"]),
        positions=jnp.asarray(lb_pg.data["positions"]),
        segment_ids=jnp.asarray(lb_pg.data["segment_ids"]),
        paged_prefix=export["pool"],
        page_tables={"block_tables": export["block_tables"],
                     "seg_start": jnp.asarray(lb_pg.data["seg_start"])},
        vocab_chunks=1)
    ld, lp = np.asarray(logp_dense), np.asarray(logp_paged)
    seg = np.asarray(lb_pg.data["segment_ids"])
    pos = np.asarray(lb_pg.data["positions"])
    sel = (seg < B) & (pos >= PROMPT)
    parity = float(np.abs(lp[sel] - ld[seg[sel], pos[sel]]).max())

    print(f"# paged learner: B={B} ({P_PROMPTS}x{G}) T={T} prompt={PROMPT}")
    print(f"  packed: {lb_pk.tokens_scored} tokens "
          f"({lb_pk.num_rows}x{lb_pk.row_len}), {pt_pk} prompt tokens "
          f"re-forwarded, {t_pk * 1e3:.1f} ms")
    print(f"  paged:  {lb_pg.tokens_scored} tokens "
          f"({lb_pg.num_rows}x{lb_pg.row_len}), {pt_pg} prompt tokens "
          f"re-forwarded, {t_pg * 1e3:.1f} ms "
          f"(pool {pool_bytes / 1e6:.2f} MB, {t_pk / t_pg:.2f}x vs packed)")
    print(f"  prefill_token_ratio {prefill_ratio:.4f} (gate <= 0.05), "
          f"tokens_scored_ratio {scored_ratio:.3f} (gate <= 0.65), "
          f"logp parity {parity:.2e}")

    emit("paged_learner/step", t_pg,
         f"tokens_scored={lb_pg.tokens_scored};rows={lb_pg.num_rows};"
         f"pack_len={lb_pg.row_len};speedup_vs_packed={t_pk / t_pg:.3f};"
         f"pool_bytes={pool_bytes}")
    emit("paged_learner/packed_step", t_pk,
         f"tokens_scored={lb_pk.tokens_scored}")
    emit("paged_learner/prefill_token_ratio", 0.0,
         f"prefill_token_ratio={prefill_ratio:.4f};"
         f"prompt_tokens_eliminated={pt_pk - pt_pg}")
    emit("paged_learner/tokens_scored_ratio", 0.0,
         f"tokens_scored_ratio={scored_ratio:.4f}")
    emit("paged_learner/logp_parity", 0.0, f"logp_parity={parity:.6f}")


if __name__ == "__main__":
    run()
