"""Proposition 1 (paper §3.1/App. A): Monte-Carlo convergence of the
HT-masked loss & gradient to the full-token GRPO values, per selector."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.grpo import full_token_loss_reference, nat_grpo_loss
from repro.core.selectors import DetTruncSelector, RPCSelector, URSSelector


def run(draws: int = 600) -> None:
    b, t = 8, 64
    key = jax.random.PRNGKey(0)
    k1, k2, k3, km = jax.random.split(key, 4)
    logp = -jnp.abs(jax.random.normal(k1, (b, t))) * 0.4
    old = logp + 0.1 * jax.random.normal(k2, (b, t))
    adv = jax.random.normal(k3, (b,))
    rm = (jnp.arange(t)[None] < 48).astype(jnp.float32) * jnp.ones((b, 1))
    lengths = rm.sum(-1)
    full = float(full_token_loss_reference(logp, old, adv, rm))
    g_full = jax.grad(lambda lp: full_token_loss_reference(lp, old, adv, rm))(logp)

    @jax.jit
    def masked_loss_grad(w):
        l, _ = nat_grpo_loss(logp, old, adv, w, lengths)
        g = jax.grad(lambda lp: nat_grpo_loss(lp, old, adv, w, lengths)[0])(logp)
        return l, g

    print("# bench_unbiasedness (Prop 1): |MC mean - full| after N draws")
    print(f"{'selector':14s} {'loss_err':>9s} {'grad_rel_err':>12s} {'verdict':>9s}")
    for name, sel in [("urs_p0.5", URSSelector(p=0.5)),
                      ("urs_p0.25", URSSelector(p=0.25)),
                      ("rpc_C4", RPCSelector(min_cut=4)),
                      ("det_trunc", DetTruncSelector(frac=0.5))]:
        t0 = time.perf_counter()
        ls, gs = [], []
        for i in range(draws):
            s = sel(jax.random.fold_in(km, i), rm)
            l, g = masked_loss_grad(s.ht_weights)
            ls.append(float(l))
            gs.append(g)
        dt = time.perf_counter() - t0
        mc = np.mean(ls)
        gmc = jnp.mean(jnp.stack(gs), 0)
        rel = float(jnp.linalg.norm(gmc - g_full) / jnp.linalg.norm(g_full))
        unbiased = name != "det_trunc"
        verdict = ("PASS" if (rel < 0.12) == unbiased else "FAIL")
        print(f"{name:14s} {abs(mc - full):9.4f} {rel:12.4f} {verdict:>9s}")
        emit(f"unbiasedness/{name}", dt / draws,
             f"loss_err={abs(mc - full):.4f};grad_rel={rel:.4f}")


if __name__ == "__main__":
    run()
