"""Perf-trajectory gates over the committed BENCH_<n>.json artifacts.

The repo commits one ``BENCH_<n>.json`` per perf-relevant PR (the
aggregated output of ``benchmarks.run --json``).  CI regenerates the next
point in the trajectory and gates it against the newest committed one, so
the workflow YAML never embeds filenames or heredoc Python:

    NEXT=$(python -m benchmarks.check_gates --next-name)
    python -m benchmarks.run --only ... --json "$NEXT"
    python -m benchmarks.check_gates "$NEXT"

Gating policy:
  * absolute floors on the headline speedups (rollout/speedup >= 1.5x,
    async/overlap_speedup >= 1.3x), on paged/decode_tps_ratio >= 0.95
    (the paged arena must not trade >5% decode throughput for memory),
    and on the serving lane (serving/cache_hit_rate >= 0.5: the radix trie
    must serve at least half of all prompt tokens under the load-gen mix;
    serving/tps above a collapse floor),
  * absolute ceilings on cost ratios (packed/tokens_scored_ratio <= 0.65:
    the packed learner must keep beating the padded grid by >= 35% scored
    tokens at a 50% keep budget; paged/prompt_kv_bytes_ratio <= 1/G +
    slack: prompt KV per group must stay O(1) in the group size;
    serving/prefill_token_ratio <= 0.5: prompt prefill work sublinear in
    the request count; serving/ttft_ms under a generous wall bound;
    chaos/recovery_overhead_ratio <= 1.5: one killed replica costs at
    most half a clean window),
  * counter-EXACT equalities on the fault-recovery counters
    (chaos/recovery_counters: groups_reclaimed == 1, publish_retries ==
    1) — the injected fault schedule implies those counts
    deterministically, so any drift is a recovery bug, not noise,
  * >10% regression vs the newest committed artifact on those same rows
    (drop for floors, rise for ceilings); pure wall-clock rows AND
    within-run wall-time ratios (rollout/speedup, async/overlap_speedup,
    paged/decode_tps_ratio) are in ABSOLUTE_ONLY and never chained (CPU
    runner noise); floors that measure thread-level parallelism are
    skipped when the producing runner had a single CPU (recorded as
    env.cpu_count in the artifact) — overlap is impossible there by
    construction, and the skip is printed, not silent,
  * a gated row present in the baseline but missing from the fresh run is
    a failure (a silently dropped suite is not a pass),
  * every other shared metric is reported (trajectory visibility), never
    gated — micro-benchmarks on shared CI runners are too noisy to block.

When ``$GITHUB_STEP_SUMMARY`` is set, the delta table and gate verdicts
are also appended there as markdown, so the trajectory renders on the
workflow run page.

``--coverage`` gates the architecture-coverage matrix instead (DESIGN.md
§9): every legal (config, layout, engine) cell recorded in the committed
``benchmarks/coverage_baseline.json`` must still be legal per
``models/capabilities.py`` — coverage can grow, never shrink.  New cells
are reported with a reminder to re-commit the baseline so the ratchet
advances.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

# row name -> (metric key, absolute floor): higher is better
GATES = {
    "rollout/speedup": ("speedup", 1.5),
    "async/overlap_speedup": ("speedup", 1.3),
    # the paged arena buys memory, not time: decode throughput must stay
    # within 5% of the dense arena at G=8 sibling groups
    "paged/decode_tps_ratio": ("tps_ratio", 0.95),
    # the radix trie must serve >= half of all prompt tokens from cached
    # pages under the system-prompt-heavy load-gen mix — a deterministic
    # counter ratio, so it also chains through the trajectory guard
    "serving/cache_hit_rate": ("cache_hit_rate", 0.5),
    # serving throughput floor: pure wall clock, bounded far below the
    # measured value so only a collapse (not runner noise) trips it
    "serving/tps": ("tps", 25.0),
    # replicated rollout fleets (DESIGN.md §12): a fleet of 2 must beat
    # the single-engine async trainer's steady-state step rate — a
    # thread-parallelism floor, skipped loudly on single-CPU runners
    "dist/fleet_speedup": ("speedup", 1.2),
}
# row name -> (metric key, absolute ceiling): lower is better
CEILINGS = {
    "packed/tokens_scored_ratio": ("tokens_scored_ratio", 0.65),
    # prompt KV per GRPO group must scale O(1) in G, not O(G): at G=8 the
    # ideal is 1/G = 0.125; slack covers page-quantization of odd prompts
    "paged/prompt_kv_bytes_ratio": ("prompt_kv_bytes_ratio", 1 / 8 + 0.075),
    # prompt-prefill work must stay sublinear in the request count: the
    # complement of the hit rate, counter-deterministic, chained
    "serving/prefill_token_ratio": ("prefill_token_ratio", 0.5),
    # mean time-to-first-token under the load-gen mix, wall clock
    "serving/ttft_ms": ("ttft_ms", 10_000.0),
    # zero re-prefill teacher forcing (DESIGN.md §11): the paged learner
    # re-forwards ONE prompt token per response (the segment head), never
    # the prompt — ideal 1/P, gated well under re-prefilling anything
    "paged_learner/prefill_token_ratio": ("prefill_token_ratio", 0.05),
    # and its scored-token budget must keep beating the padded grid at
    # least as hard as the packed lane does
    "paged_learner/tokens_scored_ratio": ("tokens_scored_ratio", 0.65),
    # device-to-device weight publication (DESIGN.md §12): the publisher's
    # host-transfer counter is deterministic and must be EXACTLY zero —
    # one staged byte means the d2d path silently fell back to the host
    "dist/publish_host_bytes": ("host_bytes", 0.0),
    # losing a fleet replica mid-window (DESIGN.md §13) may cost at most
    # 50% wall time over the clean window: one group's re-roll plus the
    # elastic join, never a stall until a timeout expires
    "chaos/recovery_overhead_ratio": ("recovery_overhead_ratio", 1.5),
}
# row name -> {metric key: exact value}: deterministic recovery counters.
# The injected fault schedule (one replica death, one transient publish
# fault — benchmarks/bench_fault_recovery.py) implies EXACTLY these
# counts; any drift is lost or duplicated recovery work, not runner noise
EXACT = {
    "chaos/recovery_counters": {"groups_reclaimed": 1.0,
                                "publish_retries": 1.0},
}
REL_REGRESSION = 0.10  # gated metrics may not regress >10% vs the baseline
# rows gated ONLY by their absolute bound: a ratio of (or a raw) CPU wall
# time swings well beyond 10% run-to-run on shared runners, so chaining
# runs via the trajectory guard would fail on pure noise — the
# floor/ceiling above already encodes the whole requirement
ABSOLUTE_ONLY = {"rollout/speedup", "async/overlap_speedup",
                 "paged/decode_tps_ratio", "serving/tps",
                 "serving/ttft_ms", "dist/fleet_speedup",
                 "chaos/recovery_overhead_ratio"}
# floors that measure thread-level parallelism: undefined on a runner with
# one CPU (actor and learner cannot overlap by construction), so they are
# skipped — loudly — when the fresh artifact records cpu_count == 1
PARALLEL_FLOORS = {"async/overlap_speedup", "dist/fleet_speedup"}


def committed_benches(root: str) -> list:
    """[(n, path)] of committed BENCH_<n>.json artifacts, sorted by n."""
    out = []
    for p in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(p))
        if m:
            out.append((int(m.group(1)), p))
    return sorted(out)


def next_name(root: str) -> str:
    benches = committed_benches(root)
    n = benches[-1][0] + 1 if benches else 1
    return f"BENCH_{n}.json"


def _load(path: str) -> tuple:
    with open(path) as f:
        payload = json.load(f)
    rows = {r["name"]: r.get("metrics", {}) for r in payload["rows"]}
    return rows, payload.get("env", {})


def _rows(path: str) -> dict:
    return _load(path)[0]


def _append_step_summary(title: str, deltas: list, gates: list,
                         failures: list) -> None:
    """Markdown delta table into $GITHUB_STEP_SUMMARY (satellite of the
    serving CI lane): the per-metric trajectory and gate verdicts render
    on the workflow run page instead of hiding in the job log."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [f"### Perf gates — {title}", ""]
    if deltas:
        lines += ["| metric | baseline | fresh | delta |",
                  "|---|---:|---:|---:|"]
        lines += [f"| `{n}` | {bv:.4g} | {fv:.4g} | {pct:+.1f}% |"
                  for n, bv, fv, pct in deltas]
        lines.append("")
    if gates:
        lines += ["| gate | value | bound | status |",
                  "|---|---:|---:|---|"]
        lines += [f"| `{n}` | {fv:.3f} | {kind} {bound:g} | {status} |"
                  for n, fv, kind, bound, status in gates]
        lines.append("")
    lines.append("**FAILED:** " + "; ".join(failures) if failures
                 else "**All perf gates passed.**")
    lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def check(fresh_path: str, root: str) -> int:
    fresh, fresh_env = _load(fresh_path)
    single_cpu = fresh_env.get("cpu_count") == 1
    baseline = [(n, p) for n, p in committed_benches(root)
                if os.path.abspath(p) != os.path.abspath(fresh_path)]
    failures = []
    deltas, gate_rows = [], []
    title = os.path.basename(fresh_path)

    if baseline:
        bn, bp = baseline[-1]
        base = _rows(bp)
        title += f" vs BENCH_{bn}.json"
        shared = sorted(set(fresh) & set(base))
        print(f"# perf trajectory: {os.path.basename(fresh_path)} "
              f"vs committed BENCH_{bn}.json ({len(shared)} shared rows)")
        for name in shared:
            for mk in sorted(set(fresh[name]) & set(base[name])):
                fv, bv = fresh[name][mk], base[name][mk]
                if not isinstance(fv, (int, float)) or not isinstance(
                        bv, (int, float)) or bv == 0:
                    continue
                pct = (fv / bv - 1) * 100
                deltas.append((f"{name}:{mk}", bv, fv, pct))
                print(f"  {name}:{mk}: {bv:.4g} -> {fv:.4g} "
                      f"({pct:+.1f}%)")
        for name in EXACT:
            if name in base and name not in fresh:
                failures.append(f"gated row {name} missing from fresh run")
        for gated, lower_is_better in ((GATES, False), (CEILINGS, True)):
            for name, (mk, _bound) in gated.items():
                if name not in base or mk not in base[name]:
                    continue
                if name not in fresh or mk not in fresh[name]:
                    failures.append(f"gated row {name} missing from fresh run")
                    continue
                if name in ABSOLUTE_ONLY:
                    continue  # bound-only: run-to-run ratio noise, no chain
                fv, bv = fresh[name][mk], base[name][mk]
                worse = (fv > bv * (1.0 + REL_REGRESSION) if lower_is_better
                         else fv < bv * (1.0 - REL_REGRESSION))
                if worse:
                    failures.append(
                        f"{name}:{mk} regressed >{REL_REGRESSION:.0%}: "
                        f"{bv:.3f} -> {fv:.3f}")
    else:
        print("# perf trajectory: no committed baseline, floors/ceilings only")

    for name, (mk, floor) in GATES.items():
        if name in fresh and mk in fresh[name]:
            fv = fresh[name][mk]
            if name in PARALLEL_FLOORS and single_cpu:
                print(f"  gate {name}:{mk} = {fv:.3f} (floor {floor}) "
                      "SKIPPED — single-CPU runner, thread overlap "
                      "impossible by construction")
                gate_rows.append((f"{name}:{mk}", fv, "floor", floor,
                                  "skipped (1 cpu)"))
                continue
            status = "ok" if fv >= floor else "FAIL"
            print(f"  gate {name}:{mk} = {fv:.3f} (floor {floor}) {status}")
            gate_rows.append((f"{name}:{mk}", fv, "floor", floor, status))
            if fv < floor:
                failures.append(f"{name}:{mk} below floor {floor}: {fv:.3f}")
    for name, (mk, ceil) in CEILINGS.items():
        if name in fresh and mk in fresh[name]:
            fv = fresh[name][mk]
            status = "ok" if fv <= ceil else "FAIL"
            print(f"  gate {name}:{mk} = {fv:.3f} (ceiling {ceil}) {status}")
            gate_rows.append((f"{name}:{mk}", fv, "ceiling", ceil, status))
            if fv > ceil:
                failures.append(f"{name}:{mk} above ceiling {ceil}: {fv:.3f}")
    for name, exacts in EXACT.items():
        if name not in fresh:
            continue
        for mk, want_v in sorted(exacts.items()):
            if mk not in fresh[name]:
                failures.append(f"{name}:{mk} counter missing from fresh run")
                continue
            fv = fresh[name][mk]
            status = "ok" if fv == want_v else "FAIL"
            print(f"  gate {name}:{mk} = {fv:g} (exact {want_v:g}) {status}")
            gate_rows.append((f"{name}:{mk}", fv, "exact", want_v, status))
            if fv != want_v:
                failures.append(
                    f"{name}:{mk} != exact {want_v:g}: {fv:g} "
                    "(deterministic recovery counter — not noise)")

    _append_step_summary(title, deltas, gate_rows, failures)
    if failures:
        print("# PERF GATES FAILED")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("# perf gates passed")
    return 0


COVERAGE_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "coverage_baseline.json")


def check_coverage(baseline_path: str = COVERAGE_BASELINE,
                   write: bool = False) -> int:
    """Coverage ratchet: the set of legal (config, layout, engine) cells
    may gain members but never lose them vs the committed baseline."""
    from repro.models.capabilities import coverage_cells

    cells = {tuple(c) for c in coverage_cells()}
    if write:
        with open(baseline_path, "w") as f:
            json.dump({"cells": sorted(list(c) for c in cells)}, f, indent=1)
            f.write("\n")
        print(f"# wrote {len(cells)} coverage cells to {baseline_path}")
        return 0
    with open(baseline_path) as f:
        base = {tuple(c) for c in json.load(f)["cells"]}
    lost = sorted(base - cells)
    gained = sorted(cells - base)
    print(f"# coverage matrix: {len(cells)} legal cells "
          f"(baseline {len(base)})")
    for c in gained:
        print(f"  + {c} (new — re-run with --write-coverage to ratchet)")
    if lost:
        print("# COVERAGE GATE FAILED — legal cells disappeared:")
        for c in lost:
            print(f"  - {c}")
        return 1
    print("# coverage gate passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", nargs="?", default="",
                    help="fresh benchmarks.run --json output to gate")
    ap.add_argument("--next-name", action="store_true",
                    help="print the next BENCH_<n>.json filename and exit")
    ap.add_argument("--root", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--coverage", action="store_true",
                    help="gate the architecture-coverage matrix instead")
    ap.add_argument("--write-coverage", action="store_true",
                    help="rewrite the committed coverage baseline")
    args = ap.parse_args(argv)
    if args.next_name:
        print(next_name(args.root))
        return 0
    if args.coverage or args.write_coverage:
        return check_coverage(write=args.write_coverage)
    if not args.fresh:
        ap.error("either --next-name or a fresh results file is required")
    return check(args.fresh, args.root)


if __name__ == "__main__":
    sys.exit(main())
