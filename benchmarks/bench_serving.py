"""Serving load generator: TTFT / TPS / cache-hit-rate under a
system-prompt-heavy many-users mix (DESIGN.md §10) — BENCH row family
``serving/*``.

The workload is the radix cache's target shape: N_SYS distinct system
prompts (3 full pages each), USERS requests per system prompt from
separate tenants, each appending a short private user suffix.  Uncached,
every request prefills its full prompt; with the trie, each system prompt
prefills once and every later arrival pays only its suffix, so

  * ``serving/cache_hit_rate`` (prefix_hit_tokens / prompt_tokens) is a
    DETERMINISTIC counter ratio — gated >= 0.5 and chained across the
    trajectory,
  * ``serving/prefill_token_ratio`` (prefill_tokens / prompt_tokens) is
    its ceiling-gated complement: prompt prefill work must stay sublinear
    in the request count,
  * ``serving/tps`` and ``serving/ttft_ms`` are wall-clock rows — gated
    by generous ABSOLUTE bounds only (CPU CI noise), never chained.

The page pool is sized BELOW the mix's worst-case working set on purpose:
placement pressure must be absorbed by deferral + LRU eviction of cold
trie branches — if ``PagePoolExhausted`` surfaces, the bench (and the CI
lane running it) fails.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.rl.engine import PagedEngineConfig, PagedRolloutEngine, Request
from repro.rl.rollout import RolloutConfig
from repro.serve import AsyncLMServer, ServeConfig

SLOTS = 8
PAGE_LEN = 16
SYS_LEN = 3 * PAGE_LEN      # 3 full pages of cacheable system prompt
USER_LEN = 8                # private suffix -> one partial page
MAX_NEW = 16
STEPS_PER_SYNC = 8
NUM_PAGES = 14              # < worst-case working set: eviction territory


def _model():
    return ModelConfig(name="bench-serve", d_model=256, n_heads=8,
                       n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
                       blocks=dense_blocks(4), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


def _workload(rng, n_sys: int, users: int):
    """(tenant, tokens) rows: ``users`` requests per system prompt, tenants
    interleaved so DRR admission mixes the system prompts."""
    sys_prompts = rng.integers(3, 512, (n_sys, SYS_LEN)).astype(np.int32)
    reqs = []
    for _u in range(users):
        for s in range(n_sys):
            user = rng.integers(3, 512, (USER_LEN,)).astype(np.int32)
            reqs.append((f"tenant{s}",
                         np.concatenate([sys_prompts[s], user])))
    return reqs


async def _serve(engine, params, key, reqs, max_new):
    server = AsyncLMServer(
        engine, params, key,
        ServeConfig(max_queue=len(reqs) + 1, max_backlog=2, quantum=128))
    await server.start()
    t0 = time.perf_counter()
    streams = [server.submit(toks, tenant=tenant, max_new=max_new)
               for tenant, toks in reqs]

    async def consume(st):
        async for _delta in st:
            pass
        return await st.result()

    comps = await asyncio.gather(*[consume(s) for s in streams])
    dt = time.perf_counter() - t0
    await server.stop()
    return server, comps, dt


def run(smoke: bool = False) -> dict:
    n_sys, users = (2, 3) if smoke else (4, 6)
    max_new = 8 if smoke else MAX_NEW
    cfg = _model()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    rng = np.random.default_rng(0)
    reqs = _workload(rng, n_sys, users)

    engine = PagedRolloutEngine(
        cfg, RolloutConfig(max_new_tokens=MAX_NEW, temperature=1.0,
                           eos_id=-1),
        PagedEngineConfig(num_slots=SLOTS, max_prompt_len=SYS_LEN + USER_LEN,
                          steps_per_sync=STEPS_PER_SYNC, page_len=PAGE_LEN,
                          num_pages=NUM_PAGES, max_group=1,
                          prefix_cache=True))

    # compile pass (prefill + step), then the timed run on a fresh session
    engine.run_groups(
        params, [[Request(uid=0, tokens=reqs[0][1], budget=2)]], key)
    server, comps, dt = asyncio.run(_serve(engine, params, key, reqs,
                                           max_new))

    st, est = server.stats, engine.stats
    n_req = len(reqs)
    assert st["completed"] == n_req and len(comps) == n_req, (
        "load-gen mix lost requests")
    assert st["shed"] == 0, "sized queue must admit the whole mix"
    hit_rate = est["prefix_hit_tokens"] / max(est["prompt_tokens"], 1)
    prefill_ratio = est["prefill_tokens"] / max(est["prompt_tokens"], 1)
    tps = st["tokens_out"] / dt
    ttft_ms = server.mean_ttft * 1e3
    ttft_max_ms = st["ttft_max"] * 1e3

    print(f"# bench_serving: {n_req} requests ({n_sys} system prompts x "
          f"{users} users), {SLOTS} slots, pool {NUM_PAGES} pages, "
          f"budget {max_new}{' [smoke]' if smoke else ''}")
    print(f"  wall={dt:.2f}s tps={tps:.1f} ttft_mean={ttft_ms:.0f}ms "
          f"ttft_max={ttft_max_ms:.0f}ms")
    print(f"  cache_hit_rate={hit_rate:.3f} "
          f"prefill_token_ratio={prefill_ratio:.3f} "
          f"(prefilled {est['prefill_tokens']}/{est['prompt_tokens']} "
          f"prompt tokens)")
    print(f"  evicted_pages={est['evicted_pages']} "
          f"peak_pages={est['peak_pages_in_use']}/{NUM_PAGES} "
          f"rounds={est['rounds']}")

    emit("serving/load_mix", dt,
         f"requests={n_req};tokens_out={st['tokens_out']};"
         f"evicted_pages={est['evicted_pages']};"
         f"peak_pages={est['peak_pages_in_use']}")
    emit("serving/tps", dt, f"tps={tps:.1f}")
    emit("serving/ttft_ms", server.mean_ttft,
         f"ttft_ms={ttft_ms:.1f};ttft_max_ms={ttft_max_ms:.1f}")
    emit("serving/cache_hit_rate", 0.0, f"cache_hit_rate={hit_rate:.4f}")
    emit("serving/prefill_token_ratio", 0.0,
         f"prefill_token_ratio={prefill_ratio:.4f}")
    return {"tps": tps, "ttft_ms": ttft_ms, "cache_hit_rate": hit_rate,
            "prefill_token_ratio": prefill_ratio,
            "evicted_pages": est["evicted_pages"]}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced mix for the blocking serving CI job")
    run(smoke=ap.parse_args().smoke)
