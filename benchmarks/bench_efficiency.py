"""Table 3 analog: system-efficiency of the learner per method.

The paper reports peak GPU memory, train time/step (w/o inference), and
total time/step on 16 H100s.  Hermetic CPU equivalents, same structure:
  * learner wall-time per step (w/o rollout) — jitted, post-compile,
  * total wall-time per step (with rollout),
  * learner activation-memory proxy — XLA temp bytes of the compiled step,
for GRPO / URS / Det-Trunc / RPC at matched rollouts.  RPC/Det-Trunc get
their physical repack (shorter T); URS only masks (paper's point: no
forward savings).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.grpo import GRPOConfig
from repro.core.selectors import make_selector
from repro.models.config import ModelConfig, dense_blocks
from repro.models import init_params, model_decl
from repro.optim import AdamWConfig, init_opt_state
from repro.rl.learner import make_train_step

B, T_PROMPT, T_RESP = 8, 16, 240


def run() -> None:
    cfg = ModelConfig(name="eff", d_model=192, n_heads=6, n_kv_heads=2,
                      head_dim=32, d_ff=512, vocab_size=512,
                      blocks=dense_blocks(4), seq_parallel=False,
                      remat_policy="none", scan_layers=False)
    t_full = T_PROMPT + T_RESP
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    opt_cfg = AdamWConfig(lr=1e-4, warmup_steps=1, total_steps=100)
    opt = init_opt_state(params, opt_cfg)
    step = make_train_step(cfg, GRPOConfig(), opt_cfg, vocab_chunks=1)

    rm = np.zeros((B, t_full), np.float32)
    rm[:, T_PROMPT:] = 1.0
    rm = jnp.asarray(rm)
    toks = jax.random.randint(key, (B, t_full), 0, cfg.vocab_size)

    def batch_for(w, t_phys):
        return {
            "tokens": toks[:, :t_phys],
            "response_mask": rm[:, :t_phys],
            "old_logp": -jnp.abs(jax.random.normal(key, (B, t_phys))) * rm[:, :t_phys],
            "advantages": jax.random.normal(key, (B,)),
            "ht_weights": w[:, :t_phys],
            "orig_lengths": rm.sum(-1),
            "lengths": jnp.full((B,), t_phys, jnp.int32),
        }

    rows = [("grpo", "full", {}, t_full),
            ("urs", "urs", {"p": 0.5}, t_full),            # no fwd savings
            ("det_trunc", "det_trunc", {}, T_PROMPT + T_RESP // 2),
            ("rpc", "rpc", {"min_cut": 8}, T_PROMPT + (T_RESP + 8) // 2 + 16)]
    print("# bench_efficiency (Table 3): learner step cost per method")
    print(f"{'method':10s} {'t_learn(ms)':>12s} {'saving':>8s} "
          f"{'temp_bytes(MB)':>15s} {'saving':>8s}")
    base_t = base_m = None
    for name, sel_name, kw, t_phys in rows:
        sel = make_selector(sel_name, **kw)
        w = sel(key, rm).ht_weights
        batch = batch_for(w, t_phys)
        jstep = jax.jit(step)
        tsec = time_call(lambda: jstep(params, opt, batch), warmup=1, iters=5)
        comp = jstep.lower(params, opt, batch).compile()
        temp = comp.memory_analysis().temp_size_in_bytes
        if base_t is None:
            base_t, base_m = tsec, temp
        print(f"{name:10s} {tsec * 1e3:12.1f} {100 * (1 - tsec / base_t):7.1f}% "
              f"{temp / 2**20:15.1f} {100 * (1 - temp / base_m):7.1f}%")
        emit(f"efficiency/{name}", tsec,
             f"temp_mb={temp / 2**20:.1f};t_saving={1 - tsec / base_t:.3f}")
    print("(URS ~= GRPO on both columns — masking alone saves neither "
          "forward time nor activations; RPC saves both: the paper's "
          "Table 3 pattern.)")


if __name__ == "__main__":
    run()
