"""Fault recovery: kill one fleet replica mid-window, measure the cost.

DESIGN.md §13's supervision layer promises that losing a rollout replica
costs one reclaimed group's re-roll plus an elastic join — not a stall,
not a re-prefill of the world, and never a silently dropped or duplicated
group.  This bench pins that promise to numbers:

* ``chaos/recovery_overhead_ratio`` — seconds-per-effective-step of a
  fleet-of-2 window that absorbs one injected replica death (detected by
  the supervisor, the orphaned group reclaimed off the shared key chain
  and re-rolled by the survivor, the rest of the window drained by the
  degraded fleet) over the same window with no faults, ceiling **1.5x**
  (ABSOLUTE_ONLY: a wall-time ratio, never chained).  The elastic
  ``add_replica`` join happens right after the timed window and must
  integrate (it is what the ``joins`` counter pins); its *cold-start* is
  excluded from the ratio because at bench scale it is dominated by the
  fresh engine's XLA compile — a compilation-cache artifact, not
  recovery work (the join's bookkeeping itself measures ~5ms);
* ``chaos/recovery_counters`` — the recovery counters the injected
  schedule implies, gated **counter-exact**: one replica death means
  exactly ``groups_reclaimed=1``, and one transient publication fault
  means exactly ``publish_retries=1``.  Any other value is lost or
  duplicated recovery work, not noise.

The faults come from the production fault-injection harness
(``testing/chaos.py``): a ``FaultSpec`` at the ``actor`` site kills
whichever replica claims group ``KILL_AT`` (fires inside the timed
window by construction — the window consumes well past it), and one
``publish``-site raise makes the epoch-0 publication retry once.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    DistNATGRPOTrainer, NATTrainerConfig, RolloutConfig, VOCAB_SIZE,
)
from repro.testing.chaos import FaultPlan, FaultSpec, InjectedActorDeath

P = 4               # prompts (groups) per step
G = 4               # rollouts kept per prompt
SLOTS = 8           # arena width per replica engine
MAX_NEW = 64        # decode budget
MAX_STALENESS = 2
FLEET = 2


def _model():
    return ModelConfig(name="bench-chaos", d_model=128, n_heads=8,
                       n_kv_heads=4, head_dim=16, d_ff=256,
                       vocab_size=VOCAB_SIZE, blocks=dense_blocks(2),
                       seq_parallel=False, remat_policy="none",
                       scan_layers=False)


def _budget_fn(step: int, r: int) -> int:
    """Deterministic short/long mix (same shape as bench_dist_overlap)."""
    if r % 5 == 0:
        return MAX_NEW
    return 4 + (r * 7919) % 13


def _trainer_cfg(max_new: int) -> NATTrainerConfig:
    return NATTrainerConfig(
        selector="det_trunc", selector_kwargs=(("frac", 0.5),),
        prompts_per_step=P, max_prompt_len=24,
        rollout=RolloutConfig(max_new_tokens=max_new, temperature=1.0,
                              group_size=G, eos_id=-1),
        num_slots=SLOTS, steps_per_sync=4,
        adamw=AdamWConfig(lr=1e-4, warmup_steps=5, total_steps=1000),
        num_buckets=1, max_staleness=MAX_STALENESS, fleet=FLEET,
        supervise=True, supervise_interval=0.02, seed=0)


def _window(trainer, warmup: int, steps: int) -> float:
    """Seconds per effective step, queue-drain-corrected (a net drain of
    the pre-rolled buffer means fewer fresh groups than pops)."""
    for _ in range(warmup):
        trainer.train_step()
    d0 = trainer.queue.qsize()
    t0 = time.perf_counter()
    for _ in range(steps):
        trainer.train_step()
    elapsed = time.perf_counter() - t0
    drained = max(0, d0 - trainer.queue.qsize())
    return elapsed / max(1, steps - drained)


def run(smoke: bool = False) -> dict:
    cfg = _model()
    max_new = 16 if smoke else MAX_NEW
    warmup, steps = (1, 5) if smoke else (3, 8)

    base = DistNATGRPOTrainer(cfg, _trainer_cfg(max_new),
                              budget_fn=_budget_fn)
    t_base = _window(base, warmup, steps)
    base.close()

    # the kill index (group indices advance one per learner step): past
    # everything the warmup claims — its consumed steps plus the actors'
    # staleness-bounded run-ahead — yet inside what the timed window
    # drains, so the claim (and the injected death) lands in-window
    kill_at = warmup + MAX_STALENESS + 1
    plan = FaultPlan([
        FaultSpec(site="actor", kind="raise", at=kill_at,
                  exc=InjectedActorDeath, times=1),
        FaultSpec(site="publish", kind="raise", times=1),  # epoch-0 retry
    ])
    chaos = DistNATGRPOTrainer(cfg, _trainer_cfg(max_new),
                               budget_fn=_budget_fn, chaos=plan)
    t_rec = _window(chaos, warmup, steps)
    # elastic heal after the timed window: join a replacement and run one
    # (untimed) settle step so it integrates — pins the joins counter
    # without folding the fresh engine's XLA compile into the ratio
    t0 = time.perf_counter()
    joined = chaos.add_replica()
    t_join = time.perf_counter() - t0
    chaos.train_step()
    stats = chaos.publication_stats()
    sup = stats["supervisor"]
    chaos.close()

    ratio = t_rec / t_base
    print(f"# bench_fault_recovery: fleet of {FLEET}, one injected "
          f"replica death at group {kill_at} + one transient publish "
          f"fault (P={P} G={G}, budget {max_new})")
    print(f"{'window':12s} {'s/step':>8s}")
    print(f"{'clean':12s} {t_base:8.2f}")
    print(f"{'recovery':12s} {t_rec:8.2f}")
    print(f"overhead {ratio:.2f}x  (reclaimed "
          f"{sup['groups_reclaimed']} group(s), "
          f"{stats['publish_retries']} publish retry(ies), "
          f"replacement={joined} joined in {t_join * 1e3:.1f}ms, "
          f"plan exhausted={plan.exhausted()})")

    emit("chaos/recovery_overhead_ratio", t_rec,
         f"recovery_overhead_ratio={ratio:.3f};"
         f"clean_s_per_step={t_base:.3f};recovery_s_per_step={t_rec:.3f};"
         f"join_ms={t_join * 1e3:.1f}")
    # counter-exact: the injected schedule implies EXACTLY these counts
    emit("chaos/recovery_counters", 0.0,
         f"groups_reclaimed={sup['groups_reclaimed']};"
         f"publish_retries={stats['publish_retries']};"
         f"replicas_failed={sup['replicas_failed']};"
         f"joins={sup['joins']};"
         f"dropped_dup={stats['dropped_dup']}")
    return {"ratio": ratio, "s_per_step_clean": t_base,
            "s_per_step_recovery": t_rec,
            "groups_reclaimed": sup["groups_reclaimed"],
            "publish_retries": stats["publish_retries"]}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny budgets: CI lane sanity run, not a benchmark")
    run(smoke=ap.parse_args().smoke)
