"""Rollout token throughput: legacy fixed-shape scan vs continuous batching.

The straggler problem, measured: a request mix where most completions are
short (4-16 tokens) and a minority run to the full budget.  The legacy path
(``rl/rollout.py::generate``) scans ``max_new_tokens`` steps for every wave
regardless of when rows finish, so the whole batch pays for its longest row.
The slot arena (``rl/engine.py``) retires rows at their budget and refills
the freed slots from the queue, so total work tracks the tokens actually
requested (DESIGN.md §3).

Both paths run the same model, same slot width, same requests, post-compile.
Emits the rollout rows of the BENCH_* perf trajectory; the acceptance gate
is ``rollout/speedup >= 1.5`` on this mix.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.rl.engine import ContinuousRolloutEngine, EngineConfig, Request
from repro.rl.rollout import RolloutConfig, generate

SLOTS = 8           # device batch width for BOTH paths
N_REQ = 64          # requests served
MAX_NEW = 128       # decode budget (the straggler tail length)
TP = 24             # prompt width
SHORT_FRAC = 0.8    # fraction of short completions
STEPS_PER_SYNC = 8  # retire-detection latency / host-sync amortization knob
ITERS = 2           # best-of-N wall times (CI runners are noisy)


def _model():
    return ModelConfig(name="bench-rollout", d_model=256, n_heads=8,
                       n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
                       blocks=dense_blocks(4), seq_parallel=False,
                       remat_policy="none", scan_layers=False)


def _mix(rng):
    """Straggler-heavy budgets: SHORT_FRAC short rows, the rest full-budget."""
    return np.array([
        int(rng.integers(4, 17)) if rng.random() < SHORT_FRAC else MAX_NEW
        for _ in range(N_REQ)], np.int32)


def _legacy_time(params, cfg, rcfg, prompts, plens, key) -> float:
    """Serve the mix in fixed-shape waves of SLOTS rows: each wave scans the
    full budget — early finishers wait on the longest row (the legacy path
    has no per-row early exit; that is the point being measured)."""
    waves = [(jnp.asarray(prompts[lo:lo + SLOTS]),
              jnp.asarray(plens[lo:lo + SLOTS]))
             for lo in range(0, N_REQ, SLOTS)]
    for toks, lens in waves:  # compile once outside the timed region
        jax.block_until_ready(generate(params, cfg, rcfg, toks, lens, key))
        break
    best = float("inf")
    for _ in range(ITERS):
        t0 = time.perf_counter()
        for toks, lens in waves:
            jax.block_until_ready(generate(params, cfg, rcfg, toks, lens, key))
        best = min(best, time.perf_counter() - t0)
    return best


def _continuous_time(params, cfg, rcfg, prompts, plens, budgets, key):
    engine = ContinuousRolloutEngine(
        cfg, rcfg, EngineConfig(num_slots=SLOTS, max_prompt_len=TP,
                                steps_per_sync=STEPS_PER_SYNC))
    reqs = [Request(uid=i, tokens=prompts[i, :plens[i]], budget=int(b))
            for i, b in enumerate(budgets)]
    engine.run(params, reqs[:SLOTS], key)  # compile prefill+step
    best = float("inf")
    for _ in range(ITERS):
        t0 = time.perf_counter()
        engine.run(params, reqs, key)
        best = min(best, time.perf_counter() - t0)
    return best, engine.stats


def run() -> dict:
    cfg = _model()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model_decl(cfg))
    rng = np.random.default_rng(0)
    budgets = _mix(rng)
    plens = rng.integers(8, TP + 1, size=N_REQ).astype(np.int32)
    prompts = np.full((N_REQ, TP), 0, np.int32)
    for i in range(N_REQ):
        prompts[i, :plens[i]] = rng.integers(3, cfg.vocab_size,
                                             size=plens[i])
    rcfg = RolloutConfig(max_new_tokens=MAX_NEW, temperature=1.0, eos_id=-1)

    useful = int(budgets.sum())
    t_leg = _legacy_time(params, cfg, rcfg, prompts, plens, key)
    t_con, stats = _continuous_time(params, cfg, rcfg, prompts, plens,
                                    budgets, key)
    tput_leg = useful / t_leg
    tput_con = useful / t_con
    speedup = tput_con / tput_leg

    print("# bench_rollout_throughput: straggler-heavy mix "
          f"({N_REQ} requests, {SHORT_FRAC:.0%} short, budget {MAX_NEW}, "
          f"{SLOTS} slots)")
    print(f"{'path':12s} {'time(s)':>8s} {'tok/s':>8s} {'seq steps':>10s}")
    leg_steps = (N_REQ + SLOTS - 1) // SLOTS * MAX_NEW
    print(f"{'legacy':12s} {t_leg:8.2f} {tput_leg:8.1f} {leg_steps:10d}")
    print(f"{'continuous':12s} {t_con:8.2f} {tput_con:8.1f} "
          f"{stats['decode_steps']:10d}")
    print(f"speedup {speedup:.2f}x  (useful tokens {useful}, "
          f"arena refills {stats['refills']}, "
          f"slot util {useful / max(stats['slot_substeps'], 1):.2f})")

    emit("rollout/legacy", t_leg, f"tok_s={tput_leg:.1f};steps={leg_steps}")
    emit("rollout/continuous", t_con,
         f"tok_s={tput_con:.1f};steps={stats['decode_steps']};"
         f"refills={stats['refills']}")
    emit("rollout/speedup", t_leg - t_con, f"speedup={speedup:.3f}")
    return {"speedup": speedup, "tok_s_legacy": tput_leg,
            "tok_s_continuous": tput_con}


if __name__ == "__main__":
    run()
