"""Figure 4 analog: gradient-norm inflation under URS.

The paper derives E||w g||^2 = ||g||^2 / p, i.e. grad norms grow ~1/sqrt(p).
We measure the actual NAT-GRPO gradient norm on a tiny model at several p
and fit the exponent.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.grpo import nat_grpo_loss
from repro.core.selectors import URSSelector

B, T = 16, 64


def run(draws: int = 200) -> None:
    key = jax.random.PRNGKey(1)
    k1, k2, k3, km = jax.random.split(key, 4)
    logp = -jnp.abs(jax.random.normal(k1, (B, T))) * 0.4
    old = logp + 0.1 * jax.random.normal(k2, (B, T))
    adv = jax.random.normal(k3, (B,))
    rm = jnp.ones((B, T), jnp.float32)
    lengths = rm.sum(-1)

    @jax.jit
    def gnorm(w):
        g = jax.grad(lambda lp: nat_grpo_loss(lp, old, adv, w, lengths)[0])(logp)
        return jnp.linalg.norm(g)

    print("# bench_gradnorm (Fig. 4): ||grad|| vs URS keep-probability p")
    ps = [1.0, 0.5, 0.25, 0.125]
    norms = []
    t0 = time.perf_counter()
    for p in ps:
        sel = URSSelector(p=p)
        vals = [float(gnorm(sel(jax.random.fold_in(km, i), rm).ht_weights))
                for i in range(draws)]
        norms.append(np.sqrt(np.mean(np.square(vals))))  # RMS norm
        print(f"  p={p:5.3f}  rms||g|| = {norms[-1]:.4f}  "
              f"(x{norms[-1] / norms[0]:.2f})")
    dt = time.perf_counter() - t0
    # fit ||g|| ~ p^(-alpha): paper predicts alpha ~= 0.5
    alpha = -np.polyfit(np.log(ps), np.log(norms), 1)[0]
    print(f"  fitted exponent alpha = {alpha:.3f} (paper: ~0.5)")
    emit("gradnorm/urs_scaling", dt / (len(ps) * draws), f"alpha={alpha:.3f}")


if __name__ == "__main__":
    run()
