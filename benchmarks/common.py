"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def ci95(xs) -> tuple:
    xs = np.asarray(xs, np.float64)
    m = xs.mean()
    if len(xs) < 2:
        return m, 0.0
    half = 1.96 * xs.std(ddof=1) / np.sqrt(len(xs))
    return float(m), float(half)


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row the harness scrapes: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
