"""Shared benchmark utilities + the machine-readable results registry.

Every benchmark reports through ``emit``; rows accumulate in ``RESULTS`` so
the driver (``benchmarks/run.py --json``) can write one aggregated JSON
artifact per CI run — the perf trajectory the repo archives (BENCH_*.json).
"""
from __future__ import annotations

import time

import jax
import numpy as np

# rows appended by emit(): {"name", "us_per_call", "derived", "metrics"}
RESULTS: list = []


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def ci95(xs) -> tuple:
    xs = np.asarray(xs, np.float64)
    m = xs.mean()
    if len(xs) < 2:
        return m, 0.0
    half = 1.96 * xs.std(ddof=1) / np.sqrt(len(xs))
    return float(m), float(half)


def _parse_derived(derived: str) -> dict:
    """'a=1.5;b=2' -> {'a': 1.5, 'b': 2.0}; non-numeric values kept as str."""
    out = {}
    for part in filter(None, derived.split(";")):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k.strip()] = float(v)
        except ValueError:
            out[k.strip()] = v.strip()
    return out


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row the harness scrapes (``name,us_per_call,derived``), plus a
    structured copy in ``RESULTS`` for the JSON artifact."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
    RESULTS.append({
        "name": name,
        "us_per_call": round(seconds * 1e6, 1),
        "derived": derived,
        "metrics": _parse_derived(derived),
    })


def emit_compiled_stats(name: str, compiled, extra: str = "") -> None:
    """Static-analysis row for a compiled XLA executable: FLOPs and bytes
    accessed from ``launch/hlo_stats.py::cost_stats`` — deterministic
    compiler counters, so BENCH_* artifacts carry a machine-independent
    cost axis next to the noisy wall-clock rows."""
    from repro.launch.hlo_stats import cost_stats

    cs = cost_stats(compiled)
    derived = f"flops={cs['flops']:.6g};bytes_accessed={cs['bytes']:.6g}"
    if extra:
        derived += ";" + extra
    emit(name, 0.0, derived)
