"""Disaggregated fleet overlap: one async engine vs a replicated fleet.

PR 3's async trainer already hides the learner behind one rollout engine;
what it cannot hide is the *rollout* bound itself — one arena means the
80/20 straggler mix drains at one engine's pace.  The disaggregated
trainer (DESIGN.md §12) replicates the engine across fleet slices, racing
N actor threads over the shared prompt index while the learner drains the
reassembled queue, and publishes weights device-to-device.

Both sides run the same model, geometry, staleness bound, and straggler
mix, post-compile.  Emits the ``dist/*`` rows of the BENCH_* trajectory:

* ``dist/fleet_speedup`` — steady-state step-rate ratio, floor 1.2x on a
  multi-core runner (thread-parallelism floor: skipped loudly on 1-CPU
  runners, where two engines cannot overlap by construction);
* ``dist/publish_host_bytes`` — the publisher's host-transfer counter,
  ceiling **0.0, counter-exact**: d2d publication must never stage
  through the host;
* ``dist/train_cell`` — FLOPs / bytes-accessed of the compiled learner
  cell (``launch/hlo_stats.py``), a machine-independent cost axis next to
  the wall-clock rows.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, emit_compiled_stats
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    AsyncNATGRPOTrainer, DistNATGRPOTrainer, NATTrainerConfig,
    RolloutConfig, VOCAB_SIZE,
)

P = 4               # prompts per step
G = 4               # rollouts kept per prompt
SLOTS = 8           # arena width per engine: recycling live mid-group
MAX_NEW = 128       # decode budget (the straggler tail length)
SHORT_EVERY = 5     # rows with r % 5 == 0 run the full budget (20% long)
MAX_STALENESS = 2
FLEET = 2
WARMUP = 3          # compile + pipeline fill
STEPS = 5           # timed steps per window
WINDOWS = 3         # best-of windows (CI runners flip contention modes)


def _model():
    return ModelConfig(name="bench-dist", d_model=128, n_heads=8,
                       n_kv_heads=4, head_dim=16, d_ff=256,
                       vocab_size=VOCAB_SIZE, blocks=dense_blocks(2),
                       seq_parallel=False, remat_policy="none",
                       scan_layers=False)


def _budget_fn(step: int, r: int) -> int:
    """Deterministic 80/20 mix, identical every step (stable buckets)."""
    if r % SHORT_EVERY == 0:
        return MAX_NEW
    return 4 + (r * 7919) % 13  # shorts: 4..16 tokens


def _trainer_cfg(max_new: int, fleet: int = 0) -> NATTrainerConfig:
    return NATTrainerConfig(
        selector="det_trunc", selector_kwargs=(("frac", 0.5),),
        prompts_per_step=P, max_prompt_len=24,
        rollout=RolloutConfig(max_new_tokens=max_new, temperature=1.0,
                              group_size=G, eos_id=-1),
        num_slots=SLOTS, steps_per_sync=4,
        adamw=AdamWConfig(lr=1e-4, warmup_steps=5, total_steps=1000),
        num_buckets=1,  # single executable: no bucket recompiles mid-bench
        max_staleness=MAX_STALENESS, fleet=fleet, seed=0)


def _time_steps(trainer, warmup: int, steps: int, windows: int) -> float:
    """Best seconds-per-effective-step (queue-drain-corrected, like
    bench_async_overlap: a net drain of the pre-rolled buffer means the
    fleet produced fewer fresh groups than we popped)."""
    for _ in range(warmup):
        trainer.train_step()
    best = float("inf")
    for _ in range(windows):
        d0 = trainer.queue.qsize()
        t0 = time.perf_counter()
        for _ in range(steps):
            trainer.train_step()
        elapsed = time.perf_counter() - t0
        drained = max(0, d0 - trainer.queue.qsize())
        best = min(best, elapsed / max(1, steps - drained))
    return best


def _train_cell_stats():
    """Compile the learner cell abstractly and read the XLA cost counters
    — no device work, deterministic across runners."""
    import jax

    from repro.configs.shapes import ShapeSpec
    from repro.launch.mesh import make_host_mesh
    from repro.launch.step_specs import make_train_cell

    cfg = _model()
    shape = ShapeSpec(name="bench-dist", kind="train",
                      seq_len=24 + MAX_NEW, global_batch=P * G)
    cell = make_train_cell(cfg, shape, make_host_mesh(), vocab_chunks=1)
    compiled = (jax.jit(cell.fn, donate_argnums=cell.donate)
                .lower(*cell.args).compile())
    emit_compiled_stats("dist/train_cell", compiled,
                        f"batch={P * G};seq={24 + MAX_NEW}")


def run(smoke: bool = False) -> dict:
    cfg = _model()
    max_new = 16 if smoke else MAX_NEW
    warmup, steps, windows = (1, 2, 1) if smoke else (WARMUP, STEPS, WINDOWS)

    single = AsyncNATGRPOTrainer(cfg, _trainer_cfg(max_new),
                                 budget_fn=_budget_fn)
    s_step = _time_steps(single, warmup, steps, windows)
    single.close()

    fleet = DistNATGRPOTrainer(cfg, _trainer_cfg(max_new, fleet=FLEET),
                               budget_fn=_budget_fn)
    f_step = _time_steps(fleet, warmup, steps, windows)
    stale = [m["staleness"] for m in fleet.history[warmup:]]
    pub = fleet.publication_stats()
    fleet.close()

    speedup = s_step / f_step
    budget = sum(_budget_fn(0, r) for r in range(P * G))

    print(f"# bench_dist_overlap: fleet of {FLEET} vs single engine "
          f"(P={P} G={G}, {SLOTS} slots each, budget {max_new}, "
          f"staleness {MAX_STALENESS})")
    print(f"{'trainer':12s} {'s/step':>8s} {'tok/s':>8s}")
    print(f"{'single':12s} {s_step:8.2f} {budget / s_step:8.1f}")
    print(f"{'fleet':12s} {f_step:8.2f} {budget / f_step:8.1f}")
    print(f"speedup {speedup:.2f}x  (mean staleness {np.mean(stale):.2f}, "
          f"watermarks {pub['watermarks']}, "
          f"published {pub['bytes_published']} B d2d, "
          f"{pub['host_bytes']} B via host)")

    emit("dist/single_step", s_step, f"tok_s={budget / s_step:.1f}")
    emit("dist/fleet_step", f_step,
         f"tok_s={budget / f_step:.1f};staleness={np.mean(stale):.2f}")
    emit("dist/fleet_speedup", s_step - f_step, f"speedup={speedup:.3f}")
    # counter-exact: d2d publication must move NOTHING through the host
    emit("dist/publish_host_bytes", 0.0,
         f"host_bytes={pub['host_bytes']};"
         f"bytes_published={pub['bytes_published']};"
         f"publishes={pub['publishes']}")
    _train_cell_stats()
    return {"speedup": speedup, "s_per_step_single": s_step,
            "s_per_step_fleet": f_step, "host_bytes": pub["host_bytes"]}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny budgets: CI lane sanity run, not a benchmark")
    run(smoke=ap.parse_args().smoke)
