"""Actor/learner overlap: serial trainer vs the bounded-staleness pipeline.

The serial tax, measured: ``NATGRPOTrainer`` runs rollout to completion,
then the learner — so the learner idles during the straggler tail and the
slot arena idles during backprop.  ``AsyncNATGRPOTrainer`` overlaps them:
the actor thread streams groups through a persistent engine session (a new
group's shorts refill slots freed mid-drain) while the learner drains the
bounded-staleness sample queue (DESIGN.md §6).

Both trainers run the same model, same geometry, same 80/20 straggler mix
(80% short rollouts, 20% full-budget — the mix the rollout bench gates),
post-compile.  Emits the ``async/*`` rows of the BENCH_* perf trajectory;
the acceptance gate is ``async/overlap_speedup >= 1.3`` steady-state.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig
from repro.rl import (
    AsyncNATGRPOTrainer, NATGRPOTrainer, NATTrainerConfig, RolloutConfig,
    VOCAB_SIZE,
)

P = 4               # prompts per step
G = 4               # rollouts kept per prompt
SLOTS = 8           # arena width: half the group width, so recycling is live
MAX_NEW = 128       # decode budget (the straggler tail length)
SHORT_EVERY = 5     # rows with r % 5 == 0 run the full budget (20% long)
MAX_STALENESS = 2
WARMUP = 3          # compile + pipeline fill
STEPS = 5           # timed steps per window
WINDOWS = 3         # best-of windows (CI runners flip contention modes)


def _model():
    return ModelConfig(name="bench-async", d_model=128, n_heads=8,
                       n_kv_heads=4, head_dim=16, d_ff=256,
                       vocab_size=VOCAB_SIZE, blocks=dense_blocks(2),
                       seq_parallel=False, remat_policy="none",
                       scan_layers=False)


def _budget_fn(step: int, r: int) -> int:
    """Deterministic 80/20 mix, identical every step (stable buckets)."""
    if r % SHORT_EVERY == 0:
        return MAX_NEW
    return 4 + (r * 7919) % 13  # shorts: 4..16 tokens


def _trainer_cfg(max_staleness: int) -> NATTrainerConfig:
    return NATTrainerConfig(
        # deterministic truncation: fixed learner bucket every step (no
        # mid-bench recompiles) and the NAT regime the overlap targets —
        # a learner cheap enough for rollout to be the bound
        selector="det_trunc", selector_kwargs=(("frac", 0.5),),
        prompts_per_step=P, max_prompt_len=24,
        # eos_id=-1: budgets bind exactly, so the mix is controlled
        rollout=RolloutConfig(max_new_tokens=MAX_NEW, temperature=1.0,
                              group_size=G, eos_id=-1),
        num_slots=SLOTS, steps_per_sync=4,
        adamw=AdamWConfig(lr=1e-4, warmup_steps=5, total_steps=1000),
        num_buckets=1,  # single executable: no bucket recompiles mid-bench
        max_staleness=max_staleness, seed=0)


def _time_steps(trainer, warmup: int, steps: int, windows: int) -> float:
    """Best seconds-per-effective-step over ``windows`` timed windows of
    ``steps`` pops each (best-of, like the rollout bench: shared runners
    flip between contention modes run to run).

    Effective steps debit groups drained from the pre-rolled queue buffer:
    a net drain means the actor produced fewer than ``steps`` fresh groups
    in-window, and quoting raw pops/s would let a big-enough buffer fake
    steady-state throughput the pipeline cannot sustain.  In the
    learner-bound regime the depth is unchanged and this is ``steps``."""
    for _ in range(warmup):
        trainer.train_step()
    best = float("inf")
    for _ in range(windows):
        d0 = trainer.queue.qsize()
        t0 = time.perf_counter()
        for _ in range(steps):
            trainer.train_step()
        elapsed = time.perf_counter() - t0
        drained = max(0, d0 - trainer.queue.qsize())
        best = min(best, elapsed / max(1, steps - drained))
    return best


def run() -> dict:
    cfg = _model()

    serial = NATGRPOTrainer(cfg, _trainer_cfg(0), budget_fn=_budget_fn)
    s_step = _time_steps(serial, WARMUP, STEPS, WINDOWS)
    serial.close()

    overlap = AsyncNATGRPOTrainer(cfg, _trainer_cfg(MAX_STALENESS),
                                  budget_fn=_budget_fn)
    o_step = _time_steps(overlap, WARMUP, STEPS, WINDOWS)
    stale = [m["staleness"] for m in overlap.history[WARMUP:]]
    waits = [m["time_wait"] for m in overlap.history[WARMUP:]]
    overlap.close()

    speedup = s_step / o_step
    budget = sum(_budget_fn(0, r) for r in range(P * G))

    print("# bench_async_overlap: 80/20 straggler mix "
          f"(P={P} G={G}, {SLOTS} slots, budget {MAX_NEW}, "
          f"{budget} tokens/step requested)")
    print(f"{'trainer':12s} {'s/step':>8s} {'tok/s':>8s}")
    print(f"{'serial':12s} {s_step:8.2f} {budget / s_step:8.1f}")
    print(f"{'overlapped':12s} {o_step:8.2f} {budget / o_step:8.1f}")
    print(f"speedup {speedup:.2f}x  (max_staleness={MAX_STALENESS}, "
          f"mean staleness {np.mean(stale):.2f}, "
          f"mean learner wait {np.mean(waits) * 1e3:.0f}ms)")

    emit("async/serial_step", s_step, f"tok_s={budget / s_step:.1f}")
    emit("async/overlap_step", o_step,
         f"tok_s={budget / o_step:.1f};staleness={np.mean(stale):.2f}")
    emit("async/overlap_speedup", s_step - o_step,
         f"speedup={speedup:.3f}")
    return {"speedup": speedup, "s_per_step_serial": s_step,
            "s_per_step_overlap": o_step}


if __name__ == "__main__":
    run()
