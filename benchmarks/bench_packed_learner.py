"""Packed vs padded learner step: update FLOPs scale with the token budget.

NAT's update-side claim (paper §4, Fig. 3) realized as a systems number:
the same HT-GRPO step is timed on the padded (B, T) grid and on the
PackedLayout batch (core/layout.py) for both selector families at a 50%
keep budget —

  * RPC (min_cut 8): kept prefixes, hull ≈ prompt + cut,
  * URS (p = 0.5): scattered picks, hull runs to the last kept token, so
    packing monetizes response-length raggedness rather than the cut.

Response lengths follow the 80/20 straggler mix every perf bench in this
repo gates on (80% short responses, 20% full-budget): that raggedness is
what the padded grid pays for and what URS packing reclaims — with
near-uniform full-length responses the URS hull IS the response and only
RPC's cut shortens the update.

Emitted rows (BENCH_* perf trajectory, gated in benchmarks/check_gates.py):
  packed/rpc_step, packed/urs_step — step time, tokens scored, ratio
  packed/tokens_scored_ratio      — the WORST per-selector ratio; CI gates
                                    <= 0.65 (the packed path must beat the
                                    padded grid by >= 35% scored tokens)

Both paths run the identical estimator — tests/test_layout.py pins
loss/grad parity — so the ratio is pure dead-compute removal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.grpo import GRPOConfig
from repro.core.layout import make_layout
from repro.core.repack import bucket_ladder
from repro.core.selectors import make_selector
from repro.models import init_params, model_decl
from repro.models.config import ModelConfig, dense_blocks
from repro.optim import AdamWConfig, init_opt_state
from repro.rl import VOCAB_SIZE
from repro.rl.learner import make_train_step

B = 32               # responses per step
T = 256              # padded grid width
PROMPT = 24          # fixed prompt length
LONG_EVERY = 5       # rows with r % 5 == 0 run the full budget (20% long)
SEED = 0


def _model():
    return ModelConfig(name="bench-packed", d_model=128, n_heads=8,
                       n_kv_heads=4, head_dim=16, d_ff=256,
                       vocab_size=VOCAB_SIZE, blocks=dense_blocks(2),
                       seq_parallel=False, remat_policy="none",
                       scan_layers=False)


def _response_lens() -> np.ndarray:
    """Deterministic 80/20 straggler mix (matches the rollout/async benches):
    every 5th row decodes the full budget, the rest stop early."""
    full = T - PROMPT
    return np.array(
        [full if r % LONG_EVERY == 0 else 32 + (r * 7919) % 33
         for r in range(B)], np.int32)


def _batch(rng):
    """Synthetic rollout-shaped batch with ragged response lengths."""
    prompt_lens = np.full(B, PROMPT, np.int32)
    response_lens = _response_lens()
    tokens = rng.integers(1, VOCAB_SIZE, (B, T)).astype(np.int32)
    rmask = np.zeros((B, T), np.float32)
    for r in range(B):
        rmask[r, PROMPT:PROMPT + response_lens[r]] = 1
        tokens[r, PROMPT + response_lens[r]:] = 0
    old_logp = (rng.standard_normal((B, T)) * 0.1 - 2).astype(np.float32)
    old_logp *= rmask
    return {
        "tokens": tokens,
        "response_mask": rmask,
        "old_logp": old_logp,
        "advantages": rng.standard_normal(B).astype(np.float32),
        "orig_lengths": response_lens.astype(np.float32),
        "lengths": (prompt_lens + response_lens).astype(np.int32),
        "behavior_logp": old_logp,
        "staleness": np.zeros((B,), np.float32),
    }, prompt_lens, response_lens, rmask


def run():
    cfg = _model()
    gcfg = GRPOConfig()
    ocfg = AdamWConfig(lr=1e-4, warmup_steps=5, total_steps=1000)
    params = init_params(jax.random.PRNGKey(SEED), model_decl(cfg))
    opt = init_opt_state(params, ocfg)
    rng = np.random.default_rng(SEED)
    batch, prompt_lens, response_lens, rmask = _batch(rng)
    ladder = bucket_ladder(T, 4, 128)

    step_pad = jax.jit(make_train_step(cfg, gcfg, ocfg, vocab_chunks=1))
    step_pk = jax.jit(make_train_step(cfg, gcfg, ocfg, vocab_chunks=1,
                                      packed=True))

    padded_tokens = B * T
    t_pad = None
    worst_ratio = 0.0
    print(f"# packed learner: B={B} T={T} prompt={PROMPT} "
          f"(padded grid {padded_tokens} tokens/step)")
    for sel_name, kw in (("rpc", {"min_cut": 8}), ("urs", {"p": 0.5})):
        sel = make_selector(sel_name, **kw)(
            jax.random.PRNGKey(SEED + 7), jnp.asarray(rmask))
        b = dict(batch)
        b["ht_weights"] = np.asarray(sel.ht_weights, np.float32)

        jb = {k: jnp.asarray(v) for k, v in b.items()}
        if t_pad is None:  # selector-independent: same grid either way
            t_pad = time_call(lambda bb: step_pad(params, opt, bb), jb)

        lb = make_layout("packed").build(
            b, prompt_lens=prompt_lens, response_lens=response_lens,
            keep_len=np.asarray(sel.keep_len),
            keep_mask=b["ht_weights"] > 0,
            prefix_structured=sel.prefix_structured, ladder=ladder)
        jpk = {k: jnp.asarray(v) for k, v in lb.data.items()}
        t_pk = time_call(lambda bb: step_pk(params, opt, bb), jpk)

        ratio = lb.tokens_scored / padded_tokens
        worst_ratio = max(worst_ratio, ratio)
        emit(f"packed/{sel_name}_step", t_pk,
             f"tokens_scored={lb.tokens_scored};ratio={ratio:.4f};"
             f"rows={lb.num_rows};pack_len={lb.row_len};"
             f"pack_efficiency={lb.pack_efficiency:.4f};"
             f"speedup={t_pad / t_pk:.3f}")
        print(f"  {sel_name}: {lb.tokens_scored} tokens/step "
              f"({lb.num_rows}x{lb.row_len}, ratio {ratio:.3f}, "
              f"kept/scored {lb.pack_efficiency:.3f}), "
              f"{t_pk * 1e3:.1f} ms vs padded {t_pad * 1e3:.1f} ms "
              f"({t_pad / t_pk:.2f}x)")

    emit("packed/padded_step", t_pad, f"tokens_scored={padded_tokens}")
    # the gated row: worst selector ratio at the 50% budget
    emit("packed/tokens_scored_ratio", 0.0,
         f"tokens_scored_ratio={worst_ratio:.4f}")
    print(f"  worst tokens_scored ratio: {worst_ratio:.3f} (gate <= 0.65)")


if __name__ == "__main__":
    run()
