"""Benchmark driver: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick defaults
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale settings
    PYTHONPATH=src python -m benchmarks.run \
        --json "$(python -m benchmarks.check_gates --next-name)"

Emits human tables plus CSV rows ``name,us_per_call,derived``; with
``--json`` the rows every bench reported through ``benchmarks.common.emit``
are aggregated into one machine-readable file — the next point of the
perf trajectory (``BENCH_<n>.json``).  ``benchmarks/check_gates.py`` names
the next point and gates it against the newest committed one; CI archives
the artifact per run.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale draws/steps/seeds (slow)")
    ap.add_argument("--only", default="",
                    help="comma list: unbiasedness,gradnorm,matrix,ratio,"
                         "efficiency,quality,rollout,async,packed,paged,"
                         "paged_learner,serving,dist,chaos,roofline")
    ap.add_argument("--json", default="",
                    help="write aggregated machine-readable results here")
    args = ap.parse_args()
    want = set(filter(None, args.only.split(",")))

    def on(name):
        return not want or name in want

    t0 = time.time()
    if on("unbiasedness"):
        from benchmarks import bench_unbiasedness
        bench_unbiasedness.run(draws=1500 if args.full else 400)
        print()
    if on("gradnorm"):
        from benchmarks import bench_gradnorm
        bench_gradnorm.run(draws=600 if args.full else 150)
        print()
    if on("matrix"):
        from benchmarks import bench_method_matrix
        bench_method_matrix.run(draws=400 if args.full else 100)
        print()
    if on("ratio"):
        from benchmarks import bench_selected_ratio
        bench_selected_ratio.run(steps=30 if args.full else 10)
        print()
    if on("efficiency"):
        from benchmarks import bench_efficiency
        bench_efficiency.run()
        print()
    if on("rollout"):
        from benchmarks import bench_rollout_throughput
        bench_rollout_throughput.run()
        print()
    if on("async"):
        from benchmarks import bench_async_overlap
        bench_async_overlap.run()
        print()
    if on("packed"):
        from benchmarks import bench_packed_learner
        bench_packed_learner.run()
        print()
    if on("paged"):
        from benchmarks import bench_paged_decode
        bench_paged_decode.run()
        print()
    if on("paged_learner"):
        from benchmarks import bench_paged_learner
        bench_paged_learner.run()
        print()
    if on("serving"):
        from benchmarks import bench_serving
        bench_serving.run()
        print()
    if on("dist"):
        from benchmarks import bench_dist_overlap
        bench_dist_overlap.run()
        print()
    if on("chaos"):
        from benchmarks import bench_fault_recovery
        bench_fault_recovery.run(smoke=not args.full)
        print()
    if on("quality"):
        from benchmarks import bench_quality
        bench_quality.run(steps=150 if args.full else 40,
                          seeds=(0, 1, 2, 3, 4) if args.full else (0, 1))
        print()
    if on("roofline"):
        import subprocess
        import sys
        subprocess.run([sys.executable, "-m", "benchmarks.roofline"],
                       check=False)
    elapsed = time.time() - t0
    print(f"\n# benchmarks done in {elapsed:.0f}s")

    if args.json:
        import jax

        from benchmarks.common import RESULTS
        payload = {
            "schema": 1,
            "suite": sorted(want) if want else ["all"],
            "full": bool(args.full),
            "elapsed_s": round(elapsed, 1),
            "env": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                # thread-parallelism floors (async/overlap_speedup) are
                # meaningless on a single-CPU runner; check_gates reads
                # this to know whether they apply
                "cpu_count": os.cpu_count(),
            },
            "rows": RESULTS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(RESULTS)} rows to {args.json}")


if __name__ == "__main__":
    main()
