"""The NAT-GRPO learner step: scoring + HT-weighted loss + grads + AdamW.

One code path serves both the CPU trainer (num_microbatches=1, tiny model)
and the production dry-run (gradient accumulation over microbatches, 512-way
mesh) so what we validate hermetically is what we lower at scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.grpo import GRPOConfig, nat_grpo_loss
from repro.dist.sharding import DEFAULT_RULES
from repro.models.config import ModelConfig
from repro.models.model import score_tokens
from repro.optim.adamw import AdamWConfig, adamw_update

F32 = jnp.float32

# behavior_logp/staleness are optional: the async trainer supplies them so
# stale samples get the truncated-IS correction (core/grpo.py); the serial
# path may omit them (or pass staleness == 0, which is bit-identical)
BATCH_KEYS = ("tokens", "response_mask", "old_logp", "advantages",
              "ht_weights", "orig_lengths", "lengths", "behavior_logp",
              "staleness")

# the packed layout (core/layout.py) swaps the per-row keys: token leaves
# are (num_rows, pack_len), per-response leaves stay (B,), and three id
# planes map packed tokens back — positions (rope), segment_ids (attention
# visibility), resp_ids (loss segment scatter)
PACKED_BATCH_KEYS = ("tokens", "positions", "segment_ids", "resp_ids",
                     "response_mask", "old_logp", "advantages", "ht_weights",
                     "orig_lengths", "behavior_logp", "staleness")

# the paged layout (zero re-prefill scoring, DESIGN.md §11) adds the page
# handoff from the rollout engine's export_learner_pages: the per-layer
# pool pages plus the per-segment block tables and suffix-start positions
PAGED_BATCH_KEYS = PACKED_BATCH_KEYS + ("pool", "block_tables", "seg_start")


def make_loss_fn(model_cfg: ModelConfig, grpo_cfg: GRPOConfig, *,
                 mesh=None, rules=None, vocab_chunks: int = 8,
                 packed: bool = False, paged: bool = False,
                 paged_impl: str = "ref"):
    """Build the learner loss.  ``packed=True`` consumes PACKED_BATCH_KEYS
    batches: scoring runs on the dense packed rows (segment-masked
    attention, original positions) and the HT reduction gathers per-token
    terms back to per-response sums via ``resp_ids`` segment scatter —
    same estimator, fewer scored tokens.

    ``paged=True`` (implies packed rows) consumes PAGED_BATCH_KEYS batches
    from ``core.layout.PagedLayout`` + the engine's
    ``export_learner_pages``: only response suffixes are forwarded, prompt
    KV is read (detached) from the rollout page pool — zero re-prefill
    (DESIGN.md §11).  ``paged_impl`` picks the attention path ("ref" |
    "kernel")."""
    rules = rules or DEFAULT_RULES  # a mesh without rules gets the defaults

    def loss_fn(params, mb: dict):
        if packed or paged:
            pg = {} if not paged else dict(
                paged_prefix=mb["pool"],
                page_tables={"block_tables": mb["block_tables"],
                             "seg_start": mb["seg_start"]},
                paged_impl=paged_impl)
            logp, aux = score_tokens(
                params, model_cfg, mb["tokens"],
                positions=mb["positions"], segment_ids=mb["segment_ids"],
                image_embeds=mb.get("image_embeds"), mesh=mesh, rules=rules,
                vocab_chunks=vocab_chunks, **pg)
            loss, metrics = nat_grpo_loss(
                logp, mb["old_logp"], mb["advantages"], mb["ht_weights"],
                mb["orig_lengths"], grpo_cfg, ref_logp=mb.get("ref_logp"),
                behavior_logp=mb.get("behavior_logp"),
                staleness=mb.get("staleness"),
                segment_ids=mb["resp_ids"],
                num_segments=mb["advantages"].shape[0])
        else:
            logp, aux = score_tokens(
                params, model_cfg, mb["tokens"], lengths=mb["lengths"],
                image_embeds=mb.get("image_embeds"), mesh=mesh, rules=rules,
                vocab_chunks=vocab_chunks)
            loss, metrics = nat_grpo_loss(
                logp, mb["old_logp"], mb["advantages"], mb["ht_weights"],
                mb["orig_lengths"], grpo_cfg, ref_logp=mb.get("ref_logp"),
                behavior_logp=mb.get("behavior_logp"),
                staleness=mb.get("staleness"))
        metrics["moe_aux"] = aux
        return loss + aux, metrics

    return loss_fn


def make_train_step(
    model_cfg: ModelConfig,
    grpo_cfg: GRPOConfig,
    opt_cfg: AdamWConfig,
    *,
    num_microbatches: int = 1,
    mesh=None,
    rules=None,
    vocab_chunks: int = 8,
    unroll_microbatches: bool = False,
    param_shardings=None,
    packed: bool = False,
    paged: bool = False,
    paged_impl: str = "ref",
):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    With num_microbatches > 1 the batch is split on dim 0 and gradients are
    accumulated in fp32 through a lax.scan (sequential microbatches — the
    standard activation-memory/compute trade at large global batch).
    ``unroll_microbatches`` uses a Python loop instead of lax.scan — the
    dry-run's roofline probes need the per-microbatch cost visible in HLO
    (XLA's cost analysis counts a while-loop body once).
    ``param_shardings`` (optional tree of NamedShardings): constrain each
    microbatch gradient to its parameter's sharding so the data-axis psum
    lowers to a reduce-scatter instead of a full all-reduce (§Perf).
    ``packed`` selects the packed-layout loss (PACKED_BATCH_KEYS).  Packed
    batches cannot be split on dim 0 — a packed row holds tokens of several
    responses while the per-response leaves stay (B,) — so with
    ``num_microbatches > 1`` the batch must be microbatched BEFORE packing
    (``core.layout.build_microbatches``: one pack plan per chunk) and the
    train step consumes a TUPLE of per-microbatch packed dicts.  The
    accumulation loop is unrolled — chunks may pack to different
    (rows, pack_len) shapes, which lax.scan cannot carry.
    ``paged=True`` swaps in the zero re-prefill loss (PAGED_BATCH_KEYS;
    see ``make_loss_fn``); the microbatch discipline is the packed one."""
    loss_fn = make_loss_fn(model_cfg, grpo_cfg, mesh=mesh, rules=rules,
                           vocab_chunks=vocab_chunks, packed=packed,
                           paged=paged, paged_impl=paged_impl)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(grads):
        if param_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            param_shardings)

    def packed_accum_step(params, opt_state, batches):
        """Packed gradient accumulation: ``batches`` is a tuple of
        ``num_microbatches`` pre-packed dicts (split on the response axis
        before packing).  Grads and metrics average over chunks exactly as
        the dense scan path does."""
        m = num_microbatches
        if not isinstance(batches, (tuple, list)) or len(batches) != m:
            raise ValueError(
                f"packed train step with num_microbatches={m} takes a "
                f"tuple of {m} pre-packed batch dicts "
                "(core.layout.build_microbatches)")
        g_acc = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        metrics0 = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params,
                                  batches[0])
        metric_acc = jax.tree.map(lambda _: jnp.zeros((), F32), metrics0)
        for mb in batches:
            (loss, metrics), g = vg(params, mb)
            g = constrain(g)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(F32) / m,
                                 g_acc, g)
            metrics = {k: v.astype(F32) / m for k, v in metrics.items()}
            metric_acc = jax.tree.map(lambda a, b: a + b, metric_acc,
                                      metrics)
        return g_acc, metric_acc

    def train_step(params, opt_state, batch: dict):
        m = num_microbatches
        if m == 1:
            (loss, metrics), grads = vg(params, batch)
            grads = constrain(grads)
        elif packed or paged:
            grads, metrics = packed_accum_step(params, opt_state, batch)
        else:
            def split(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            mbs = {k: split(v) for k, v in batch.items()}

            def acc(carry, mb):
                g_acc, metric_acc = carry
                (loss, metrics), g = vg(params, mb)
                g = constrain(g)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(F32) / m, g_acc, g)
                metrics = {k: v.astype(F32) / m for k, v in metrics.items()}
                metric_acc = jax.tree.map(lambda a, b: a + b, metric_acc, metrics)
                return (g_acc, metric_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            mb0 = jax.tree.map(lambda x: x[0], mbs)
            metrics0 = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params, mb0)
            metric0 = jax.tree.map(lambda _: jnp.zeros((), F32), metrics0)
            if unroll_microbatches:
                carry = (g0, metric0)
                for i in range(m):
                    carry, _ = acc(carry, jax.tree.map(lambda x: x[i], mbs))
                grads, metrics = carry
            else:
                (grads, metrics), _ = jax.lax.scan(acc, (g0, metric0), mbs)

        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def with_publication(train_step, publisher):
    """Compose a train step with device-to-device weight publication
    (DESIGN.md §12): after each update the new params are snapshotted onto
    every rollout slice via ``dist.publish.WeightPublisher`` — a pure
    ``jax.device_put`` resharding, zero bytes through the host — before
    the step returns.  Publication is async-dispatched device work, so it
    overlaps the host-side metrics fetch that follows in the trainer.

    Epochs auto-increment from the publisher's last epoch; the
    disaggregated trainer maps them 1:1 onto learner versions
    (``rl/dist_trainer.py::DistNATGRPOTrainer._publish``).
    """

    def published_step(params, opt_state, batch, *args, **kwargs):
        new_params, new_opt, metrics = train_step(
            params, opt_state, batch, *args, **kwargs)
        publisher.publish(new_params)
        return new_params, new_opt, metrics

    return published_step
