"""Cross-request radix prefix cache over the paged KV pool (DESIGN.md §10).

SGLang/vLLM-style RadixAttention at page granularity: a trie keyed by
``page_len``-token chunks of prompt token ids, where each node owns exactly
one read-only pool page (and exactly one allocator reference on it).  The
paged engine consults the trie at group placement — the longest ready
chain of matched nodes contributes its pages directly to the new group's
block tables, and only the unmatched suffix is prefilled into fresh pages
— then chains the suffix's *full* pages back into the trie so later
requests can reuse them.  Partial trailing pages are never cached (their
in-page layout depends on the prompt length), and a fully cached prompt
deliberately drops its last matched page so at least one token is always
recomputed: the prefill's last-token logits feed sampling, exactly like
vLLM's last-block recompute.

Ownership protocol (the invariant the property tests pin):

* the trie holds ONE reference per resident page, taken at ``insert``;
* readers (groups whose block tables name a cached page) hold their own
  references via the engine's usual retain/release flow;
* eviction only touches *leaf* nodes whose page has refcount exactly 1 —
  i.e. trie-only, no live reader — so a page under an active request can
  never be reclaimed; releasing the trie's reference frees the page.

Freshly inserted nodes stay ``ready=False`` until the next ``step()``
(drive round): their K/V is still being written by this round's batched
prefill dispatch, so same-round lookups from other lanes must not match
them.  ``flush()`` starts a new epoch (weights changed — cached K/V is
stale): old-epoch nodes stop matching, evictable ones are freed at once,
and ``reap()`` collects stragglers as their readers drain.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence

import numpy as np


class RadixNode:
    """One resident pool page: the KV of one full page of prompt tokens."""

    __slots__ = ("key", "page", "parent", "children", "clock", "ready",
                 "epoch")

    def __init__(self, key: tuple, page: int, parent: "RadixNode",
                 epoch: int):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: dict = {}
        self.clock = 0
        self.ready = False
        self.epoch = epoch


class RadixPrefixCache:
    """Trie over token-id chunks; one pool page (and one allocator ref)
    per node.  ``alloc`` is duck-typed as ``rl.engine.PageAllocator``
    (``retain``/``release``/``refcount``)."""

    def __init__(self, alloc, page_len: int):
        self.alloc = alloc
        self.page_len = int(page_len)
        self.root = RadixNode((), -1, None, 0)
        self.root.ready = True
        self._clock = 0
        self._epoch = 0
        self._pending: List[RadixNode] = []
        self._has_stale = False
        self._stale_roots: List[RadixNode] = []

    # ------------------------------------------------------ round lifecycle
    def step(self) -> None:
        """Open the nodes inserted last round for matching: their pages'
        prefill writes landed when the previous round's step retired."""
        for nd in self._pending:
            nd.ready = True
        self._pending.clear()

    # ------------------------------------------------------------ matching
    def lookup(self, tokens) -> List[RadixNode]:
        """Longest ready chain of full-page chunks of ``tokens``.

        Pure: takes no references and bumps no clocks, so a placement that
        aborts (pool pressure) leaks nothing.  The engine retains the
        matched pages and calls ``touch`` when it commits.
        """
        t = tuple(int(x) for x in np.asarray(tokens).reshape(-1).tolist())
        pl = self.page_len
        node, out, i = self.root, [], 0
        while i + pl <= len(t):
            child = node.children.get(t[i:i + pl])
            if child is None or not child.ready or child.epoch != self._epoch:
                break
            out.append(child)
            node = child
            i += pl
        return out

    def touch(self, nodes: Sequence[RadixNode]) -> None:
        """LRU clock bump along a committed match chain."""
        self._clock += 1
        for nd in nodes:
            nd.clock = self._clock

    # ----------------------------------------------------------- insertion
    def insert(self, parent: Optional[RadixNode], tokens, start: int,
               pages: Sequence[int]) -> List[RadixNode]:
        """Chain ``pages`` below ``parent`` as the full-page chunks of
        ``tokens[start:]``; ``start`` must be page-aligned and ``parent``
        the node covering ``tokens[:start]`` (or None for the root).

        The trie retains each page it adopts.  A chunk already present
        keeps its incumbent node — the duplicate page stays caller-owned
        and dies with its group — and chaining continues underneath it.
        Returns the newly adopted nodes (ready after the next ``step()``).
        """
        t = tuple(int(x) for x in np.asarray(tokens).reshape(-1).tolist())
        pl = self.page_len
        assert start % pl == 0, "insert start must be page-aligned"
        node = parent if parent is not None else self.root
        self._clock += 1
        adopted: List[RadixNode] = []
        for j, page in enumerate(pages):
            i = start + j * pl
            key = t[i:i + pl]
            assert len(key) == pl, "only full pages are cacheable"
            incumbent = node.children.get(key)
            if incumbent is not None and incumbent.epoch == self._epoch:
                node = incumbent
                continue
            if incumbent is not None:
                # same chunk, stale epoch: shadow it — the stale node keeps
                # its page until reaped, but stops being reachable by key
                self._orphan(incumbent)
            child = RadixNode(key, int(page), node, self._epoch)
            child.clock = self._clock
            self.alloc.retain([int(page)])
            node.children[key] = child
            self._pending.append(child)
            adopted.append(child)
            node = child
        return adopted

    def _orphan(self, nd: RadixNode) -> None:
        """Detach a stale subtree so a fresh chain can take its key; its
        nodes stay reapable through ``_stale_roots``."""
        nd.parent.children.pop(nd.key, None)
        nd.parent = None
        self._stale_roots.append(nd)

    # ------------------------------------------------------------ eviction
    def _iter_nodes(self, root: Optional[RadixNode] = None
                    ) -> Iterator[RadixNode]:
        stack = list((root or self.root).children.values())
        if root is None:
            stack.extend(self._stale_roots)
        while stack:
            nd = stack.pop()
            yield nd
            stack.extend(nd.children.values())

    def _evictable(self, stale_only: bool) -> List[RadixNode]:
        out = []
        for nd in self._iter_nodes():
            if nd.children or not nd.ready:
                continue
            if stale_only and nd.epoch == self._epoch:
                continue
            if int(self.alloc.refcount[nd.page]) != 1:
                continue  # a live reader still reaches this page
            out.append(nd)
        return out

    def _drop(self, nd: RadixNode) -> List[int]:
        if nd.parent is not None:
            nd.parent.children.pop(nd.key, None)
        else:
            self._stale_roots = [r for r in self._stale_roots if r is not nd]
        return self.alloc.release([nd.page])

    def evict(self, want: int, stale_only: bool = False) -> List[int]:
        """Free up to ``want`` pages: stale-epoch branches first, then the
        coldest (LRU) current leaves.  Cascades — freeing a leaf may expose
        its parent as the next candidate.  Returns the freed page ids (the
        engine must pos-poison them before reuse)."""
        freed: List[int] = []
        while len(freed) < want:
            cands = self._evictable(stale_only=True)
            if not cands and not stale_only:
                cands = self._evictable(stale_only=False)
            if not cands:
                break
            cands.sort(key=lambda nd: nd.clock)
            for nd in cands:
                freed += self._drop(nd)
                if len(freed) >= want:
                    break
        if not self._evictable(stale_only=True):
            self._has_stale = bool(self._stale_roots) or any(
                nd.epoch != self._epoch for nd in self._iter_nodes())
        return freed

    def flush(self) -> List[int]:
        """Invalidate every cached prefix (weights changed: resident KV no
        longer matches the policy).  Evictable branches are freed now;
        branches with live readers survive — unreachable to ``lookup`` —
        until ``reap()`` collects them."""
        self._epoch += 1
        self._has_stale = True
        return self.evict(1 << 30, stale_only=True)

    def reap(self) -> List[int]:
        """Collect stale-epoch branches whose readers have drained; called
        once per drive round, cheap no-op when nothing is stale."""
        if not self._has_stale:
            return []
        return self.evict(1 << 30, stale_only=True)

    # --------------------------------------------------------- introspection
    @property
    def resident_pages(self) -> set:
        return {nd.page for nd in self._iter_nodes()}

    @property
    def num_resident(self) -> int:
        return sum(1 for _ in self._iter_nodes())
