"""RL substrate: verifiable envs, rollout engine, NAT-GRPO learner/trainer."""
from repro.rl.env import (
    EOS,
    PAD,
    VOCAB_SIZE,
    CopyCalcEnv,
    ModArithEnv,
    decode_tokens,
    encode,
    make_env,
)
from repro.rl.learner import make_loss_fn, make_train_step
from repro.rl.rollout import RolloutBatch, RolloutConfig, generate, rollout_group
from repro.rl.trainer import NATGRPOTrainer, NATTrainerConfig

__all__ = [
    "EOS", "PAD", "VOCAB_SIZE", "CopyCalcEnv", "ModArithEnv", "decode_tokens",
    "encode", "make_env", "make_loss_fn", "make_train_step", "RolloutBatch",
    "RolloutConfig", "generate", "rollout_group", "NATGRPOTrainer",
    "NATTrainerConfig",
]
