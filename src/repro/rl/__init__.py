"""RL substrate: verifiable envs, rollout engine, NAT-GRPO learner/trainer."""
from repro.rl.env import (
    EOS,
    PAD,
    VOCAB_SIZE,
    CopyCalcEnv,
    ModArithEnv,
    decode_tokens,
    encode,
    make_env,
)
from repro.rl.async_trainer import (
    AsyncNATGRPOTrainer,
    KeyChain,
    SampleQueue,
    TaggedGroup,
)
from repro.rl.dist_trainer import (
    DistNATGRPOTrainer,
    FleetReplica,
    make_dist_trainer,
)
from repro.rl.supervision import (
    QuiesceTimeout,
    ReplicaSupervisor,
    RetryPolicy,
    SupervisorError,
    retry_call,
)
from repro.rl.engine import (
    Completion,
    ContinuousRolloutEngine,
    DisaggPagedRolloutEngine,
    EngineConfig,
    PageAllocator,
    PagedEngineConfig,
    PagedRolloutEngine,
    PagePoolExhausted,
    Request,
    make_engine,
    make_paged_engine,
)
from repro.rl.learner import make_loss_fn, make_train_step
from repro.rl.radix import RadixNode, RadixPrefixCache
from repro.rl.rollout import (
    RolloutBatch,
    RolloutConfig,
    generate,
    rollout_group,
    rollout_group_continuous,
)
from repro.rl.trainer import NATGRPOTrainer, NATTrainerConfig

__all__ = [
    "EOS", "PAD", "VOCAB_SIZE", "CopyCalcEnv", "ModArithEnv", "decode_tokens",
    "encode", "make_env", "make_loss_fn", "make_train_step", "Completion",
    "ContinuousRolloutEngine", "EngineConfig", "PageAllocator",
    "PagedEngineConfig", "PagedRolloutEngine", "PagePoolExhausted",
    "Request", "make_engine", "make_paged_engine",
    "RadixNode", "RadixPrefixCache",
    "RolloutBatch", "RolloutConfig", "generate", "rollout_group",
    "rollout_group_continuous", "NATGRPOTrainer", "NATTrainerConfig",
    "AsyncNATGRPOTrainer", "SampleQueue", "TaggedGroup", "KeyChain",
    "DistNATGRPOTrainer", "DisaggPagedRolloutEngine", "make_dist_trainer",
    "FleetReplica", "ReplicaSupervisor", "RetryPolicy", "SupervisorError",
    "QuiesceTimeout", "retry_call",
]
