"""NAT-GRPO trainer: the full RLVR loop with token-efficient learning.

Per step:
  1. sample P prompts (deterministic pipeline),
  2. rollout G completions per prompt (over-provisioned quota),
  3. verify rewards on FULL responses -> group-relative advantages (Eq. 2),
  4. draw the NAT token selection (Full / URS / RPC / Det-Trunc / Entropy),
  5. (prefix-structured selectors) physically repack the batch to the
     smallest TPU length bucket covering prompt+cut — the learner genuinely
     processes fewer tokens (RPC's forward saving),
  6. HT-weighted GRPO loss (Eqs. 6/9) + AdamW.

Per-bucket executables come from jit's shape-keyed cache: each ladder length
compiles once and is reused for the rest of training.
"""
from __future__ import annotations

import dataclasses
import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grpo import GRPOConfig, group_advantages
from repro.core.repack import bucket_ladder, pick_bucket
from repro.core.selectors import EntropySelector, make_selector
# NOTE: repro.data sits ABOVE repro.rl in the layering (data imports
# rl.env), so importing it at module scope would be circular whenever
# repro.data.pipeline is the entry point.  Import lazily at use sites.
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.models.model import model_decl
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.rl.learner import make_train_step
from repro.rl.rollout import (
    RolloutConfig, rollout_group, rollout_group_continuous,
)
from repro.rl.env import make_env


@dataclasses.dataclass(frozen=True)
class NATTrainerConfig:
    env: str = "mod_arith"
    env_kwargs: tuple = ()
    selector: str = "rpc"            # full | urs | rpc | det_trunc | entropy
    selector_kwargs: tuple = ()      # e.g. (("min_cut", 8),) or (("p", 0.5),)
    prompts_per_step: int = 8        # P
    max_prompt_len: int = 24
    rollout: RolloutConfig = RolloutConfig()
    rollout_engine: str = "continuous"  # continuous (slot arena) | legacy
    num_slots: int = 0               # arena slots; 0 -> P * G
    steps_per_sync: int = 4          # engine decode substeps per host sync
    grpo: GRPOConfig = GRPOConfig()
    adamw: AdamWConfig = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=500)
    bucket_align: int = 16
    num_buckets: int = 4
    repack: bool = True              # physical prefix truncation for RPC
    seed: int = 0


class NATGRPOTrainer:
    def __init__(self, model_cfg: ModelConfig, tcfg: NATTrainerConfig,
                 params=None, mesh=None, rules=None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.env = make_env(tcfg.env, **dict(tcfg.env_kwargs))
        from repro.data.pipeline import PromptPipeline

        self.pipeline = PromptPipeline(
            self.env, batch_size=tcfg.prompts_per_step,
            max_prompt_len=tcfg.max_prompt_len, seed=tcfg.seed)
        self.key = jax.random.PRNGKey(tcfg.seed)
        if params is None:
            self.key, k = jax.random.split(self.key)
            params = init_params(k, model_decl(model_cfg))
        self.params = params
        self.opt_state = init_opt_state(params, tcfg.adamw)
        self.selector = make_selector(tcfg.selector, **dict(tcfg.selector_kwargs))
        if tcfg.rollout_engine not in ("continuous", "legacy"):
            raise ValueError(f"unknown rollout_engine {tcfg.rollout_engine!r}")
        if tcfg.rollout_engine == "continuous" and not model_cfg.num_codebooks:
            from repro.rl.engine import ContinuousRolloutEngine, EngineConfig

            self.engine = ContinuousRolloutEngine(
                model_cfg, tcfg.rollout, EngineConfig(
                    num_slots=tcfg.num_slots
                    or tcfg.prompts_per_step * tcfg.rollout.group_size,
                    max_prompt_len=tcfg.max_prompt_len,
                    steps_per_sync=tcfg.steps_per_sync))
        else:
            # legacy scan — explicit opt-out, or codebook models (audio),
            # which the slot arena does not serve yet
            self.engine = None
        self.step_count = 0
        self._train_step = jax.jit(make_train_step(
            model_cfg, tcfg.grpo, tcfg.adamw, mesh=mesh, rules=rules,
            vocab_chunks=1))
        t_max = tcfg.max_prompt_len + tcfg.rollout.max_new_tokens
        self.ladder = bucket_ladder(t_max, tcfg.num_buckets, tcfg.bucket_align)
        self.history: list = []

    # ------------------------------------------------------------------ step
    def train_step(self) -> dict:
        t0 = time.perf_counter()
        tcfg = self.tcfg
        pb = next(self.pipeline)
        self.key, k_roll, k_sel = jax.random.split(self.key, 3)

        if self.engine is not None:
            rb = rollout_group_continuous(
                self.params, self.model_cfg, tcfg.rollout,
                pb.tokens, pb.prompt_lens, k_roll, engine=self.engine)
        else:
            rb = rollout_group(self.params, self.model_cfg, tcfg.rollout,
                               pb.tokens, pb.prompt_lens, k_roll)
        t_roll = time.perf_counter()

        # rewards on FULL responses (never affected by token selection)
        p, g = tcfg.prompts_per_step, tcfg.rollout.group_size
        rewards = np.zeros((p, g), np.float32)
        for i in range(p):
            for j in range(g):
                r = i * g + j
                pl, rl = int(rb.prompt_lens[r]), int(rb.response_lens[r])
                resp = rb.tokens[r, pl:pl + rl]
                rewards[i, j] = self.env.reward(pb.prompts[i], resp)
        adv = np.asarray(group_advantages(jnp.asarray(rewards),
                                          tcfg.grpo.adv_eps)).reshape(-1)

        # NAT selection
        rmask = jnp.asarray(rb.response_mask)
        if isinstance(self.selector, EntropySelector):
            sel = self.selector(k_sel, rmask, jnp.asarray(rb.entropies))
        else:
            sel = self.selector(k_sel, rmask)
        ht_w = np.asarray(sel.ht_weights, np.float32)
        keep_len = np.asarray(sel.keep_len)

        batch = {
            "tokens": rb.tokens,
            "response_mask": rb.response_mask,
            "old_logp": rb.old_logp,
            "advantages": adv.astype(np.float32),
            "ht_weights": ht_w,
            "orig_lengths": rb.response_lens.astype(np.float32),
            "lengths": (rb.prompt_lens + rb.response_lens).astype(np.int32),
        }

        # physical prefix truncation (RPC / Det-Trunc): slice to bucket
        if tcfg.repack and sel.prefix_structured:
            keep_total = rb.prompt_lens + np.minimum(keep_len, rb.response_lens)
            t_new = pick_bucket(int(keep_total.max()), self.ladder)
            t_new = min(t_new, rb.tokens.shape[1])
            batch = {k: (v[:, :t_new] if getattr(v, "ndim", 0) >= 2 else v)
                     for k, v in batch.items()}
            batch["lengths"] = keep_total.astype(np.int32)
        t_sel = time.perf_counter()

        self.params, self.opt_state, metrics = self._train_step(
            self.params, self.opt_state, {k: jnp.asarray(v)
                                          for k, v in batch.items()})
        metrics = {k: float(v) for k, v in metrics.items()}
        t_end = time.perf_counter()

        rstats = rb.stats or {}
        metrics.update(
            reward_mean=float(rewards.mean()),
            reward_max=float(rewards.max(axis=1).mean()),
            completed_frac=float(rb.completed.mean()),
            resp_len_mean=float(rb.response_lens.mean()),
            learner_tokens=int(batch["tokens"].shape[0] * batch["tokens"].shape[1]),
            bucket_len=int(batch["tokens"].shape[1]),
            # rollout token cost: with the slot arena, over-provisioned groups
            # pay for generated tokens, not G' full budgets (ISSUE 2)
            tokens_generated=int(rstats.get("tokens_generated", 0)),
            tokens_budget=int(rstats.get("tokens_budget", 0)),
            rollout_decode_steps=int(rstats.get("decode_steps", 0)),
            rollout_cancelled=int(rstats.get("cancelled", 0)),
            rollout_utilization=(
                rstats.get("tokens_generated", 0)
                / max(rstats.get("slot_substeps", 0), 1)),
            entropy_behavior=float(
                (rb.entropies * rb.response_mask).sum()
                / max(rb.response_mask.sum(), 1)),
            time_rollout=t_roll - t0,
            time_select=t_sel - t_roll,
            time_learn=t_end - t_sel,
            time_total=t_end - t0,
            step=self.step_count,
        )
        self.step_count += 1
        self.history.append(metrics)
        return metrics

    def run(self, num_steps: int, log_every: int = 0) -> list:
        for i in range(num_steps):
            m = self.train_step()
            if log_every and i % log_every == 0:
                print(f"step {m['step']:4d} reward={m['reward_mean']:.3f} "
                      f"loss={m['loss']:+.4f} sel={m.get('selected_ratio', 1):.2f} "
                      f"bucket={m['bucket_len']} t={m['time_total']:.2f}s")
        return self.history

    # ------------------------------------------------------------------ eval
    def evaluate(self, num_prompts: int = 32, temperature: float = 0.0) -> dict:
        """Greedy accuracy on fresh prompts (reward == 1 counts as correct).

        Uses the legacy single-wave path: eval is G=1 with no
        over-provisioning, so there is no recycling for the arena to
        exploit, and the training engine's jit cache (keyed on the training
        RolloutConfig) is left untouched."""
        from repro.data.pipeline import PromptPipeline

        pipe = PromptPipeline(self.env, batch_size=num_prompts,
                              max_prompt_len=self.tcfg.max_prompt_len,
                              seed=self.tcfg.seed + 10_000)
        pb = next(pipe)
        rcfg = dataclasses.replace(self.tcfg.rollout, temperature=temperature,
                                   group_size=1, overprovision=1.0)
        self.key, k = jax.random.split(self.key)
        rb = rollout_group(self.params, self.model_cfg, rcfg,
                           pb.tokens, pb.prompt_lens, k)
        correct = 0
        for i in range(num_prompts):
            pl, rl = int(rb.prompt_lens[i]), int(rb.response_lens[i])
            r = self.env.reward(pb.prompts[i], rb.tokens[i, pl:pl + rl])
            correct += int(r >= 1.0)
        return {"accuracy": correct / num_prompts,
                "resp_len": float(rb.response_lens.mean())}
