"""NAT-GRPO trainer: the serial entry point over the async machinery.

Per step:
  1. sample P prompts (deterministic pipeline),
  2. rollout G completions per prompt (over-provisioned quota),
  3. verify rewards on FULL responses -> group-relative advantages (Eq. 2),
  4. draw the NAT token selection (Full / URS / RPC / Det-Trunc / Entropy),
  5. lay the batch out for the learner (``NATTrainerConfig.layout``,
     core/layout.py): ``bucketed`` slices prefix-structured selections to
     the smallest TPU length bucket covering prompt+cut, ``packed``
     bin-packs each response's kept-span hull into dense segment-id rows
     (update FLOPs scale with the token budget for URS too), ``padded``
     scores the raw grid,
  6. HT-weighted GRPO loss (Eqs. 6/9) + AdamW.

The whole loop lives in ``rl/async_trainer.py``: an actor thread drives
the rollout engine and deposits finished groups into a bounded-staleness
sample queue, a learner drains it (DESIGN.md §6).  ``NATGRPOTrainer`` is
that machinery pinned to ``max_staleness=0``, which is *token-exact* with
the historical serial loop: the actor is gated until the learner has
consumed every outstanding group, so rollouts for step k always run on
the step-k parameters and the staleness correction is identically 1
(asserted bitwise in ``tests/test_async_trainer.py``).  Use
``AsyncNATGRPOTrainer`` directly for ``max_staleness > 0`` overlap.

Per-bucket executables come from jit's shape-keyed cache: each ladder
length compiles once and is reused for the rest of training.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig
from repro.rl.async_trainer import (
    AsyncNATGRPOTrainer,
    NATTrainerConfig,
)

__all__ = ["NATGRPOTrainer", "NATTrainerConfig"]


class NATGRPOTrainer(AsyncNATGRPOTrainer):
    """Serial NAT-GRPO trainer: ``AsyncNATGRPOTrainer`` at staleness 0.

    Any ``max_staleness`` requested in the config is pinned to 0 — this
    class *is* the serial contract.  Construct ``AsyncNATGRPOTrainer``
    yourself to opt into bounded-staleness overlap.
    """

    def __init__(self, model_cfg: ModelConfig, tcfg: NATTrainerConfig,
                 params=None, mesh=None, rules=None, budget_fn=None):
        if tcfg.max_staleness != 0:
            tcfg = dataclasses.replace(tcfg, max_staleness=0)
        super().__init__(model_cfg, tcfg, params=params, mesh=mesh,
                         rules=rules, budget_fn=budget_fn)
