"""Stream-overlapped NAT trainer: bounded-staleness actor/learner pipeline.

The serial trainer pays a serial tax NAT's own systems analysis warns
about: the learner idles while long-tail rollouts drain, and the slot
arena idles during backprop.  This module splits the step into two loops
connected by a bounded-staleness sample queue (DESIGN.md §6):

* **Actor** (background thread) — drives the rollout engine, one *group*
  (= P prompts x G kept rollouts, over-provisioned and quota-cancelled) at
  a time, tagging each with the policy version that generated it, and
  deposits assembled groups into the queue.  With ``max_staleness > 0``
  the actor streams groups through a persistent engine session, so a new
  group's prompts refill slots freed by the previous group's stragglers —
  the arena never drains to a barrier between steps.
* **Learner** (the caller of ``train_step``) — pops the oldest group,
  scores rewards, draws the NAT selection, and applies the HT-weighted
  GRPO update.  Samples whose behaviour version lags the learner get a
  truncated importance correction composed with their HT weights
  (``core/grpo.py::nat_grpo_loss``); the queue refuses to serve anything
  staler than ``max_staleness`` versions.

Weight publication is a versioned snapshot swap: the learner rebinds a
``(params, version)`` tuple; the actor picks it up at its next group
admission and hands it to the engine via ``set_params`` — the jitted
engine step in flight keeps the (immutable) reference it was called with,
so publication never copies or races device work.

``max_staleness=0`` degenerates to the serial trainer *token-exactly* —
and structurally: no actor thread exists at all (a thread could only roll
while ``train_step`` blocked on it, so it would be pure overhead and a
leak for callers that never ``close()``); the group is produced inline on
a per-group engine session with the same key chain, and the staleness
correction multiplies by exactly 1.0 (``tests/test_async_trainer.py``
asserts bitwise parity).  ``rl/trainer.py::NATGRPOTrainer`` is that
special case, kept as the stable serial entry point.  ``max_staleness>0``
trainers own a daemon actor thread: call ``close()`` when done with one.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.grpo import GRPOConfig, group_advantages
from repro.core.layout import make_layout
from repro.core.repack import bucket_ladder
from repro.core.selectors import EntropySelector, make_selector
from repro.models import capabilities as caps
# NOTE: repro.data sits ABOVE repro.rl in the layering (data imports
# rl.env), so importing it at module scope would be circular whenever
# repro.data.pipeline is the entry point.  Import lazily at use sites.
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.models.model import model_decl
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.rl.env import make_env
from repro.rl.learner import make_train_step
from repro.rl.rollout import (
    RolloutConfig, batch_from_completions, rollout_group,
    rollout_group_continuous,
)


@dataclasses.dataclass(frozen=True)
class NATTrainerConfig:
    env: str = "mod_arith"
    env_kwargs: tuple = ()
    selector: str = "rpc"            # full | urs | rpc | det_trunc | entropy
    selector_kwargs: tuple = ()      # e.g. (("min_cut", 8),) or (("p", 0.5),)
    prompts_per_step: int = 8        # P
    max_prompt_len: int = 24
    rollout: RolloutConfig = RolloutConfig()
    # continuous (dense slot arena) | paged (paged KV pool with group
    # prefix sharing, DESIGN.md §8) | legacy (fixed-shape scan)
    rollout_engine: str = "continuous"
    num_slots: int = 0               # arena slots; 0 -> P * G
    steps_per_sync: int = 4          # engine decode substeps per host sync
    page_len: int = 16               # paged arena: tokens per KV page
    num_pages: int = 0               # paged arena: pool size; 0 -> worst case
    grpo: GRPOConfig = GRPOConfig()
    adamw: AdamWConfig = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=500)
    bucket_align: int = 16
    num_buckets: int = 4
    repack: bool = True              # physical prefix truncation for RPC
    # batch layout for the learner step (core/layout.py, DESIGN.md §7):
    # "" derives from ``repack`` ("bucketed" when True, "padded" otherwise);
    # "packed" bin-packs kept-span hulls into dense segment-id rows
    layout: str = ""
    layout_kwargs: tuple = ()        # e.g. (("row_quant", 2),)
    seed: int = 0
    # -- actor/learner overlap (DESIGN.md §6) --
    max_staleness: int = 0           # 0 reproduces the serial trainer exactly
    queue_groups: int = 0            # sample-queue capacity; 0 -> staleness+1
    # -- disaggregated fleets (DESIGN.md §12, rl/dist_trainer.py) --
    fleet: int = 0                   # N>0: N replicated rollout fleet slices
    disagg: str = ""                 # "" | "prefill,decode": split each slice
    # -- supervision / elasticity (DESIGN.md §13, rl/supervision.py) --
    supervise: bool = True           # heartbeat + reclaim supervisor (fleets)
    hang_timeout: float = 300.0      # claimed group + no heartbeat/progress
    supervise_interval: float = 0.2  # monitor poll period
    publish_retries: int = 3         # bounded WeightPublisher attempts
    publish_backoff: float = 0.05    # base publish backoff (doubles/attempt)
    placement_retries: int = 3       # bounded attempts under PagePoolExhausted
    placement_backoff: float = 0.05  # base placement backoff (doubles/attempt)


@dataclasses.dataclass
class TaggedGroup:
    """One finished rollout group in the sample queue."""

    index: int             # actor step index (== the learner step it feeds)
    behavior_version: int  # learner version whose params generated it
    batch: object          # RolloutBatch
    prompt_batch: object   # data.pipeline.PromptBatch (for reward eval)
    key_sel: jax.Array     # the selection key split for this step
    t_rollout: float       # actor wall-clock spent rolling the group
    # actor key-chain state *before* this group's splits: checkpoints rewind
    # to the oldest unconsumed group so resume re-rolls it identically
    key0: Optional[jax.Array] = None


class StaleSampleError(RuntimeError):
    """A queued group exceeded the staleness bound (never served)."""


class SampleQueue:
    """Bounded, index-ordered queue between actor(s) and learner with a
    staleness contract: ``pop(current_version)`` never returns a group whose
    behaviour version lags by more than ``max_staleness`` — over-stale groups
    are dropped and counted, not served.  Errors from a producing thread
    surface on the consumer via ``fail`` (first error wins: a later ``fail``
    — e.g. the poison pill from ``close()`` — never masks the root cause).

    **Multi-producer ordering (DESIGN.md §12).**  With one actor, groups
    arrive already index-ordered and this is the PR 3 FIFO.  With a fleet of
    N actors racing, groups finish out of order; the learner still consumes
    the serial index sequence, so the queue reassembles: ``put`` inserts
    sorted by ``TaggedGroup.index``, and a producer **reserves** its index
    before rolling so ``pop`` can tell "index 4 is absent" from "index 4 is
    still in flight" and hold younger groups until the gap fills.  A
    reservation counts toward capacity (the slot is pre-admitted), which is
    what makes reassembly deadlock-free: the deposit of a reserved group
    never blocks on a full queue, so the oldest in-flight group can always
    land and unblock the head.  ``watermarks`` tracks, per producer, the
    newest behaviour version deposited — the fleet's publication-lag
    telemetry."""

    def __init__(self, capacity: int, max_staleness: int):
        self.capacity = max(1, capacity)
        self.max_staleness = max_staleness
        self.dropped_stale = 0
        self.dropped_dup = 0             # late re-deposits of a served index
        self.watermarks: Dict[str, int] = {}
        # fault-injection hook (testing/chaos.py, DESIGN.md §13): when set,
        # fired at put() entry with the producer name and group index
        self.chaos = None
        self._items: list = []           # sorted by .index (stable)
        self._keys: list = []            # parallel list of .index
        self._inflight: set = set()      # reserved, not yet deposited
        self._max_served = -1            # newest index pop() has returned
        self._cv = threading.Condition()
        self._error: Optional[BaseException] = None

    def qsize(self) -> int:
        with self._cv:
            return len(self._items)

    def inflight(self) -> int:
        with self._cv:
            return len(self._inflight)

    def peek(self) -> Optional[TaggedGroup]:
        """The oldest queued group without consuming it (None when empty)."""
        with self._cv:
            return self._items[0] if self._items else None

    def fail(self, err: BaseException) -> None:
        with self._cv:
            if self._error is None:  # first error wins
                self._error = err
            self._cv.notify_all()

    def reserve(self, index: int, timeout: Optional[float] = None) -> None:
        """Claim ``index`` before rolling it.  Blocks while the queue plus
        in-flight reservations are at capacity, so total admitted work is
        bounded; the matching ``put`` is then exempt from the capacity
        wait.  Pair with ``cancel`` on abandonment."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while (len(self._items) + len(self._inflight) >= self.capacity
                   and self._error is None):
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError("SampleQueue.reserve timed out")
                self._cv.wait(0.05)
            if self._error is not None:
                raise self._error
            self._inflight.add(index)

    def cancel(self, index: int) -> None:
        """Drop a reservation without depositing (producer abandoned the
        group); ``pop`` stops waiting for the gap."""
        with self._cv:
            self._inflight.discard(index)
            self._cv.notify_all()

    def remove_producer(self, name: str, *, cancel: tuple = ()) -> None:
        """Forget a dead producer (supervision, DESIGN.md §13): its
        watermark is deleted so publication-lag telemetry never reports a
        ghost, and any reservation indices in ``cancel`` that nobody will
        reclaim are dropped so ``pop`` stops holding younger groups for
        them.  (The supervisor's reclaim path instead *keeps* the dead
        replica's reservation — a survivor adopts it and deposits under
        the same index.)"""
        with self._cv:
            self.watermarks.pop(name, None)
            for i in cancel:
                self._inflight.discard(i)
            self._cv.notify_all()

    def put(self, group: TaggedGroup, timeout: Optional[float] = None,
            producer: Optional[str] = None) -> None:
        if self.chaos is not None:
            self.chaos.fire("queue_put", replica=producer,
                            index=group.index)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if (group.index <= self._max_served
                    or group.index in self._keys):
                # late duplicate: a condemned replica woke up after its
                # claimed group was reclaimed and re-deposited (or even
                # already consumed).  At-most-once per index: drop it,
                # release any stale reservation, count it.
                self._inflight.discard(group.index)
                self.dropped_dup += 1
                self._cv.notify_all()
                return
            while (group.index not in self._inflight
                   and len(self._items) >= self.capacity
                   and self._error is None):
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError("SampleQueue.put timed out")
                self._cv.wait(0.05)
            if self._error is not None:
                raise self._error
            self._inflight.discard(group.index)
            if producer is not None:
                self.watermarks[producer] = max(
                    self.watermarks.get(producer, -1), group.behavior_version)
            k = bisect.bisect_right(self._keys, group.index)
            self._keys.insert(k, group.index)
            self._items.insert(k, group)
            self._cv.notify_all()

    def _head_ready(self) -> bool:
        """Serve the head only when no smaller index is still in flight —
        the learner consumes the serial index order."""
        if not self._items:
            return False
        return not self._inflight or self._keys[0] < min(self._inflight)

    def pop(self, current_version: int,
            timeout: Optional[float] = None) -> TaggedGroup:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if self._error is not None:
                    raise self._error
                while self._head_ready():
                    g = self._items.pop(0)
                    self._keys.pop(0)
                    self._max_served = max(self._max_served, g.index)
                    self._cv.notify_all()  # wake a producer blocked on full
                    if (current_version - g.behavior_version
                            <= self.max_staleness):
                        return g
                    # the staleness contract: drop, never serve
                    self.dropped_stale += 1
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError("SampleQueue.pop timed out")
                self._cv.wait(0.05)


class KeyChain:
    """Thread-safe view of the actor's serial key chain (DESIGN.md §12).

    The serial trainer derives group ``i``'s keys by walking
    ``state, k_roll, k_sel = split(state, 3)`` from the seed.  A fleet of
    actors claims indices out of order, so the chain is materialized lazily
    and cached: ``keys_for(i)`` returns the exact ``(key0, k_roll, k_sel)``
    the serial walk would produce for group ``i``, whichever replica asks
    first.  This is what makes fleet rollouts per-group token-exact against
    the single-engine oracle — same index, same keys, same tokens."""

    def __init__(self, key0: jax.Array, base_index: int = 0):
        self._lock = threading.Lock()
        self._base = base_index
        self._states = [key0]    # _states[k] = chain state before base+k

    def _state(self, k: int) -> jax.Array:
        if k < 0:
            raise IndexError(f"group index below chain base {self._base}")
        while len(self._states) <= k:
            self._states.append(jax.random.split(self._states[-1], 3)[0])
        return self._states[k]

    def state_before(self, i: int) -> jax.Array:
        """Chain state before group ``i``'s splits (checkpoint rewind)."""
        with self._lock:
            return self._state(i - self._base)

    def keys_for(self, i: int):
        """``(key0, k_roll, k_sel)`` for group ``i`` — the serial walk's
        exact splits, regardless of claim order."""
        with self._lock:
            key0 = self._state(i - self._base)
            _, k_roll, k_sel = jax.random.split(key0, 3)
            return key0, k_roll, k_sel


class _GroupState:
    """Actor-side assembly buffer for one in-flight streaming group."""

    def __init__(self, index, pb, key_sel, version, p, g, gp, budget_total,
                 stats0, key0=None):
        self.index = index
        self.pb = pb
        self.key_sel = key_sel
        self.version = version
        self.key0 = key0
        self.comps: dict = {}            # local row -> Completion
        self.n_completed = np.zeros((p,), np.int32)
        self.g, self.gp = g, gp
        self.budget_total = budget_total
        self.stats0 = stats0             # engine cumulative stats at admission
        self.t_admit = time.perf_counter()


class AsyncNATGRPOTrainer:
    """The full NAT-GRPO loop with bounded-staleness actor/learner overlap.

    ``budget_fn(step, row) -> int`` optionally overrides the decode budget
    per rollout row (row = prompt_index * G' + j); benches use it to shape
    straggler mixes, schedules can use it as a length curriculum.
    """

    def __init__(self, model_cfg: ModelConfig, tcfg: NATTrainerConfig,
                 params=None, mesh=None, rules=None,
                 budget_fn: Optional[Callable[[int, int], int]] = None,
                 chaos=None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.budget_fn = budget_fn
        # fault-injection plan (testing/chaos.py, DESIGN.md §13) threaded
        # into the queue/engine hook points; None in production
        self.chaos = chaos
        self.env = make_env(tcfg.env, **dict(tcfg.env_kwargs))
        from repro.data.pipeline import PromptPipeline

        self.pipeline = PromptPipeline(
            self.env, batch_size=tcfg.prompts_per_step,
            max_prompt_len=tcfg.max_prompt_len, seed=tcfg.seed)
        key = jax.random.PRNGKey(tcfg.seed)
        if params is None:
            key, k = jax.random.split(key)
            params = init_params(k, model_decl(model_cfg))
        # the actor owns the serial trainer's key chain (token-exact parity
        # at max_staleness=0); evaluate() gets its own decorrelated stream
        self._actor_key = key
        self.key = jax.random.fold_in(key, 0xE7A1)
        self.params = params
        self.opt_state = init_opt_state(params, tcfg.adamw)
        self.selector = make_selector(tcfg.selector, **dict(tcfg.selector_kwargs))
        if tcfg.rollout_engine not in ("continuous", "paged", "legacy"):
            raise ValueError(f"unknown rollout_engine {tcfg.rollout_engine!r}")
        self.engine = self._build_engine()
        self.step_count = 0
        layout_name = tcfg.layout or ("bucketed" if tcfg.repack else "padded")
        if layout_name == "packed":
            # fail at config time, naming the capability-table row, rather
            # than silently falling back or erroring steps later in-jit
            caps.check_packed(model_cfg)
        if layout_name == "paged":
            # the paged layout needs the page handoff from a learner-retain
            # rollout session (export_learner_pages), which this trainer's
            # replay/checkpoint contract does not carry yet — drive it via
            # rl.learner.make_train_step(paged=True) directly (DESIGN.md §11)
            raise NotImplementedError(
                "NATTrainerConfig(layout='paged') is not wired into the "
                "async trainer; use core.layout.PagedLayout + "
                "rl.learner.make_train_step(paged=True) with a "
                "learner_retain paged engine (DESIGN.md §11)")
        self.layout = make_layout(layout_name, **dict(tcfg.layout_kwargs))
        self._train_step = jax.jit(make_train_step(
            model_cfg, tcfg.grpo, tcfg.adamw, mesh=mesh, rules=rules,
            vocab_chunks=1, packed=self.layout.packed))
        t_max = tcfg.max_prompt_len + tcfg.rollout.max_new_tokens
        self.ladder = bucket_ladder(t_max, tcfg.num_buckets, tcfg.bucket_align)
        self.history: list = []

        # -- actor/learner machinery --
        p, g = tcfg.prompts_per_step, tcfg.rollout.group_size
        self._p, self._g = p, g
        self._gp = int(np.ceil(g * tcfg.rollout.overprovision))
        self._rows = p * self._gp
        # capacity floor of max_staleness+1 guarantees the deposit of every
        # admitted group fits, so the actor can never wedge in put() while
        # a checkpoint quiesce waits for it
        self.queue = SampleQueue(
            max(tcfg.queue_groups or 0, tcfg.max_staleness + 1),
            tcfg.max_staleness)
        self.queue.chaos = chaos
        if chaos is not None and self.engine is not None:
            self.engine.chaos = chaos
        self._cv = threading.Condition()
        self._learner_version = 0
        self._next_group = 0
        self._published = (self.params, 0)   # versioned snapshot
        self._paused = False
        self._stop_evt = threading.Event()
        self._actor_idle = threading.Event()
        self._actor: Optional[threading.Thread] = None
        self._stream_groups: dict = {}

    def _build_engine(self, *, device=None, prefill_device=None):
        """Construct one rollout engine per the config — the seam the
        disaggregated trainer reuses to build slice-pinned fleet replicas
        (``device`` commits the arena; ``prefill_device`` additionally
        splits prompt prefill onto its own cell, DESIGN.md §12).  Returns
        None for the legacy scan / codebook models (no arena)."""
        tcfg, model_cfg = self.tcfg, self.model_cfg
        if tcfg.rollout_engine == "paged" and not model_cfg.num_codebooks:
            from repro.rl.engine import (
                DisaggPagedRolloutEngine, PagedEngineConfig,
                PagedRolloutEngine,
            )

            gp = int(np.ceil(tcfg.rollout.group_size
                             * tcfg.rollout.overprovision))
            # default slot count must cover one full G' group: configs
            # with per-slot sequence state place groups atomically
            pecfg = PagedEngineConfig(
                num_slots=tcfg.num_slots
                or max(tcfg.prompts_per_step * tcfg.rollout.group_size, gp),
                max_prompt_len=tcfg.max_prompt_len,
                steps_per_sync=tcfg.steps_per_sync,
                page_len=tcfg.page_len, num_pages=tcfg.num_pages,
                max_group=gp)
            if prefill_device is not None:
                return DisaggPagedRolloutEngine(
                    model_cfg, tcfg.rollout, pecfg,
                    prefill_device=prefill_device, decode_device=device)
            return PagedRolloutEngine(model_cfg, tcfg.rollout, pecfg,
                                      device=device)
        elif (tcfg.rollout_engine == "continuous"
              and not model_cfg.num_codebooks):
            from repro.rl.engine import ContinuousRolloutEngine, EngineConfig

            return ContinuousRolloutEngine(
                model_cfg, tcfg.rollout, EngineConfig(
                    num_slots=tcfg.num_slots
                    or tcfg.prompts_per_step * tcfg.rollout.group_size,
                    max_prompt_len=tcfg.max_prompt_len,
                    steps_per_sync=tcfg.steps_per_sync),
                device=device)
        # legacy scan — explicit opt-out, or codebook models (audio),
        # which the slot arena does not serve yet
        return None

    # ------------------------------------------------------------- actor side
    def _ensure_actor(self) -> None:
        """Start the actor thread — only for ``max_staleness > 0``.  At
        staleness 0 the learner gate makes a thread pure overhead (it could
        only roll while a ``train_step`` is blocked waiting for it), so the
        serial path produces groups inline and owns no thread at all:
        nothing leaks when callers never ``close()``."""
        if self.tcfg.max_staleness == 0:
            return
        if self._actor is None or not self._actor.is_alive():
            self._stop_evt.clear()
            target = (self._actor_streaming if self.engine is not None
                      else self._actor_pergroup)
            self._actor = threading.Thread(
                target=self._actor_main, args=(target,), daemon=True,
                name="nat-actor")
            self._actor.start()

    def _actor_main(self, target) -> None:
        try:
            target()
        except BaseException as e:  # surface on the learner thread
            self.queue.fail(e)

    def _gate_open(self, i: int) -> bool:
        return i - self._learner_version <= self.tcfg.max_staleness

    def _budgets_for(self, step: int) -> Optional[np.ndarray]:
        if self.budget_fn is None:
            return None
        n = self.tcfg.rollout.max_new_tokens
        return np.array(
            [min(n, max(1, int(self.budget_fn(step, r))))
             for r in range(self._rows)], np.int32)

    def _roll_next_group(self, params, version: int) -> TaggedGroup:
        """Roll group ``self._next_group`` to completion on a per-group
        engine session — the serial trainer's exact computation — and
        advance the cursor.  Called inline by the staleness-0 learner and
        from the actor thread for the legacy-rollout overlap path."""
        tcfg = self.tcfg
        i = self._next_group
        pb = self.pipeline.batch_at(i)
        self.pipeline.step = i + 1  # keep the checkpoint cursor honest
        key0 = self._actor_key
        self._actor_key, k_roll, k_sel = jax.random.split(self._actor_key, 3)
        t0 = time.perf_counter()
        if self.engine is not None:
            rb = rollout_group_continuous(
                params, self.model_cfg, tcfg.rollout,
                pb.tokens, pb.prompt_lens, k_roll, engine=self.engine,
                budgets=self._budgets_for(i))
        else:
            rb = rollout_group(params, self.model_cfg, tcfg.rollout,
                               pb.tokens, pb.prompt_lens, k_roll)
        self._next_group = i + 1
        return TaggedGroup(
            index=i, behavior_version=version, batch=rb,
            prompt_batch=pb, key_sel=k_sel,
            t_rollout=time.perf_counter() - t0, key0=key0)

    def _actor_pergroup(self) -> None:
        """Per-group rollouts from a pipelined thread: the overlap path for
        the legacy scan rollout (no arena to stream through)."""
        while not self._stop_evt.is_set():
            with self._cv:
                while (not self._stop_evt.is_set()
                       and (self._paused
                            or not self._gate_open(self._next_group))):
                    self._actor_idle.set()
                    self._cv.wait(0.05)
                if self._stop_evt.is_set():
                    return
                # clear under the lock: _quiesce must never observe an idle
                # flag left over from the gate wait while a roll is starting
                self._actor_idle.clear()
                params, version = self._published
            self.queue.put(self._roll_next_group(params, version))

    # -- streaming mode: persistent session, groups drain across boundaries
    def _admit_group(self) -> bool:
        from repro.rl.engine import Request

        with self._cv:
            if self._paused or not self._gate_open(self._next_group):
                return False
            params, version = self._published
        i = self._next_group
        pb = self.pipeline.batch_at(i)
        self.pipeline.step = i + 1
        key0 = self._actor_key
        # same chain layout as the per-group path (k_roll feeds the session
        # at begin(); per-admission it is split but unused)
        self._actor_key, _k_roll, k_sel = jax.random.split(self._actor_key, 3)
        self.engine.set_params(params)  # snapshot swap at a round boundary
        budgets = self._budgets_for(i)
        n = self.tcfg.rollout.max_new_tokens
        gs = _GroupState(
            i, pb, k_sel, version, self._p, self._g, self._gp,
            int(budgets.sum()) if budgets is not None else self._rows * n,
            dict(self.engine.stats), key0=key0)
        self._stream_groups[i] = gs
        # group-wise submission: the paged arena prefills each prompt once
        # and shares its pages across the G' siblings; on the dense arena
        # submit_group is plain FIFO submit, so the stream is unchanged
        for pi in range(self._p):
            self.engine.submit_group([
                Request(
                    uid=i * self._rows + pi * self._gp + j,
                    tokens=np.asarray(pb.tokens[pi, :int(pb.prompt_lens[pi])]),
                    budget=(int(budgets[pi * self._gp + j])
                            if budgets is not None else n))
                for j in range(self._gp)])
        self._next_group = i + 1
        return True

    def _stream_on_finish(self, c):
        """Quota cancellation, routed per group: the moment a prompt has G
        completed rollouts, its unfinished siblings are cancelled."""
        gi, local = divmod(c.uid, self._rows)
        gs = self._stream_groups[gi]
        gs.comps[local] = c
        pi = local // self._gp
        if not c.completed:
            return None
        gs.n_completed[pi] += 1
        if gs.n_completed[pi] == self._g:
            base = gi * self._rows + pi * self._gp
            return [base + j for j in range(self._gp)
                    if pi * self._gp + j not in gs.comps]
        return None

    def _assemble_ready(self) -> int:
        """Deposit every fully-harvested streaming group, oldest first."""
        deposited = 0
        for gi in sorted(self._stream_groups):
            gs = self._stream_groups[gi]
            if len(gs.comps) < self._rows:
                break  # FIFO: group gi blocks younger groups
            comps = [gs.comps[l] for l in range(self._rows)]
            cur = self.engine.stats
            stats = {
                "tokens_generated": int(sum(c.response_len for c in comps)),
                "cancelled": int(sum(c.cancelled for c in comps)),
                "tokens_budget": gs.budget_total,
                # engine-wide deltas since admission: an *attribution* of
                # shared arena work, exact only when groups do not overlap
                "rounds": cur["rounds"] - gs.stats0["rounds"],
                "decode_steps": cur["decode_steps"] - gs.stats0["decode_steps"],
                "slot_substeps": (cur["slot_substeps"]
                                  - gs.stats0["slot_substeps"]),
                "refills": cur["refills"] - gs.stats0["refills"],
            }
            rb = batch_from_completions(
                comps, gs.pb.tokens, gs.pb.prompt_lens, self.tcfg.rollout,
                self._p, self._g, self._gp, stats)
            del self._stream_groups[gi]
            self.queue.put(TaggedGroup(
                index=gi, behavior_version=gs.version, batch=rb,
                prompt_batch=gs.pb, key_sel=gs.key_sel,
                t_rollout=time.perf_counter() - gs.t_admit, key0=gs.key0))
            deposited += 1
        return deposited

    def _actor_streaming(self) -> None:
        k_session = jax.random.fold_in(self._actor_key, 0x5e55)
        self.engine.begin(self._published[0], k_session,
                          on_finish=self._stream_on_finish)
        while not self._stop_evt.is_set():
            admitted = self._admit_group()
            progressed = False
            if not self.engine.idle:
                self.engine.drive()  # on_finish routes into _stream_groups
                progressed = True
            if self._assemble_ready():
                progressed = True
            if not (admitted or progressed):
                with self._cv:
                    self._actor_idle.set()
                    self._cv.wait(0.05)
                self._actor_idle.clear()

    # ----------------------------------------------------------- learner side
    def _publish(self) -> None:
        with self._cv:
            self._learner_version += 1
            self._published = (self.params, self._learner_version)
            self._cv.notify_all()

    def train_step(self) -> dict:
        self._ensure_actor()
        t0 = time.perf_counter()
        tcfg = self.tcfg
        if tcfg.max_staleness == 0 and self.queue.qsize() == 0:
            # serial path: produce inline, no actor thread exists (the gate
            # would only ever let it roll while this call waited anyway)
            with self._cv:
                params, version = self._published
            self.queue.put(self._roll_next_group(params, version))
        # generous timeout: surfaces a wedged actor as an error instead of a
        # hung CI job (actor errors propagate via SampleQueue.fail)
        tg = self.queue.pop(self._learner_version, timeout=600.0)
        rb, pb = tg.batch, tg.prompt_batch
        staleness = self._learner_version - tg.behavior_version
        t_roll = time.perf_counter()

        # rewards on FULL responses (never affected by token selection)
        p, g = tcfg.prompts_per_step, tcfg.rollout.group_size
        rewards = np.zeros((p, g), np.float32)
        for i in range(p):
            for j in range(g):
                r = i * g + j
                pl, rl = int(rb.prompt_lens[r]), int(rb.response_lens[r])
                resp = rb.tokens[r, pl:pl + rl]
                rewards[i, j] = self.env.reward(pb.prompts[i], resp)
        adv = np.asarray(group_advantages(jnp.asarray(rewards),
                                          tcfg.grpo.adv_eps)).reshape(-1)

        # NAT selection
        rmask = jnp.asarray(rb.response_mask)
        if isinstance(self.selector, EntropySelector):
            sel = self.selector(tg.key_sel, rmask, jnp.asarray(rb.entropies))
        else:
            sel = self.selector(tg.key_sel, rmask)
        ht_w = np.asarray(sel.ht_weights, np.float32)
        keep_len = np.asarray(sel.keep_len)

        batch = {
            "tokens": rb.tokens,
            "response_mask": rb.response_mask,
            "old_logp": rb.old_logp,
            "advantages": adv.astype(np.float32),
            "ht_weights": ht_w,
            "orig_lengths": rb.response_lens.astype(np.float32),
            "lengths": (rb.prompt_lens + rb.response_lens).astype(np.int32),
            # staleness-corrected HT objective (DESIGN.md §6): the engine's
            # in-flight logprobs are the behaviour policy; rows that lag the
            # learner version get the truncated-IS correction in the loss
            "behavior_logp": rb.old_logp,
            "staleness": np.full((rb.tokens.shape[0],), staleness, np.float32),
        }

        # batch layout (core/layout.py): bucketed slicing, hull packing, or
        # the raw padded grid — the selection above is layout-invariant
        lb = self.layout.build(
            batch, prompt_lens=rb.prompt_lens,
            response_lens=rb.response_lens, keep_len=keep_len,
            keep_mask=ht_w > 0, prefix_structured=sel.prefix_structured,
            ladder=self.ladder)
        batch = lb.data
        t_sel = time.perf_counter()

        self.params, self.opt_state, metrics = self._train_step(
            self.params, self.opt_state, {k: jnp.asarray(v)
                                          for k, v in batch.items()})
        metrics = {k: float(v) for k, v in metrics.items()}
        self._publish()
        t_end = time.perf_counter()

        rstats = rb.stats or {}
        metrics.update(
            reward_mean=float(rewards.mean()),
            reward_max=float(rewards.max(axis=1).mean()),
            completed_frac=float(rb.completed.mean()),
            resp_len_mean=float(rb.response_lens.mean()),
            # legacy alias of tokens_scored (pre-layout consumers)
            learner_tokens=lb.tokens_scored,
            bucket_len=lb.row_len,
            # layout accounting (DESIGN.md §7): tokens the update physically
            # scored and the kept-budget fraction of them — the learner-side
            # twin of rollout_utilization below
            tokens_scored=lb.tokens_scored,
            learner_rows=lb.num_rows,
            pack_efficiency=lb.pack_efficiency,
            # rollout token cost: with the slot arena, over-provisioned groups
            # pay for generated tokens, not G' full budgets (ISSUE 2)
            tokens_generated=int(rstats.get("tokens_generated", 0)),
            tokens_budget=int(rstats.get("tokens_budget", 0)),
            rollout_decode_steps=int(rstats.get("decode_steps", 0)),
            rollout_cancelled=int(rstats.get("cancelled", 0)),
            rollout_utilization=(
                rstats.get("tokens_generated", 0)
                / max(rstats.get("slot_substeps", 0), 1)),
            entropy_behavior=float(
                (rb.entropies * rb.response_mask).sum()
                / max(rb.response_mask.sum(), 1)),
            # overlap bookkeeping
            policy_version=self._learner_version,
            behavior_version=tg.behavior_version,
            staleness=staleness,
            queue_depth=self.queue.qsize(),
            dropped_stale=self.queue.dropped_stale,
            time_rollout=tg.t_rollout,
            time_wait=t_roll - t0,
            time_select=t_sel - t_roll,
            time_learn=t_end - t_sel,
            time_total=t_end - t0,
            step=self.step_count,
        )
        self.step_count += 1
        self.history.append(metrics)
        return metrics

    def run(self, num_steps: int, log_every: int = 0) -> list:
        for i in range(num_steps):
            m = self.train_step()
            if log_every and i % log_every == 0:
                print(f"step {m['step']:4d} reward={m['reward_mean']:.3f} "
                      f"loss={m['loss']:+.4f} sel={m.get('selected_ratio', 1):.2f} "
                      f"rows={m['learner_rows']}x{m['bucket_len']} "
                      f"eff={m['pack_efficiency']:.2f} t={m['time_total']:.2f}s")
        return self.history

    # --------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Stop the actor thread (idempotent, *terminal*): queued groups are
        dropped and the sample queue is poisoned, so a producer blocked on a
        full queue exits instead of leaking, and any later ``train_step``
        raises instead of hanging."""
        self._stop_evt.set()
        with self._cv:
            self._cv.notify_all()
        self.queue.fail(RuntimeError("trainer closed"))
        if self._actor is not None:
            self._actor.join(timeout=10.0)
            self._actor = None

    def _quiesce(self, timeout: float = 300.0) -> None:
        """Pause admission and wait for in-flight rollouts to deposit.
        Queued groups stay queued — the checkpoint cursor rewinds past them
        (``TaggedGroup.key0``), so quiescing never runs hidden learner
        steps and checkpoint cadence cannot change the training stream."""
        with self._cv:
            self._paused = True
            self._cv.notify_all()
        if self._actor is None or not self._actor.is_alive():
            return
        deadline = time.monotonic() + timeout
        while True:
            if self._actor_idle.is_set() and not self._stream_groups:
                return
            if not self._actor.is_alive():
                return
            if time.monotonic() > deadline:
                raise TimeoutError("actor failed to quiesce")
            time.sleep(0.005)

    def _resume_admission(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    # -------------------------------------------------------------- checkpoint
    def save_checkpoint(self, mgr, blocking: bool = True) -> int:
        """Pause admission, wait for in-flight rollouts to deposit, persist
        params/opt plus the async cursors.  Unconsumed rollout data is
        never serialized and never flushed: the saved actor cursor rewinds
        to the oldest unconsumed group (its pre-roll key-chain state rides
        in the queue), so resume re-rolls it — under the same params for
        the serial path, which makes staleness-0 resume token-exact.  For
        ``max_staleness > 0`` the snapshot is a clean group boundary; the
        restored run re-rolls from a fresh engine session, so its sample
        stream is valid (exact behaviour logprobs, staleness bound intact)
        but not bit-identical to the uninterrupted run."""
        try:
            self._quiesce()
            head = self.queue.peek()
            if head is not None:
                saved_next, saved_key = head.index, head.key0
            else:
                saved_next, saved_key = self._next_group, self._actor_key
            tree = {"params": self.params, "opt": self.opt_state}
            extra = {
                "learner_version": int(self._learner_version),
                "step_count": int(self.step_count),
                "next_group": int(saved_next),
                "actor_key": np.asarray(saved_key).tolist(),
                "eval_key": np.asarray(self.key).tolist(),
                "pipeline": {"step": int(saved_next),
                             "seed": self.pipeline.seed},
                "max_staleness": int(self.tcfg.max_staleness),
            }
            mgr.save(self._learner_version, tree, extra, blocking=blocking)
        finally:
            self._resume_admission()
        return int(self._learner_version)

    def restore_checkpoint(self, mgr, step: Optional[int] = None) -> dict:
        """Restore params/opt and the async cursors saved by
        ``save_checkpoint``.  Must be called before the actor starts (i.e.
        before the first ``train_step`` of this instance)."""
        if self._actor is not None and self._actor.is_alive():
            raise RuntimeError("restore_checkpoint before the first train_step")
        if step is None:
            step = mgr.latest_step()
        tree, extra = mgr.restore(
            step, {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self._learner_version = int(extra["learner_version"])
        self.step_count = int(extra["step_count"])
        self._next_group = int(extra["next_group"])
        self._actor_key = jnp.asarray(np.array(extra["actor_key"], np.uint32))
        self.key = jnp.asarray(np.array(extra["eval_key"], np.uint32))
        self.pipeline.load_state_dict(extra["pipeline"])
        self._published = (self.params, self._learner_version)
        return extra

    # ------------------------------------------------------------------ eval
    def evaluate(self, num_prompts: int = 32, temperature: float = 0.0) -> dict:
        """Greedy accuracy on fresh prompts (reward == 1 counts as correct).

        Uses the legacy single-wave path: eval is G=1 with no
        over-provisioning, so there is no recycling for the arena to
        exploit, and the training engine's jit cache (keyed on the training
        RolloutConfig) is left untouched."""
        from repro.data.pipeline import PromptPipeline

        pipe = PromptPipeline(self.env, batch_size=num_prompts,
                              max_prompt_len=self.tcfg.max_prompt_len,
                              seed=self.tcfg.seed + 10_000)
        pb = next(pipe)
        rcfg = dataclasses.replace(self.tcfg.rollout, temperature=temperature,
                                   group_size=1, overprovision=1.0)
        self.key, k = jax.random.split(self.key)
        rb = rollout_group(self.params, self.model_cfg, rcfg,
                           pb.tokens, pb.prompt_lens, k)
        correct = 0
        for i in range(num_prompts):
            pl, rl = int(rb.prompt_lens[i]), int(rb.response_lens[i])
            r = self.env.reward(pb.prompts[i], rb.tokens[i, pl:pl + rl])
            correct += int(r >= 1.0)
        return {"accuracy": correct / num_prompts,
                "resp_len": float(rb.response_lens.mean())}
