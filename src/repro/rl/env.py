"""Verifiable-reward environments (the RLVR substrate).

The paper trains on math benchmarks with exact-answer verifiers; we replace
the datasets with hermetic synthetic tasks that keep the *reward interface*
identical (``reward(prompt, response) -> float`` on full responses) so the
whole RLVR loop runs on CPU:

* ``ModArithEnv`` — "a OP b mod m = ?": the model must emit the answer digits
  then EOS.  Exact-match reward with optional partial credit.
* ``CopyCalcEnv`` — the prompt embeds a key-value table and asks for the
  value at a key ("ctx k1:v1 k2:v2 ... q k2 = ?") — a retrieval-flavoured
  task whose answers get *longer* with difficulty, exercising NAT's
  long-trajectory regime.

Tokenizer: a tiny fixed character vocabulary shared by both tasks.
"""
from __future__ import annotations

import dataclasses
import numpy as np

PAD, BOS, EOS = 0, 1, 2
_CHARS = "0123456789+-*%=?:# "
CHAR_BASE = 3
VOCAB_SIZE = CHAR_BASE + len(_CHARS)  # 23
_C2T = {c: CHAR_BASE + i for i, c in enumerate(_CHARS)}
_T2C = {v: k for k, v in _C2T.items()}


def encode(s: str) -> list:
    return [_C2T[c] for c in s]


def decode_tokens(toks) -> str:
    out = []
    for t in toks:
        t = int(t)
        if t == EOS:
            break
        out.append(_T2C.get(t, ""))
    return "".join(out)


@dataclasses.dataclass(frozen=True)
class Prompt:
    tokens: np.ndarray  # (Tp,) int32, BOS-prefixed
    answer: str


class ModArithEnv:
    """(a OP b) mod m with OP in {+, -, *}.  Difficulty scales digit count."""

    name = "mod_arith"

    def __init__(self, max_val: int = 99, mod: int = 97, partial_credit: bool = True):
        self.max_val = max_val
        self.mod = mod
        self.partial_credit = partial_credit

    def sample(self, rng: np.random.Generator) -> Prompt:
        a = int(rng.integers(0, self.max_val + 1))
        b = int(rng.integers(0, self.max_val + 1))
        op = "+-*"[int(rng.integers(0, 3))]
        val = {"+": a + b, "-": a - b, "*": a * b}[op] % self.mod
        text = f"{a}{op}{b}%{self.mod}=?"
        return Prompt(
            tokens=np.array([BOS] + encode(text), np.int32), answer=str(val))

    def reward(self, prompt: Prompt, response_tokens) -> float:
        got = decode_tokens(response_tokens).strip()
        want = prompt.answer
        if got == want:
            return 1.0
        if self.partial_credit and got and want.startswith(got):
            return 0.2 * len(got) / len(want)
        return 0.0


class CopyCalcEnv:
    """Retrieval + copy: "#k:v " pairs then "?k=" — answer is that v."""

    name = "copy_calc"

    def __init__(self, n_pairs: int = 4, val_digits: int = 3):
        self.n_pairs = n_pairs
        self.val_digits = val_digits

    def sample(self, rng: np.random.Generator) -> Prompt:
        keys = rng.choice(90, size=self.n_pairs, replace=False) + 10
        vals = rng.integers(10 ** (self.val_digits - 1), 10 ** self.val_digits,
                            size=self.n_pairs)
        qi = int(rng.integers(0, self.n_pairs))
        parts = [f"#{k}:{v} " for k, v in zip(keys, vals)]
        text = "".join(parts) + f"?{keys[qi]}="
        return Prompt(
            tokens=np.array([BOS] + encode(text), np.int32), answer=str(vals[qi]))

    def reward(self, prompt: Prompt, response_tokens) -> float:
        got = decode_tokens(response_tokens).strip()
        return 1.0 if got == prompt.answer else 0.0


ENVS = {"mod_arith": ModArithEnv, "copy_calc": CopyCalcEnv}


def make_env(name: str, **kw):
    return ENVS[name](**kw)
