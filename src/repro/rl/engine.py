"""Continuous-batching rollout engine: a fixed slot arena with recycling.

The legacy path (``rl/rollout.py::generate``) scans every row for the full
``max_new_tokens`` budget, so a batch is as slow as its longest row — the
straggler bottleneck NAT's APRIL-style over-provisioning attacks.  This
engine keeps a fixed ``(num_slots, cache_len)`` KV arena instead: a row that
emits EOS (or exhausts its per-request budget) is *retired* immediately, its
outputs harvested, and its slot re-prefilled with the next queued prompt
while the other slots keep decoding (DESIGN.md §3).

One executable serves the whole run.  The jitted step takes static shapes
only — ``(R, Tp)`` refill lanes, ``(S,)`` masks — and does:

  1. deactivate cancelled slots (host-driven APRIL quota cancellation),
  2. ``lax.cond``-gated prefill of up to R refill lanes (R < S keeps refill
     FLOPs proportional to actual turnover, not arena width), scattered
     row-wise into the arena at their target slots so a retired slot's
     cache rows are fully overwritten before reuse,
  3. a ``lax.scan`` of ``steps_per_sync`` masked decode substeps collecting
     behaviour logprobs/entropies in flight (the GRPO scoring fusion of the
     legacy path, preserved).

Because slot state transitions are data (masks), no shape ever depends on
which rows retire — there are zero per-batch recompiles.  The host loop only
syncs two ``(S,)`` control planes per round; retire-detection latency is
bounded by ``steps_per_sync`` substeps.

Per-request token budgets make the engine double as the serving decode loop
(``examples/serve_decode.py``): requests carry their own ``max_tokens``, and
short requests stop paying for long neighbours.

The host side is a *session* API (DESIGN.md §6): ``begin`` installs params
and a fresh arena, ``submit`` enqueues requests at any time, ``drive`` runs
exactly one harvest/refill/step round and returns the completions it
retired, and ``set_params`` swaps in a new parameter snapshot for the
*next* dispatched step — the in-flight executable keeps the reference it
was called with, so weight publication never copies or races a running
step.  ``run`` is the run-to-completion wrapper over the same rounds; the
stream-overlapped trainer (``rl/async_trainer.py``) drives sessions
directly so rollouts from one policy version keep draining while the
learner steps the next.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import (
    cache_decl,
    decode_step,
    invalidate_cache_rows,
    prefill,
)

Array = jax.Array
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static arena geometry — part of the jit cache key."""

    num_slots: int = 8
    max_prompt_len: int = 32
    steps_per_sync: int = 4  # decode substeps per host round-trip
    refill_lanes: int = 0  # prefill width per step; 0 -> ceil(num_slots / 4)

    @property
    def lanes(self) -> int:
        return self.refill_lanes or max(1, -(-self.num_slots // 4))


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    tokens: np.ndarray  # (Tp,) int32, unpadded prompt
    budget: int = 0  # max new tokens; 0 -> rollout config's max_new_tokens


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: np.ndarray  # (response_len,) generated tokens (incl. EOS if hit)
    logp: np.ndarray  # (response_len,) behaviour logprobs
    entropy: np.ndarray  # (response_len,) behaviour entropies
    completed: bool  # emitted EOS within budget
    cancelled: bool = False  # retired early by the caller (quota met)

    @property
    def response_len(self) -> int:
        return int(self.tokens.shape[0])


class ContinuousRolloutEngine:
    """Slot-arena decode over the same sharded params the learner updates.

    The engine is stateless between ``run`` calls; ``last_state`` keeps the
    final device state of the most recent run for arena introspection
    (tests assert the retire/refill invariants on it).
    """

    def __init__(self, cfg: ModelConfig, rcfg, ecfg: EngineConfig):
        if cfg.num_codebooks:
            raise NotImplementedError("engine serves text LMs (no codebooks)")
        if ecfg.lanes > ecfg.num_slots:
            raise ValueError("refill_lanes cannot exceed num_slots")
        self.cfg = cfg
        self.rcfg = rcfg
        self.ecfg = ecfg
        self.cache_len = ecfg.max_prompt_len + rcfg.max_new_tokens
        # donate the state: the arena (the big buffer) is updated in place
        # instead of copied every round
        self._step = jax.jit(self._make_step(), donate_argnums=(1,))
        self._cache_tmpl = None  # abstract cache template, memoized per run
        self.last_state: Optional[dict] = None
        self.stats: dict = {}
        # session fields (installed by begin(); benign defaults so `idle`
        # and introspection work on a never-begun engine)
        self._params = None
        self._on_finish = None
        self._queue: collections.deque = collections.deque()
        self._slot_uid: list = [None] * ecfg.num_slots
        self._to_cancel: set = set()
        self._state: Optional[dict] = None

    # ------------------------------------------------------------ device side
    def _init_state(self, params, key: Array) -> dict:
        """Zeroed arena.  The cache template comes from an abstract prefill
        so storage dtype matches what refills actually produce (bit-exact
        logprob parity with the legacy path under f32 params), with
        ``cache_decl`` shapes as the contract."""
        s = self.ecfg.num_slots
        n = self.rcfg.max_new_tokens
        if self._cache_tmpl is None:  # abstract trace once per engine
            tmpl = jax.eval_shape(
                lambda p: prefill(
                    p, self.cfg,
                    jnp.zeros((s, self.ecfg.max_prompt_len), jnp.int32),
                    cache_len=self.cache_len,
                    prefill_len=jnp.ones((s,), jnp.int32))[1],
                params)
            decl = cache_decl(self.cfg, s, self.cache_len)

            def check(a, b):
                assert a.shape == b.shape, \
                    f"cache shape drift {a.shape}!={b.shape}"

            jax.tree.map(check, tmpl, decl)
            self._cache_tmpl = tmpl
        cache = jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype),
                             self._cache_tmpl)
        cache = invalidate_cache_rows(cache, jnp.ones((s,), bool))
        return {
            "cache": cache,
            "logits": jnp.zeros((s, self.cfg.vocab_size), F32),
            "pos": jnp.zeros((s,), jnp.int32),
            "prompt_len": jnp.zeros((s,), jnp.int32),
            "n_gen": jnp.zeros((s,), jnp.int32),
            "budget": jnp.zeros((s,), jnp.int32),
            "active": jnp.zeros((s,), bool),
            "done": jnp.zeros((s,), bool),
            "eos_hit": jnp.zeros((s,), bool),
            # copy: the state is donated to the step, and the caller's key
            # must survive this run
            "key": jnp.array(key),
            "out_tok": jnp.full((s, n), self.rcfg.pad_id, jnp.int32),
            "out_logp": jnp.zeros((s, n), F32),
            "out_ent": jnp.zeros((s, n), F32),
        }

    def _make_step(self):
        cfg, rcfg, ecfg = self.cfg, self.rcfg, self.ecfg
        s_slots = ecfg.num_slots
        n = rcfg.max_new_tokens
        cache_len = self.cache_len

        def step(params, state, refill_toks, refill_lens, refill_budgets,
                 refill_slots, refill_mask, cancel_mask):
            # refill_* are (R,) lanes; refill_slots names each lane's target
            # arena slot; masked-out lanes scatter nowhere (index S, dropped).
            st = dict(state)
            # 1. cancelled slots become free (harvest already happened on host)
            st["active"] = st["active"] & ~cancel_mask
            st["done"] = st["done"] & ~cancel_mask

            # 2. refill: R-wide prefill scattered into the arena at the
            # target slots.  lax.cond skips it on pure-decode rounds, and
            # R < S keeps prefill cost on turnover, not arena width.
            tgt = jnp.where(refill_mask, refill_slots, s_slots).astype(jnp.int32)

            def scat_rows(arena, rows):
                # arena (repeat, S, ...) <- rows (repeat, R, ...) at dim 1
                return arena.at[:, tgt].set(rows.astype(arena.dtype),
                                            mode="drop")

            def scat_plane(plane, vals):
                return plane.at[tgt].set(vals.astype(plane.dtype), mode="drop")

            def do_refill(st):
                st = dict(st)
                logits0, fresh = prefill(
                    params, cfg, refill_toks, cache_len=cache_len,
                    prefill_len=jnp.maximum(refill_lens, 1))
                st["cache"] = jax.tree.map(scat_rows, st["cache"], fresh)
                st["logits"] = st["logits"].at[tgt].set(
                    logits0.astype(F32), mode="drop")
                st["pos"] = scat_plane(st["pos"], refill_lens)
                st["prompt_len"] = scat_plane(st["prompt_len"], refill_lens)
                st["n_gen"] = scat_plane(st["n_gen"], jnp.zeros_like(refill_lens))
                st["budget"] = scat_plane(st["budget"], refill_budgets)
                ones = jnp.ones_like(refill_mask)
                st["active"] = scat_plane(st["active"], ones)
                st["done"] = scat_plane(st["done"], ~ones)
                st["eos_hit"] = scat_plane(st["eos_hit"], ~ones)
                r = refill_mask.shape[0]
                st["out_tok"] = st["out_tok"].at[tgt].set(
                    jnp.full((r, n), rcfg.pad_id, st["out_tok"].dtype),
                    mode="drop")
                st["out_logp"] = st["out_logp"].at[tgt].set(
                    jnp.zeros((r, n), F32), mode="drop")
                st["out_ent"] = st["out_ent"].at[tgt].set(
                    jnp.zeros((r, n), F32), mode="drop")
                return st

            st = jax.lax.cond(refill_mask.any(), do_refill, lambda s: dict(s), st)

            # 3. masked decode substeps: retired/empty slots ride along (the
            # shapes are static) but emit nothing and hold their state.
            def substep(st, _):
                st = dict(st)
                live = st["active"] & ~st["done"]
                key, k1 = jax.random.split(st["key"])
                if rcfg.temperature == 0.0:
                    nxt = jnp.argmax(st["logits"], axis=-1)
                else:
                    nxt = jax.random.categorical(
                        k1, st["logits"] / rcfg.temperature, axis=-1)
                logp_all = jax.nn.log_softmax(st["logits"], axis=-1)
                logp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
                ent = -jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1)
                nxt = jnp.where(live, nxt, rcfg.pad_id).astype(jnp.int32)

                bi = jnp.arange(s_slots)
                idx = jnp.minimum(st["n_gen"], n - 1)
                st["out_tok"] = st["out_tok"].at[bi, idx].set(
                    jnp.where(live, nxt, st["out_tok"][bi, idx]))
                st["out_logp"] = st["out_logp"].at[bi, idx].set(
                    jnp.where(live, logp, st["out_logp"][bi, idx]))
                st["out_ent"] = st["out_ent"].at[bi, idx].set(
                    jnp.where(live, ent, st["out_ent"][bi, idx]))

                new_logits, new_cache = decode_step(
                    params, cfg, nxt, st["cache"], st["pos"])
                st["cache"] = new_cache
                st["logits"] = jnp.where(
                    live[:, None], new_logits.astype(F32), st["logits"])
                st["pos"] = st["pos"] + live
                st["n_gen"] = st["n_gen"] + live
                hit_eos = live & (nxt == rcfg.eos_id)
                st["eos_hit"] = st["eos_hit"] | hit_eos
                st["done"] = st["done"] | (
                    live & (hit_eos | (st["n_gen"] >= st["budget"])))
                st["key"] = key
                return st, None

            st, _ = jax.lax.scan(substep, st, None, length=ecfg.steps_per_sync)
            return st

        return step

    # ----------------------------------------------------- host side: session
    def begin(
        self,
        params,
        key: Array,
        *,
        on_finish: Optional[Callable[[Completion], Optional[Iterable[int]]]]
        = None,
    ) -> None:
        """Open a session: fresh arena, empty queue, zeroed stats.

        ``on_finish(completion)`` fires as each request retires (inside
        ``drive``) and may return uids to cancel — queued uids are dropped
        before placement, in-flight uids retire early with
        ``cancelled=True`` in the same round they are discovered."""
        self._params = params
        self._on_finish = on_finish
        self._queue: collections.deque = collections.deque()
        self._slot_uid: list = [None] * self.ecfg.num_slots
        self._to_cancel: set = set()
        self._state = self._init_state(params, key)
        self.stats = {"rounds": 0, "decode_steps": 0, "refills": 0,
                      "tokens_generated": 0, "cancelled": 0,
                      "slot_substeps": 0}

    def submit(self, requests: Sequence[Request]) -> None:
        """Enqueue requests; callable at any point during a session, so new
        work streams in while earlier rollouts are still draining."""
        rcfg, tp = self.rcfg, self.ecfg.max_prompt_len
        for r in requests:
            if len(r.tokens) > tp:
                raise ValueError(f"request {r.uid}: prompt longer than {tp}")
            if r.budget > rcfg.max_new_tokens:
                raise ValueError(f"request {r.uid}: budget > max_new_tokens")
        self._queue.extend(requests)

    def set_params(self, params) -> None:
        """Versioned snapshot swap: the *next* dispatched step decodes under
        ``params``.  The step already in flight keeps the reference it was
        called with (jax arrays are immutable), so no copy and no race."""
        self._params = params

    def cancel(self, uids: Iterable[int]) -> None:
        """Mark uids for cancellation, handled at the next ``drive``."""
        self._to_cancel.update(uids)

    @property
    def idle(self) -> bool:
        """No queued work and every slot free — ``drive`` would be a no-op."""
        return not self._queue and all(u is None for u in self._slot_uid)

    def _harvest(self, s: int, host, cancelled: bool) -> Completion:
        uid = self._slot_uid[s]
        rl = int(host["n_gen"][s])
        comp = Completion(
            uid=uid,
            prompt_len=int(host["prompt_len"][s]),
            tokens=host["out_tok"][s, :rl].copy(),
            logp=host["out_logp"][s, :rl].copy(),
            entropy=host["out_ent"][s, :rl].copy(),
            completed=bool(host["eos_hit"][s]) and not cancelled,
            cancelled=cancelled)
        self._slot_uid[s] = None
        self.stats["tokens_generated"] += rl
        if cancelled:
            self.stats["cancelled"] += 1
        if self._on_finish is not None:
            self._to_cancel.update(self._on_finish(comp) or ())
        return comp

    def drive(self) -> list:
        """One round: sync the control planes, harvest retirements, refill
        free slots from the queue, dispatch the jitted step.  Returns the
        Completions retired this round (possibly empty).  When the session
        is idle the call is a no-op."""
        ecfg, rcfg = self.ecfg, self.rcfg
        s_slots, tp = ecfg.num_slots, ecfg.max_prompt_len
        state, slot_uid, queue = self._state, self._slot_uid, self._queue
        to_cancel = self._to_cancel
        harvested: list = []

        # -- sync the two control planes; fetch buffers only on retirement
        active = np.asarray(state["active"])
        done = np.asarray(state["done"])
        retired = [s for s in range(s_slots)
                   if slot_uid[s] is not None and active[s] and done[s]]
        cancel_mask = np.zeros((s_slots,), bool)
        host = None
        need_fetch = bool(retired) or any(
            u in to_cancel for u in slot_uid if u is not None)
        if need_fetch:
            host = {k: np.asarray(state[k]) for k in
                    ("n_gen", "prompt_len", "eos_hit",
                     "out_tok", "out_logp", "out_ent")}
        # snapshot cancel state first: rows in `retired` finished on
        # their own (EOS/budget), so cancellations issued by on_finish
        # callbacks *during* this harvest loop must not relabel them
        was_cancelled = {s: slot_uid[s] in to_cancel for s in retired}
        for s in retired:
            harvested.append(self._harvest(s, host, was_cancelled[s]))
            cancel_mask[s] = True  # clears active/done on device
        # quota-cancel rows still decoding (including cancellations the
        # on_finish callbacks just issued): retire them as partials now
        if host is not None:
            for s in range(s_slots):
                if slot_uid[s] is not None and slot_uid[s] in to_cancel:
                    harvested.append(self._harvest(s, host, True))
                    cancel_mask[s] = True

        # -- refill free slots from the queue (skipping cancelled uids),
        # at most R lanes per round
        lanes = ecfg.lanes
        refill_mask = np.zeros((lanes,), bool)
        refill_toks = np.full((lanes, tp), rcfg.pad_id, np.int32)
        refill_lens = np.ones((lanes,), np.int32)
        refill_budgets = np.zeros((lanes,), np.int32)
        refill_slots = np.zeros((lanes,), np.int32)
        lane = 0
        for s in range(s_slots):
            if slot_uid[s] is not None or lane >= lanes:
                continue
            while queue and queue[0].uid in to_cancel:
                r = queue.popleft()
                comp = Completion(
                    uid=r.uid, prompt_len=len(r.tokens),
                    tokens=np.zeros((0,), np.int32),
                    logp=np.zeros((0,), np.float32),
                    entropy=np.zeros((0,), np.float32),
                    completed=False, cancelled=True)
                harvested.append(comp)
                self.stats["cancelled"] += 1
                # the contract fires on_finish for every request,
                # including ones cancelled before they were placed
                if self._on_finish is not None:
                    to_cancel.update(self._on_finish(comp) or ())
            if not queue:
                break
            r = queue.popleft()
            pl = len(r.tokens)
            refill_toks[lane, :pl] = r.tokens
            refill_lens[lane] = pl
            refill_budgets[lane] = r.budget or rcfg.max_new_tokens
            refill_slots[lane] = s
            refill_mask[lane] = True
            slot_uid[s] = r.uid
            lane += 1

        if not refill_mask.any() and all(u is None for u in slot_uid):
            self.last_state = state  # session quiescent: expose for tests
            return harvested

        self._state = self._step(
            self._params, state, jnp.asarray(refill_toks),
            jnp.asarray(refill_lens), jnp.asarray(refill_budgets),
            jnp.asarray(refill_slots), jnp.asarray(refill_mask),
            jnp.asarray(cancel_mask))
        self.stats["rounds"] += 1
        self.stats["decode_steps"] += ecfg.steps_per_sync
        self.stats["slot_substeps"] += ecfg.steps_per_sync * s_slots
        self.stats["refills"] += int(refill_mask.sum())
        return harvested

    def drain(self) -> list:
        """Drive rounds until the session is idle; returns all Completions
        harvested along the way."""
        out: list = []
        while True:
            got = self.drive()
            out.extend(got)
            if self.idle and not got:
                return out

    def run(
        self,
        params,
        requests: Sequence[Request],
        key: Array,
        *,
        on_finish: Optional[Callable[[Completion], Optional[Iterable[int]]]]
        = None,
    ) -> list:
        """Serve ``requests`` through the arena; returns Completions in
        submission order.  Run-to-completion wrapper over ``begin`` /
        ``submit`` / ``drive``."""
        self.begin(params, key, on_finish=on_finish)
        self.submit(requests)
        out = {c.uid: c for c in self.drain()}
        self.last_state = self._state
        return [out[r.uid] for r in requests if r.uid in out]


def make_engine(cfg: ModelConfig, rcfg, *, num_slots: int,
                max_prompt_len: int, steps_per_sync: int = 4,
                ) -> ContinuousRolloutEngine:
    return ContinuousRolloutEngine(
        cfg, rcfg, EngineConfig(num_slots=num_slots,
                                max_prompt_len=max_prompt_len,
                                steps_per_sync=steps_per_sync))
