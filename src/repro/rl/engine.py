"""Continuous-batching rollout engine: a fixed slot arena with recycling.

The legacy path (``rl/rollout.py::generate``) scans every row for the full
``max_new_tokens`` budget, so a batch is as slow as its longest row — the
straggler bottleneck NAT's APRIL-style over-provisioning attacks.  This
engine keeps a fixed ``(num_slots, cache_len)`` KV arena instead: a row that
emits EOS (or exhausts its per-request budget) is *retired* immediately, its
outputs harvested, and its slot re-prefilled with the next queued prompt
while the other slots keep decoding (DESIGN.md §3).

One executable serves the whole run.  The jitted step takes static shapes
only — ``(R, Tp)`` refill lanes, ``(S,)`` masks — and does:

  1. deactivate cancelled slots (host-driven APRIL quota cancellation),
  2. ``lax.cond``-gated prefill of up to R refill lanes (R < S keeps refill
     FLOPs proportional to actual turnover, not arena width), scattered
     row-wise into the arena at their target slots so a retired slot's
     cache rows are fully overwritten before reuse,
  3. a ``lax.scan`` of ``steps_per_sync`` masked decode substeps collecting
     behaviour logprobs/entropies in flight (the GRPO scoring fusion of the
     legacy path, preserved).

Because slot state transitions are data (masks), no shape ever depends on
which rows retire — there are zero per-batch recompiles.  The host loop only
syncs two ``(S,)`` control planes per round; retire-detection latency is
bounded by ``steps_per_sync`` substeps.

Per-request token budgets make the engine double as the serving decode loop
(``examples/serve_decode.py``): requests carry their own ``max_tokens``, and
short requests stop paying for long neighbours.

The host side is a *session* API (DESIGN.md §6): ``begin`` installs params
and a fresh arena, ``submit`` enqueues requests at any time, ``drive`` runs
exactly one harvest/refill/step round and returns the completions it
retired, and ``set_params`` swaps in a new parameter snapshot for the
*next* dispatched step — the in-flight executable keeps the reference it
was called with, so weight publication never copies or races a running
step.  ``run`` is the run-to-completion wrapper over the same rounds; the
stream-overlapped trainer (``rl/async_trainer.py``) drives sessions
directly so rollouts from one policy version keep draining while the
learner steps the next.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import capabilities as caps
from repro.models.attention import gather_pages
from repro.models.config import ModelConfig
from repro.models.model import (
    cache_decl,
    decode_step,
    invalidate_cache_rows,
    invalidate_pages,
    paged_cache_decl,
    paged_prefill,
    prefill,
)
from repro.dist.publish import tree_bytes as _tree_bytes
from repro.rl.radix import RadixPrefixCache

Array = jax.Array
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static arena geometry — part of the jit cache key."""

    num_slots: int = 8
    max_prompt_len: int = 32
    steps_per_sync: int = 4  # decode substeps per host round-trip
    refill_lanes: int = 0  # prefill width per step; 0 -> ceil(num_slots / 4)

    @property
    def lanes(self) -> int:
        return self.refill_lanes or max(1, -(-self.num_slots // 4))


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    tokens: np.ndarray  # (Tp,) int32, unpadded prompt
    budget: int = 0  # max new tokens; 0 -> rollout config's max_new_tokens


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: np.ndarray  # (response_len,) generated tokens (incl. EOS if hit)
    logp: np.ndarray  # (response_len,) behaviour logprobs
    entropy: np.ndarray  # (response_len,) behaviour entropies
    completed: bool  # emitted EOS within budget
    cancelled: bool = False  # retired early by the caller (quota met)

    @property
    def response_len(self) -> int:
        return int(self.tokens.shape[0])


# --------------------------------------------------- shared substep pieces
def _substep_sample(st: dict, rcfg, n: int, s_slots: int):
    """Sample the next token from the current logits and record it for live
    slots — the head every arena substep (dense or paged) shares.  Mutates
    ``st`` in place (out_* planes + key) and returns (nxt, live)."""
    live = st["active"] & ~st["done"]
    key, k1 = jax.random.split(st["key"])
    if rcfg.temperature == 0.0:
        nxt = jnp.argmax(st["logits"], axis=-1)
    else:
        nxt = jax.random.categorical(
            k1, st["logits"] / rcfg.temperature, axis=-1)
    logp_all = jax.nn.log_softmax(st["logits"], axis=-1)
    logp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
    ent = -jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1)
    nxt = jnp.where(live, nxt, rcfg.pad_id).astype(jnp.int32)

    bi = jnp.arange(s_slots)
    idx = jnp.minimum(st["n_gen"], n - 1)
    st["out_tok"] = st["out_tok"].at[bi, idx].set(
        jnp.where(live, nxt, st["out_tok"][bi, idx]))
    st["out_logp"] = st["out_logp"].at[bi, idx].set(
        jnp.where(live, logp, st["out_logp"][bi, idx]))
    st["out_ent"] = st["out_ent"].at[bi, idx].set(
        jnp.where(live, ent, st["out_ent"][bi, idx]))
    st["key"] = key
    return nxt, live


def _place_slot_planes(st: dict, tgt, lens, budgets, logits, n: int,
                       pad_id: int) -> dict:
    """Scatter freshly-placed slots' per-slot planes — shared by the paged
    step's prefill placement and parked-sibling resume: prompt logits in,
    counters zeroed, output buffers cleared.  ``tgt`` carries the
    slot-count sentinel for masked lanes (dropped)."""
    rg = tgt.shape[0]
    st["logits"] = st["logits"].at[tgt].set(logits.astype(F32), mode="drop")
    st["pos"] = st["pos"].at[tgt].set(lens, mode="drop")
    st["prompt_len"] = st["prompt_len"].at[tgt].set(lens, mode="drop")
    st["n_gen"] = st["n_gen"].at[tgt].set(0, mode="drop")
    st["budget"] = st["budget"].at[tgt].set(budgets, mode="drop")
    st["active"] = st["active"].at[tgt].set(True, mode="drop")
    st["done"] = st["done"].at[tgt].set(False, mode="drop")
    st["eos_hit"] = st["eos_hit"].at[tgt].set(False, mode="drop")
    st["out_tok"] = st["out_tok"].at[tgt].set(
        jnp.full((rg, n), pad_id, st["out_tok"].dtype), mode="drop")
    st["out_logp"] = st["out_logp"].at[tgt].set(
        jnp.zeros((rg, n), F32), mode="drop")
    st["out_ent"] = st["out_ent"].at[tgt].set(
        jnp.zeros((rg, n), F32), mode="drop")
    return st


def _substep_advance(st: dict, nxt, live, new_logits, rcfg) -> dict:
    """Shared substep tail: merge the new logits for live slots, advance the
    position/count planes, latch EOS/budget retirement."""
    st["logits"] = jnp.where(
        live[:, None], new_logits.astype(F32), st["logits"])
    st["pos"] = st["pos"] + live
    st["n_gen"] = st["n_gen"] + live
    hit_eos = live & (nxt == rcfg.eos_id)
    st["eos_hit"] = st["eos_hit"] | hit_eos
    st["done"] = st["done"] | (
        live & (hit_eos | (st["n_gen"] >= st["budget"])))
    return st


class ContinuousRolloutEngine:
    """Slot-arena decode over the same sharded params the learner updates.

    The engine is stateless between ``run`` calls; ``last_state`` keeps the
    final device state of the most recent run for arena introspection
    (tests assert the retire/refill invariants on it).
    """

    def __init__(self, cfg: ModelConfig, rcfg, ecfg: EngineConfig,
                 *, device=None):
        if cfg.num_codebooks:
            raise NotImplementedError("engine serves text LMs (no codebooks)")
        caps.check_engine(cfg, "continuous")
        if ecfg.lanes > ecfg.num_slots:
            raise ValueError("refill_lanes cannot exceed num_slots")
        self.cfg = cfg
        self.rcfg = rcfg
        self.ecfg = ecfg
        # slice pinning (DESIGN.md §12): with a device, params and arena
        # state are committed there, so every donated step — and the whole
        # session — runs on that slice regardless of where the caller's
        # arrays live.  None keeps the pre-fleet behaviour (default device).
        self._device = device
        self.cache_len = ecfg.max_prompt_len + rcfg.max_new_tokens
        # donate the state: the arena (the big buffer) is updated in place
        # instead of copied every round
        self._step = jax.jit(self._make_step(), donate_argnums=(1,))
        self._cache_tmpl = None  # abstract cache template, memoized per run
        self.last_state: Optional[dict] = None
        self.stats: dict = {}
        # fault-injection seam (testing/chaos.py, DESIGN.md §13): when a
        # FaultPlan is installed, drive() fires once per round with this
        # engine's replica tag — injected PagePoolExhausted here fakes
        # transient pool pressure for the trainer's bounded retry
        self.chaos = None
        self.chaos_replica: Optional[str] = None
        # session fields (installed by begin(); benign defaults so `idle`
        # and introspection work on a never-begun engine)
        self._params = None
        self._on_finish = None
        self._on_token = None
        self._streamed: list = [0] * ecfg.num_slots
        self._queue: collections.deque = collections.deque()
        self._slot_uid: list = [None] * ecfg.num_slots
        self._to_cancel: set = set()
        self._state: Optional[dict] = None

    # ------------------------------------------------------------ device side
    def _init_state(self, params, key: Array) -> dict:
        """Zeroed arena.  The cache template comes from an abstract prefill
        so storage dtype matches what refills actually produce (bit-exact
        logprob parity with the legacy path under f32 params), with
        ``cache_decl`` shapes as the contract."""
        s = self.ecfg.num_slots
        n = self.rcfg.max_new_tokens
        if self._cache_tmpl is None:  # abstract trace once per engine
            tmpl = jax.eval_shape(
                lambda p: prefill(
                    p, self.cfg,
                    jnp.zeros((s, self.ecfg.max_prompt_len), jnp.int32),
                    cache_len=self.cache_len,
                    prefill_len=jnp.ones((s,), jnp.int32))[1],
                params)
            decl = cache_decl(self.cfg, s, self.cache_len)

            def check(a, b):
                assert a.shape == b.shape, \
                    f"cache shape drift {a.shape}!={b.shape}"

            jax.tree.map(check, tmpl, decl)
            self._cache_tmpl = tmpl
        cache = jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype),
                             self._cache_tmpl)
        cache = invalidate_cache_rows(cache, jnp.ones((s,), bool))
        return {
            "cache": cache,
            "logits": jnp.zeros((s, self.cfg.vocab_size), F32),
            "pos": jnp.zeros((s,), jnp.int32),
            "prompt_len": jnp.zeros((s,), jnp.int32),
            "n_gen": jnp.zeros((s,), jnp.int32),
            "budget": jnp.zeros((s,), jnp.int32),
            "active": jnp.zeros((s,), bool),
            "done": jnp.zeros((s,), bool),
            "eos_hit": jnp.zeros((s,), bool),
            # copy: the state is donated to the step, and the caller's key
            # must survive this run
            "key": jnp.array(key),
            "out_tok": jnp.full((s, n), self.rcfg.pad_id, jnp.int32),
            "out_logp": jnp.zeros((s, n), F32),
            "out_ent": jnp.zeros((s, n), F32),
        }

    def _make_step(self):
        cfg, rcfg, ecfg = self.cfg, self.rcfg, self.ecfg
        s_slots = ecfg.num_slots
        n = rcfg.max_new_tokens
        cache_len = self.cache_len

        def step(params, state, refill_toks, refill_lens, refill_budgets,
                 refill_slots, refill_mask, cancel_mask):
            # refill_* are (R,) lanes; refill_slots names each lane's target
            # arena slot; masked-out lanes scatter nowhere (index S, dropped).
            st = dict(state)
            # 1. cancelled slots become free (harvest already happened on host)
            st["active"] = st["active"] & ~cancel_mask
            st["done"] = st["done"] & ~cancel_mask

            # 2. refill: R-wide prefill scattered into the arena at the
            # target slots.  lax.cond skips it on pure-decode rounds, and
            # R < S keeps prefill cost on turnover, not arena width.
            tgt = jnp.where(refill_mask, refill_slots, s_slots).astype(jnp.int32)

            def scat_rows(arena, rows):
                # arena (repeat, S, ...) <- rows (repeat, R, ...) at dim 1
                return arena.at[:, tgt].set(rows.astype(arena.dtype),
                                            mode="drop")

            def scat_plane(plane, vals):
                return plane.at[tgt].set(vals.astype(plane.dtype), mode="drop")

            def do_refill(st):
                st = dict(st)
                logits0, fresh = prefill(
                    params, cfg, refill_toks, cache_len=cache_len,
                    prefill_len=jnp.maximum(refill_lens, 1))
                st["cache"] = jax.tree.map(scat_rows, st["cache"], fresh)
                st["logits"] = st["logits"].at[tgt].set(
                    logits0.astype(F32), mode="drop")
                st["pos"] = scat_plane(st["pos"], refill_lens)
                st["prompt_len"] = scat_plane(st["prompt_len"], refill_lens)
                st["n_gen"] = scat_plane(st["n_gen"], jnp.zeros_like(refill_lens))
                st["budget"] = scat_plane(st["budget"], refill_budgets)
                ones = jnp.ones_like(refill_mask)
                st["active"] = scat_plane(st["active"], ones)
                st["done"] = scat_plane(st["done"], ~ones)
                st["eos_hit"] = scat_plane(st["eos_hit"], ~ones)
                r = refill_mask.shape[0]
                st["out_tok"] = st["out_tok"].at[tgt].set(
                    jnp.full((r, n), rcfg.pad_id, st["out_tok"].dtype),
                    mode="drop")
                st["out_logp"] = st["out_logp"].at[tgt].set(
                    jnp.zeros((r, n), F32), mode="drop")
                st["out_ent"] = st["out_ent"].at[tgt].set(
                    jnp.zeros((r, n), F32), mode="drop")
                return st

            st = jax.lax.cond(refill_mask.any(), do_refill, lambda s: dict(s), st)

            # 3. masked decode substeps: retired/empty slots ride along (the
            # shapes are static) but emit nothing and hold their state.
            def substep(st, _):
                st = dict(st)
                nxt, live = _substep_sample(st, rcfg, n, s_slots)
                new_logits, new_cache = decode_step(
                    params, cfg, nxt, st["cache"], st["pos"])
                st["cache"] = new_cache
                st = _substep_advance(st, nxt, live, new_logits, rcfg)
                return st, None

            st, _ = jax.lax.scan(substep, st, None, length=ecfg.steps_per_sync)
            return st

        return step

    # ----------------------------------------------------- host side: session
    def begin(
        self,
        params,
        key: Array,
        *,
        on_finish: Optional[Callable[[Completion], Optional[Iterable[int]]]]
        = None,
        on_token: Optional[Callable[[int, np.ndarray], None]] = None,
    ) -> None:
        """Open a session: fresh arena, empty queue, zeroed stats.

        ``on_finish(completion)`` fires as each request retires (inside
        ``drive``) and may return uids to cancel — queued uids are dropped
        before placement, in-flight uids retire early with
        ``cancelled=True`` in the same round they are discovered.

        ``on_token(uid, tokens)`` streams incremental output: it fires at
        the top of each ``drive`` with the tokens a request generated
        since its last delivery (latency bounded by ``steps_per_sync``
        substeps), and a request's deltas always arrive before its
        Completion.  Streaming syncs two extra planes per round, so leave
        it off for pure-throughput rollout."""
        if self._device is not None:
            params = jax.device_put(params, self._device)
        self._params = params
        self._on_finish = on_finish
        self._on_token = on_token
        self._streamed = [0] * self.ecfg.num_slots
        self._queue: collections.deque = collections.deque()
        self._slot_uid: list = [None] * self.ecfg.num_slots
        self._to_cancel: set = set()
        state = self._init_state(params, key)
        if self._device is not None:
            state = jax.device_put(state, self._device)
        self._state = state
        self.stats = {"rounds": 0, "decode_steps": 0, "refills": 0,
                      "tokens_generated": 0, "cancelled": 0,
                      "slot_substeps": 0}

    def _validate_requests(self, requests: Sequence[Request]) -> None:
        rcfg, tp = self.rcfg, self.ecfg.max_prompt_len
        for r in requests:
            if len(r.tokens) > tp:
                raise ValueError(f"request {r.uid}: prompt longer than {tp}")
            if r.budget > rcfg.max_new_tokens:
                raise ValueError(f"request {r.uid}: budget > max_new_tokens")

    def submit(self, requests: Sequence[Request]) -> None:
        """Enqueue requests; callable at any point during a session, so new
        work streams in while earlier rollouts are still draining."""
        self._validate_requests(requests)
        self._queue.extend(requests)

    def submit_group(self, requests: Sequence[Request]) -> None:
        """Enqueue one GRPO group's sibling requests.  The dense arena has
        no prompt sharing, so this is plain ``submit``; the paged engine
        overrides it to prefill the shared prompt once (DESIGN.md §8).
        Call sites that know the group structure should use this."""
        self.submit(requests)

    def set_params(self, params) -> None:
        """Versioned snapshot swap: the *next* dispatched step decodes under
        ``params``.  The step already in flight keeps the reference it was
        called with (jax arrays are immutable), so no copy and no race.
        On a slice-pinned engine the snapshot is committed to the slice
        (a no-op when the publisher already delivered it there)."""
        if self._device is not None:
            params = jax.device_put(params, self._device)
        self._params = params

    def cancel(self, uids: Iterable[int]) -> None:
        """Mark uids for cancellation, handled at the next ``drive``."""
        self._to_cancel.update(uids)

    @property
    def idle(self) -> bool:
        """No queued work and every slot free — ``drive`` would be a no-op."""
        return not self._queue and all(u is None for u in self._slot_uid)

    @property
    def backlog(self) -> int:
        """Accepted-but-unplaced work units queued on the host — the
        admission signal the serving front-end throttles on."""
        return len(self._queue)

    def _harvest(self, s: int, host, cancelled: bool) -> Completion:
        uid = self._slot_uid[s]
        rl = int(host["n_gen"][s])
        comp = Completion(
            uid=uid,
            prompt_len=int(host["prompt_len"][s]),
            tokens=host["out_tok"][s, :rl].copy(),
            logp=host["out_logp"][s, :rl].copy(),
            entropy=host["out_ent"][s, :rl].copy(),
            completed=bool(host["eos_hit"][s]) and not cancelled,
            cancelled=cancelled)
        self._slot_uid[s] = None
        self.stats["tokens_generated"] += rl
        if cancelled:
            self.stats["cancelled"] += 1
        if self._on_finish is not None:
            self._to_cancel.update(self._on_finish(comp) or ())
        return comp

    def _collect_retirements(self) -> tuple:
        """Sync the control planes and harvest every retired or cancelled
        slot.  Returns (harvested Completions, device cancel_mask (S,)) —
        the round head shared by the dense and paged drive loops."""
        state, slot_uid = self._state, self._slot_uid
        to_cancel = self._to_cancel
        s_slots = self.ecfg.num_slots
        harvested: list = []

        # -- streaming: deliver each live slot's new tokens before any
        # harvest below, so a request's deltas always precede its finish
        if self._on_token is not None and any(
                u is not None for u in slot_uid):
            n_gen_h = np.asarray(state["n_gen"])
            out_tok_h = np.asarray(state["out_tok"])
            for s in range(s_slots):
                if slot_uid[s] is None:
                    continue
                k = int(n_gen_h[s])
                if k > self._streamed[s]:
                    self._on_token(
                        slot_uid[s],
                        out_tok_h[s, self._streamed[s]:k].copy())
                    self._streamed[s] = k

        # -- sync the two control planes; fetch buffers only on retirement
        active = np.asarray(state["active"])
        done = np.asarray(state["done"])
        retired = [s for s in range(s_slots)
                   if slot_uid[s] is not None and active[s] and done[s]]
        cancel_mask = np.zeros((s_slots,), bool)
        host = None
        need_fetch = bool(retired) or any(
            u in to_cancel for u in slot_uid if u is not None)
        if need_fetch:
            host = {k: np.asarray(state[k]) for k in
                    ("n_gen", "prompt_len", "eos_hit",
                     "out_tok", "out_logp", "out_ent")}
        # snapshot cancel state first: rows in `retired` finished on
        # their own (EOS/budget), so cancellations issued by on_finish
        # callbacks *during* this harvest loop must not relabel them
        was_cancelled = {s: slot_uid[s] in to_cancel for s in retired}
        for s in retired:
            harvested.append(self._harvest(s, host, was_cancelled[s]))
            cancel_mask[s] = True  # clears active/done on device
        # quota-cancel rows still decoding (including cancellations the
        # on_finish callbacks just issued): retire them as partials now
        if host is not None:
            for s in range(s_slots):
                if slot_uid[s] is not None and slot_uid[s] in to_cancel:
                    harvested.append(self._harvest(s, host, True))
                    cancel_mask[s] = True
        return harvested, cancel_mask

    def _cancelled_completion(self, r: Request) -> Completion:
        """Empty Completion for a request cancelled before placement.  The
        contract fires on_finish for every request, including these."""
        comp = Completion(
            uid=r.uid, prompt_len=len(r.tokens),
            tokens=np.zeros((0,), np.int32),
            logp=np.zeros((0,), np.float32),
            entropy=np.zeros((0,), np.float32),
            completed=False, cancelled=True)
        self.stats["cancelled"] += 1
        if self._on_finish is not None:
            self._to_cancel.update(self._on_finish(comp) or ())
        return comp

    def drive(self) -> list:
        """One round: sync the control planes, harvest retirements, refill
        free slots from the queue, dispatch the jitted step.  Returns the
        Completions retired this round (possibly empty).  When the session
        is idle the call is a no-op."""
        if self.chaos is not None:
            self.chaos.fire("drive", replica=self.chaos_replica,
                            index=self.stats.get("rounds", 0))
        ecfg, rcfg = self.ecfg, self.rcfg
        s_slots, tp = ecfg.num_slots, ecfg.max_prompt_len
        state, slot_uid, queue = self._state, self._slot_uid, self._queue
        to_cancel = self._to_cancel
        harvested, cancel_mask = self._collect_retirements()

        # -- refill free slots from the queue (skipping cancelled uids),
        # at most R lanes per round
        lanes = ecfg.lanes
        refill_mask = np.zeros((lanes,), bool)
        refill_toks = np.full((lanes, tp), rcfg.pad_id, np.int32)
        refill_lens = np.ones((lanes,), np.int32)
        refill_budgets = np.zeros((lanes,), np.int32)
        refill_slots = np.zeros((lanes,), np.int32)
        lane = 0
        for s in range(s_slots):
            if slot_uid[s] is not None or lane >= lanes:
                continue
            while queue and queue[0].uid in to_cancel:
                harvested.append(self._cancelled_completion(queue.popleft()))
            if not queue:
                break
            r = queue.popleft()
            pl = len(r.tokens)
            refill_toks[lane, :pl] = r.tokens
            refill_lens[lane] = pl
            refill_budgets[lane] = r.budget or rcfg.max_new_tokens
            refill_slots[lane] = s
            refill_mask[lane] = True
            slot_uid[s] = r.uid
            self._streamed[s] = 0
            lane += 1

        if not refill_mask.any() and all(u is None for u in slot_uid):
            self.last_state = state  # session quiescent: expose for tests
            return harvested

        self._state = self._step(
            self._params, state, jnp.asarray(refill_toks),
            jnp.asarray(refill_lens), jnp.asarray(refill_budgets),
            jnp.asarray(refill_slots), jnp.asarray(refill_mask),
            jnp.asarray(cancel_mask))
        self.stats["rounds"] += 1
        self.stats["decode_steps"] += ecfg.steps_per_sync
        self.stats["slot_substeps"] += ecfg.steps_per_sync * s_slots
        self.stats["refills"] += int(refill_mask.sum())
        return harvested

    def drain(self) -> list:
        """Drive rounds until the session is idle; returns all Completions
        harvested along the way."""
        out: list = []
        while True:
            got = self.drive()
            out.extend(got)
            if self.idle and not got:
                return out

    def run_groups(
        self,
        params,
        groups: Sequence[Sequence[Request]],
        key: Array,
        *,
        on_finish: Optional[Callable[[Completion], Optional[Iterable[int]]]]
        = None,
    ) -> list:
        """Serve ``groups`` (one ``submit_group`` each) to completion;
        returns Completions in submission order.  The group-aware
        run-to-completion wrapper shared by ``rollout_group_continuous``,
        the benchmarks, and the serving example — on the paged arena each
        group's prompt pages are shared across its siblings."""
        self.begin(params, key, on_finish=on_finish)
        for g in groups:
            self.submit_group(g)
        out = {c.uid: c for c in self.drain()}
        self.last_state = self._state
        return [out[r.uid] for g in groups for r in g if r.uid in out]

    def run(
        self,
        params,
        requests: Sequence[Request],
        key: Array,
        *,
        on_finish: Optional[Callable[[Completion], Optional[Iterable[int]]]]
        = None,
    ) -> list:
        """Serve ungrouped ``requests`` through the arena; returns
        Completions in submission order (``run_groups`` with singleton
        groups — identical FIFO submission on the dense arena)."""
        return self.run_groups(params, [[r] for r in requests], key,
                               on_finish=on_finish)


def make_engine(cfg: ModelConfig, rcfg, *, num_slots: int,
                max_prompt_len: int, steps_per_sync: int = 4,
                ) -> ContinuousRolloutEngine:
    return ContinuousRolloutEngine(
        cfg, rcfg, EngineConfig(num_slots=num_slots,
                                max_prompt_len=max_prompt_len,
                                steps_per_sync=steps_per_sync))


# ======================================================= paged KV arena
@dataclasses.dataclass(frozen=True)
class PagedEngineConfig:
    """Static geometry of the paged arena (DESIGN.md §8).

    The KV store is a fixed ``(num_pages, page_len)`` pool per attention
    layer plus per-slot block tables; a GRPO group's prompt pages are
    prefilled once and refcounted across all its siblings, so prompt KV
    memory per group is O(1) in the group size instead of O(G).
    """

    num_slots: int = 8
    max_prompt_len: int = 32
    steps_per_sync: int = 4    # decode substeps per host round-trip
    page_len: int = 16         # tokens per KV page
    num_pages: int = 0         # pool size; 0 -> dense-equivalent worst case
    group_lanes: int = 1       # groups prefilled per round
    max_group: int = 8         # widest group submit_group accepts
    resume_lanes: int = 0      # parked siblings placed per round; 0 -> auto
    attn_impl: str = "ref"     # "ref" (jnp gather) | "kernel" (Pallas)
    # cross-request radix prefix cache (DESIGN.md §10): longest-prefix
    # match reuses resident read-only pages, only the suffix prefills,
    # full suffix pages are chained back into the trie, and cold branches
    # are LRU-evicted under pool pressure instead of raising.  Off by
    # default: RL rollout re-prefills under fresh params every sync, so
    # only fixed-params serving benefits (pure-attention configs only —
    # see capabilities.check_prefix_cache).
    prefix_cache: bool = False
    # zero re-prefill learner handoff (DESIGN.md §11): every harvested
    # completion's prompt pages take an extra refcount reference so the
    # learner can score straight from the pool (export_learner_pages);
    # the reference survives radix eviction and the set_params epoch
    # flush, and is dropped by release_learner_pages after the grad step.
    # Pure-attention configs only (capabilities.check_paged_score).
    learner_retain: bool = False

    @property
    def lanes(self) -> int:
        return self.group_lanes

    @property
    def resumes(self) -> int:
        """Resume lane width: bounds the (lanes, vocab) logits operand
        shipped to the step each round, so it stays a group's worth, not
        an arena's worth."""
        return self.resume_lanes or max(1, min(self.num_slots,
                                               self.max_group))


class PagePoolExhausted(RuntimeError):
    """The page pool cannot satisfy an allocation.

    Raised eagerly on the host — never silently corrupting the arena —
    with the pool occupancy in the message.  Fix by growing ``num_pages``
    (the auto default of ``num_slots * pages_per_slot`` can never
    exhaust) or shrinking slots/budgets.
    """


class PageAllocator:
    """Host-side free list + refcounts over the device page pool.

    Pages are a shared resource: a GRPO group's prompt pages carry one
    reference per live sibling and are freed when the last sibling
    retires; decode pages are slot-private (refcount 1) and return to the
    free list the moment their slot retires or is cancelled.  The
    allocator only does bookkeeping — the device learns about reuse via
    the engine's free-page invalidation mask.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))  # LIFO stack
        self.refcount = np.zeros((num_pages,), np.int32)
        self.peak_in_use = 0

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int, what: str = "") -> list:
        if n > len(self._free):
            raise PagePoolExhausted(
                f"page pool exhausted allocating {n} page(s){what}: "
                f"{self.in_use}/{self.num_pages} pages in use "
                f"({len(self._free)} free); grow PagedEngineConfig.num_pages "
                "or reduce num_slots / budgets")
        pages = [self._free.pop() for _ in range(n)]
        self.refcount[pages] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        self.refcount[list(pages)] += 1

    def release(self, pages: Sequence[int]) -> list:
        """Drop one reference per page; returns the pages actually freed
        (refcount hit zero) — these need invalidation before reuse."""
        freed = []
        for p in pages:
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0, f"page {p} over-released"
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed


class PagedRolloutEngine(ContinuousRolloutEngine):
    """Slot arena over a paged KV pool with group-level prefix sharing.

    Same session API and retire/refill discipline as the dense arena, with
    the memory model rewritten (DESIGN.md §8):

    * attention KV lives in a fixed ``(num_pages, page_len)`` pool per
      layer; per-slot structure is a host-built block table passed into
      every round — retiring a slot is a free-list push, not a row
      invalidation,
    * ``submit_group`` registers a GRPO group: the shared prompt is
      prefilled ONCE into refcounted read-only pages and every sibling's
      block table starts with them (decode tokens always open a fresh
      slot-private page, so copy-on-write is never needed),
    * siblings placed in the prefill round get the prompt logits and the
      O(window)/O(1) non-attention states broadcast on device; for
      pure-attention configs the remaining siblings are PARKED — the
      prompt logits persist in a ``prefill_logits`` state plane, the host
      snapshots them one round later, and each parked sibling resumes
      into any freed slot with a pure scatter (prompt pages + saved
      logits ARE the prompt state; nothing recomputes, so group width
      never serializes the arena).  Configs with per-slot sequence state
      (local rings, ssm/rec) place atomically instead,
    * APRIL cancellation frees a straggler's decode pages the moment the
      host learns of it; freed pages are ``pos``-poisoned on device before
      any reuse (gather isolation),
    * page allocation is host-side and allocate-ahead: before each round
      every occupied slot owns enough decode pages for ``steps_per_sync``
      tokens, so the jitted step never allocates; exhaustion raises
      ``PagePoolExhausted`` instead of corrupting the arena.
    """

    def __init__(self, cfg: ModelConfig, rcfg, ecfg: PagedEngineConfig,
                 *, device=None):
        caps.check_paged(cfg)
        if ecfg.prefix_cache:
            caps.check_prefix_cache(cfg)
        if ecfg.learner_retain:
            caps.check_paged_score(cfg)
        pl_ = ecfg.page_len
        self._n_pp = -(-ecfg.max_prompt_len // pl_)    # max prompt pages
        self._n_dp = -(-rcfg.max_new_tokens // pl_)    # max decode pages
        self._max_pages = self._n_pp + self._n_dp      # block table width
        self.num_pages = ecfg.num_pages or ecfg.num_slots * self._max_pages
        # deferred sibling placement needs the prompt state to live wholly
        # in shared pages + saved logits: true only for pure pool-resident
        # stacks (capability table shared_prefix_ok: attn full KV, mla
        # latents; local rings / ssm / rec carry per-slot sequence state)
        self._pure_pool = caps.pure_pool_prefix(cfg)
        if not self._pure_pool and ecfg.max_group > ecfg.num_slots:
            raise ValueError(
                "max_group cannot exceed num_slots: per-slot-state mixers "
                "(local/ssm/rec) place groups atomically")
        super().__init__(cfg, rcfg, ecfg, device=device)
        self._reset_pool()

    # ------------------------------------------------------------ host pool
    def _reset_pool(self) -> None:
        s = self.ecfg.num_slots
        self._alloc = PageAllocator(self.num_pages)
        self._slot_prompt_pages: list = [[] for _ in range(s)]
        self._slot_decode_pages: list = [[] for _ in range(s)]
        self._slot_plen = np.zeros((s,), np.int32)
        self._slot_budget = np.zeros((s,), np.int32)
        self._n_gen_ub = np.zeros((s,), np.int64)  # host upper bound on n_gen
        self._dirty: set = set()  # freed pages awaiting pos-invalidation
        # partially-placed groups: prompt prefilled, some siblings parked
        # awaiting a free slot; each record holds one extra prompt-page
        # reference until its last sibling places or cancels
        self._pending: list = []
        # learner-retained prompt pages: uid -> (pages, prompt_len); each
        # record holds one refcount reference (taken at harvest) until
        # release_learner_pages drops it
        self._retained: dict = {}
        self._prefix_cache = (RadixPrefixCache(self._alloc, self.ecfg.page_len)
                              if self.ecfg.prefix_cache else None)

    def begin(self, params, key: Array, *, on_finish=None,
              on_token=None) -> None:
        super().begin(params, key, on_finish=on_finish, on_token=on_token)
        self._reset_pool()
        self.stats.update(prompt_prefills=0, pages_in_use=0,
                          peak_pages_in_use=0, prompt_tokens=0,
                          prefill_tokens=0, prefix_hit_tokens=0,
                          evicted_pages=0)

    def set_params(self, params) -> None:
        """Weight swap invalidates every cached prefix: resident KV was
        computed under the old params.  Evictable branches free at once;
        branches with live readers drain via ``reap()``."""
        super().set_params(params)
        if self._prefix_cache is not None:
            self._dirty.update(self._prefix_cache.flush())

    def _ensure_free(self, n: int) -> bool:
        """Make >= ``n`` pages available, LRU-evicting cold radix branches
        under pressure; False when the pool still cannot satisfy it."""
        short = n - self._alloc.num_free
        if short > 0 and self._prefix_cache is not None:
            freed = self._prefix_cache.evict(short)
            self._dirty.update(freed)
            self.stats["evicted_pages"] += len(freed)
        return self._alloc.num_free >= n

    def _free_slot_pages(self, s: int) -> None:
        freed = self._alloc.release(self._slot_decode_pages[s])
        freed += self._alloc.release(self._slot_prompt_pages[s])
        self._dirty.update(freed)
        self._slot_decode_pages[s] = []
        self._slot_prompt_pages[s] = []

    def _harvest(self, s: int, host, cancelled: bool) -> Completion:
        comp = super()._harvest(s, host, cancelled)
        if self.ecfg.learner_retain:
            # take the learner's reference BEFORE the slot's own refs drop:
            # the prompt pages stay resident (and read-only — nothing
            # rewrites a page whose refcount is nonzero) until
            # release_learner_pages, surviving radix eviction and the
            # set_params epoch flush
            ppages = list(self._slot_prompt_pages[s])
            self._alloc.retain(ppages)
            self._retained[comp.uid] = (ppages, int(self._slot_plen[s]))
        self._free_slot_pages(s)
        return comp

    # -------------------------------------------------- learner page handoff
    def export_learner_pages(self, uids: Sequence) -> dict:
        """Slice the retained prompt pages of ``uids`` out of the pool for
        zero re-prefill scoring (DESIGN.md §11).

        Returns ``{"pool": tree, "block_tables": (len(uids), M) int32,
        "prompt_lens": (len(uids),) int32}`` where ``pool`` mirrors the
        cache layout per attention layer (``{"k"/"v": (repeat, P',
        page_len, KV, D), "pos": (repeat, P', page_len)}``) over the
        COMPACTED union of the requested pages, and ``block_tables`` is
        renumbered into it (-1 padded).  Pages shared by GRPO siblings
        appear once.  Feed straight into ``score_tokens(paged_prefix=
        pool, page_tables=...)`` with a ``PagedLayout`` batch whose
        segment order matches ``uids``.

        Host-side copy (``jnp.take``): must run between ``drive()`` calls
        — the live state is donated into the next jitted step.  Raises
        ``KeyError`` for a uid that was never harvested under
        ``learner_retain=True`` (e.g. cancelled before placement).
        """
        caps.check_paged_score(self.cfg)
        recs = [self._retained[uid] for uid in uids]
        pages_used: list = []
        index: dict = {}
        tables = np.full((len(recs), self._n_pp), -1, np.int32)
        plens = np.zeros((len(recs),), np.int32)
        for i, (ppages, plen) in enumerate(recs):
            plens[i] = plen
            for k, p in enumerate(ppages):
                if p not in index:
                    index[p] = len(pages_used)
                    pages_used.append(p)
                tables[i, k] = index[p]
        sel = jnp.asarray(np.asarray(pages_used or [0], np.int32))
        pool = {}
        for gi, (pattern, _repeat) in enumerate(self.cfg.blocks):
            grp = {}
            for j, _kind in enumerate(pattern):
                e = self._state["cache"][f"group{gi}"][f"l{j}"]
                grp[f"l{j}"] = {key: jnp.take(e[key], sel, axis=1)
                                for key in ("k", "v", "pos")}
            pool[f"group{gi}"] = grp
        return {"pool": pool, "block_tables": jnp.asarray(tables),
                "prompt_lens": plens}

    def release_learner_pages(self, uids: Optional[Sequence] = None) -> None:
        """Drop the learner references taken at harvest (all of them when
        ``uids`` is None) — call after the grad step consumed the export.
        Pages whose refcount hits zero rejoin the free list and are
        pos-poisoned before reuse, exactly like any other release."""
        keys = list(self._retained) if uids is None else list(uids)
        for uid in keys:
            pages, _plen = self._retained.pop(uid)
            self._dirty.update(self._alloc.release(pages))

    # ------------------------------------------------------------- submit
    def submit(self, requests: Sequence[Request]) -> None:
        """Ungrouped requests: each becomes its own group of one (no
        sharing, but the paged lifecycle still applies)."""
        for r in requests:
            self.submit_group([r])

    def submit_group(self, requests: Sequence[Request]) -> None:
        """Enqueue one group: siblings share a single prompt whose pages
        are prefilled once and refcounted across all of them."""
        reqs = list(requests)
        if not reqs:
            return
        if len(reqs) > self.ecfg.max_group:
            raise ValueError(
                f"group of {len(reqs)} exceeds max_group="
                f"{self.ecfg.max_group}")
        self._validate_requests(reqs)
        t0 = np.asarray(reqs[0].tokens)
        for r in reqs[1:]:
            if not np.array_equal(np.asarray(r.tokens), t0):
                raise ValueError(
                    "submit_group: siblings must share one prompt "
                    f"(uid {r.uid} differs from uid {reqs[0].uid})")
        pl_, n = self.ecfg.page_len, self.rcfg.max_new_tokens
        # worst-case CONCURRENT need: prompt pages once, plus decode pages
        # for the largest siblings that can run at the same time (parking
        # bounds concurrency by the slot count)
        dp = sorted((-(-(r.budget or n) // pl_) for r in reqs), reverse=True)
        need = -(-len(t0) // pl_) + sum(dp[:self.ecfg.num_slots])
        if need > self.num_pages:
            raise PagePoolExhausted(
                f"group needs up to {need} concurrent pages but the pool "
                f"holds only {self.num_pages}; grow "
                "PagedEngineConfig.num_pages")
        self._queue.append(reqs)

    # ------------------------------------------------------------ device side
    def _init_state(self, params, key: Array) -> dict:
        """Zeroed pool + per-slot planes.  Pool storage dtype comes from an
        abstract ``paged_prefill`` (what refills actually produce), with
        ``paged_cache_decl`` shapes as the contract; every page starts
        pos-poisoned (-1 = empty)."""
        ecfg = self.ecfg
        s, n = ecfg.num_slots, self.rcfg.max_new_tokens
        pl_, npg = ecfg.page_len, self.num_pages
        cfg = self.cfg
        if self._cache_tmpl is None:
            raw = jax.eval_shape(
                lambda p: paged_prefill(
                    p, cfg,
                    jnp.zeros((ecfg.group_lanes, ecfg.max_prompt_len),
                              jnp.int32),
                    cache_len=self.cache_len,
                    prefill_len=jnp.ones((ecfg.group_lanes,), jnp.int32))[1],
                params)
            decl = paged_cache_decl(cfg, s, self.cache_len,
                                    num_pages=npg, page_len=pl_)
            tmpl = {}
            for gi, (pattern, repeat) in enumerate(cfg.blocks):
                layer = {}
                for j, kind in enumerate(pattern):
                    e = raw[f"group{gi}"][f"l{j}"]
                    mixer = cfg.mixer_of(kind)
                    if mixer == "attn":
                        kvh, dh = e["k"].shape[-2:]
                        layer[f"l{j}"] = {
                            "k": jax.ShapeDtypeStruct(
                                (repeat, npg, pl_, kvh, dh), e["k"].dtype),
                            "v": jax.ShapeDtypeStruct(
                                (repeat, npg, pl_, kvh, dh), e["v"].dtype),
                            "pos": jax.ShapeDtypeStruct(
                                (repeat, npg, pl_), jnp.int32),
                        }
                    elif mixer == "mla":
                        layer[f"l{j}"] = {
                            "c_kv": jax.ShapeDtypeStruct(
                                (repeat, npg, pl_, e["c_kv"].shape[-1]),
                                e["c_kv"].dtype),
                            "k_rope": jax.ShapeDtypeStruct(
                                (repeat, npg, pl_, e["k_rope"].shape[-1]),
                                e["k_rope"].dtype),
                            "pos": jax.ShapeDtypeStruct(
                                (repeat, npg, pl_), jnp.int32),
                        }
                    else:
                        # per-slot entry: widen the lane batch dim to S
                        layer[f"l{j}"] = jax.tree.map(
                            lambda d: jax.ShapeDtypeStruct(
                                (d.shape[0], s) + d.shape[2:], d.dtype), e)
                tmpl[f"group{gi}"] = layer

            def check(a, b):
                assert a.shape == b.shape, \
                    f"paged cache shape drift {a.shape}!={b.shape}"

            jax.tree.map(check, tmpl, decl)
            self._cache_tmpl = tmpl
        cache = jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype),
                             self._cache_tmpl)
        cache = invalidate_pages(cfg, cache, jnp.ones((npg,), bool))
        return {
            "cache": cache,
            # prompt logits of the last prefill, per lane: survives the
            # round so the host can snapshot them for parked siblings
            "prefill_logits": jnp.zeros(
                (ecfg.group_lanes, self.cfg.vocab_size), F32),
            "logits": jnp.zeros((s, self.cfg.vocab_size), F32),
            "pos": jnp.zeros((s,), jnp.int32),
            "prompt_len": jnp.zeros((s,), jnp.int32),
            "n_gen": jnp.zeros((s,), jnp.int32),
            "budget": jnp.zeros((s,), jnp.int32),
            "active": jnp.zeros((s,), bool),
            "done": jnp.zeros((s,), bool),
            "eos_hit": jnp.zeros((s,), bool),
            "key": jnp.array(key),
            "out_tok": jnp.full((s, n), self.rcfg.pad_id, jnp.int32),
            "out_logp": jnp.zeros((s, n), F32),
            "out_ent": jnp.zeros((s, n), F32),
        }

    def _make_step(self, external_prefill: bool = False):
        cfg, rcfg, ecfg = self.cfg, self.rcfg, self.ecfg
        s_slots = ecfg.num_slots
        n = rcfg.max_new_tokens
        tp = ecfg.max_prompt_len
        pl_ = ecfg.page_len
        npg = self.num_pages
        n_pp, max_pages = self._n_pp, self._max_pages
        gmax = ecfg.max_group
        pad_t = n_pp * pl_
        cache_len = self.cache_len
        attn_impl = ecfg.attn_impl
        use_prefix = ecfg.prefix_cache
        # external prefill (DESIGN.md §12): the prompt prefill ran on the
        # prefill slice; this step receives its (logits0, fresh KV) as
        # trailing operands and only scatters — state stays operand 1, so
        # donate_argnums is unchanged.  Incompatible with the radix prefix
        # cache (the match would need pool pages from the decode slice
        # inside the prefill computation).
        assert not (external_prefill and use_prefix), \
            "prefix_cache cannot span the prefill/decode split"

        def step(params, state, block_tables, free_page_mask, refill_toks,
                 refill_lens, refill_prefix_len, refill_prefix_bt,
                 refill_page_ids, refill_slots, refill_budgets,
                 refill_mask, resume_slots, resume_logits, resume_lens,
                 resume_budgets, resume_mask, cancel_mask, *handoff):
            st = dict(state)
            # 1. cancelled slots become free (harvest happened on host)
            st["active"] = st["active"] & ~cancel_mask
            st["done"] = st["done"] & ~cancel_mask
            # 2. pos-poison freed pages before any reuse this round: a
            # recycled page must never leak its previous occupant's
            # positions as valid entries (gather isolation)
            st["cache"] = invalidate_pages(cfg, st["cache"], free_page_mask)

            # 3. group refill: one prompt prefill per lane, its raw KV
            # scattered into the shared prompt pages, logits and per-slot
            # (non-attention) states broadcast to every sibling slot
            tgt = jnp.where(refill_slots < s_slots, refill_slots,
                            s_slots).astype(jnp.int32).reshape(-1)  # (R*Gmax,)
            flat_pages = jnp.minimum(refill_page_ids,
                                     npg).astype(jnp.int32).reshape(-1)

            def do_refill(st):
                st = dict(st)
                # radix prefix resume: gather the matched pages' K/V per
                # layer (post-invalidation, so evicted pages are already
                # invisible) and prefill only the unmatched suffix; the
                # scatter below lands suffix K/V in the fresh pages with
                # positions offset past the cached prefix.  With the cache
                # off, refill_prefix_len is all-zero and this is exactly
                # the old full-prompt prefill.
                if external_prefill:
                    # computed on the prefill slice, shipped device-to-
                    # device by _dispatch; zero-filled buffers on
                    # pure-decode rounds (branch result unused)
                    logits0, fresh = handoff
                elif use_prefix:
                    pfx = {}
                    for gi, (pattern, _repeat) in enumerate(cfg.blocks):
                        grp_p = {}
                        for j, _kind in enumerate(pattern):
                            e = st["cache"][f"group{gi}"][f"l{j}"]
                            kg, vg, posg = jax.vmap(
                                gather_pages, in_axes=(0, None))(
                                    {"k": e["k"], "v": e["v"],
                                     "pos": e["pos"]}, refill_prefix_bt)
                            grp_p[f"l{j}"] = {"k": kg, "v": vg, "pos": posg}
                        pfx[f"group{gi}"] = grp_p
                    logits0, fresh = paged_prefill(
                        params, cfg, refill_toks, cache_len=cache_len,
                        prefill_len=jnp.maximum(refill_lens, 1),
                        prefix_kv=pfx, prefix_len=refill_prefix_len)
                else:
                    logits0, fresh = paged_prefill(
                        params, cfg, refill_toks, cache_len=cache_len,
                        prefill_len=jnp.maximum(refill_lens, 1),
                        prefix_kv=None, prefix_len=None)
                qpos = jnp.arange(pad_t)[None, :]
                page_vals = jnp.where(
                    qpos < refill_lens[:, None],
                    refill_prefix_len[:, None] + qpos, -1).astype(jnp.int32)
                page_vals = page_vals.reshape(-1, pl_)       # (R*n_pp, pl)

                new_cache = {}
                for gi, (pattern, repeat) in enumerate(cfg.blocks):
                    grp = {}
                    for j, kind in enumerate(pattern):
                        e_old = st["cache"][f"group{gi}"][f"l{j}"]
                        e_new = fresh[f"group{gi}"][f"l{j}"]
                        if caps.pool_resident(cfg.mixer_of(kind)):
                            def scat_pool(pool, raw):
                                # raw (repeat, R, Tp, *feat) -> page blocks
                                # (attn: KV, D feature dims; mla: R / Dr)
                                raw = jnp.pad(
                                    raw, ((0, 0), (0, 0), (0, pad_t - tp))
                                    + ((0, 0),) * (raw.ndim - 3))
                                rep, r_ = raw.shape[:2]
                                raw = raw.reshape(rep, r_ * n_pp, pl_,
                                                  *raw.shape[3:])
                                return pool.at[:, flat_pages].set(
                                    raw.astype(pool.dtype), mode="drop")

                            rep = e_old["pos"].shape[0]
                            pos_new = e_old["pos"].at[:, flat_pages].set(
                                jnp.broadcast_to(
                                    page_vals, (rep,) + page_vals.shape),
                                mode="drop")
                            entry = {key: scat_pool(e_old[key], e_new[key])
                                     for key in e_new}
                            entry["pos"] = pos_new
                            grp[f"l{j}"] = entry
                        else:
                            def scat_slot(arena, rows):
                                rows = jnp.repeat(rows, gmax, axis=1)
                                return arena.at[:, tgt].set(
                                    rows.astype(arena.dtype), mode="drop")

                            grp[f"l{j}"] = jax.tree.map(scat_slot, e_old,
                                                        e_new)
                    new_cache[f"group{gi}"] = grp
                st["cache"] = new_cache

                st["prefill_logits"] = logits0.astype(F32)
                full_lens = refill_prefix_len + refill_lens
                return _place_slot_planes(
                    st, tgt, jnp.repeat(full_lens, gmax),
                    refill_budgets.reshape(-1),
                    jnp.repeat(logits0, gmax, axis=0), n, rcfg.pad_id)

            st = jax.lax.cond(refill_mask.any(), do_refill,
                              lambda s_: dict(s_), st)

            # 3b. resume parked siblings (pure-attention configs): the
            # prompt state is exactly its shared pages (already in the
            # block table) + the saved prompt logits — placement is a
            # pure scatter, nothing recomputes
            rtgt = jnp.where(resume_slots < s_slots, resume_slots,
                             s_slots).astype(jnp.int32)

            def do_resume(st):
                return _place_slot_planes(dict(st), rtgt, resume_lens,
                                          resume_budgets, resume_logits, n,
                                          rcfg.pad_id)

            st = jax.lax.cond(resume_mask.any(), do_resume,
                              lambda s_: dict(s_), st)

            # 4. masked decode substeps through the block tables
            def substep(st, _):
                st = dict(st)
                nxt, live = _substep_sample(st, rcfg, n, s_slots)
                # write target: decode token i = n_gen opens/extends the
                # slot's private pages AFTER its prompt pages — never a
                # shared page, so prompt pages stay read-only
                n_pp_s = (st["prompt_len"] + pl_ - 1) // pl_
                page_slot = jnp.minimum(n_pp_s + st["n_gen"] // pl_,
                                        max_pages - 1)
                bt_entry = jnp.take_along_axis(
                    block_tables, page_slot[:, None], axis=1)[:, 0]
                wp = jnp.where(live & (bt_entry >= 0), bt_entry,
                               npg).astype(jnp.int32)
                wo = (st["n_gen"] % pl_).astype(jnp.int32)
                new_logits, new_cache = decode_step(
                    params, cfg, nxt, st["cache"], st["pos"],
                    block_tables=block_tables, write_page=wp, write_off=wo,
                    attn_impl=attn_impl)
                st["cache"] = new_cache
                st = _substep_advance(st, nxt, live, new_logits, rcfg)
                return st, None

            st, _ = jax.lax.scan(substep, st, None,
                                 length=ecfg.steps_per_sync)
            return st

        return step

    # ------------------------------------------------------------- drive
    def _dispatch(self, state, bt, free_mask, refill_toks, refill_lens,
                  refill_prefix_len, refill_prefix_bt, refill_page_ids,
                  refill_slots, refill_budgets, refill_mask, resume_slots,
                  resume_logits, resume_lens, resume_budgets, resume_mask,
                  cancel_mask):
        """Run the round's jitted step over host-built operands and return
        the new device state — the seam the disaggregated engine overrides
        to interpose the cross-slice prefill handoff (DESIGN.md §12)."""
        return self._step(
            self._params, state, jnp.asarray(bt), jnp.asarray(free_mask),
            jnp.asarray(refill_toks), jnp.asarray(refill_lens),
            jnp.asarray(refill_prefix_len), jnp.asarray(refill_prefix_bt),
            jnp.asarray(refill_page_ids), jnp.asarray(refill_slots),
            jnp.asarray(refill_budgets), jnp.asarray(refill_mask),
            jnp.asarray(resume_slots), jnp.asarray(resume_logits),
            jnp.asarray(resume_lens), jnp.asarray(resume_budgets),
            jnp.asarray(resume_mask), jnp.asarray(cancel_mask))

    @property
    def idle(self) -> bool:
        return super().idle and not self._pending

    @property
    def backlog(self) -> int:
        """Queued groups plus partially-placed (parked) groups."""
        return len(self._queue) + len(self._pending)

    def drive(self) -> list:
        """One paged round: harvest (freeing pages), resume parked
        siblings into freed slots, place queued groups with one shared
        prompt prefill each, allocate-ahead decode pages, dispatch the
        jitted step with fresh block tables."""
        if self.chaos is not None:
            # pool-pressure injection point: a PagePoolExhausted raised
            # here is indistinguishable from a real transient exhaustion
            # at placement/allocate-ahead (testing/chaos.py)
            self.chaos.fire("placement", replica=self.chaos_replica,
                            index=self.stats.get("rounds", 0))
        ecfg, rcfg = self.ecfg, self.rcfg
        s_slots, tp = ecfg.num_slots, ecfg.max_prompt_len
        pl_, sps = ecfg.page_len, ecfg.steps_per_sync
        state, slot_uid, queue = self._state, self._slot_uid, self._queue
        harvested, cancel_mask = self._collect_retirements()

        if self._prefix_cache is not None:
            # nodes inserted last round are matchable now (their prefill
            # retired with the previous step), and stale-epoch branches
            # whose readers drained get collected
            self._prefix_cache.step()
            self._dirty.update(self._prefix_cache.reap())

        # snapshot prompt logits for parked groups (written by the prefill
        # one round earlier; read before any new prefill reuses the lane)
        if any(rec["logits"] is None for rec in self._pending):
            lane_logits = np.asarray(state["prefill_logits"])
            for rec in self._pending:
                if rec["logits"] is None:
                    rec["logits"] = lane_logits[rec["lane"]].copy()

        # -- allocate-ahead for slots already decoding: each must own
        # pages for every token it can write this round (exhaustion here
        # is a real undersized pool — raise, never corrupt)
        occupied = [s for s in range(s_slots) if slot_uid[s] is not None]
        for s in occupied:
            want = int(min(self._n_gen_ub[s] + sps, self._slot_budget[s]))
            need = -(-want // pl_)
            short = need - len(self._slot_decode_pages[s])
            if short > 0:
                self._ensure_free(short)  # evict cold branches, else raise
            while len(self._slot_decode_pages[s]) < need:
                self._slot_decode_pages[s].extend(
                    self._alloc.alloc(1, f" (slot {s} decode-ahead)"))
        free_slots = [s for s in range(s_slots) if slot_uid[s] is None]

        def place(s: int, r: Request, plen: int, ppages: list,
                  first_ref: bool) -> int:
            """Install sibling ``r`` in slot ``s``: take a prompt-page
            reference (unless it inherits the allocation's first ref) and
            allocate its first decode pages."""
            budget = r.budget or rcfg.max_new_tokens
            if not first_ref:
                self._alloc.retain(ppages)
            slot_uid[s] = r.uid
            self._streamed[s] = 0
            self._slot_prompt_pages[s] = ppages
            self._slot_decode_pages[s] = self._alloc.alloc(
                -(-min(sps, budget) // pl_), f" (slot {s} decode)")
            self._slot_plen[s] = plen
            self._slot_budget[s] = budget
            self._n_gen_ub[s] = 0
            occupied.append(s)
            return budget

        # -- resume parked siblings into freed slots (pure scatter: their
        # prompt state is the shared pages + the saved prompt logits);
        # lane width bounds the (lanes, vocab) logits operand per round —
        # leftovers simply wait for the next round
        rw = ecfg.resumes
        resume_mask = np.zeros((rw,), bool)
        resume_slots = np.full((rw,), s_slots, np.int32)
        resume_logits = np.zeros((rw, self.cfg.vocab_size), np.float32)
        resume_lens = np.ones((rw,), np.int32)
        resume_budgets = np.zeros((rw,), np.int32)
        ri = 0
        for rec in list(self._pending):
            still = []
            for r in rec["reqs"]:
                if r.uid in self._to_cancel:
                    harvested.append(self._cancelled_completion(r))
                else:
                    still.append(r)
            rec["reqs"] = still
            while (still and free_slots and ri < rw
                   and rec["logits"] is not None):
                budget = still[0].budget or rcfg.max_new_tokens
                if not self._ensure_free(-(-min(sps, budget) // pl_)):
                    if not occupied and not resume_mask.any():
                        self._alloc.alloc(  # raises with occupancy
                            -(-min(sps, budget) // pl_), " (sibling resume)")
                    break
                r = still.pop(0)
                s = free_slots.pop(0)
                resume_budgets[ri] = place(s, r, rec["plen"], rec["ppages"],
                                           first_ref=False)
                resume_mask[ri] = True
                resume_slots[ri] = s
                resume_logits[ri] = rec["logits"]
                resume_lens[ri] = rec["plen"]
                ri += 1
            if not rec["reqs"]:
                # last sibling placed/cancelled: drop the record's ref
                self._dirty.update(self._alloc.release(rec["ppages"]))
                self._pending.remove(rec)

        # -- place queued groups, one prompt prefill per lane; siblings
        # beyond the free slots are parked (pure-attention) or the whole
        # group waits (per-slot-state mixers place atomically)
        lanes, gmax, n_pp = ecfg.group_lanes, ecfg.max_group, self._n_pp
        refill_mask = np.zeros((lanes,), bool)
        refill_toks = np.full((lanes, tp), rcfg.pad_id, np.int32)
        refill_lens = np.ones((lanes,), np.int32)
        refill_prefix_len = np.zeros((lanes,), np.int32)
        refill_prefix_bt = np.full((lanes, n_pp), -1, np.int32)
        refill_page_ids = np.full((lanes, n_pp), self.num_pages, np.int32)
        refill_slots = np.full((lanes, gmax), s_slots, np.int32)
        refill_budgets = np.zeros((lanes, gmax), np.int32)
        lane = 0
        while lane < lanes and queue and free_slots:
            group = queue[0]
            live = []
            for r in group:
                if r.uid in self._to_cancel:
                    harvested.append(self._cancelled_completion(r))
                else:
                    live.append(r)
            # strip emitted cancellations from the QUEUED group in place:
            # the defer breaks below leave the group at the queue head, and
            # a re-examined sibling must never re-emit its Completion
            group[:] = live
            if not live:
                queue.popleft()
                continue
            if not self._pure_pool and len(live) > len(free_slots):
                break  # atomic placement: wait for slots to free up
            placed = live[:len(free_slots)]
            parked = live[len(placed):]
            toks0 = np.asarray(live[0].tokens)
            plen = len(toks0)
            n_pp_g = -(-plen // pl_)
            # radix longest-prefix match: matched pages join the group's
            # block tables read-only; only the suffix prefills.  A fully
            # cached prompt drops its last matched page so >= 1 token is
            # always recomputed — the prefill's last-token logits seed
            # sampling (vLLM-style last-block recompute).
            m_nodes: list = []
            if self._prefix_cache is not None:
                m_nodes = self._prefix_cache.lookup(toks0)
                if m_nodes and len(m_nodes) * pl_ >= plen:
                    m_nodes = m_nodes[:-1]
            m_pages = [nd.page for nd in m_nodes]
            mlen = len(m_pages) * pl_
            n_fresh = n_pp_g - len(m_pages)
            need = n_fresh + sum(
                -(-min(sps, r.budget or rcfg.max_new_tokens) // pl_)
                for r in placed)
            if m_pages:
                # pin the match before eviction can consider those pages
                self._alloc.retain(m_pages)
                self._prefix_cache.touch(m_nodes)
            if not self._ensure_free(need):
                if m_pages:
                    self._dirty.update(self._alloc.release(m_pages))
                if (not occupied and not refill_mask.any()
                        and not resume_mask.any()):
                    self._alloc.alloc(need, " (group placement)")  # raises
                break  # wait for retirements to return pages
            fresh_pages = self._alloc.alloc(n_fresh, " (group prompt)")
            ppages = m_pages + fresh_pages
            queue.popleft()
            refill_mask[lane] = True
            refill_toks[lane, :plen - mlen] = toks0[mlen:]
            refill_lens[lane] = plen - mlen
            refill_prefix_len[lane] = mlen
            refill_prefix_bt[lane, :len(m_pages)] = m_pages
            refill_page_ids[lane, :n_fresh] = fresh_pages
            for gidx, r in enumerate(placed):
                s = free_slots.pop(0)
                refill_slots[lane, gidx] = s
                refill_budgets[lane, gidx] = place(s, r, plen, ppages,
                                                   first_ref=(gidx == 0))
            if parked:
                self._alloc.retain(ppages)  # the pending record's ref
                self._pending.append({"reqs": parked, "ppages": ppages,
                                      "plen": plen, "lane": lane,
                                      "logits": None})
            if self._prefix_cache is not None:
                # chain the suffix's FULL pages into the trie (ready next
                # round, once their prefill has retired); the partial
                # trailing page stays group-private
                n_full_new = plen // pl_ - len(m_pages)
                if n_full_new > 0:
                    self._prefix_cache.insert(
                        m_nodes[-1] if m_nodes else None, toks0, mlen,
                        fresh_pages[:n_full_new])
                self.stats["prefix_hit_tokens"] += mlen
            self.stats["prompt_tokens"] += plen
            self.stats["prefill_tokens"] += plen - mlen
            self.stats["prompt_prefills"] += 1
            lane += 1

        if not refill_mask.any() and not resume_mask.any() and not occupied:
            self.last_state = state  # session quiescent: expose for tests
            return harvested

        # -- block tables + free-page invalidation mask, rebuilt per round
        bt = np.full((s_slots, self._max_pages), -1, np.int32)
        for s in occupied:
            n_pp_s = -(-int(self._slot_plen[s]) // pl_)
            bt[s, :n_pp_s] = self._slot_prompt_pages[s]
            dp = self._slot_decode_pages[s]
            bt[s, n_pp_s:n_pp_s + len(dp)] = dp
        free_mask = np.zeros((self.num_pages,), bool)
        if self._dirty:
            free_mask[sorted(self._dirty)] = True

        self._state = self._dispatch(
            state, bt, free_mask, refill_toks, refill_lens,
            refill_prefix_len, refill_prefix_bt, refill_page_ids,
            refill_slots, refill_budgets, refill_mask, resume_slots,
            resume_logits, resume_lens, resume_budgets, resume_mask,
            cancel_mask)
        self._dirty.clear()
        for s in occupied:
            self._n_gen_ub[s] = min(self._n_gen_ub[s] + sps,
                                    int(self._slot_budget[s]))
        self.stats["rounds"] += 1
        self.stats["decode_steps"] += sps
        self.stats["slot_substeps"] += sps * s_slots
        self.stats["refills"] += (int((refill_slots < s_slots).sum())
                                  + int(resume_mask.sum()))
        self.stats["pages_in_use"] = self._alloc.in_use
        self.stats["peak_pages_in_use"] = self._alloc.peak_in_use
        return harvested


class DisaggPagedRolloutEngine(PagedRolloutEngine):
    """Prefill/decode-disaggregated paged engine (DESIGN.md §12).

    The paged round's one fused step does both prompt prefill and decode
    substeps on one device; this engine splits them across a fleet slice's
    two cells: prompt prefill runs as its own jitted cell on the
    **prefill device**, and its output — the prompt logits plus the fresh
    per-layer page payloads — is shipped device-to-device to the **decode
    device**, where the (external-prefill) step scatters it into the
    shared pool exactly as the fused step would have.  The handoff is the
    group's block-table contract: pages are allocated on the decode slice
    by the same host allocator, prefill writes arrive via the existing
    scatter path, and nothing else (counters, planes, block tables)
    changes — so token streams are bit-identical to the fused engine.

    Requires every mixer pool-resident (``capabilities.check_slice_handoff``):
    per-slot sequence state (local rings, ssm/rec) would be stranded on
    the prefill slice.  The radix prefix cache is incompatible — a match
    would need decode-slice pool pages inside the prefill computation.
    """

    def __init__(self, cfg: ModelConfig, rcfg, ecfg: PagedEngineConfig,
                 *, prefill_device=None, decode_device=None):
        caps.check_slice_handoff(cfg)
        if ecfg.prefix_cache:
            raise ValueError(
                "prefix_cache cannot span the prefill/decode split: the "
                "radix match needs decode-slice pool pages inside the "
                "prefill computation")
        self._prefill_device = prefill_device or jax.devices()[0]
        super().__init__(cfg, rcfg, ecfg,
                         device=decode_device or jax.devices()[0])
        self._prefill_fn = jax.jit(self._make_prefill())
        self._params_prefill = None
        self._zero_handoff = None

    def _make_step(self, external_prefill: bool = True):
        return super()._make_step(external_prefill=True)

    def _make_prefill(self):
        cfg, cache_len = self.cfg, self.cache_len

        def prefill_cell(params, toks, lens):
            # the exact computation the fused step's do_refill runs (prefix
            # cache off), so the handoff changes placement, never values
            return paged_prefill(params, cfg, toks, cache_len=cache_len,
                                 prefill_len=jnp.maximum(lens, 1),
                                 prefix_kv=None, prefix_len=None)

        return prefill_cell

    def begin(self, params, key: Array, *, on_finish=None,
              on_token=None) -> None:
        # handoff counters are cumulative across group sessions (the
        # trainer's publication_stats reads them as lifetime telemetry);
        # the parent resets self.stats per session, so carry them over
        carry = {k: getattr(self, "stats", {}).get(k, 0)
                 for k in ("handoffs", "handoff_bytes")}
        super().begin(params, key, on_finish=on_finish, on_token=on_token)
        self.stats.update(carry)
        self._params_prefill = jax.device_put(params, self._prefill_device)
        if self._zero_handoff is None:
            lanes, tp = self.ecfg.group_lanes, self.ecfg.max_prompt_len
            shapes = jax.eval_shape(
                self._prefill_fn, self._params_prefill,
                jnp.zeros((lanes, tp), jnp.int32),
                jnp.ones((lanes,), jnp.int32))
            # pure-decode rounds still pass handoff operands (static jit
            # signature); zero-filled once, resident on the decode slice
            self._zero_handoff = jax.device_put(
                jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype), shapes),
                self._device)

    def set_params(self, params) -> None:
        super().set_params(params)
        self._params_prefill = jax.device_put(params, self._prefill_device)

    def _dispatch(self, state, bt, free_mask, refill_toks, refill_lens,
                  refill_prefix_len, refill_prefix_bt, refill_page_ids,
                  refill_slots, refill_budgets, refill_mask, resume_slots,
                  resume_logits, resume_lens, resume_budgets, resume_mask,
                  cancel_mask):
        if refill_mask.any():
            toks = jax.device_put(jnp.asarray(refill_toks),
                                  self._prefill_device)
            lens = jax.device_put(jnp.asarray(refill_lens),
                                  self._prefill_device)
            logits0, fresh = self._prefill_fn(
                self._params_prefill, toks, lens)
            handoff = jax.device_put((logits0, fresh), self._device)
            self.stats["handoffs"] += 1
            self.stats["handoff_bytes"] += _tree_bytes(handoff)
        else:
            handoff = self._zero_handoff
        return self._step(
            self._params, state, jnp.asarray(bt), jnp.asarray(free_mask),
            jnp.asarray(refill_toks), jnp.asarray(refill_lens),
            jnp.asarray(refill_prefix_len), jnp.asarray(refill_prefix_bt),
            jnp.asarray(refill_page_ids), jnp.asarray(refill_slots),
            jnp.asarray(refill_budgets), jnp.asarray(refill_mask),
            jnp.asarray(resume_slots), jnp.asarray(resume_logits),
            jnp.asarray(resume_lens), jnp.asarray(resume_budgets),
            jnp.asarray(resume_mask), jnp.asarray(cancel_mask), *handoff)


def make_paged_engine(cfg: ModelConfig, rcfg, *, num_slots: int,
                      max_prompt_len: int, steps_per_sync: int = 4,
                      page_len: int = 16, num_pages: int = 0,
                      max_group: int = 0, attn_impl: str = "ref",
                      prefix_cache: bool = False,
                      learner_retain: bool = False,
                      ) -> PagedRolloutEngine:
    return PagedRolloutEngine(
        cfg, rcfg, PagedEngineConfig(
            num_slots=num_slots, max_prompt_len=max_prompt_len,
            steps_per_sync=steps_per_sync, page_len=page_len,
            num_pages=num_pages,
            max_group=max_group or min(num_slots, rcfg.group_size),
            attn_impl=attn_impl, prefix_cache=prefix_cache,
            learner_retain=learner_retain))
