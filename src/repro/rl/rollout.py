"""Rollout paths on the training model: legacy fixed-shape scan + the
continuous-batching group front-end.

The paper's pipeline pairs an external inference engine (SGLang) with an
FSDP learner and ships weights between them.  On TPU we colocate: rollout
decodes over the SAME sharded parameters the learner updates — no weight
transfer, no second engine (DESIGN.md §3).

Two paths produce the identical learner-batch contract (``RolloutBatch``):

* ``rollout_group`` — the legacy fixed-shape ``lax.scan``: every row pays
  the full ``max_new_tokens`` budget even after emitting EOS.  Kept as the
  parity reference and for single-wave eval.
* ``rollout_group_continuous`` — the slot-arena engine (``rl/engine.py``):
  rows retire at EOS and their slots are re-prefilled with queued prompts,
  so over-provisioned groups (G' > G) cost only the tokens actually
  generated, and a prompt's stragglers are cancelled the moment its G-quota
  of finished rollouts is met (the APRIL discipline made physical).

Both collect behaviour logprobs + per-token entropies *during* decode (the
forward-scoring stage of GRPO is fused into rollout) and report a ``stats``
dict (tokens_generated / decode_steps / tokens_budget / ...) so the token
cost of rollout is measurable per step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig
from repro.rl.env import EOS, PAD

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RolloutConfig:
    max_new_tokens: int = 64
    temperature: float = 1.0
    group_size: int = 8           # G rollouts kept per prompt
    overprovision: float = 1.0    # G' = ceil(G * overprovision) sampled
    eos_id: int = EOS
    pad_id: int = PAD


@dataclasses.dataclass
class RolloutBatch:
    """Learner-ready (B, T) grid: prompt + response, right-padded."""

    tokens: np.ndarray          # (B, T) int32
    response_mask: np.ndarray   # (B, T) f32 — 1 on generated tokens
    old_logp: np.ndarray        # (B, T) f32 — behaviour logprobs
    entropies: np.ndarray       # (B, T) f32 — behaviour entropies
    prompt_lens: np.ndarray     # (B,)
    response_lens: np.ndarray   # (B,)
    completed: np.ndarray       # (B,) bool — emitted EOS within budget
    stats: Optional[dict] = None  # rollout cost: tokens_generated, steps, ...


def _sample_logits(key, logits, temperature):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@partial(jax.jit, static_argnames=("cfg", "rcfg"))
def generate(
    params,
    cfg: ModelConfig,
    rcfg: RolloutConfig,
    prompt_tokens: Array,     # (B, Tp) PAD-right
    prompt_lens: Array,       # (B,)
    key: Array,
):
    """Returns (tokens (B, Tp+N), logp (B, N), entropy (B, N), resp_len (B,),
    completed (B,))."""
    b, tp = prompt_tokens.shape
    n = rcfg.max_new_tokens
    cache_len = tp + n

    logits0, cache = prefill(params, cfg, prompt_tokens, cache_len=cache_len,
                             prefill_len=prompt_lens)

    def step(carry, _):
        cache, cur_logits, pos, done, key = carry
        key, k1 = jax.random.split(key)
        nxt = _sample_logits(k1, cur_logits, rcfg.temperature)
        logp_all = jax.nn.log_softmax(cur_logits, axis=-1)
        logp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
        ent = -jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1)
        nxt = jnp.where(done, rcfg.pad_id, nxt).astype(jnp.int32)
        new_logits, cache = decode_step(params, cfg, nxt, cache, pos)
        new_done = done | (nxt == rcfg.eos_id)
        emitted = ~done
        return (cache, new_logits, pos + 1, new_done, key), (
            nxt, logp * emitted, ent * emitted, emitted)

    done0 = jnp.zeros((b,), bool)
    carry0 = (cache, logits0, prompt_lens, done0, key)
    _, (toks, logps, ents, emitted) = jax.lax.scan(step, carry0, None, length=n)
    toks = jnp.moveaxis(toks, 0, 1)          # (B, N)
    logps = jnp.moveaxis(logps, 0, 1)
    ents = jnp.moveaxis(ents, 0, 1)
    emitted = jnp.moveaxis(emitted, 0, 1)    # (B, N) True while generating

    resp_len = jnp.sum(emitted, axis=1).astype(jnp.int32)
    completed = jnp.any(toks == rcfg.eos_id, axis=1)
    full = jnp.concatenate([prompt_tokens, jnp.where(emitted, toks, rcfg.pad_id)],
                           axis=1)
    return full, logps, ents, resp_len, completed


def _quota_keep_rows(resp_len, completed, p, g, gp):
    """APRIL quota selection, shared by both rollout paths: per prompt keep
    G of its G' rows — completed ones first, shorter stragglers preferred
    among the incomplete — returned sorted (groups stay contiguous)."""
    keep_rows = []
    for i in range(p):
        rows = np.arange(i * gp, (i + 1) * gp)
        order = np.lexsort((resp_len[rows], ~completed[rows]))
        keep_rows.extend(rows[order[:g]])
    return np.array(sorted(keep_rows))


def _pack_grid(prompt_tokens, prompt_lens, gen_tokens, logps, ents, resp_len):
    """Host-side: compact each row to [prompt | response] with no gap, build
    the learner (B, T) grid and aligned per-token arrays."""
    b, tp = prompt_tokens.shape
    n = gen_tokens.shape[1] - tp
    t = tp + n
    tokens = np.full((b, t), PAD, np.int32)
    rmask = np.zeros((b, t), np.float32)
    logp = np.zeros((b, t), np.float32)
    ent = np.zeros((b, t), np.float32)
    for i in range(b):
        pl, rl = int(prompt_lens[i]), int(resp_len[i])
        tokens[i, :pl] = prompt_tokens[i, :pl]
        tokens[i, pl:pl + rl] = gen_tokens[i, tp:tp + rl]
        rmask[i, pl:pl + rl] = 1.0
        logp[i, pl:pl + rl] = logps[i, :rl]
        ent[i, pl:pl + rl] = ents[i, :rl]
    return tokens, rmask, logp, ent


def rollout_group(
    params,
    cfg: ModelConfig,
    rcfg: RolloutConfig,
    prompt_tokens: np.ndarray,   # (P, Tp) — P distinct prompts
    prompt_lens: np.ndarray,
    key: Array,
) -> RolloutBatch:
    """Sample G' rollouts per prompt, keep G per prompt (completed first —
    the APRIL-style quota), return the flattened (P*G, T) learner batch."""
    p, tp = prompt_tokens.shape
    g = rcfg.group_size
    gp = int(np.ceil(g * rcfg.overprovision))
    rep_toks = jnp.asarray(np.repeat(prompt_tokens, gp, axis=0))
    rep_lens = jnp.asarray(np.repeat(prompt_lens, gp, axis=0))

    full, logps, ents, resp_len, completed = generate(
        params, cfg, rcfg, rep_toks, rep_lens, key)
    full = np.asarray(full)
    logps = np.asarray(logps)
    ents = np.asarray(ents)
    resp_len = np.asarray(resp_len)
    completed = np.asarray(completed)
    keep_rows = _quota_keep_rows(resp_len, completed, p, g, gp)

    toks, rmask, logp, ent = _pack_grid(
        np.repeat(prompt_tokens, gp, axis=0)[keep_rows],
        np.repeat(prompt_lens, gp, axis=0)[keep_rows],
        full[keep_rows], logps[keep_rows], ents[keep_rows],
        resp_len[keep_rows])
    stats = {
        # every sampled row pays the full scan in the legacy path
        "tokens_generated": int(resp_len.sum()),
        "decode_steps": rcfg.max_new_tokens,
        "slot_substeps": int(p * gp * rcfg.max_new_tokens),
        "tokens_budget": int(p * gp * rcfg.max_new_tokens),
        "refills": int(p * gp),
        "cancelled": 0,
    }
    return RolloutBatch(
        tokens=toks, response_mask=rmask, old_logp=logp, entropies=ent,
        prompt_lens=np.repeat(prompt_lens, gp, axis=0)[keep_rows],
        response_lens=resp_len[keep_rows], completed=completed[keep_rows],
        stats=stats)


# ----------------------------------------------------- continuous batching
def batch_from_completions(
    comps,
    prompt_tokens: np.ndarray,   # (P, Tp)
    prompt_lens: np.ndarray,     # (P,)
    rcfg: RolloutConfig,
    p: int,
    g: int,
    gp: int,
    stats: Optional[dict] = None,
) -> RolloutBatch:
    """Assemble the learner batch from ``p * gp`` engine Completions in
    request order: APRIL quota selection down to G rows per prompt, then the
    [prompt | response] grid.  Shared by the serial front-end
    (``rollout_group_continuous``) and the stream-overlapped actor
    (``rl/async_trainer.py``), which deposits groups assembled here into
    the bounded-staleness sample queue."""
    resp_len_all = np.array([c.response_len for c in comps])
    completed_all = np.array([c.completed for c in comps])
    keep_rows = _quota_keep_rows(resp_len_all, completed_all, p, g, gp)

    rep_prompts = np.repeat(prompt_tokens, gp, axis=0)[keep_rows]
    rep_lens = np.repeat(prompt_lens, gp, axis=0)[keep_rows]
    toks, rmask, logp, ent, resp_len, completed = _grid_from_completions(
        [comps[r] for r in keep_rows], rep_prompts, rep_lens,
        prompt_tokens.shape[1] + rcfg.max_new_tokens)
    return RolloutBatch(
        tokens=toks, response_mask=rmask, old_logp=logp, entropies=ent,
        prompt_lens=rep_lens, response_lens=resp_len, completed=completed,
        stats=stats)


def _grid_from_completions(comps, prompt_tokens, prompt_lens, t):
    """Build the learner (B, T) grid from engine Completions (same contract
    as ``_pack_grid``: [prompt | response], right-padded, aligned arrays)."""
    b = len(comps)
    tokens = np.full((b, t), PAD, np.int32)
    rmask = np.zeros((b, t), np.float32)
    logp = np.zeros((b, t), np.float32)
    ent = np.zeros((b, t), np.float32)
    resp_len = np.zeros((b,), np.int32)
    completed = np.zeros((b,), bool)
    for i, c in enumerate(comps):
        pl, rl = int(prompt_lens[i]), c.response_len
        tokens[i, :pl] = prompt_tokens[i, :pl]
        tokens[i, pl:pl + rl] = c.tokens
        rmask[i, pl:pl + rl] = 1.0
        logp[i, pl:pl + rl] = c.logp
        ent[i, pl:pl + rl] = c.entropy
        resp_len[i] = rl
        completed[i] = c.completed
    return tokens, rmask, logp, ent, resp_len, completed


def rollout_group_continuous(
    params,
    cfg: ModelConfig,
    rcfg: RolloutConfig,
    prompt_tokens: np.ndarray,   # (P, Tp) — P distinct prompts
    prompt_lens: np.ndarray,
    key: Array,
    *,
    engine=None,
    num_slots: int = 0,          # 0 -> P * G (recycling absorbs G' - G)
    steps_per_sync: int = 4,
    cancel_on_quota: bool = True,
    budgets: Optional[np.ndarray] = None,  # (P*G',) per-row token budgets
    paged: bool = False,
    page_len: int = 16,
    num_pages: int = 0,
) -> RolloutBatch:
    """``rollout_group`` semantics on the slot-arena engine.

    All G' = ceil(G * overprovision) rollouts per prompt are queued as
    independent requests; the arena serves them through ``num_slots`` slots
    with retire/refill recycling.  The moment a prompt has G *completed*
    rollouts, its remaining requests are cancelled (queued ones never start,
    in-flight ones retire at the next sync) — over-provisioning then costs
    only the tokens actually generated, not G' full budgets.

    Requests are submitted group-wise (``submit_group`` per prompt): on the
    dense arena that is plain FIFO submission, on the paged arena
    (``paged=True``, DESIGN.md §8) each group's prompt KV is prefilled once
    into refcounted shared pages across all G' siblings.

    ``budgets`` overrides the per-row decode budget (row r = prompt r//G',
    rollout r%G'), the hook length-curricula and the overlap benchmark's
    straggler mixes use; default is ``max_new_tokens`` everywhere.
    """
    from repro.rl.engine import (
        ContinuousRolloutEngine, EngineConfig, PagedEngineConfig,
        PagedRolloutEngine, Request,
    )

    p, tp = prompt_tokens.shape
    g = rcfg.group_size
    gp = int(np.ceil(g * rcfg.overprovision))
    if engine is None:
        if paged:
            # default slot count must cover one full G' group: configs
            # with per-slot sequence state place groups atomically
            engine = PagedRolloutEngine(
                cfg, rcfg, PagedEngineConfig(
                    num_slots=num_slots or max(p * g, gp),
                    max_prompt_len=tp,
                    steps_per_sync=steps_per_sync, page_len=page_len,
                    num_pages=num_pages, max_group=gp))
        else:
            engine = ContinuousRolloutEngine(
                cfg, rcfg, EngineConfig(num_slots=num_slots or p * g,
                                        max_prompt_len=tp,
                                        steps_per_sync=steps_per_sync))
    requests = [
        Request(uid=i * gp + j,
                tokens=np.asarray(prompt_tokens[i, :int(prompt_lens[i])]),
                budget=(int(budgets[i * gp + j]) if budgets is not None
                        else rcfg.max_new_tokens))
        for i in range(p) for j in range(gp)]

    n_completed = np.zeros((p,), np.int32)
    finished: set = set()

    def on_finish(c):
        finished.add(c.uid)
        pi = c.uid // gp
        if not c.completed:
            return None
        n_completed[pi] += 1
        if cancel_on_quota and n_completed[pi] == g:
            return [pi * gp + j for j in range(gp)
                    if pi * gp + j not in finished]
        return None

    # group-wise submission so the paged arena can share prompts; the
    # dense arena sees the same FIFO request order as before
    comps = engine.run_groups(
        params, [requests[i * gp:(i + 1) * gp] for i in range(p)], key,
        on_finish=on_finish)

    stats = dict(engine.stats)
    stats["tokens_budget"] = (int(budgets.sum()) if budgets is not None
                              else int(p * gp * rcfg.max_new_tokens))
    return batch_from_completions(comps, prompt_tokens, prompt_lens, rcfg,
                                  p, g, gp, stats)
