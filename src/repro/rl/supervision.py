"""Replica supervision for disaggregated fleets (DESIGN.md §13).

A fleet actor can fail three ways: its thread **dies** (a rollout raised),
it **hangs** (alive but making no progress — a wedged device call, an
injected stall), or its work is **transiently refused** (publication
failure, page-pool pressure).  Before this layer any of them killed the
whole run: a dead producer left a reserved index in the ``SampleQueue``
that ``pop`` waits on forever, and a failed publication escalated
instantly.

The ``ReplicaSupervisor`` turns replica failure into bounded, *token-exact*
recovery:

* every actor heartbeats (``heartbeat``) around its claim/roll/deposit
  loop, and registers an engine **progress watermark** (completed drive
  rounds) so a long-but-advancing rollout is never mistaken for a hang;
* a monitor thread detects death (thread no longer alive) and hangs
  (claimed group + heartbeat/progress stale past ``hang_timeout``) and
  responds identically: the victim's claimed-but-undelivered group index
  is pushed onto a **reclaim heap**, its queue watermark is removed, and
  surviving actors are woken.  A survivor takes the reclaimed index
  *before* claiming fresh work and re-derives its exact keys from the
  shared ``KeyChain`` — same index, same keys, same tokens, so recovery
  is invisible in the sample stream (the kill-one-replica test pins
  per-group token equality against the no-fault oracle);
* the reclaimed index keeps its original queue **reservation** — the
  learner keeps holding younger groups for the gap, and the survivor's
  deposit is exempt from the capacity wait, exactly as if the first
  claimer had delivered.  A condemned-but-alive replica that later wakes
  and deposits the same index is dropped as a duplicate by the queue
  (at-most-once per group, ``dropped_dup``);
* when the last replica is gone the supervisor fails the queue with a
  structured ``SupervisorError`` naming every replica's fate — the
  learner's next ``pop`` raises it instead of timing out.

``RetryPolicy``/``retry_call`` implement the bounded-backoff contract the
tentpole demands for transient faults: never a silent spin, never an
unbounded wait — attempts are counted and the final failure escalates
with the original exception chained.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple


class SupervisorError(RuntimeError):
    """A clean, structured supervision failure: the run cannot continue
    (e.g. every replica is dead) and this names who failed and how."""

    def __init__(self, msg: str, statuses: Optional[List["ReplicaStatus"]]
                 = None):
        super().__init__(msg)
        self.statuses = statuses or []


class QuiesceTimeout(TimeoutError):
    """A quiesce/join deadline expired; the message names each replica,
    its claimed group, its watermark, and its last heartbeat age."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff (never a silent spin)."""

    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0


def retry_call(fn: Callable, policy: RetryPolicy,
               retryable: Tuple[type, ...],
               on_retry: Optional[Callable[[int, BaseException], None]]
               = None):
    """Call ``fn`` with up to ``policy.max_attempts`` attempts; only
    ``retryable`` exceptions are retried, anything else escalates
    immediately.  ``on_retry(attempt, exc)`` fires before each backoff
    sleep (counters, logging).  The final failure re-raises the last
    exception — bounded attempts, then escalate."""
    attempts = max(1, int(policy.max_attempts))
    for attempt in range(1, attempts + 1):
        try:
            return fn()
        except retryable as e:
            if attempt >= attempts:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(policy.backoff_s * policy.backoff_mult
                       ** (attempt - 1))


@dataclasses.dataclass
class ReplicaStatus:
    """Point-in-time snapshot of one replica, for structured errors."""

    name: str
    alive: bool
    dead: bool
    condemned: bool
    claimed: Optional[int]
    watermark: Optional[int]
    heartbeat_age: float
    error: Optional[BaseException] = None

    def describe(self) -> str:
        state = ("dead" if self.dead else
                 "condemned" if self.condemned else
                 "alive" if self.alive else "not-started")
        s = (f"{self.name}: state={state} claimed={self.claimed} "
             f"watermark={self.watermark} "
             f"heartbeat_age={self.heartbeat_age:.1f}s")
        if self.error is not None:
            s += f" error={type(self.error).__name__}: {self.error}"
        return s


class _Replica:
    __slots__ = ("name", "thread", "progress_fn", "hb", "last_activity",
                 "last_progress", "claimed", "dead", "condemned", "error")

    def __init__(self, name, thread, progress_fn, now):
        self.name = name
        self.thread = thread
        self.progress_fn = progress_fn
        self.hb = now               # last explicit heartbeat
        self.last_activity = now    # hb or progress-watermark advance
        self.last_progress = None
        self.claimed: Optional[int] = None
        self.dead = False
        self.condemned = False
        self.error: Optional[BaseException] = None


class ReplicaSupervisor:
    """Heartbeat monitor + token-exact group reclaim for a replica fleet.

    The supervisor's lock is a *leaf*: actors may call every method here
    while holding the trainer's condition variable, and the monitor thread
    only ever takes the trainer lock through ``wake`` (invoked outside the
    supervisor lock), so no cycle exists.
    """

    def __init__(self, queue, *, hang_timeout: float = 300.0,
                 interval: float = 0.2,
                 wake: Optional[Callable[[], None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._queue = queue
        self.hang_timeout = float(hang_timeout)
        self.interval = float(interval)
        self._wake = wake or (lambda: None)
        self._clock = clock
        self._lock = threading.Lock()
        self._replicas: Dict[str, _Replica] = {}
        self._reclaim: List[int] = []      # min-heap of orphaned indices
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.stats: Dict[str, int] = {
            "replicas_failed": 0,       # threads that died
            "replicas_condemned": 0,    # hangs detected (thread still alive)
            "groups_reclaimed": 0,      # orphaned indices handed to survivors
            "joins": 0,                 # replicas added mid-run
        }

    # --------------------------------------------------------- registration
    def register(self, name: str, thread=None, progress=None,
                 joined: bool = False) -> None:
        """Track a replica.  ``progress`` is a nullary callable returning a
        monotonically increasing work counter (engine drive rounds);
        ``joined=True`` counts it as a mid-run elastic join."""
        with self._lock:
            self._replicas[name] = _Replica(name, thread, progress,
                                            self._clock())
            if joined:
                self.stats["joins"] += 1

    # --------------------------------------- actor-side protocol (leaf-safe)
    def heartbeat(self, name: str) -> None:
        r = self._replicas.get(name)
        if r is not None:
            r.hb = self._clock()

    def claim(self, name: str, index: int) -> None:
        with self._lock:
            r = self._replicas[name]
            r.claimed = index
            r.hb = self._clock()

    def delivered(self, name: str, index: int) -> None:
        with self._lock:
            r = self._replicas.get(name)
            if r is not None and r.claimed == index:
                r.claimed = None

    def should_stop(self, name: str) -> bool:
        """A condemned/dead replica's loop must exit instead of claiming
        more work (its late in-flight deposit is still accepted-or-deduped
        by the queue)."""
        r = self._replicas.get(name)
        return r is None or r.dead or r.condemned

    def report_failure(self, name: str, exc: BaseException) -> None:
        """An actor thread died with ``exc``: reclaim its claimed group,
        drop its ghost watermark, wake survivors — or fail the queue with
        a structured error if it was the last one standing."""
        self._retire(name, exc, dead=True)

    # ----------------------------------------------------- reclaim protocol
    def take_reclaim(self, name: str) -> Optional[int]:
        """Pop the oldest orphaned group index and atomically assign it to
        ``name`` (so a crash while re-rolling re-reclaims it).  ``None``
        when nothing is orphaned."""
        with self._lock:
            if not self._reclaim:
                return None
            i = heapq.heappop(self._reclaim)
            r = self._replicas.get(name)
            if r is not None:
                r.claimed = i
                r.hb = self._clock()
            return i

    def reclaim_pending(self) -> bool:
        return bool(self._reclaim)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._monitor, daemon=True,
                                        name="nat-supervisor")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # ------------------------------------------------------------- monitor
    def _monitor(self) -> None:
        while not self._stop.wait(self.interval):
            dead, hung = [], []
            now = self._clock()
            with self._lock:
                for r in self._replicas.values():
                    if r.dead or r.condemned:
                        continue
                    # ident is None until the thread actually starts:
                    # replicas are registered before start() (so their
                    # first heartbeat/claim always finds them), and a
                    # not-yet-started thread is not a dead one
                    if (r.thread is not None and r.thread.ident is not None
                            and not r.thread.is_alive()):
                        dead.append(r.name)
                        continue
                    if r.progress_fn is not None:
                        try:
                            p = r.progress_fn()
                        except Exception:
                            p = r.last_progress
                        if p != r.last_progress:
                            r.last_progress = p
                            r.last_activity = now
                    last = max(r.hb, r.last_activity)
                    if (r.claimed is not None
                            and now - last > self.hang_timeout):
                        hung.append(r.name)
            for name in dead:
                self._retire(name, SupervisorError(
                    f"replica {name!r} thread exited without reporting"),
                    dead=True)
            for name in hung:
                self._retire(name, SupervisorError(
                    f"replica {name!r} hung: claimed a group but neither "
                    f"heartbeat nor engine progress advanced within "
                    f"{self.hang_timeout:.1f}s"), dead=False)

    def _retire(self, name: str, exc: BaseException, *, dead: bool) -> None:
        """Common death/condemnation path: reclaim, de-watermark, wake."""
        fail_all: Optional[SupervisorError] = None
        with self._lock:
            r = self._replicas.get(name)
            if r is None or r.dead or r.condemned:
                return  # already handled (e.g. condemned, then died)
            if dead:
                r.dead = True
                self.stats["replicas_failed"] += 1
            else:
                r.condemned = True
                self.stats["replicas_condemned"] += 1
            r.error = exc
            if r.claimed is not None:
                heapq.heappush(self._reclaim, r.claimed)
                self.stats["groups_reclaimed"] += 1
                r.claimed = None
            if all(x.dead or x.condemned for x in self._replicas.values()):
                fail_all = SupervisorError(
                    "all fleet replicas are dead or condemned:\n  "
                    + "\n  ".join(s.describe() for s in self._status()),
                    self._status())
        # callbacks outside the leaf lock
        self._queue.remove_producer(name)
        if fail_all is not None:
            self._queue.fail(fail_all)
        self._wake()

    # --------------------------------------------------------------- status
    def _status(self) -> List[ReplicaStatus]:
        """Caller holds the lock."""
        now = self._clock()
        out = []
        for r in self._replicas.values():
            out.append(ReplicaStatus(
                name=r.name,
                alive=bool(r.thread is not None and r.thread.is_alive()),
                dead=r.dead, condemned=r.condemned, claimed=r.claimed,
                watermark=self._queue.watermarks.get(r.name),
                heartbeat_age=now - max(r.hb, r.last_activity),
                error=r.error))
        return out

    def status(self) -> List[ReplicaStatus]:
        with self._lock:
            return self._status()

    def describe(self) -> str:
        return "; ".join(s.describe() for s in self.status())

    def all_dead(self) -> bool:
        with self._lock:
            return bool(self._replicas) and all(
                r.dead or r.condemned for r in self._replicas.values())
