"""Disaggregated actor/learner: replicated rollout fleets over mesh
slices, device-to-device weight publication (DESIGN.md §12), and
chaos-hardened supervision with token-exact failure recovery
(DESIGN.md §13).

``AsyncNATGRPOTrainer`` (PR 3) overlaps one rollout engine with one
learner in a single process; the weight "publication" is an in-process
reference swap and every flop shares one device set.  This trainer scales
the same bounded-staleness design out across a carved topology
(``dist/placement.py``):

* the **learner** keeps the sharded train step on its own slice,
* **N fleet replicas** each own a slice-pinned rollout engine and an actor
  thread, all pulling prompts from the shared deterministic pipeline by
  index and depositing into one multi-producer ``SampleQueue`` that
  reassembles the serial index order (reservations mark in-flight gaps),
* **publication** reshards the learner params straight onto every fleet
  slice with ``jax.device_put`` (``dist/publish.py``) — zero bytes through
  the host, one epoch per learner version, so the staleness contract is
  unchanged: a group rolled from epoch ``e`` params has
  ``behavior_version == e``,
* with ``disagg="prefill,decode"`` each fleet slice further splits into a
  prefill cell and a paged decode arena
  (``rl/engine.py::DisaggPagedRolloutEngine``), handing groups off by
  block table through the page pool,
* a **ReplicaSupervisor** (``rl/supervision.py``) heartbeats every actor,
  reclaims a dead/hung replica's claimed group index for a survivor to
  re-roll token-exactly off the shared ``KeyChain``, and admits replicas
  *joining* mid-run (``add_replica``: a fresh slice-pinned engine
  receiving the current publication epoch, claiming from the next clean
  group boundary).

Determinism contract: group ``i``'s rollout keys come from the shared
``KeyChain`` — the exact splits the serial walk produces — and the queue
serves groups in index order, so a fleet of 1 at staleness 0 is
**bit-exact** against ``NATGRPOTrainer``, and any fleet's group ``i`` is
token-exact against a single-engine oracle rolling the same index under
the same params (``tests/test_dist_trainer.py``).  What a fleet of N
changes is only *which version's params* a group sees within the
staleness bound — the same freedom PR 3's single actor already had.
The same property is what makes failure recovery deterministic: a
reclaimed index re-derives the dead claimer's exact keys, so a fleet of
2 with one killed replica produces the same per-group tokens as the
no-fault fleet (``tests/test_supervision.py``).
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Optional

import jax

from repro.dist import SliceTopology, WeightPublisher, carve
from repro.models import capabilities as caps
from repro.models.config import ModelConfig
from repro.rl.async_trainer import (
    AsyncNATGRPOTrainer, KeyChain, NATTrainerConfig, TaggedGroup,
)
from repro.rl.learner import with_publication
from repro.rl.rollout import rollout_group_continuous
from repro.rl.supervision import (
    QuiesceTimeout, ReplicaSupervisor, RetryPolicy, SupervisorError,
    retry_call,
)


def _parse_disagg(spec: str) -> bool:
    if not spec:
        return False
    roles = {r.strip() for r in spec.split(",") if r.strip()}
    if roles != {"prefill", "decode"}:
        raise ValueError(
            f"disagg must be '' or 'prefill,decode', got {spec!r}")
    return True


@dataclass
class FleetReplica:
    """One fleet member's runtime record — replicas are dynamic now
    (supervised death, elastic join), so the roster lives here rather
    than being read off the static topology."""

    name: str
    engine: object
    device: object
    prefill_device: object = None
    idle: threading.Event = field(default_factory=threading.Event)
    thread: Optional[threading.Thread] = None


class DistNATGRPOTrainer(AsyncNATGRPOTrainer):
    """Fleet-replicated, slice-placed NAT-GRPO trainer.

    ``devices`` (default ``jax.devices()``) is carved into a learner slice
    plus ``tcfg.fleet`` rollout slices; on a single-device host every
    slice degenerates to that device and only the placement collapses —
    the orchestration (fleet threads, ordered reassembly, publication
    epochs) runs identically, which is what the parity tests pin.
    """

    def __init__(self, model_cfg: ModelConfig, tcfg: NATTrainerConfig,
                 params=None, mesh=None, rules=None, budget_fn=None,
                 devices=None, chaos=None):
        fleet = max(1, int(tcfg.fleet))
        disagg = _parse_disagg(tcfg.disagg)
        if disagg:
            if tcfg.rollout_engine != "paged":
                raise ValueError(
                    "disagg='prefill,decode' requires rollout_engine="
                    f"'paged' (got {tcfg.rollout_engine!r}): the handoff "
                    "contract is the paged pool's block tables")
            caps.check_slice_handoff(model_cfg)
        super().__init__(model_cfg, tcfg, params=params, mesh=mesh,
                         rules=rules, budget_fn=budget_fn, chaos=chaos)
        if self.engine is None:
            raise ValueError(
                "the disaggregated trainer needs a rollout engine "
                f"(rollout_engine={tcfg.rollout_engine!r} resolved to the "
                "legacy scan — no arena to pin to a slice)")

        self._disagg = disagg
        self.topology: SliceTopology = carve(devices, fleet=fleet,
                                             disagg=disagg)
        # one slice-pinned replica per fleet; replica 0 doubles as
        # self.engine so the parent's inline staleness-0 path (and its
        # introspection) runs on a fleet slice, not a detached engine
        self._replicas: list[FleetReplica] = []
        for fs in self.topology.fleets:
            eng = self._build_engine(
                device=fs.decode[0],
                prefill_device=fs.prefill[0] if disagg else None)
            eng.chaos = chaos
            eng.chaos_replica = fs.name
            self._replicas.append(FleetReplica(
                name=fs.name, engine=eng, device=fs.decode[0],
                prefill_device=fs.prefill[0] if disagg else None))
        self._replica_serial = len(self._replicas)  # next join's number
        self.fleet_engines = [r.engine for r in self._replicas]
        self.engine = self.fleet_engines[0]

        # device-to-device publication: one replicated target per fleet
        # slice, epochs mapped 1:1 onto learner versions (epoch 0 = init).
        # The train step itself carries the publication hook, so the
        # snapshot dispatch overlaps the metrics fetch that follows it;
        # _publish() then just swaps the version-tagged references.
        # Transient publication failures retry with bounded backoff
        # (DESIGN.md §13) before escalating as PublicationError.
        self.publisher = WeightPublisher(
            {r.name: r.device for r in self._replicas},
            max_attempts=max(1, tcfg.publish_retries),
            backoff_s=tcfg.publish_backoff)
        self.publisher.chaos = chaos
        self._train_step = with_publication(self._train_step, self.publisher)
        pub = self.publisher.publish(self.params, epoch=0)
        self._published_f = {name: (tree, 0) for name, tree in pub.items()}
        self._published = (pub[self._replicas[0].name], 0)

        # shared serial key chain: whichever replica claims group i gets
        # the exact keys the serial walk would have produced for it
        self._key_chain = KeyChain(self._actor_key, self._next_group)
        self._fleet_threads: list = []
        self._placement_retries = 0

        # supervision (DESIGN.md §13): heartbeat monitor + reclaim heap.
        # The supervisor lock is a leaf under self._cv, and its wake
        # callback runs outside that lock — see supervision.py.
        self.supervisor: Optional[ReplicaSupervisor] = None
        if tcfg.supervise:
            self.supervisor = ReplicaSupervisor(
                self.queue, hang_timeout=tcfg.hang_timeout,
                interval=tcfg.supervise_interval, wake=self._wake_actors)

    def _wake_actors(self) -> None:
        with self._cv:
            self._cv.notify_all()

    # ------------------------------------------------------------- actor side
    def _ensure_actor(self) -> None:
        if self.tcfg.max_staleness == 0:
            return  # inline production on fleet slice 0, no threads
        if self._fleet_threads:
            # already launched: replica lifecycle now belongs to the
            # supervisor (dead replicas are not silently resurrected —
            # use add_replica to restore capacity)
            return
        self._stop_evt.clear()
        for rep in self._replicas:
            self._spawn_replica_thread(rep)
        self._actor = self._fleet_threads[0]  # parent lifecycle hooks
        if self.supervisor is not None:
            self.supervisor.start()

    def _spawn_replica_thread(self, rep: FleetReplica,
                              joined: bool = False) -> None:
        t = threading.Thread(target=self._fleet_main, args=(rep,),
                             daemon=True, name=f"nat-actor-{rep.name}")
        rep.thread = t
        self._fleet_threads.append(t)
        if self.supervisor is not None:
            eng = rep.engine
            self.supervisor.register(
                rep.name, thread=t, joined=joined,
                # progress watermark: completed drive rounds + decode
                # steps — a long-but-advancing rollout is not a hang
                progress=lambda e=eng: (int(e.stats.get("rounds", 0)),
                                        int(e.stats.get("decode_steps", 0))))
        t.start()

    def _fleet_main(self, rep: FleetReplica) -> None:
        """Replica thread entry: route failures to the supervisor (which
        reclaims the claimed group and keeps the run alive) — or, when
        supervision is off, poison the queue like the PR 3 single actor."""
        try:
            self._actor_fleet(rep)
        except BaseException as e:
            if self.supervisor is not None:
                self.supervisor.report_failure(rep.name, e)
            else:
                self.queue.fail(e)

    def _actor_fleet(self, rep: FleetReplica) -> None:
        """One fleet replica's loop: claim the next group index under the
        staleness gate (taking any reclaimed orphan index first), roll it
        on this replica's slice under the newest published snapshot,
        deposit in index order (per-group sessions — the chain keys make
        every group independently reproducible)."""
        sup = self.supervisor
        name, engine, idle = rep.name, rep.engine, rep.idle
        while not self._stop_evt.is_set():
            with self._cv:
                # wait while there is nothing to do: no orphaned index to
                # reclaim (reclaims proceed even when paused — they are
                # already-admitted work a quiesce must drain) and either
                # admission is paused or the staleness gate is shut
                while (not self._stop_evt.is_set()
                       and not (sup is not None
                                and (sup.should_stop(name)
                                     or sup.reclaim_pending()))
                       and (self._paused
                            or not self._gate_open(self._next_group))):
                    idle.set()
                    if sup is not None:
                        sup.heartbeat(name)
                    self._cv.wait(0.05)
                if self._stop_evt.is_set():
                    return
                if sup is not None and sup.should_stop(name):
                    idle.set()
                    return
                idle.clear()
                i = sup.take_reclaim(name) if sup is not None else None
                if i is None and (self._paused or not
                                  self._gate_open(self._next_group)):
                    continue  # lost a reclaim race; re-enter the wait
                if i is None:
                    i = self._next_group
                    pb = self.pipeline.batch_at(i)
                    self.pipeline.step = max(self.pipeline.step, i + 1)
                    self._next_group = i + 1
                    # keep the parent's checkpoint cursor honest:
                    # _actor_key is always the chain state before the next
                    # unclaimed group
                    self._actor_key = self._key_chain.state_before(i + 1)
                    # claim the queue slot inside the lock: pop must know
                    # this index is in flight before any younger deposit
                    # can land.  The gate bounds outstanding groups to
                    # <= capacity, so this never blocks; the timeout
                    # surfaces contract bugs.
                    self.queue.reserve(i, timeout=600.0)
                    if sup is not None:
                        sup.claim(name, i)
                else:
                    # reclaimed orphan: its reservation survived its dead
                    # claimer (pop is still holding younger groups for
                    # it), and the pipeline/key cursors already passed it
                    pb = self.pipeline.batch_at(i)
                key0, k_roll, k_sel = self._key_chain.keys_for(i)
                params, version = self._published_f[name]
            if sup is not None:
                sup.heartbeat(name)
            if self.chaos is not None:
                # injected death/stall lands after the claim, while the
                # reservation is live — the exact window reclaim covers
                self.chaos.fire("actor", replica=name, index=i)
            t0 = time.perf_counter()
            try:
                rb = self._roll_group(engine, params, pb, k_roll, i)
            except BaseException:
                if sup is None:
                    self.queue.cancel(i)  # unblock pop before fail() lands
                # supervised: keep the reservation — report_failure will
                # push i onto the reclaim heap and a survivor adopts it
                raise
            self.queue.put(
                TaggedGroup(index=i, behavior_version=version, batch=rb,
                            prompt_batch=pb, key_sel=k_sel,
                            t_rollout=time.perf_counter() - t0, key0=key0),
                producer=name)
            if sup is not None:
                sup.delivered(name, i)
                sup.heartbeat(name)

    def _roll_group(self, engine, params, pb, k_roll, i: int):
        """Roll group ``i`` on ``engine`` — split out so chaos/property
        tests can substitute a deterministic fake roll.  Transient
        ``PagePoolExhausted`` (pool pressure from a draining previous
        session, or injected) is retried with bounded backoff on a fresh
        per-group session; persistent exhaustion escalates after
        ``tcfg.placement_retries`` attempts — never a silent spin."""
        from repro.rl.engine import PagePoolExhausted

        def roll():
            return rollout_group_continuous(
                params, self.model_cfg, self.tcfg.rollout,
                pb.tokens, pb.prompt_lens, k_roll, engine=engine,
                budgets=self._budgets_for(i))

        def on_retry(attempt, exc):
            self._placement_retries += 1

        return retry_call(
            roll,
            RetryPolicy(max_attempts=max(1, self.tcfg.placement_retries),
                        backoff_s=self.tcfg.placement_backoff),
            (PagePoolExhausted,), on_retry)

    # ----------------------------------------------------------- elasticity
    def add_replica(self, *, name: Optional[str] = None, device=None,
                    prefill_device=None) -> str:
        """Join a fresh replica mid-run (fleet elasticity, DESIGN.md §13).

        The handshake: build a slice-pinned engine (device defaults to
        round-robin over the carved fleet slices — i.e. a replacement
        lands on the dead replica's slice), register it as a publication
        target and push it the *current* epoch's params, add it to the
        published map and the roster, then start its actor thread.  All
        under the trainer lock, so the newcomer's first claim is the next
        clean group boundary — it can never see a group the fleet already
        claimed, and its first deposit carries the current epoch's
        ``behavior_version``.  Call between train steps (learner thread).
        """
        with self._cv:
            n = self._replica_serial
            self._replica_serial += 1
            fs = self.topology.fleets[n % self.topology.num_fleets]
            if name is None:
                name = f"fleet{n}"
            if any(r.name == name for r in self._replicas):
                raise ValueError(f"replica {name!r} already exists")
            dev = device if device is not None else fs.decode[0]
            pdev = (prefill_device if prefill_device is not None
                    else (fs.prefill[0] if self._disagg else None))
            eng = self._build_engine(device=dev, prefill_device=pdev)
            eng.chaos = self.chaos
            eng.chaos_replica = name
            tree = self.publisher.add_target(
                name, dev, params=self.params, epoch=self._learner_version)
            self._published_f[name] = (tree, self._learner_version)
            rep = FleetReplica(name=name, engine=eng, device=dev,
                               prefill_device=pdev)
            self._replicas.append(rep)
            self.fleet_engines.append(eng)
            started = bool(self._fleet_threads)
        if started:
            self._spawn_replica_thread(rep, joined=True)
        return name

    # ----------------------------------------------------------- learner side
    def _publish(self) -> None:
        with self._cv:
            self._learner_version += 1
            pub = {}
            for rep in self._replicas:
                tree, epoch = self.publisher.latest(rep.name)
                if epoch != self._learner_version:
                    raise RuntimeError(
                        f"publication epoch {epoch} != learner version "
                        f"{self._learner_version}: the train step's "
                        "with_publication hook is out of sync")
                pub[rep.name] = tree
            self._published_f = {name: (tree, self._learner_version)
                                 for name, tree in pub.items()}
            self._published = (pub[self._replicas[0].name],
                               self._learner_version)
            self._cv.notify_all()

    # --------------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self.supervisor is not None:
            self.supervisor.stop()
        super().close()  # joins thread 0 via self._actor
        stuck = []
        for rep in self._replicas:
            if rep.thread is None:
                continue
            rep.thread.join(timeout=10.0)
            if rep.thread.is_alive():
                stuck.append(rep.name)
        self._fleet_threads = []
        if stuck:
            # close() must not raise, but the operator needs to know who
            # wedged and in what state — the structured report names each
            # replica's claimed group, watermark, and heartbeat age
            warnings.warn(
                "close(): fleet threads failed to join within 10.0s — "
                + self._replica_report(stuck), RuntimeWarning,
                stacklevel=2)

    def _replica_report(self, names=None) -> str:
        """One structured line per replica: identity, liveness, claimed
        group, queue watermark, heartbeat age — the error payload for
        quiesce/join timeouts (DESIGN.md §13)."""
        sup_status = {}
        if self.supervisor is not None:
            sup_status = {s.name: s for s in self.supervisor.status()}
        lines = []
        for rep in self._replicas:
            if names is not None and rep.name not in names:
                continue
            s = sup_status.get(rep.name)
            alive = rep.thread.is_alive() if rep.thread is not None else False
            hb = f"{s.heartbeat_age:.1f}s" if s is not None else "n/a"
            claimed = s.claimed if s is not None else None
            state = ("dead" if s is not None and s.dead else
                     "condemned" if s is not None and s.condemned else
                     "alive" if alive else "not-started")
            lines.append(
                f"{rep.name}: state={state} idle={rep.idle.is_set()} "
                f"claimed={claimed} "
                f"watermark={self.queue.watermarks.get(rep.name)} "
                f"heartbeat_age={hb}")
        return "; ".join(lines)

    def _quiesce(self, timeout: float = 300.0) -> None:
        with self._cv:
            self._paused = True
            self._cv.notify_all()
        deadline = time.monotonic() + timeout
        while True:
            # checked before the settled test: a fleet whose every thread
            # already exited would otherwise "settle" trivially and let a
            # checkpoint save proceed over a failed run
            if self.supervisor is not None and self.supervisor.all_dead():
                raise SupervisorError(
                    "cannot quiesce: every fleet replica is dead or "
                    "condemned — " + self._replica_report(),
                    self.supervisor.status())
            settled = all(rep.idle.is_set()
                          or rep.thread is None
                          or not rep.thread.is_alive()
                          for rep in self._replicas)
            if settled and self.queue.inflight() == 0:
                return
            if time.monotonic() > deadline:
                raise QuiesceTimeout(
                    f"fleet actors failed to quiesce within {timeout:.0f}s"
                    f" — " + self._replica_report())
            time.sleep(0.005)

    # -------------------------------------------------------------- checkpoint
    def restore_checkpoint(self, mgr, step: Optional[int] = None) -> dict:
        extra = super().restore_checkpoint(mgr, step)
        # re-seed the chain at the restored cursor and re-publish the
        # restored params as the current epoch on every fleet slice
        self._key_chain = KeyChain(self._actor_key, self._next_group)
        pub = self.publisher.publish(self.params,
                                     epoch=self._learner_version)
        self._published_f = {name: (tree, self._learner_version)
                             for name, tree in pub.items()}
        self._published = (pub[self._replicas[0].name],
                           self._learner_version)
        return extra

    # ------------------------------------------------------------------ stats
    def publication_stats(self) -> dict:
        """Publisher counters + per-replica version watermarks — the
        zero-host-bytes gate reads ``host_bytes`` from here, the recovery
        gates read ``publish_retries``/``groups_reclaimed``."""
        stats = dict(self.publisher.stats)
        stats["watermarks"] = dict(self.queue.watermarks)
        stats["dropped_dup"] = int(self.queue.dropped_dup)
        stats["placement_retries"] = int(self._placement_retries)
        if self.supervisor is not None:
            stats["supervisor"] = dict(self.supervisor.stats)
        if hasattr(self.engine, "stats"):
            stats["handoffs"] = int(self.engine.stats.get("handoffs", 0))
            stats["handoff_bytes"] = int(
                self.engine.stats.get("handoff_bytes", 0))
        return stats


def make_dist_trainer(model_cfg: ModelConfig, tcfg: NATTrainerConfig,
                      **kw) -> AsyncNATGRPOTrainer:
    """Config-dispatched constructor: fleet/disagg set -> the dist trainer,
    otherwise the plain async trainer (what ``launch/train.py`` calls)."""
    if tcfg.fleet or tcfg.disagg:
        return DistNATGRPOTrainer(model_cfg, tcfg, **kw)
    return AsyncNATGRPOTrainer(model_cfg, tcfg, **kw)
