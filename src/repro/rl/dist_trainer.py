"""Disaggregated actor/learner: replicated rollout fleets over mesh
slices, device-to-device weight publication (DESIGN.md §12).

``AsyncNATGRPOTrainer`` (PR 3) overlaps one rollout engine with one
learner in a single process; the weight "publication" is an in-process
reference swap and every flop shares one device set.  This trainer scales
the same bounded-staleness design out across a carved topology
(``dist/placement.py``):

* the **learner** keeps the sharded train step on its own slice,
* **N fleet replicas** each own a slice-pinned rollout engine and an actor
  thread, all pulling prompts from the shared deterministic pipeline by
  index and depositing into one multi-producer ``SampleQueue`` that
  reassembles the serial index order (reservations mark in-flight gaps),
* **publication** reshards the learner params straight onto every fleet
  slice with ``jax.device_put`` (``dist/publish.py``) — zero bytes through
  the host, one epoch per learner version, so the staleness contract is
  unchanged: a group rolled from epoch ``e`` params has
  ``behavior_version == e``,
* with ``disagg="prefill,decode"`` each fleet slice further splits into a
  prefill cell and a paged decode arena
  (``rl/engine.py::DisaggPagedRolloutEngine``), handing groups off by
  block table through the page pool.

Determinism contract: group ``i``'s rollout keys come from the shared
``KeyChain`` — the exact splits the serial walk produces — and the queue
serves groups in index order, so a fleet of 1 at staleness 0 is
**bit-exact** against ``NATGRPOTrainer``, and any fleet's group ``i`` is
token-exact against a single-engine oracle rolling the same index under
the same params (``tests/test_dist_trainer.py``).  What a fleet of N
changes is only *which version's params* a group sees within the
staleness bound — the same freedom PR 3's single actor already had.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import jax

from repro.dist import SliceTopology, WeightPublisher, carve
from repro.models import capabilities as caps
from repro.models.config import ModelConfig
from repro.rl.async_trainer import (
    AsyncNATGRPOTrainer, KeyChain, NATTrainerConfig, TaggedGroup,
)
from repro.rl.learner import with_publication
from repro.rl.rollout import rollout_group_continuous


def _parse_disagg(spec: str) -> bool:
    if not spec:
        return False
    roles = {r.strip() for r in spec.split(",") if r.strip()}
    if roles != {"prefill", "decode"}:
        raise ValueError(
            f"disagg must be '' or 'prefill,decode', got {spec!r}")
    return True


class DistNATGRPOTrainer(AsyncNATGRPOTrainer):
    """Fleet-replicated, slice-placed NAT-GRPO trainer.

    ``devices`` (default ``jax.devices()``) is carved into a learner slice
    plus ``tcfg.fleet`` rollout slices; on a single-device host every
    slice degenerates to that device and only the placement collapses —
    the orchestration (fleet threads, ordered reassembly, publication
    epochs) runs identically, which is what the parity tests pin.
    """

    def __init__(self, model_cfg: ModelConfig, tcfg: NATTrainerConfig,
                 params=None, mesh=None, rules=None, budget_fn=None,
                 devices=None):
        fleet = max(1, int(tcfg.fleet))
        disagg = _parse_disagg(tcfg.disagg)
        if disagg:
            if tcfg.rollout_engine != "paged":
                raise ValueError(
                    "disagg='prefill,decode' requires rollout_engine="
                    f"'paged' (got {tcfg.rollout_engine!r}): the handoff "
                    "contract is the paged pool's block tables")
            caps.check_slice_handoff(model_cfg)
        super().__init__(model_cfg, tcfg, params=params, mesh=mesh,
                         rules=rules, budget_fn=budget_fn)
        if self.engine is None:
            raise ValueError(
                "the disaggregated trainer needs a rollout engine "
                f"(rollout_engine={tcfg.rollout_engine!r} resolved to the "
                "legacy scan — no arena to pin to a slice)")

        self.topology: SliceTopology = carve(devices, fleet=fleet,
                                             disagg=disagg)
        # one slice-pinned replica per fleet; replica 0 doubles as
        # self.engine so the parent's inline staleness-0 path (and its
        # introspection) runs on a fleet slice, not a detached engine
        self.fleet_engines = [
            self._build_engine(
                device=fs.decode[0],
                prefill_device=fs.prefill[0] if disagg else None)
            for fs in self.topology.fleets
        ]
        self.engine = self.fleet_engines[0]

        # device-to-device publication: one replicated target per fleet
        # slice, epochs mapped 1:1 onto learner versions (epoch 0 = init).
        # The train step itself carries the publication hook, so the
        # snapshot dispatch overlaps the metrics fetch that follows it;
        # _publish() then just swaps the version-tagged references.
        self.publisher = WeightPublisher(
            {fs.name: fs.decode[0] for fs in self.topology.fleets})
        self._train_step = with_publication(self._train_step, self.publisher)
        pub = self.publisher.publish(self.params, epoch=0)
        self._published_f = {name: (tree, 0) for name, tree in pub.items()}
        self._published = (pub[self.topology.fleets[0].name], 0)

        # shared serial key chain: whichever replica claims group i gets
        # the exact keys the serial walk would have produced for it
        self._key_chain = KeyChain(self._actor_key, self._next_group)
        self._fleet_threads: list = []
        self._fleet_idle = [threading.Event()
                            for _ in range(self.topology.num_fleets)]

    # ------------------------------------------------------------- actor side
    def _ensure_actor(self) -> None:
        if self.tcfg.max_staleness == 0:
            return  # inline production on fleet slice 0, no threads
        if self._fleet_threads and all(t.is_alive()
                                       for t in self._fleet_threads):
            return
        self._stop_evt.clear()
        self._fleet_threads = []
        for f, fs in enumerate(self.topology.fleets):
            t = threading.Thread(
                target=self._actor_main,
                args=((lambda f=f: self._actor_fleet(f)),),
                daemon=True, name=f"nat-actor-{fs.name}")
            t.start()
            self._fleet_threads.append(t)
        self._actor = self._fleet_threads[0]  # parent lifecycle hooks

    def _actor_fleet(self, f: int) -> None:
        """One fleet replica's loop: claim the next group index under the
        staleness gate, roll it on this replica's slice under the newest
        published snapshot, deposit in index order (per-group sessions —
        the chain keys make every group independently reproducible)."""
        fs = self.topology.fleets[f]
        engine = self.fleet_engines[f]
        idle = self._fleet_idle[f]
        while not self._stop_evt.is_set():
            with self._cv:
                while (not self._stop_evt.is_set()
                       and (self._paused
                            or not self._gate_open(self._next_group))):
                    idle.set()
                    self._cv.wait(0.05)
                if self._stop_evt.is_set():
                    return
                idle.clear()
                i = self._next_group
                pb = self.pipeline.batch_at(i)
                self.pipeline.step = max(self.pipeline.step, i + 1)
                key0, k_roll, k_sel = self._key_chain.keys_for(i)
                self._next_group = i + 1
                # keep the parent's checkpoint cursor honest: _actor_key
                # is always the chain state before the next unclaimed group
                self._actor_key = self._key_chain.state_before(i + 1)
                params, version = self._published_f[fs.name]
                # claim the queue slot inside the lock: pop must know this
                # index is in flight before any younger deposit can land.
                # The gate bounds outstanding groups to <= capacity, so
                # this never blocks; the timeout surfaces contract bugs.
                self.queue.reserve(i, timeout=600.0)
            t0 = time.perf_counter()
            try:
                rb = rollout_group_continuous(
                    params, self.model_cfg, self.tcfg.rollout,
                    pb.tokens, pb.prompt_lens, k_roll, engine=engine,
                    budgets=self._budgets_for(i))
            except BaseException:
                self.queue.cancel(i)  # unblock pop before fail() lands
                raise
            self.queue.put(
                TaggedGroup(index=i, behavior_version=version, batch=rb,
                            prompt_batch=pb, key_sel=k_sel,
                            t_rollout=time.perf_counter() - t0, key0=key0),
                producer=fs.name)

    # ----------------------------------------------------------- learner side
    def _publish(self) -> None:
        with self._cv:
            self._learner_version += 1
            pub = {}
            for fs in self.topology.fleets:
                tree, epoch = self.publisher.latest(fs.name)
                if epoch != self._learner_version:
                    raise RuntimeError(
                        f"publication epoch {epoch} != learner version "
                        f"{self._learner_version}: the train step's "
                        "with_publication hook is out of sync")
                pub[fs.name] = tree
            self._published_f = {name: (tree, self._learner_version)
                                 for name, tree in pub.items()}
            self._published = (pub[self.topology.fleets[0].name],
                               self._learner_version)
            self._cv.notify_all()

    # --------------------------------------------------------------- lifecycle
    def close(self) -> None:
        super().close()  # joins thread 0 via self._actor
        for t in self._fleet_threads:
            t.join(timeout=10.0)
        self._fleet_threads = []

    def _quiesce(self, timeout: float = 300.0) -> None:
        with self._cv:
            self._paused = True
            self._cv.notify_all()
        alive = [t for t in self._fleet_threads if t.is_alive()]
        if not alive:
            return
        deadline = time.monotonic() + timeout
        while True:
            settled = all(ev.is_set() or not t.is_alive()
                          for t, ev in zip(self._fleet_threads,
                                           self._fleet_idle))
            if settled and self.queue.inflight() == 0:
                return
            if time.monotonic() > deadline:
                raise TimeoutError("fleet actors failed to quiesce")
            time.sleep(0.005)

    # -------------------------------------------------------------- checkpoint
    def restore_checkpoint(self, mgr, step: Optional[int] = None) -> dict:
        extra = super().restore_checkpoint(mgr, step)
        # re-seed the chain at the restored cursor and re-publish the
        # restored params as the current epoch on every fleet slice
        self._key_chain = KeyChain(self._actor_key, self._next_group)
        pub = self.publisher.publish(self.params,
                                     epoch=self._learner_version)
        self._published_f = {name: (tree, self._learner_version)
                             for name, tree in pub.items()}
        self._published = (pub[self.topology.fleets[0].name],
                           self._learner_version)
        return extra

    # ------------------------------------------------------------------ stats
    def publication_stats(self) -> dict:
        """Publisher counters + per-replica version watermarks — the
        zero-host-bytes gate reads ``host_bytes`` from here."""
        stats = dict(self.publisher.stats)
        stats["watermarks"] = dict(self.queue.watermarks)
        if hasattr(self.engine, "stats"):
            stats["handoffs"] = int(self.engine.stats.get("handoffs", 0))
            stats["handoff_bytes"] = int(
                self.engine.stats.get("handoff_bytes", 0))
        return stats


def make_dist_trainer(model_cfg: ModelConfig, tcfg: NATTrainerConfig,
                      **kw) -> AsyncNATGRPOTrainer:
    """Config-dispatched constructor: fleet/disagg set -> the dist trainer,
    otherwise the plain async trainer (what ``launch/train.py`` calls)."""
    if tcfg.fleet or tcfg.disagg:
        return DistNATGRPOTrainer(model_cfg, tcfg, **kw)
    return AsyncNATGRPOTrainer(model_cfg, tcfg, **kw)
