"""Logical-axis sharding: the single place where "what a dimension means"
is mapped to "where it lives on the mesh" (DESIGN.md §5).

Model code never mentions mesh axes.  Parameter declarations, activation
constraints, and cache trees all carry *logical* axis names ("embed",
"heads", "batch", ...); a ``ShardingRules`` table maps each name to a mesh
axis (``"model"``), a tuple of mesh axes (``("pod", "data")``), or ``None``
(replicate).  Resolution is **best-effort**:

* a dimension that is not divisible by its mesh-axis extent replicates
  instead of erroring — small/smoke configs lower on big meshes unchanged;
* tuple rules fall back to the longest prefix whose size product divides
  the dimension (``batch -> ("pod", "data")`` uses only ``"pod"`` when the
  batch covers the pod axis but not pod×data);
* mesh axes missing from the current mesh are dropped (the same rules
  drive the 256-chip single-pod and 512-chip multi-pod layouts);
* each mesh axis is used at most once per spec (first dimension wins).

The result is always a valid ``PartitionSpec`` for the given mesh, for any
shape — property-tested in ``tests/test_sharding.py``.

``mesh`` only needs a ``.shape`` mapping (name -> size), so shape-only
stand-ins work for tests; real entry points pass ``jax.sharding.Mesh``.
With ``mesh=None`` every helper degrades to a no-op/replicated form, so the
CPU trainer and the hermetic test-suite run the exact production code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# A rule maps one logical axis name to a mesh axis, a tuple of mesh axes
# (sharded over their product, major-to-minor), or None (replicated).
Rule = Union[str, Tuple[str, ...], None]


# ------------------------------------------------------------------ rules
@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Immutable logical-axis -> mesh-axis table.

    Stored as a tuple of (name, rule) pairs so instances are hashable and
    usable as jit static arguments.  Unknown names resolve to None
    (replicate) — new logical axes are safe by default.
    """

    rules: Tuple[Tuple[str, Rule], ...] = ()

    def get(self, name: str) -> Rule:
        for n, r in self.rules:
            if n == name:
                return r
        return None

    def override(self, **kw: Rule) -> "ShardingRules":
        """New table with the given names replaced (or appended)."""
        out = [(n, kw.pop(n)) if n in kw else (n, r) for n, r in self.rules]
        out.extend(kw.items())
        return ShardingRules(rules=tuple(out))


DEFAULT_RULES = ShardingRules(rules=(
    # ---- data / activation axes
    ("batch", ("pod", "data")),          # DP/FSDP batch split
    ("act_seq", "model"),                # Megatron SP (gated by cfg.seq_parallel)
    ("kv_seq", None),                    # long-decode override via rules_for()
    ("image_tokens", None),
    # embed-grad scatter accumulator + int8 moment blocks: split over every
    # mesh axis (layers.py _sg_bwd, optim/adamw.py opt_state_shardings)
    ("opt_blocks", ("pod", "data", "model")),
    # ---- structural axes (never sharded)
    ("layers", None),
    ("codebooks", None),
    ("conv", None),
    ("head_dim", None),
    ("ssm_state", None),
    # ---- weight axes: FSDP over "data", TP over "model"
    ("embed", "data"),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("experts", "model"),                # EP shares the TP axis
    ("expert_mlp", "model"),             # active when #experts is indivisible
    ("kv_lora", "model"),
    ("q_lora", "model"),
    ("lru_width", "model"),
    ("ssm_heads", "model"),
))

# Pure FSDP: every device is a data shard; weights split along the embed
# dim over the whole mesh, no tensor parallelism.
_FSDP_RULES = DEFAULT_RULES.override(
    batch=("pod", "data", "model"),
    act_seq=None,
    embed=("data", "model"),
    vocab=None, heads=None, kv_heads=None, mlp=None,
    experts=None, expert_mlp=None, kv_lora=None, q_lora=None,
    lru_width=None, ssm_heads=None,
)

# Pure Megatron TP: weights replicated across the data axes, split over
# "model"; batch stays on the data axes.
_TP_RULES = DEFAULT_RULES.override(embed=None, act_seq=None)

# Megatron sequence parallelism = TP + residual-stream seq split.
_SP_RULES = _TP_RULES.override(act_seq="model")

# Sub-1B hillclimb: replicated weights, every mesh axis is data-parallel.
_SMALL_MODEL_RULES = ShardingRules(rules=(
    ("batch", ("pod", "data", "model")),
    ("opt_blocks", ("pod", "data", "model")),
))

RULE_PROFILES = {
    "default": DEFAULT_RULES,
    "fsdp": _FSDP_RULES,
    "tensor_parallel": _TP_RULES,
    "sequence_parallel": _SP_RULES,
    "small_model": _SMALL_MODEL_RULES,
}


# -------------------------------------------------------------- resolution
def is_axes_tuple(x: Any) -> bool:
    """Pytree leaf predicate for logical-axes tuples (as produced by
    ``models.params.param_specs`` / ``models.model.cache_axes``)."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def best_effort_spec(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                     mesh, rules: ShardingRules = DEFAULT_RULES) -> P:
    """Resolve logical axes to a PartitionSpec that is always valid.

    Per dimension: look up the rule, drop mesh axes absent from ``mesh`` or
    already used by an earlier dimension, then take the longest prefix of
    the remaining axes whose size product divides the dimension.  A single
    surviving axis becomes a bare string entry; none -> replicated.
    """
    assert len(shape) == len(axes), (shape, axes)
    mesh_shape = mesh.shape
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        rule = rules.get(name) if name is not None else None
        if rule is None:
            entries.append(None)
            continue
        cand = (rule,) if isinstance(rule, str) else tuple(rule)
        cand = tuple(a for a in cand if a in mesh_shape and a not in used)
        assign, prod = [], 1
        for a in cand:
            if dim % (prod * mesh_shape[a]) != 0:
                break
            assign.append(a)
            prod *= mesh_shape[a]
        if not assign:
            entries.append(None)
            continue
        used.update(assign)
        entries.append(assign[0] if len(assign) == 1 else tuple(assign))
    return P(*entries)


def logical_to_sharding(shape, axes, mesh,
                        rules: ShardingRules = DEFAULT_RULES):
    """NamedSharding for one array.  ``mesh=None`` -> ``None`` (jit treats
    an unspecified sharding as replicated on the default device), so CPU
    code paths need no special-casing."""
    if mesh is None:
        return None
    return NamedSharding(mesh, best_effort_spec(tuple(shape), tuple(axes),
                                                mesh, rules))


def tree_shardings(abs_tree, axes_tree, mesh,
                   rules: ShardingRules = DEFAULT_RULES):
    """Shardings for a whole pytree of arrays/ShapeDtypeStructs.

    ``axes_tree`` mirrors ``abs_tree`` with logical-axes tuples at the
    leaves (``param_specs`` / ``cache_axes`` output)."""
    return jax.tree.map(
        lambda ax, leaf: logical_to_sharding(leaf.shape, ax, mesh, rules),
        axes_tree, abs_tree, is_leaf=is_axes_tuple)


def shard_constraint(x, axes, mesh=None,
                     rules: ShardingRules = DEFAULT_RULES):
    """``with_sharding_constraint`` through the logical-axis table.

    Model code curries mesh/rules once (``models/model.py _make_shard``)
    and annotates activations by logical name.  Without a mesh this is the
    identity, so the same model code runs unsharded on CPU."""
    if mesh is None:
        return x
    spec = best_effort_spec(tuple(x.shape), tuple(axes), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
