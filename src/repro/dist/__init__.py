"""repro.dist — distributed layout: logical-axis sharding rules and
best-effort PartitionSpec resolution (FSDP / TP / EP / SP profiles).

See DESIGN.md §5 for the design and repro.dist.sharding for the API.
"""
from repro.dist.sharding import (
    DEFAULT_RULES,
    RULE_PROFILES,
    ShardingRules,
    best_effort_spec,
    is_axes_tuple,
    logical_to_sharding,
    shard_constraint,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "RULE_PROFILES",
    "ShardingRules",
    "best_effort_spec",
    "is_axes_tuple",
    "logical_to_sharding",
    "shard_constraint",
    "tree_shardings",
]
