"""repro.dist — distributed layout: logical-axis sharding rules,
best-effort PartitionSpec resolution (FSDP / TP / EP / SP profiles),
slice placement for disaggregated actor/learner topologies, and
device-to-device weight publication.

See DESIGN.md §5 (sharding) and §12 (placement + publication).
"""
from repro.dist.placement import FleetSlice, SliceTopology, carve
from repro.dist.publish import PublicationError, WeightPublisher, tree_bytes
from repro.dist.sharding import (
    DEFAULT_RULES,
    RULE_PROFILES,
    ShardingRules,
    best_effort_spec,
    is_axes_tuple,
    logical_to_sharding,
    shard_constraint,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "RULE_PROFILES",
    "FleetSlice",
    "PublicationError",
    "ShardingRules",
    "SliceTopology",
    "WeightPublisher",
    "best_effort_spec",
    "carve",
    "is_axes_tuple",
    "logical_to_sharding",
    "shard_constraint",
    "tree_bytes",
    "tree_shardings",
]
