"""Device-to-device weight publication (DESIGN.md §12).

The learner's params live sharded on the learner slice; every rollout
fleet wants a replicated snapshot on *its* slice.  The naive path — gather
to host, then feed each engine — serializes the whole parameter tree
through host RAM once per optimizer step and stalls both sides.  This
module reshards instead: one ``jax.device_put`` per fleet target moves the
tree straight between device buffers (ICI/NVLink on real backends, a
buffer copy on CPU), never materializing a host copy.

Epoch protocol: each ``publish`` call stamps a monotonically increasing
``epoch``; ``latest(name)`` returns the newest snapshot for that target.
The trainer maps epochs 1:1 onto learner versions, so the SampleQueue's
staleness contract (version-tagged groups, PR 3) is unchanged — a fleet
actor that picks up ``latest`` at admission produces a group whose
``behavior_version`` is exactly the snapshot's epoch.

"Zero bytes through the host" is asserted two ways:

* **counter-exact** — ``host_bytes`` counts bytes moved via any host
  staging path.  The device_put path never stages, so the counter stays 0
  by construction; the parity test and ``check_gates.py`` ceiling assert
  it stays that way (ABSOLUTE_ONLY: exempt from wall-time noise).
* **belt-and-braces** — publication runs under
  ``jax.transfer_guard_device_to_host("disallow")``.  On CPU the guard is
  inert (host platform "transfers" are aliasing, so nothing fires —
  which is why the counter, not the guard, is the gate), but on real
  backends it turns an accidental host gather into a hard error.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

import jax


def tree_bytes(tree: Any) -> int:
    """Total payload size of a pytree of arrays, in bytes."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        size = getattr(leaf, "size", None)
        dtype = getattr(leaf, "dtype", None)
        if size is not None and dtype is not None:
            total += int(size) * dtype.itemsize
    return total


class WeightPublisher:
    """Reshards learner params onto each rollout slice's replicated layout.

    ``targets`` maps a fleet name to a placement: either a single device
    (the common fully-replicated engine layout) or a ``Sharding``.  The
    publisher is thread-safe — the learner publishes from the train loop
    while fleet actor threads read ``latest`` at group admission.
    """

    def __init__(self, targets: Dict[str, Any]):
        if not targets:
            raise ValueError("WeightPublisher needs at least one target")
        self._targets = dict(targets)
        self._lock = threading.Lock()
        self._latest: Dict[str, Tuple[Any, int]] = {}
        self.stats: Dict[str, int] = {
            "publishes": 0,
            "bytes_published": 0,
            "host_bytes": 0,
            "epoch": 0,
        }

    @property
    def targets(self) -> Dict[str, Any]:
        return dict(self._targets)

    def publish(self, params: Any, *, epoch: Optional[int] = None) -> Dict[str, Any]:
        """Snapshot ``params`` onto every target, device-to-device.

        Returns ``{name: resharded_tree}``.  ``epoch`` defaults to the
        next integer after the last published epoch.
        """
        with self._lock:
            if epoch is None:
                epoch = self.stats["epoch"] + 1
            out: Dict[str, Any] = {}
            nbytes = tree_bytes(params)
            with jax.transfer_guard_device_to_host("disallow"):
                for name, placement in self._targets.items():
                    out[name] = jax.device_put(params, placement)
            for name, tree in out.items():
                self._latest[name] = (tree, epoch)
            self.stats["publishes"] += 1
            self.stats["bytes_published"] += nbytes * len(self._targets)
            self.stats["epoch"] = int(epoch)
            return out

    def latest(self, name: str) -> Tuple[Any, int]:
        """Newest ``(params, epoch)`` snapshot for target ``name``."""
        with self._lock:
            if name not in self._latest:
                raise KeyError(
                    f"no snapshot published yet for target {name!r}")
            return self._latest[name]
