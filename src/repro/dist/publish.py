"""Device-to-device weight publication (DESIGN.md §12).

The learner's params live sharded on the learner slice; every rollout
fleet wants a replicated snapshot on *its* slice.  The naive path — gather
to host, then feed each engine — serializes the whole parameter tree
through host RAM once per optimizer step and stalls both sides.  This
module reshards instead: one ``jax.device_put`` per fleet target moves the
tree straight between device buffers (ICI/NVLink on real backends, a
buffer copy on CPU), never materializing a host copy.

Epoch protocol: each ``publish`` call stamps a monotonically increasing
``epoch``; ``latest(name)`` returns the newest snapshot for that target.
The trainer maps epochs 1:1 onto learner versions, so the SampleQueue's
staleness contract (version-tagged groups, PR 3) is unchanged — a fleet
actor that picks up ``latest`` at admission produces a group whose
``behavior_version`` is exactly the snapshot's epoch.

"Zero bytes through the host" is asserted two ways:

* **counter-exact** — ``host_bytes`` counts bytes moved via any host
  staging path.  The device_put path never stages, so the counter stays 0
  by construction; the parity test and ``check_gates.py`` ceiling assert
  it stays that way (ABSOLUTE_ONLY: exempt from wall-time noise).
* **belt-and-braces** — publication runs under
  ``jax.transfer_guard_device_to_host("disallow")``.  On CPU the guard is
  inert (host platform "transfers" are aliasing, so nothing fires —
  which is why the counter, not the guard, is the gate), but on real
  backends it turns an accidental host gather into a hard error.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax


class PublicationError(RuntimeError):
    """Publication failed after exhausting its bounded retry budget."""


def tree_bytes(tree: Any) -> int:
    """Total payload size of a pytree of arrays, in bytes."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        size = getattr(leaf, "size", None)
        dtype = getattr(leaf, "dtype", None)
        if size is not None and dtype is not None:
            total += int(size) * dtype.itemsize
    return total


class WeightPublisher:
    """Reshards learner params onto each rollout slice's replicated layout.

    ``targets`` maps a fleet name to a placement: either a single device
    (the common fully-replicated engine layout) or a ``Sharding``.  The
    publisher is thread-safe — the learner publishes from the train loop
    while fleet actor threads read ``latest`` at group admission.

    ``max_attempts``/``backoff_s`` bound the retry loop around the
    device_put sweep (DESIGN.md §13): a transient failure (an injected
    fault, a flaky interconnect on real backends) is retried with doubling
    backoff and counted in ``publish_retries``; exhausting the budget
    escalates as ``PublicationError`` — never a silent spin.
    """

    def __init__(self, targets: Dict[str, Any], *, max_attempts: int = 1,
                 backoff_s: float = 0.05):
        if not targets:
            raise ValueError("WeightPublisher needs at least one target")
        self._targets = dict(targets)
        self._lock = threading.Lock()
        self._latest: Dict[str, Tuple[Any, int]] = {}
        self._max_attempts = max(1, int(max_attempts))
        self._backoff_s = float(backoff_s)
        # fault-injection hook (testing/chaos.py, DESIGN.md §13): fired
        # inside the retry loop so injected failures exercise it
        self.chaos = None
        self.stats: Dict[str, int] = {
            "publishes": 0,
            "bytes_published": 0,
            "host_bytes": 0,
            "publish_retries": 0,
            "epoch": 0,
        }

    @property
    def targets(self) -> Dict[str, Any]:
        return dict(self._targets)

    def publish(self, params: Any, *, epoch: Optional[int] = None) -> Dict[str, Any]:
        """Snapshot ``params`` onto every target, device-to-device.

        Returns ``{name: resharded_tree}``.  ``epoch`` defaults to the
        next integer after the last published epoch.
        """
        with self._lock:
            if epoch is None:
                epoch = self.stats["epoch"] + 1
            nbytes = tree_bytes(params)
            for attempt in range(1, self._max_attempts + 1):
                try:
                    if self.chaos is not None:
                        self.chaos.fire("publish", index=int(epoch))
                    out: Dict[str, Any] = {}
                    with jax.transfer_guard_device_to_host("disallow"):
                        for name, placement in self._targets.items():
                            out[name] = jax.device_put(params, placement)
                    break
                except Exception as e:
                    if attempt >= self._max_attempts:
                        raise PublicationError(
                            f"publication of epoch {epoch} failed after "
                            f"{self._max_attempts} attempts") from e
                    self.stats["publish_retries"] += 1
                    time.sleep(self._backoff_s * 2 ** (attempt - 1))
            for name, tree in out.items():
                self._latest[name] = (tree, epoch)
            self.stats["publishes"] += 1
            self.stats["bytes_published"] += nbytes * len(self._targets)
            self.stats["epoch"] = int(epoch)
            return out

    def add_target(self, name: str, placement: Any, params: Any = None,
                   *, epoch: Optional[int] = None) -> Any:
        """Register a publication target mid-run (fleet elasticity,
        DESIGN.md §13).  With ``params``, the current snapshot is pushed
        to the newcomer immediately, stamped with ``epoch`` (default: the
        publisher's current epoch) — the joiner starts at the fleet's
        publication epoch instead of waiting a step.  Returns the
        resharded tree (or None without ``params``)."""
        with self._lock:
            if name in self._targets:
                raise ValueError(f"target {name!r} already registered")
            self._targets[name] = placement
            if params is None:
                return None
            e = self.stats["epoch"] if epoch is None else int(epoch)
            with jax.transfer_guard_device_to_host("disallow"):
                tree = jax.device_put(params, placement)
            self._latest[name] = (tree, e)
            self.stats["bytes_published"] += tree_bytes(params)
            return tree

    def remove_target(self, name: str) -> None:
        """Stop publishing to a departed replica (its last snapshot is
        dropped too — a rejoin under the same name starts fresh)."""
        with self._lock:
            self._targets.pop(name, None)
            self._latest.pop(name, None)

    def latest(self, name: str) -> Tuple[Any, int]:
        """Newest ``(params, epoch)`` snapshot for target ``name``."""
        with self._lock:
            if name not in self._latest:
                raise KeyError(
                    f"no snapshot published yet for target {name!r}")
            return self._latest[name]
