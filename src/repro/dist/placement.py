"""Slice carving: split one device set into a learner slice and N rollout
fleet slices (DESIGN.md §12).

``repro.dist`` so far answered "how is an array laid out on a mesh"
(``sharding.py``); this module answers the question above it: *which
devices does each role own*.  The disaggregated trainer
(``rl/dist_trainer.py``) carves the ambient devices once at construction:

* the **learner slice** keeps the sharded training step (params + optimizer
  live there, laid out by the usual ``ShardingRules``),
* each **fleet slice** hosts one data-parallel rollout engine replica whose
  params are a replicated snapshot published device-to-device
  (``dist/publish.py``),
* with prefill/decode disaggregation a fleet slice is itself split: prefill
  cells on one sub-slice, the paged decode arena on another, groups handed
  off by block table through the page pool (``rl/engine.py::
  DisaggPagedRolloutEngine``).

Carving is **best-effort**, mirroring ``best_effort_spec``: on a machine
with fewer devices than roles the slices overlap (round-robin over the
rollout pool, learner keeps at least one device), degenerating to
"everything on device 0" on a single-device host — so the CPU test suite
runs the exact production topology code with the placement collapsed, and
the 8-virtual-device CI lane runs it with real slice separation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class FleetSlice:
    """One rollout replica's devices.  ``prefill`` is empty unless the
    fleet is prefill/decode-disaggregated, in which case prefill cells run
    there and hand raw KV off to the decode sub-slice by block table."""

    index: int
    decode: Tuple
    prefill: Tuple = ()

    @property
    def name(self) -> str:
        return f"fleet{self.index}"

    @property
    def devices(self) -> Tuple:
        return tuple(dict.fromkeys(self.decode + self.prefill))


@dataclasses.dataclass(frozen=True)
class SliceTopology:
    """The carved placement: who owns which devices."""

    learner: Tuple
    fleets: Tuple[FleetSlice, ...]
    disagg: bool = False

    @property
    def num_fleets(self) -> int:
        return len(self.fleets)

    def describe(self) -> str:
        parts = [f"learner={[d.id for d in self.learner]}"]
        for f in self.fleets:
            s = f"{f.name}: decode={[d.id for d in f.decode]}"
            if f.prefill:
                s += f" prefill={[d.id for d in f.prefill]}"
            parts.append(s)
        return "; ".join(parts)


def carve(devices: Optional[Sequence] = None, *, fleet: int = 1,
          disagg: bool = False, learner_devices: int = 0) -> SliceTopology:
    """Carve ``devices`` (default: ``jax.devices()``) into a learner slice
    plus ``fleet`` rollout slices.

    Policy: rollout roles claim one device each from the tail of the device
    list (decode, plus a prefill cell per fleet under ``disagg``); the
    learner keeps the head — at least one device, or exactly
    ``learner_devices`` when given.  When there are more roles than
    devices, rollout roles wrap round-robin over the non-learner pool (and
    over the whole list on a single device), so the topology is always
    constructible — placement quality degrades, correctness does not.
    """
    if fleet < 1:
        raise ValueError(f"fleet must be >= 1, got {fleet}")
    devices = list(devices if devices is not None else jax.devices())
    d = len(devices)
    need = fleet * (2 if disagg else 1)
    if learner_devices:
        if learner_devices > d:
            raise ValueError(
                f"learner_devices={learner_devices} exceeds the "
                f"{d} available device(s)")
        n_learner = learner_devices
    else:
        n_learner = max(1, d - need)
    learner = tuple(devices[:n_learner])
    pool = devices[n_learner:] or devices  # overlap when nothing is left

    fleets = []
    k = 0
    for f in range(fleet):
        decode = (pool[k % len(pool)],)
        k += 1
        prefill: Tuple = ()
        if disagg:
            prefill = (pool[k % len(pool)],)
            k += 1
        fleets.append(FleetSlice(index=f, decode=decode, prefill=prefill))
    return SliceTopology(learner=learner, fleets=tuple(fleets),
                         disagg=disagg)
