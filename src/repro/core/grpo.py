"""GRPO objective with NAT token masking and Horvitz-Thompson reweighting.

Implements paper Eqs. (1)-(6) and (9): group-relative advantages, PPO-style
clipped surrogate, optional k3 KL regularizer against a reference policy,
and the HT-weighted per-sequence-mean aggregation.

The loss consumes *token logprobs* so it composes with either the reference
jnp path (``token_logprobs_from_logits``) or the fused Pallas head
(``repro.kernels.ht_loss``) that never materializes the (B, T, V) softmax.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GRPOConfig:
    clip_eps: float = 0.2
    kl_beta: float = 0.0          # DAPO-style default: KL disabled
    adv_eps: float = 1e-4         # epsilon in Eq. (2)
    clip_eps_high: Optional[float] = None  # DAPO clip-higher; None = symmetric


def group_advantages(rewards: Array, eps: float = 1e-4) -> Array:
    """Eq. (2): normalized group-relative advantages.

    rewards: (num_prompts, G) rewards for G rollouts of each prompt.
    Returns advantages of the same shape.  Uses the biased (1/G) std exactly
    as written in the paper.
    """
    mu = jnp.mean(rewards, axis=-1, keepdims=True)
    sigma = jnp.sqrt(jnp.mean((rewards - mu) ** 2, axis=-1, keepdims=True))
    return (rewards - mu) / (sigma + eps)


def token_logprobs_from_logits(logits: Array, tokens: Array) -> Array:
    """log pi(o_t | ...) for the realized tokens.  logits: (..., T, V)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    tok = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    return tok - logz


def token_entropy_from_logits(logits: Array) -> Array:
    """Exact categorical entropy per position: H = logZ - E_p[logit]."""
    logz = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - logz)
    return (logz[..., 0] - jnp.sum(p * logits, axis=-1))


def clipped_surrogate(
    logp: Array, old_logp: Array, adv: Array, clip_eps: float,
    clip_eps_high: Optional[float] = None,
) -> tuple[Array, Array]:
    """Eq. (3): PPO clipped surrogate per token (to be MAXIMIZED).

    Returns (surrogate, clip_fraction_indicator).
    """
    hi = clip_eps if clip_eps_high is None else clip_eps_high
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + hi)
    s = jnp.minimum(ratio * adv, clipped * adv)
    was_clipped = (ratio * adv > clipped * adv).astype(jnp.float32)
    return s, was_clipped


def kl_k3(logp: Array, ref_logp: Array) -> Array:
    """k3 estimator of KL(pi_theta || pi_ref) from sampled-action logprobs:
    exp(ref - theta) - (ref - theta) - 1  (non-negative, low variance)."""
    d = ref_logp - logp
    return jnp.exp(d) - d - 1.0


def nat_grpo_loss(
    logp: Array,
    old_logp: Array,
    advantages: Array,
    ht_weights: Array,
    orig_lengths: Array,
    cfg: GRPOConfig = GRPOConfig(),
    ref_logp: Optional[Array] = None,
    entropies: Optional[Array] = None,
) -> tuple[Array, dict]:
    """The NAT objective (Eqs. 5, 6, 9) — returns (loss, metrics).

    Args:
      logp:        (B, T) current-policy logprobs of realized tokens.
      old_logp:    (B, T) behaviour-policy logprobs (from rollout scoring).
      advantages:  (B,) or (B, T) group-relative advantages (shared per row).
      ht_weights:  (B, T) w = m/p from the selector (0 on excluded/prompt
                   tokens).  Full-token GRPO is the special case w = m = 1.
      orig_lengths:(B,) ORIGINAL response length T_i — the HT estimator
                   divides by the full-sequence length even when only a
                   prefix was physically processed (Eq. 9).
      ref_logp:    optional (B, T) reference-policy logprobs for the KL term.
      entropies:   optional (B, T) per-token entropies for metrics.

    The loss is the negative of Eq. (5) with L_{i,t} replaced by the HT
    estimate: mean_i [ (1/T_i) sum_t w_{i,t} (S_{i,t} - beta*KL_{i,t}) ].
    """
    if advantages.ndim == 1:
        advantages = advantages[:, None]
    s, was_clipped = clipped_surrogate(
        logp, old_logp, advantages, cfg.clip_eps, cfg.clip_eps_high
    )
    per_token = s
    metrics: dict = {}
    if cfg.kl_beta > 0.0 and ref_logp is not None:
        kl = kl_k3(logp, ref_logp)
        per_token = per_token - cfg.kl_beta * kl
        metrics["kl"] = _masked_mean(kl, ht_weights > 0)

    inv_len = 1.0 / jnp.maximum(orig_lengths.astype(jnp.float32), 1.0)
    per_seq = jnp.sum(ht_weights * per_token, axis=-1) * inv_len  # Eq. 6/9
    j = jnp.mean(per_seq)
    loss = -j

    sel = ht_weights > 0
    n_sel = jnp.maximum(jnp.sum(sel), 1.0)
    metrics.update(
        loss=loss,
        surrogate=j,
        clip_frac=jnp.sum(was_clipped * sel) / n_sel,
        ratio_mean=_masked_mean(jnp.exp(logp - old_logp), sel),
        selected_tokens=jnp.sum(sel),
        selected_ratio=jnp.sum(sel)
        / jnp.maximum(jnp.sum(orig_lengths.astype(jnp.float32)), 1.0),
        ht_weight_max=jnp.max(ht_weights),
    )
    if entropies is not None:
        metrics["entropy"] = _masked_mean(entropies, sel)
    return loss, metrics


def _masked_mean(x: Array, mask: Array) -> Array:
    m = mask.astype(jnp.float32)
    return jnp.sum(x * m) / jnp.maximum(jnp.sum(m), 1.0)


def full_token_loss_reference(
    logp: Array, old_logp: Array, advantages: Array, response_mask: Array,
    cfg: GRPOConfig = GRPOConfig(), ref_logp: Optional[Array] = None,
) -> Array:
    """Vanilla full-token GRPO loss (Eq. 5) — the oracle the HT estimator
    must match in expectation.  Used by unbiasedness tests/benchmarks."""
    rm = response_mask.astype(jnp.float32)
    lengths = rm.sum(axis=-1)
    loss, _ = nat_grpo_loss(
        logp, old_logp, advantages, rm, lengths, cfg, ref_logp
    )
    return loss
