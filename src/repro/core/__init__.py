"""NAT core: token selectors, Horvitz-Thompson reweighting, GRPO objective,
physical prefix repacking, and learner batch layouts — the paper's primary
contribution."""
from repro.core.grpo import (
    GRPOConfig,
    clipped_surrogate,
    full_token_loss_reference,
    group_advantages,
    kl_k3,
    nat_grpo_loss,
    token_entropy_from_logits,
    token_logprobs_from_logits,
)
from repro.core.layout import (
    BatchLayout,
    BucketedLayout,
    LayoutBatch,
    PackedLayout,
    PaddedLayout,
    PagedLayout,
    build_microbatches,
    layout_names,
    make_layout,
    plan_pack,
)
from repro.core.repack import (
    RepackPlan,
    apply_plan,
    bucket_ladder,
    expected_token_savings,
    pick_bucket,
    plan_microbatches,
    repack_batch,
)
from repro.core.selectors import (
    DetTruncSelector,
    EntropySelector,
    FullSelector,
    RPCSelector,
    Selection,
    URSSelector,
    make_selector,
    response_positions,
    rpc_survival,
)

__all__ = [
    "GRPOConfig", "clipped_surrogate", "full_token_loss_reference",
    "group_advantages", "kl_k3", "nat_grpo_loss",
    "token_entropy_from_logits", "token_logprobs_from_logits",
    "BatchLayout", "BucketedLayout", "LayoutBatch", "PackedLayout",
    "PaddedLayout", "PagedLayout", "build_microbatches", "layout_names",
    "make_layout", "plan_pack",
    "RepackPlan", "apply_plan", "bucket_ladder", "expected_token_savings",
    "pick_bucket", "plan_microbatches", "repack_batch",
    "DetTruncSelector", "EntropySelector", "FullSelector", "RPCSelector",
    "Selection", "URSSelector", "make_selector", "response_positions",
    "rpc_survival",
]
