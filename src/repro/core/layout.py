"""Batch layouts: how a learner step lays selected tokens out in memory.

NAT's update-side claim is that train FLOPs scale with the kept-token
*budget*, not the padded grid (paper §4, Fig. 3).  Whether the learner
actually realizes that depends entirely on the physical batch layout, so the
layout is a first-class, swappable object (DESIGN.md §7):

* ``PaddedLayout``  — the (B, T) grid as rolled out.  Zero host work, full
  padded cost.  The reference every other layout must match numerically.
* ``BucketedLayout`` — prefix-structured selectors (RPC / Det-Trunc) slice
  every row to the smallest static bucket covering ``prompt + cut``
  (core/repack.py ladder).  One executable per bucket; per-row stragglers
  still pad the whole microbatch to the shared bucket length.
* ``PackedLayout``  — bin-packs each response's kept-span hull (prompt +
  response tokens up to the last kept index) end to end into fixed
  ``(num_rows, pack_len)`` rows with per-token segment IDs and ORIGINAL
  position IDs.  Dead padding is bounded by the bins' tails instead of
  per-row stragglers, and — unlike bucketing — it also compresses URS-style
  scattered selections (their hull ends at the last kept token, not at T).

The packed invariant (tested in tests/test_layout.py): every kept token's
forward context is exactly its own segment, so logp / loss / grads match
the padded reference per token, and the HT estimator (Eq. 6) is untouched
— the layout changes WHERE tokens sit, never WHICH tokens contribute.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.repack import pick_bucket

# Segment id for padding slots in packed rows: larger than any real pack id,
# so per-row segment ids stay monotone (the kernel's block-range skip relies
# on min/max block summaries) and padding only ever attends to itself.
PAD_SEGMENT = np.int32(2**30)


@dataclasses.dataclass
class LayoutBatch:
    """A layout's output: the learner batch plus its cost accounting."""

    data: dict                # arrays for the (jitted) train step
    packed: bool              # True -> the learner needs the packed loss path
    tokens_scored: int        # tokens the update physically processes
    kept_tokens: int          # tokens with nonzero HT weight (the budget)
    num_rows: int
    row_len: int

    @property
    def pack_efficiency(self) -> float:
        """kept tokens / scored tokens — 1.0 means zero dead compute."""
        return self.kept_tokens / max(self.tokens_scored, 1)


class BatchLayout:
    """Strategy interface: host-side transform of the (B, T) learner batch.

    ``build`` consumes the trainer's padded batch dict ((B, T) per-token
    leaves + (B,) per-response leaves) plus the selection geometry, and
    returns the arrays the train step runs on.  Implementations must be
    deterministic functions of their inputs — the async trainer's replay /
    checkpoint contract depends on it.
    """

    name: str = "base"
    packed: bool = False

    def build(
        self,
        batch: dict,
        *,
        prompt_lens: np.ndarray,
        response_lens: np.ndarray,
        keep_len: np.ndarray,
        keep_mask: np.ndarray,
        prefix_structured: bool,
        ladder: Sequence[int],
    ) -> LayoutBatch:
        raise NotImplementedError

    @staticmethod
    def _kept(keep_mask: np.ndarray) -> int:
        return int(np.asarray(keep_mask).astype(bool).sum())


class PaddedLayout(BatchLayout):
    """The identity layout: score the full (B, T) grid as rolled out."""

    name = "padded"

    def build(self, batch, *, prompt_lens, response_lens, keep_len,
              keep_mask, prefix_structured, ladder) -> LayoutBatch:
        b, t = batch["tokens"].shape[:2]
        return LayoutBatch(data=dict(batch), packed=False,
                           tokens_scored=b * t,
                           kept_tokens=self._kept(keep_mask),
                           num_rows=b, row_len=t)


class BucketedLayout(BatchLayout):
    """Physical prefix truncation to the repack bucket ladder.

    Exactly the historical trainer behavior (bit-for-bit: the staleness-0
    parity oracle in tests/test_async_trainer.py runs against this): for
    prefix-structured selections, slice every (B, T) leaf to the smallest
    bucket covering max(prompt + cut) and set ``lengths`` to the per-row
    keep totals; unstructured selections fall back to the padded grid.
    """

    name = "bucketed"

    def build(self, batch, *, prompt_lens, response_lens, keep_len,
              keep_mask, prefix_structured, ladder) -> LayoutBatch:
        b, t = batch["tokens"].shape[:2]
        if not prefix_structured:
            return LayoutBatch(data=dict(batch), packed=False,
                               tokens_scored=b * t,
                               kept_tokens=self._kept(keep_mask),
                               num_rows=b, row_len=t)
        keep_total = prompt_lens + np.minimum(keep_len, response_lens)
        t_new = min(pick_bucket(int(keep_total.max()), ladder), t)
        data = {k: (v[:, :t_new] if getattr(v, "ndim", 0) >= 2 else v)
                for k, v in batch.items()}
        data["lengths"] = keep_total.astype(np.int32)
        return LayoutBatch(data=data, packed=False,
                           tokens_scored=b * t_new,
                           kept_tokens=self._kept(keep_mask),
                           num_rows=b, row_len=t_new)


@dataclasses.dataclass
class PackedLayout(BatchLayout):
    """Bin-pack kept-span hulls into dense ``(num_rows, pack_len)`` rows.

    Per response b the *hull* is grid span ``[0, h_b)`` with ``h_b`` = last
    kept index + 1 — the prompt plus every response token needed to
    condition the kept ones (for RPC the hull IS the kept prefix; for URS
    it covers the gaps between scattered picks, which the model must still
    score for exact conditioning).  Hulls are first-fit-decreasing packed
    into rows of ``pack_len`` = the ladder bucket covering the longest
    hull, so dead padding is bounded by the bins' tails.

    Emitted per-token arrays (alongside every packed batch leaf):
      positions    — ORIGINAL grid position of each token (rope stays exact)
      segment_ids  — per-row-monotone pack ids; padding = PAD_SEGMENT.
                     Feed these to the model: attention masks on equality,
                     and the Pallas kernel skips whole KV blocks whose
                     segment range cannot intersect the query block's.
      resp_ids     — original response index in [0, B); padding = 0 (inert:
                     padding HT weight is 0).  Feed these to the loss for
                     the segment-scatter back to per-response sums.

    Responses with no kept tokens are not packed at all — their Eq. 6 term
    is exactly 0 either way, and the loss means over ``num_segments`` = B
    regardless.  ``row_quant`` rounds the row count up (fewer distinct
    shapes -> fewer jit recompiles) at the cost of whole padding rows.

    Which mixers accept packed rows is decided by the capability table
    (``models/capabilities.py``): attention kinds mask on segment
    equality, ssm/rec zero their state at segment starts, xattn refuses.
    ``NATTrainerConfig(layout="packed")`` on an unsupported config raises
    ``CapabilityError`` at construction (``capabilities.check_packed``).
    """

    row_quant: int = 1
    name: str = "packed"
    packed: bool = True

    def build(self, batch, *, prompt_lens, response_lens, keep_len,
              keep_mask, prefix_structured, ladder) -> LayoutBatch:
        b, t = batch["tokens"].shape[:2]
        keep_mask = np.asarray(keep_mask).astype(bool)
        kept = int(keep_mask.sum())
        # hull end per row: one past the last kept grid index (0 if none)
        any_kept = keep_mask.any(axis=1)
        hull = np.where(any_kept,
                        t - np.argmax(keep_mask[:, ::-1], axis=1), 0)
        hull = hull.astype(np.int64)

        pack_len = min(pick_bucket(int(max(hull.max(), 1)), ladder), t)
        plan = plan_pack(hull, pack_len)
        rows = max(len(plan), 1)
        if self.row_quant > 1:
            rows = int(np.ceil(rows / self.row_quant)) * self.row_quant

        data = {}
        for key, v in batch.items():
            if key == "lengths":
                continue  # padded-grid key mask; meaningless once packed
            if getattr(v, "ndim", 0) >= 2:
                data[key] = np.zeros((rows, pack_len) + v.shape[2:], v.dtype)
            else:
                data[key] = v  # per-response leaves ride through as (B,)
        positions = np.zeros((rows, pack_len), np.int32)
        segment_ids = np.full((rows, pack_len), PAD_SEGMENT, np.int32)
        resp_ids = np.zeros((rows, pack_len), np.int32)

        pack_id = 0
        for r, row in enumerate(plan):
            off = 0
            for src in row:
                h = int(hull[src])
                for key, v in batch.items():
                    if key != "lengths" and getattr(v, "ndim", 0) >= 2:
                        data[key][r, off:off + h] = v[src, :h]
                positions[r, off:off + h] = np.arange(h, dtype=np.int32)
                segment_ids[r, off:off + h] = pack_id
                resp_ids[r, off:off + h] = src
                pack_id += 1
                off += h
        data["positions"] = positions
        data["segment_ids"] = segment_ids
        data["resp_ids"] = resp_ids
        return LayoutBatch(data=data, packed=True,
                           tokens_scored=rows * pack_len, kept_tokens=kept,
                           num_rows=rows, row_len=pack_len)


@dataclasses.dataclass
class PagedLayout(BatchLayout):
    """Suffix-only packing for zero re-prefill scoring (DESIGN.md §11).

    Where ``PackedLayout`` packs each response's FULL hull (prompt +
    response), this layout packs only the suffix ``[P-1, hull)`` — the last
    prompt token plus the kept-span hull of the response — because the
    prompt's K/V already exists in the rollout engine's page pool.  The
    learner scores these rows with ``score_tokens(paged_prefix=...)``: the
    paged prefill kernel attends each suffix token to pool positions
    ``[0, seg_start)`` via the block table plus the in-batch suffix keys.
    The last prompt token is re-forwarded (one token, not P) so the
    response's first token gets a true logp; its own logp slot is zeroed by
    the segment-start rule, same as any packed segment head.

    Kernel contract (pinned by tests/test_paged_score.py):
      * segment ids ARE response indices ``src`` in [0, B) — the kernel
        indexes ``block_tables[s]`` / ``seg_start[s]`` by segment id, and
        the engine's ``export_learner_pages`` emits row ``s`` for response
        ``s``.  S = B statically, even for responses with no kept tokens
        (their segments are empty; the kernels skip them).
      * every segment's row offset and allotted length are multiples of
        ``qblock`` (= ``models.attention.PAGED_SCORE_BLOCK``), so each
        kernel query block is single-segment (+ PAD tail).
      * ids are NOT per-row monotone (unlike PackedLayout): the suffix
        kernel's min/max block-range skip just sees wider intervals —
        correctness is by per-token equality either way.

    Emits ``seg_start`` (B,) — the absolute position of each segment's
    first suffix token (= clamped ``prompt_len - 1``); pool visibility is
    ``pos < seg_start[s]``, which also hides the pool's duplicate of the
    last prompt token.  ``positions`` stay absolute, so rope is exact.
    """

    qblock: int = 16
    name: str = "paged"
    packed: bool = True

    def build(self, batch, *, prompt_lens, response_lens, keep_len,
              keep_mask, prefix_structured, ladder) -> LayoutBatch:
        b, t = batch["tokens"].shape[:2]
        keep_mask = np.asarray(keep_mask).astype(bool)
        kept = int(keep_mask.sum())
        any_kept = keep_mask.any(axis=1)
        hull = np.where(any_kept,
                        t - np.argmax(keep_mask[:, ::-1], axis=1), 0)
        start = np.minimum(np.maximum(np.asarray(prompt_lens, np.int64) - 1,
                                      0), t - 1)
        slen = np.where(any_kept, np.maximum(hull - start, 0), 0)
        slen = slen.astype(np.int64)
        alen = -(-slen // self.qblock) * self.qblock

        pack_len = pick_bucket(int(max(alen.max(), 1)), ladder)
        pack_len = -(-max(pack_len, int(alen.max())) // self.qblock)
        pack_len *= self.qblock
        plan = plan_pack(alen, pack_len)
        rows = max(len(plan), 1)

        data = {}
        for key, v in batch.items():
            if key == "lengths":
                continue  # padded-grid key mask; meaningless once packed
            if getattr(v, "ndim", 0) >= 2:
                data[key] = np.zeros((rows, pack_len) + v.shape[2:], v.dtype)
            else:
                data[key] = v  # per-response leaves ride through as (B,)
        positions = np.zeros((rows, pack_len), np.int32)
        segment_ids = np.full((rows, pack_len), PAD_SEGMENT, np.int32)
        resp_ids = np.zeros((rows, pack_len), np.int32)

        for r, row in enumerate(plan):
            off = 0
            for src in row:
                s0, n = int(start[src]), int(slen[src])
                for key, v in batch.items():
                    if key != "lengths" and getattr(v, "ndim", 0) >= 2:
                        data[key][r, off:off + n] = v[src, s0:s0 + n]
                positions[r, off:off + n] = np.arange(s0, s0 + n,
                                                      dtype=np.int32)
                segment_ids[r, off:off + n] = src
                resp_ids[r, off:off + n] = src
                off += int(alen[src])  # next segment stays qblock-aligned
        data["positions"] = positions
        data["segment_ids"] = segment_ids
        data["resp_ids"] = resp_ids
        data["seg_start"] = start.astype(np.int32)
        return LayoutBatch(data=data, packed=True,
                           tokens_scored=rows * pack_len, kept_tokens=kept,
                           num_rows=rows, row_len=pack_len)


def build_microbatches(
    layout: BatchLayout,
    batch: dict,
    num_microbatches: int,
    *,
    prompt_lens: np.ndarray,
    response_lens: np.ndarray,
    keep_len: np.ndarray,
    keep_mask: np.ndarray,
    prefix_structured: bool,
    ladder: Sequence[int],
) -> list:
    """Split the padded batch on the RESPONSE axis, then lay out each chunk.

    Gradient accumulation must split before packing, never after: a packed
    row holds tokens of several responses while the per-response leaves
    stay (B,), so slicing packed rows would tear responses apart.  Chunks
    are contiguous (rows [i*B/m, (i+1)*B/m)), so GRPO groups stay whole as
    long as m divides the prompt count.  Each chunk gets its own
    ``layout.build`` — its own pack plan, bucket, and ``num_segments`` —
    and the learner (``rl/learner.py``) consumes the resulting tuple of
    batches with an unrolled accumulation loop (shapes may differ per
    chunk).  Returns a list of ``num_microbatches`` LayoutBatches.
    """
    b = batch["tokens"].shape[0]
    m = num_microbatches
    if b % m:
        raise ValueError(f"batch of {b} responses does not split into "
                         f"{m} microbatches")
    c = b // m
    out = []
    for i in range(m):
        sl = slice(i * c, (i + 1) * c)
        sub = {k: (v[sl] if getattr(v, "ndim", 0) >= 1 else v)
               for k, v in batch.items()}
        out.append(layout.build(
            sub, prompt_lens=prompt_lens[sl], response_lens=response_lens[sl],
            keep_len=keep_len[sl], keep_mask=keep_mask[sl],
            prefix_structured=prefix_structured, ladder=ladder))
    return out


def plan_pack(hull: np.ndarray, pack_len: int) -> list:
    """First-fit-decreasing bin packing of hull lengths into ``pack_len``
    bins.  Returns a list of rows, each a list of source row indices in
    placement order.  Deterministic: ties broken by original index
    (stable argsort).  Zero-length hulls are skipped entirely.
    """
    order = np.argsort(-hull, kind="stable")
    rows: list = []
    space: list = []
    for src in order:
        h = int(hull[src])
        if h == 0:
            continue
        if h > pack_len:
            raise ValueError(f"hull {h} exceeds pack_len {pack_len}")
        for r, free in enumerate(space):
            if free >= h:
                rows[r].append(int(src))
                space[r] -= h
                break
        else:
            rows.append([int(src)])
            space.append(pack_len - h)
    return rows


_LAYOUTS = {
    "padded": PaddedLayout,
    "bucketed": BucketedLayout,
    "packed": PackedLayout,
    "paged": PagedLayout,
}


def make_layout(name: str, **kwargs) -> BatchLayout:
    """Factory: ``make_layout('packed', row_quant=2)``."""
    try:
        cls = _LAYOUTS[name]
    except KeyError as e:
        raise ValueError(
            f"unknown layout {name!r}; available: {sorted(_LAYOUTS)}"
        ) from e
    return cls(**kwargs)


def layout_names() -> tuple:
    return tuple(sorted(_LAYOUTS))
