"""Physical prefix truncation + TPU length bucketing for RPC.

RPC's system win comes from *actually shortening* the sequences the learner
processes.  Because RPC masks are contiguous prefixes, truncation is a slice
— no gather.  XLA/TPU needs static shapes, so instead of slicing each batch
to its own max cut (a recompile per batch), we slice to the smallest bucket
of a static ladder; one executable per bucket is compiled once and reused.

The ladder defaults to {T/4, T/2, 3T/4, T} rounded up to multiples of 128
(MXU/lane alignment).  Under the paper's uniform cutoff, E[L] ~ T/2 + C/2,
so steady state mostly hits the T/2 and 3T/4 buckets.

``plan_microbatches`` goes further (beyond-paper): it sorts rows by keep
length and splits the batch into microbatches so short-cut rows do not pay
for a long straggler's bucket — the learner-side analogue of the rollout
length-scheduling systems the paper cites (RollPacker/SortedRL).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def bucket_ladder(max_len: int, num_buckets: int = 4,
                  align: int = 128) -> tuple[int, ...]:
    """Static ladder of padded lengths, each a multiple of ``align``."""
    out = []
    for i in range(1, num_buckets + 1):
        l = math.ceil(max_len * i / num_buckets / align) * align
        out.append(min(l, math.ceil(max_len / align) * align))
    ladder = tuple(sorted(set(out)))
    return ladder


def pick_bucket(needed: int, ladder: Sequence[int]) -> int:
    """Smallest ladder entry >= needed (host-side planning; static result).

    Raises when no entry covers ``needed``: silently returning ``ladder[-1]``
    would TRUNCATE kept tokens — a wrong-answer failure mode, not a
    performance one — so an undersized ladder is a hard error at plan time.
    """
    for b in ladder:
        if b >= needed:
            return b
    raise ValueError(
        f"needed length {needed} exceeds the bucket ladder (max "
        f"{ladder[-1] if len(ladder) else 'empty'}): kept tokens would be "
        "silently dropped; build the ladder from the true max length")


@dataclasses.dataclass(frozen=True)
class RepackPlan:
    """Host-side plan: which rows go to which bucket, in what order."""

    bucket_len: int
    row_order: np.ndarray  # permutation of row indices

    @property
    def num_rows(self) -> int:
        return len(self.row_order)


def repack_batch(batch: dict, keep_total: np.ndarray, ladder: Sequence[int]) -> dict:
    """Slice every (B, T) leaf of ``batch`` to the bucket covering
    max(keep_total).  ``keep_total`` = prompt_len + RPC keep_len per row
    (total tokens that must stay in the physical buffer).

    Returns a new dict with shorter T.  1-D / scalar leaves pass through.
    """
    t_new = pick_bucket(int(np.max(keep_total)), ladder)

    def slc(x):
        if hasattr(x, "ndim") and x.ndim >= 2 and x.shape[1] >= t_new:
            return x[:, :t_new]
        return x

    return {k: slc(v) for k, v in batch.items()}


def plan_microbatches(
    keep_total: np.ndarray,
    num_microbatches: int,
    ladder: Sequence[int],
) -> list[RepackPlan]:
    """Sort rows by keep length (desc) and split into equal microbatches,
    each padded only to its own bucket.  Deterministic given inputs.
    """
    order = np.argsort(-keep_total, kind="stable")
    b = len(keep_total)
    assert b % num_microbatches == 0, (b, num_microbatches)
    per = b // num_microbatches
    plans = []
    for i in range(num_microbatches):
        rows = order[i * per : (i + 1) * per]
        need = int(keep_total[rows].max()) if len(rows) else ladder[0]
        plans.append(RepackPlan(bucket_len=pick_bucket(need, ladder), row_order=rows))
    return plans


def apply_plan(batch: dict, plan: RepackPlan) -> dict:
    """Gather the plan's rows and slice to its bucket length."""
    rows = jnp.asarray(plan.row_order)

    def take(x):
        x = jnp.asarray(x)
        if x.ndim == 0:
            return x
        x = x[rows]
        if x.ndim >= 2 and x.shape[1] >= plan.bucket_len:
            x = x[:, : plan.bucket_len]
        return x

    return {k: take(v) for k, v in batch.items()}


def expected_token_savings(lengths: np.ndarray, min_cut: int) -> float:
    """E[kept]/E[full] under uniform-cutoff RPC with minimum C — the paper's
    Fig. 3 prediction 0.5 + C/(2 E[T])."""
    t = np.asarray(lengths, dtype=np.float64)
    c = np.minimum(min_cut, t)
    return float(((c + t) / 2).sum() / np.maximum(t.sum(), 1.0))
