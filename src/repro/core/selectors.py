"""Token selectors: the NAT framework's sampling designs.

A selector draws a binary inclusion mask ``m`` over *response* tokens and
reports the per-token inclusion probability ``p`` so the learner can form the
Horvitz-Thompson weight ``w = m / p`` (paper Eq. 6).  Everything is laid out
on the padded ``(B, T)`` token grid; prompt and padding positions always have
``m = 0`` and ``p = 1`` (they never enter the loss, so their weight is 0).

Selectors are pure functions of a PRNG key and the batch geometry, so the
same code path runs on host (data pipeline) and on device (inside jit).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Selection:
    """Result of a token-selection draw.

    Attributes:
      mask:       (B, T) float32 in {0, 1}; 1 = token participates in update.
      inclusion:  (B, T) float32 in (0, 1]; Pr[m=1] under the design.
      keep_len:   (B,)   int32; number of *response* tokens kept when the
                  design is prefix-structured (RPC / Det-Trunc); for
                  unstructured designs it is the count of selected tokens.
      prefix_structured: static bool — True when ``mask`` is guaranteed to be
                  a contiguous prefix of the response (enables repacking).
    """

    mask: Array
    inclusion: Array
    keep_len: Array
    prefix_structured: bool = dataclasses.field(default=False)

    @property
    def ht_weights(self) -> Array:
        """Horvitz-Thompson weights m/p (zero on excluded tokens)."""
        return self.mask / self.inclusion

    def tree_flatten(self):
        return (self.mask, self.inclusion, self.keep_len), (self.prefix_structured,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, prefix_structured=aux[0])


def response_positions(response_mask: Array) -> tuple[Array, Array]:
    """Per-token index within the response and per-row response length.

    ``response_mask`` is (B, T) with 1 on response (generated) tokens.
    Returns (pos, length): ``pos[b, t]`` is the 0-based index of token t
    within row b's response (undefined but finite on non-response tokens),
    and ``length[b]`` is the number of response tokens.
    """
    rm = response_mask.astype(jnp.int32)
    pos = jnp.cumsum(rm, axis=-1) - 1  # 0-based; -1 before response starts
    length = rm.sum(axis=-1)
    return pos, length


@dataclasses.dataclass(frozen=True)
class FullSelector:
    """Vanilla GRPO: every response token participates (m=1, p=1)."""

    name: str = "full"

    def __call__(self, key: Optional[Array], response_mask: Array) -> Selection:
        rm = response_mask.astype(jnp.float32)
        _, length = response_positions(response_mask)
        return Selection(
            mask=rm,
            inclusion=jnp.ones_like(rm),
            keep_len=length,
            prefix_structured=True,
        )


@dataclasses.dataclass(frozen=True)
class URSSelector:
    """Uniform Random Sampling: i.i.d. Bernoulli(p) token masks (paper §3.1).

    Unbiased under HT reweighting; saves backward FLOPs only.
    """

    p: float = 0.5
    name: str = "urs"

    def __call__(self, key: Array, response_mask: Array) -> Selection:
        rm = response_mask.astype(jnp.float32)
        bern = jax.random.bernoulli(key, self.p, shape=response_mask.shape)
        mask = bern.astype(jnp.float32) * rm
        inclusion = jnp.where(rm > 0, jnp.float32(self.p), jnp.float32(1.0))
        return Selection(
            mask=mask,
            inclusion=inclusion,
            keep_len=mask.sum(axis=-1).astype(jnp.int32),
            prefix_structured=False,
        )


def rpc_survival(pos: Array, length: Array, min_cut: int) -> Array:
    """Survival function p_{i,t} = Pr(L_i >= t) for uniform cutoff on
    {C..T_i} (paper §4, Minimum-cutoff RPC).  ``pos`` is 0-based so token
    index t (1-based) = pos + 1:

        p = 1                       for t <= C
        p = (T - t + 1)/(T - C + 1) for t  > C
    """
    t = pos + 1  # 1-based token index within the response
    length = length[:, None].astype(jnp.float32)
    c = jnp.minimum(jnp.float32(min_cut), length)  # C cannot exceed T_i
    tf = t.astype(jnp.float32)
    tail = (length - tf + 1.0) / jnp.maximum(length - c + 1.0, 1.0)
    p = jnp.where(tf <= c, 1.0, tail)
    return jnp.clip(p, 1e-9, 1.0)


@dataclasses.dataclass(frozen=True)
class RPCSelector:
    """Random Prefix Cutting with a minimum retained prefix (paper §4).

    Samples L_i ~ Uniform({min(C,T_i) .. T_i}) per row and keeps tokens with
    index <= L_i.  Inclusion probabilities follow the survival function; the
    mask is a contiguous prefix, enabling *physical* truncation of the
    forward pass (see repack.py).
    """

    min_cut: int = 100
    name: str = "rpc"

    def __call__(self, key: Array, response_mask: Array) -> Selection:
        pos, length = response_positions(response_mask)
        b = length.shape[0]
        c = jnp.minimum(jnp.int32(self.min_cut), length)
        # L ~ Uniform({C..T}); randint high is exclusive.
        u = jax.random.uniform(key, (b,))
        span = (length - c + 1).astype(jnp.float32)
        cut = c + jnp.floor(u * span).astype(jnp.int32)
        cut = jnp.clip(cut, c, length)
        rm = response_mask.astype(jnp.float32)
        mask = (pos < cut[:, None]).astype(jnp.float32) * rm
        inclusion = jnp.where(rm > 0, rpc_survival(pos, length, self.min_cut), 1.0)
        return Selection(
            mask=mask, inclusion=inclusion, keep_len=cut, prefix_structured=True
        )


@dataclasses.dataclass(frozen=True)
class DetTruncSelector:
    """Deterministic prefix truncation (the paper's *biased* baseline).

    Keeps the first floor(frac * T_i) tokens with weight 1.  Violates the HT
    requirement p>0 on the suffix — implemented exactly as the paper's
    baseline for the bias ablations, NOT as an HT design.
    """

    frac: float = 0.5
    name: str = "det_trunc"

    def __call__(self, key: Optional[Array], response_mask: Array) -> Selection:
        pos, length = response_positions(response_mask)
        cut = jnp.maximum(
            jnp.floor(length.astype(jnp.float32) * self.frac).astype(jnp.int32), 1
        )
        cut = jnp.minimum(cut, length)
        rm = response_mask.astype(jnp.float32)
        mask = (pos < cut[:, None]).astype(jnp.float32) * rm
        # p=1 on the kept prefix: this is what makes the estimator biased.
        return Selection(
            mask=mask,
            inclusion=jnp.ones_like(rm),
            keep_len=cut,
            prefix_structured=True,
        )


@dataclasses.dataclass(frozen=True)
class EntropySelector:
    """Information-aware selector (paper §7 future work, implemented here).

    Sets p_{i,t} = clip(p_floor + (1 - p_floor) * h_t / max_h, p_floor, 1)
    from per-token predictive entropies h_t of the behaviour policy, so
    compute concentrates on high-entropy "decision" tokens (Wang et al. 2025)
    while the HT weights keep the estimator unbiased.
    """

    p_floor: float = 0.2
    budget: float = 0.5  # target expected fraction of tokens kept
    name: str = "entropy"

    def __call__(self, key: Array, response_mask: Array, entropies: Array) -> Selection:
        rm = response_mask.astype(jnp.float32)
        h = jnp.where(rm > 0, entropies, 0.0)
        denom = jnp.sum(h, axis=-1, keepdims=True)
        n_resp = jnp.maximum(jnp.sum(rm, axis=-1, keepdims=True), 1.0)
        # Scale so that mean p over the response ~= budget, then floor/clip.
        raw = jnp.where(denom > 0, h / jnp.maximum(denom, 1e-9) * n_resp * self.budget,
                        self.budget)
        p = jnp.clip(raw, self.p_floor, 1.0)
        p = jnp.where(rm > 0, p, 1.0)
        bern = jax.random.uniform(key, response_mask.shape) < p
        mask = bern.astype(jnp.float32) * rm
        return Selection(
            mask=mask,
            inclusion=p,
            keep_len=mask.sum(axis=-1).astype(jnp.int32),
            prefix_structured=False,
        )


_REGISTRY = {
    "full": FullSelector,
    "grpo": FullSelector,
    "urs": URSSelector,
    "rpc": RPCSelector,
    "det_trunc": DetTruncSelector,
    "entropy": EntropySelector,
}


def make_selector(name: str, **kwargs):
    """Factory: ``make_selector('rpc', min_cut=100)``."""
    try:
        cls = _REGISTRY[name]
    except KeyError as e:
        raise ValueError(
            f"unknown selector {name!r}; available: {sorted(_REGISTRY)}"
        ) from e
    return cls(**kwargs)
