"""AdamW (decoupled weight decay) with:

* global-norm gradient clipping,
* linear-warmup + cosine-decay schedule,
* optional **int8 block-quantized moments** (bitsandbytes-style, block 128
  along the flattened last axis) — the distributed-optimization trick that
  makes 340B-scale training fit a 16 GB/chip v5e pod: moments drop from
  8 bytes/param (fp32 m+v) to ~2.06 bytes/param,
* states sharded exactly like their parameters (same logical axes).

Everything is pure pytree code — no optax dependency.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

Array = jax.Array
F32 = jnp.float32
QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    warmup_steps: int = 10
    total_steps: int = 1000
    end_lr_frac: float = 0.1
    moment_dtype: str = "fp32"  # fp32 | int8


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup then cosine decay to end_lr_frac * lr."""
    s = step.astype(F32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.end_lr_frac + (1.0 - cfg.end_lr_frac) * cos
    return cfg.lr * warm * frac


# ----------------------------------------------------- int8 moment storage
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Block-quantized int8 tensor with per-block fp32 scales.

    Blocks run along the LAST axis only; all leading axes keep the parent
    parameter's layout, so the quantized moments inherit the parameter's
    sharding dim-for-dim and (de)quantization never reshapes across a
    sharded boundary (a flat-block layout forced XLA to replicate 500 GB+
    fp32 temporaries on the 340B config — EXPERIMENTS.md §Perf)."""

    q: Array        # int8  (..., n_blocks, QBLOCK)
    scale: Array    # f32   (..., n_blocks, 1)
    shape: tuple    # original shape (static)

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


def quantize(x: Array) -> QTensor:
    shape = x.shape
    last = shape[-1] if shape else 1
    pad = (-last) % QBLOCK
    xf = x.astype(F32).reshape(shape if shape else (1,))
    if pad:
        widths = [(0, 0)] * (xf.ndim - 1) + [(0, pad)]
        xf = jnp.pad(xf, widths)
    blocked = xf.reshape(xf.shape[:-1] + (-1, QBLOCK))
    scale = jnp.max(jnp.abs(blocked), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocked / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale, shape=shape)


def dequantize(t: QTensor) -> Array:
    blocked = t.q.astype(F32) * t.scale
    flat_last = blocked.reshape(blocked.shape[:-2] + (-1,))
    last = t.shape[-1] if t.shape else 1
    return flat_last[..., :last].reshape(t.shape)


def _is_q(x) -> bool:
    return isinstance(x, QTensor)


# ------------------------------------------------------------- optimizer
def quantize_v(x: Array) -> QTensor:
    """Second moments are quantized in the SQRT domain: linear int8 maps
    zero out entries ~254x below the block max, and a zeroed v blows up
    m/(sqrt(v)+eps).  sqrt halves the dynamic range (64k:1 in v maps to
    254:1 in sqrt(v)), which keeps the Adam denominator stable."""
    return quantize(jnp.sqrt(jnp.maximum(x, 0.0)))


def dequantize_v(t: QTensor) -> Array:
    return jnp.square(dequantize(t))


def init_opt_state(params, cfg: AdamWConfig):
    int8 = cfg.moment_dtype == "int8"

    def zeros_m(p):
        z = jnp.zeros(p.shape, F32)
        return quantize(z) if int8 else z

    def zeros_v(p):
        z = jnp.zeros(p.shape, F32)
        return quantize_v(z) if int8 else z

    return {
        "m": jax.tree.map(zeros_m, params),
        "v": jax.tree.map(zeros_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(F32) * factor).astype(g.dtype), grads), gn


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        gf = g.astype(F32)
        mf = dequantize(m) if _is_q(m) else m
        vf = dequantize_v(v) if _is_q(v) else v
        mf = b1 * mf + (1.0 - b1) * gf
        vf = b2 * vf + (1.0 - b2) * jnp.square(gf)
        mh = mf / bc1
        vh = vf / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * delta).astype(p.dtype)
        new_m = quantize(mf) if _is_q(m) else mf
        new_v = quantize_v(vf) if _is_q(v) else vf
        return new_p, new_m, new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=_is_q)
    flat_v = jax.tree.leaves(state["v"], is_leaf=_is_q)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_shardings(abs_opt, param_specs_tree, mesh, rules):
    """NamedShardings for the optimizer state: fp32 moments shard like their
    parameters; int8 QTensors (flattened into aligned blocks) shard block-dim
    over the FSDP axes."""
    from repro.dist.sharding import is_axes_tuple, logical_to_sharding

    def moment(axes, leaf):
        if _is_q(leaf):
            # blocks run along the last axis: q/scale inherit the parameter's
            # axes with the last one applied to the block dim
            q_axes = tuple(axes[:-1]) + (axes[-1] if axes else None, None)
            return QTensor(
                q=logical_to_sharding(leaf.q.shape, q_axes, mesh, rules),
                scale=logical_to_sharding(leaf.scale.shape, q_axes, mesh, rules),
                shape=leaf.shape)
        return logical_to_sharding(leaf.shape, axes, mesh, rules)

    def moments(abs_moments):
        return jax.tree.map(moment, param_specs_tree, abs_moments,
                            is_leaf=is_axes_tuple)

    return {
        "m": moments(abs_opt["m"]),
        "v": moments(abs_opt["v"]),
        "step": logical_to_sharding((), (), mesh, rules),
    }
