"""Optimizer substrate: AdamW + schedules + int8 moment compression."""
from repro.optim.adamw import (
    AdamWConfig,
    QTensor,
    adamw_update,
    clip_by_global_norm,
    dequantize,
    global_norm,
    init_opt_state,
    opt_state_shardings,
    quantize,
    schedule,
)

__all__ = [
    "AdamWConfig", "QTensor", "adamw_update", "clip_by_global_norm",
    "dequantize", "global_norm", "init_opt_state", "opt_state_shardings",
    "quantize", "schedule",
]
