"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
``xla_force_host_platform_device_count`` before jax initializes.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    try:  # jax >= 0.5: explicit Auto axis types
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
    except (AttributeError, TypeError):  # older jax: Auto is the only mode
        return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False, shape=None, axes=None):
    """Default production meshes:
        single-pod: (16, 16)   axes ("data", "model")   = 256 chips
        multi-pod:  (2, 16, 16) axes ("pod", "data", "model") = 512 chips

    The "pod" axis is just an outer FSDP/DP axis; scaling to N pods
    (N*256 chips) is ``shape=(N, 16, 16)`` — no code change."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    assert axes is not None and len(axes) == len(shape)
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Trivial 1-device mesh for CPU training/tests."""
    return _make_mesh((1,), ("data",))


def slice_mesh(devices, axis: str = "data"):
    """1-D mesh over an explicit device list — the per-fleet-slice mesh the
    disaggregated trainer (DESIGN.md §12) publishes onto.  Unlike
    ``make_production_mesh`` this takes the devices verbatim (a slice from
    ``repro.dist.placement.carve``), so it composes with any carving."""
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devices), (axis,))


def set_ambient_mesh(mesh):
    """jax.set_mesh where available (jax >= 0.6).  On older jax the explicit
    NamedShardings passed to jit carry the mesh, so this is optional."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
