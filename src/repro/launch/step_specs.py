"""Builders for the three lowered entry points (train / prefill / decode):
abstract inputs (ShapeDtypeStruct — never allocated) + NamedShardings from
the logical-axis rules.  Shared by the dry-run and the real launcher so the
thing we validate is the thing we'd run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.core.grpo import GRPOConfig
from repro.dist.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_to_sharding,
    tree_shardings,
)
from repro.models.config import ModelConfig
from repro.models.model import cache_axes, cache_decl, model_decl, prefill
from repro.models.params import abstract_params, param_specs
from repro.optim.adamw import AdamWConfig, init_opt_state, opt_state_shardings

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    fn: callable              # the step function to jit
    args: tuple               # abstract args
    in_shardings: tuple
    out_shardings: object     # tree or None
    donate: tuple = ()


def _sh(mesh, rules, shape, axes):
    return logical_to_sharding(shape, axes, mesh, rules)


def params_and_shardings(cfg: ModelConfig, mesh, rules: ShardingRules):
    decl = model_decl(cfg)
    abs_p = abstract_params(decl)
    shard_p = tree_shardings(abs_p, param_specs(decl), mesh, rules)
    return abs_p, shard_p


# ------------------------------------------------------------------- train
def train_inputs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 rules: ShardingRules = DEFAULT_RULES,
                 layout: str = "padded",
                 num_segments: Optional[int] = None):
    """Abstract NAT-GRPO learner batch.

    ``layout="padded"`` is the (global_batch, seq) grid — the bucketed
    layout lowers the same executable at each ladder length, so one padded
    cell per bucket covers it.  ``layout="packed"`` is the dense packed
    batch (core/layout.py): ``global_batch`` counts PACKED ROWS, ``seq``
    is the pack length, per-token id planes ride along, and per-response
    leaves are (num_segments,) — default 2 segments per packed row, the
    steady state at the paper's ~50% keep budget.
    """
    b, t = shape.global_batch, shape.seq_len
    batch = {
        "tokens": SDS((b, t, cfg.num_codebooks) if cfg.num_codebooks else (b, t),
                      jnp.int32),
        "response_mask": SDS((b, t), jnp.float32),
        "old_logp": SDS((b, t), jnp.float32),
        "advantages": SDS((b,), jnp.float32),
        "ht_weights": SDS((b, t), jnp.float32),
        "orig_lengths": SDS((b,), jnp.float32),
        "lengths": SDS((b,), jnp.int32),
        # async pipeline (DESIGN.md §6): behaviour logprobs + per-sample
        # version lag drive the truncated-IS staleness correction; the
        # production cell lowers with them so the overlapped trainer and
        # the dry-run validate the same executable
        "behavior_logp": SDS((b, t), jnp.float32),
        "staleness": SDS((b,), jnp.float32),
    }
    axes = {
        "tokens": ("batch", None, None) if cfg.num_codebooks else ("batch", None),
        "response_mask": ("batch", None),
        "old_logp": ("batch", None),
        "advantages": ("batch",),
        "ht_weights": ("batch", None),
        "orig_lengths": ("batch",),
        "lengths": ("batch",),
        "behavior_logp": ("batch", None),
        "staleness": ("batch",),
    }
    if layout == "packed":
        s = num_segments or 2 * b
        del batch["lengths"], axes["lengths"]  # no padded-grid key mask
        for key in ("advantages", "orig_lengths", "staleness"):
            # per-RESPONSE leaves: segment count is decoupled from the row
            # count, so they replicate (tiny) instead of sharding on batch
            batch[key] = SDS((s,), jnp.float32)
            axes[key] = (None,)
        for key, ax in (("positions", ("batch", None)),
                        ("segment_ids", ("batch", None)),
                        ("resp_ids", ("batch", None))):
            batch[key] = SDS((b, t), jnp.int32)
            axes[key] = ax
    elif layout != "padded":
        raise ValueError(f"unknown step-spec layout {layout!r}")
    if cfg.num_image_tokens:
        batch["image_embeds"] = SDS(
            (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        axes["image_embeds"] = ("batch", "image_tokens", None)
    shards = {k: _sh(mesh, rules, batch[k].shape, axes[k]) for k in batch}
    return batch, shards


def make_train_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    rules: ShardingRules = DEFAULT_RULES,
                    opt_cfg: Optional[AdamWConfig] = None,
                    grpo_cfg: GRPOConfig = GRPOConfig(),
                    num_microbatches: int = 1,
                    unroll_microbatches: bool = False,
                    vocab_chunks: int = 8,
                    constrain_grads: bool = True,
                    layout: str = "padded",
                    num_segments: Optional[int] = None) -> CellSpec:
    from repro.rl.learner import make_train_step

    opt_cfg = opt_cfg or AdamWConfig(moment_dtype="int8")
    abs_p, shard_p = params_and_shardings(cfg, mesh, rules)
    abs_opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), abs_p)
    decl = model_decl(cfg)
    shard_opt = opt_state_shardings(abs_opt, param_specs(decl), mesh, rules)
    if layout == "packed" and num_microbatches > 1:
        # packed accumulation consumes a tuple of pre-packed chunks
        # (core.layout.build_microbatches): each chunk holds 1/m of the
        # rows and segments, so the abstract cell sizes per-chunk work
        # honestly (real runs may still pack each chunk to a different
        # shape — the spec models equal-shaped chunks)
        m = num_microbatches
        if shape.global_batch % m:
            raise ValueError(
                f"global_batch {shape.global_batch} does not split into "
                f"{m} microbatches")
        chunk_shape = dataclasses.replace(
            shape, global_batch=shape.global_batch // m)
        seg = (num_segments or 2 * shape.global_batch)
        batch, shard_b = train_inputs(cfg, chunk_shape, mesh, rules,
                                      layout=layout,
                                      num_segments=max(seg // m, 1))
        batch = tuple(batch for _ in range(m))
        shard_b = tuple(shard_b for _ in range(m))
    else:
        batch, shard_b = train_inputs(cfg, shape, mesh, rules, layout=layout,
                                      num_segments=num_segments)

    step = make_train_step(cfg, grpo_cfg, opt_cfg,
                           num_microbatches=num_microbatches,
                           mesh=mesh, rules=rules, vocab_chunks=vocab_chunks,
                           unroll_microbatches=unroll_microbatches,
                           param_shardings=shard_p if constrain_grads else None,
                           packed=(layout == "packed"))
    metrics_shard = None  # replicated scalars
    return CellSpec(
        fn=step,
        args=(abs_p, abs_opt, batch),
        in_shardings=(shard_p, shard_opt, shard_b),
        out_shardings=(shard_p, shard_opt, metrics_shard),
        donate=(0, 1),
    )


# ----------------------------------------------------------------- prefill
def make_prefill_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      rules: ShardingRules = DEFAULT_RULES) -> CellSpec:
    b, t = shape.global_batch, shape.seq_len
    abs_p, shard_p = params_and_shardings(cfg, mesh, rules)
    tokens = SDS((b, t, cfg.num_codebooks) if cfg.num_codebooks else (b, t),
                 jnp.int32)
    plens = SDS((b,), jnp.int32)
    tok_sh = _sh(mesh, rules, tokens.shape,
                 ("batch", None, None) if cfg.num_codebooks else ("batch", None))
    plen_sh = _sh(mesh, rules, plens.shape, ("batch",))
    extra_args, extra_shard = (), ()
    if cfg.num_image_tokens:
        img = SDS((b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        extra_args = (img,)
        extra_shard = (_sh(mesh, rules, img.shape, ("batch", "image_tokens", None)),)

    cache_sh = tree_shardings(cache_decl(cfg, b, t), cache_axes(cfg), mesh, rules)

    def fn(params, tokens, plens, *img):
        return prefill(params, cfg, tokens, cache_len=t, prefill_len=plens,
                       image_embeds=img[0] if img else None, mesh=mesh,
                       rules=rules)

    logits_sh = None
    return CellSpec(
        fn=fn,
        args=(abs_p, tokens, plens) + extra_args,
        in_shardings=(shard_p, tok_sh, plen_sh) + extra_shard,
        out_shardings=(logits_sh, cache_sh),
    )


# ------------------------------------------------------------------ decode
def make_decode_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     rules: ShardingRules = DEFAULT_RULES) -> CellSpec:
    from repro.models.model import decode_step

    b, t = shape.global_batch, shape.seq_len
    abs_p, shard_p = params_and_shardings(cfg, mesh, rules)
    abs_cache = cache_decl(cfg, b, t)
    shard_cache = tree_shardings(abs_cache, cache_axes(cfg), mesh, rules)
    tokens = SDS((b, cfg.num_codebooks) if cfg.num_codebooks else (b,), jnp.int32)
    pos = SDS((b,), jnp.int32)
    tok_sh = _sh(mesh, rules, tokens.shape,
                 ("batch", None) if cfg.num_codebooks else ("batch",))
    pos_sh = _sh(mesh, rules, pos.shape, ("batch",))

    def fn(params, tokens, cache, pos):
        return decode_step(params, cfg, tokens, cache, pos)

    return CellSpec(
        fn=fn,
        args=(abs_p, tokens, abs_cache, pos),
        in_shardings=(shard_p, tok_sh, shard_cache, pos_sh),
        out_shardings=(None, shard_cache),
        donate=(2,),
    )


# -------------------------------------------------------------- publication
def publication_shardings(cfg: ModelConfig, fleet_mesh):
    """Replicated NamedShardings for publishing learner params onto one
    fleet slice (DESIGN.md §12).

    A fleet replica runs the whole model, so every param leaf is fully
    replicated over the slice's (usually 1-D) mesh — this is the target
    tree a multi-device slice would hand to ``WeightPublisher`` instead of
    a single device.  Returns ``(abstract_params, shardings)`` so the
    dry-run can validate the resharding transfer without allocating."""
    decl = model_decl(cfg)
    abs_p = abstract_params(decl)
    replicated = jax.sharding.NamedSharding(
        fleet_mesh, jax.sharding.PartitionSpec())
    return abs_p, jax.tree_util.tree_map(lambda _: replicated, abs_p)


def make_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
              rules: ShardingRules = DEFAULT_RULES, **kw) -> CellSpec:
    if shape.kind == "train":
        return make_train_cell(cfg, shape, mesh, rules, **kw)
    if shape.kind == "prefill":
        return make_prefill_cell(cfg, shape, mesh, rules)
    if shape.kind == "decode":
        return make_decode_cell(cfg, shape, mesh, rules)
    raise ValueError(shape.kind)


def rules_for(shape: ShapeSpec, rules: ShardingRules = DEFAULT_RULES,
              profile: str = "default") -> ShardingRules:
    """Shape-dependent rule overrides: long-context decode (batch=1) shards
    the KV-cache sequence over BOTH mesh axes.  ``profile`` selects a named
    base rule-set (e.g. "small_model" replicates weights, full DP)."""
    from repro.dist.sharding import RULE_PROFILES

    if profile != "default":
        rules = RULE_PROFILES[profile]
    if shape.kind == "decode" and shape.global_batch == 1:
        return rules.override(kv_seq=("data", "model"))
    return rules
