"""Multi-pod dry-run: prove every (architecture × input shape × mesh) cell
lowers + compiles under the production sharding config, and extract the
roofline terms — with NO real hardware and NO array allocation.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch nemotron-4-340b \
        --shape train_4k --mesh single --probes

Per cell this script:
  1. builds abstract inputs + NamedShardings (launch/step_specs.py),
  2. jit().lower().compile() the REAL program (scan-over-layers, grad
     accumulation) — prints memory_analysis()/cost_analysis(), validating
     the sharding config and the per-device memory fit,
  3. (--probes) compiles small UNROLLED probe variants (1 vs 2 superblocks
     per layer group; 1 vs 2 microbatches) and affinely extrapolates exact
     per-device FLOPs / bytes / collective bytes — XLA's cost analysis
     counts while-loop bodies once, so the scanned compile cannot be used
     for totals directly (see launch/hlo_stats.py),
  4. appends a JSON record to --out (default experiments/dryrun.jsonl).
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; these
# two lines must run before ANY other import — jax locks the device count
# on first initialization.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ALL_ARCHS, get_config, SHAPES, shapes_for  # noqa: E402
from repro.configs.shapes import ShapeSpec  # noqa: E402
from repro.launch import hlo_stats  # noqa: E402
from repro.launch.mesh import make_production_mesh, set_ambient_mesh  # noqa: E402
from repro.launch.step_specs import make_cell, rules_for  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.model import model_decl  # noqa: E402
from repro.models.params import count_params  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402


# ----------------------------------------------------------- compile one
def compile_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                 rules_profile: str = "default", **kw):
    rules = rules_for(shape, profile=rules_profile)
    cell = make_cell(cfg, shape, mesh, rules, **kw)
    set_ambient_mesh(mesh)
    t0 = time.time()
    lowered = jax.jit(
        cell.fn, in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate or ()).lower(*cell.args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


def _measure(compiled, num_devices: int) -> dict:
    txt = compiled.as_text()
    return {
        **hlo_stats.cost_stats(compiled),
        "collectives": hlo_stats.collective_bytes(txt, num_devices),
        "memory": hlo_stats.memory_stats(compiled),
    }


# ------------------------------------------------------------- probe math
def _probe_cfg(cfg: ModelConfig, depths) -> ModelConfig:
    blocks = tuple((pat, d) for (pat, _), d in zip(cfg.blocks, depths))
    return dataclasses.replace(cfg, blocks=blocks, scan_layers=False)


def _probe_shape(shape: ShapeSpec, batch: int) -> ShapeSpec:
    return dataclasses.replace(shape, global_batch=batch)


def probe_extrapolate(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                      micro_rows: int, num_micro: int, opt_cfg,
                      rules_profile: str = "default") -> dict:
    """Affine probe extrapolation of per-device flops/bytes/collective bytes.

    Model (exact for homogeneous layer groups):
      train:    cost(M, L) = opt_base + Σ_g opt_g·L_g + M·(c_base + Σ_g c_g·L_g)
      pre/dec:  cost(L)    = c_base + Σ_g c_g·L_g
    Probes hold the PER-MICROBATCH row count at the real value (micro_rows)
    and vary (M ∈ {1,2}, depth_g ∈ {1,2}) with everything unrolled, so XLA's
    cost analysis sees every instance.
    """
    nd = mesh.devices.size
    groups = len(cfg.blocks)
    depths1 = [1] * groups
    is_train = shape.kind == "train"

    def run(depths, m=1):
        if is_train:
            kw = dict(opt_cfg=opt_cfg, num_microbatches=m,
                      unroll_microbatches=True)
            s = _probe_shape(shape, micro_rows * m)
        else:
            kw = {}
            s = shape
        comp, _ = compile_cell(_probe_cfg(cfg, depths), s, mesh,
                               rules_profile=rules_profile, **kw)
        meas = _measure(comp, nd)
        return {"flops": meas["flops"], "bytes": meas["bytes"],
                "coll": meas["collectives"].get("total", 0.0)}

    def bump(g):
        d = list(depths1)
        d[g] = 2
        return d

    real_depths = [r for _, r in cfg.blocks]
    pa = run(depths1, m=1)
    s1 = [{k: run(bump(g), m=1)[k] - pa[k] for k in pa} for g in range(groups)]

    total = {}
    if is_train:
        pc = run(depths1, m=2)
        u = {k: pc[k] - pa[k] for k in pa}                  # c_base + Σ c_g
        s2 = [{k: run(bump(g), m=2)[k] - pc[k] for k in pa}
              for g in range(groups)]                        # opt_g + 2 c_g
        for k in pa:
            c_g = [s2[g][k] - s1[g][k] for g in range(groups)]
            opt_g = [s1[g][k] - c_g[g] for g in range(groups)]
            c_base = u[k] - sum(c_g)
            opt_base = pa[k] - sum(opt_g) - u[k]
            total[k] = (opt_base
                        + sum(opt_g[g] * real_depths[g] for g in range(groups))
                        + num_micro * (c_base + sum(
                            c_g[g] * real_depths[g] for g in range(groups))))
    else:
        for k in pa:
            c_g = [s1[g][k] for g in range(groups)]
            c_base = pa[k] - sum(c_g)
            total[k] = c_base + sum(c_g[g] * real_depths[g]
                                    for g in range(groups))
    return total


# ----------------------------------------------------------- model flops
def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token: total minus non-routed expert weights."""
    total = count_params(model_decl(cfg))
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    inactive = 0
    for pattern, repeat in cfg.blocks:
        for kind in pattern:
            if cfg.mlp_of(kind) == "moe":
                inactive += repeat * (m.num_experts - m.top_k) * per_expert
    return total - inactive


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (prefill) /
    2·N_active·batch (decode, per step)."""
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


# ------------------------------------------------------------------ main
def plan_microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh) -> tuple:
    """(micro_rows, num_micro): default 1 row per data shard per microbatch,
    bounded so num_micro >= 1."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    micro_rows = max(dp, shape.global_batch // 16)
    micro_rows = min(micro_rows, shape.global_batch)
    num_micro = max(shape.global_batch // micro_rows, 1)
    return micro_rows, num_micro


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             probes: bool, out_path: str,
             rules_profile: str = "default",
             seq_len: int = 0, label: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if seq_len:  # ad-hoc hillclimb cell (e.g. the RPC expected bucket)
        shape = dataclasses.replace(shape, seq_len=seq_len)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    nd = mesh.devices.size
    rec = {"arch": arch, "shape": label or shape_name, "mesh": mesh_name,
           "devices": nd, "status": "ok", "rules": rules_profile}
    try:
        opt_cfg = AdamWConfig(moment_dtype="int8")
        kw = {}
        if shape.kind == "train":
            if rules_profile == "small_model":
                # pure DP: one microbatch, batch over every axis
                micro_rows, num_micro = shape.global_batch, 1
            else:
                micro_rows, num_micro = plan_microbatches(cfg, shape, mesh)
            kw = dict(opt_cfg=opt_cfg, num_microbatches=num_micro)
            rec.update(micro_rows=micro_rows, num_micro=num_micro)
        compiled, times = compile_cell(cfg, shape, mesh,
                                       rules_profile=rules_profile, **kw)
        rec.update(times)
        meas = _measure(compiled, nd)
        rec["memory"] = meas["memory"]
        rec["scan_cost"] = {"flops": meas["flops"], "bytes": meas["bytes"],
                            "coll": meas["collectives"]}
        print(compiled.memory_analysis())
        print(hlo_stats.cost_stats(compiled))
        del compiled

        if probes and mesh_name == "single":
            tot = probe_extrapolate(cfg, shape, mesh,
                                    micro_rows=rec.get("micro_rows", 1),
                                    num_micro=rec.get("num_micro", 1),
                                    opt_cfg=opt_cfg,
                                    rules_profile=rules_profile)
            rec["probe_total_per_dev"] = tot
            mf = model_flops(cfg, shape)
            rec["model_flops_total"] = mf
            rec["hlo_flops_total"] = tot["flops"] * nd
            rec["useful_ratio"] = mf / max(tot["flops"] * nd, 1.0)
            rec["roofline"] = hlo_stats.roofline_terms(
                tot["flops"], tot["bytes"], tot["coll"])
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    status = rec["status"]
    dom = rec.get("roofline", {}).get("dominant", "-")
    print(f"[{status}] {arch} × {shape_name} × {mesh_name} "
          f"compile={rec.get('compile_s', 0):.1f}s dominant={dom}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--probes", action="store_true",
                    help="run roofline probe compiles (single-pod only)")
    ap.add_argument("--rules", default="default",
                    choices=["default", "small_model"],
                    help="sharding-rule profile (small_model = replicated "
                         "weights, full DP — the sub-1B hillclimb)")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--seq-len", type=int, default=0,
                    help="override the shape's seq_len (hillclimb cells)")
    ap.add_argument("--label", default="",
                    help="shape label override for the record")
    args = ap.parse_args()

    archs = ALL_ARCHS if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        cell_shapes = ([s.name for s in shapes_for(cfg)]
                       if args.shape == "all" else [args.shape])
        for shape_name in cell_shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape_name, mesh_name,
                               probes=args.probes, out_path=args.out,
                               rules_profile=args.rules,
                               seq_len=args.seq_len, label=args.label)
                n_fail += rec["status"] != "ok"
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
