"""Real training entry point (the launcher a cluster job would run).

    PYTHONPATH=src python -m repro.launch.train \
        --arch nat-qwen3-8b --preset smoke --selector rpc --steps 50 \
        --ckpt-dir /tmp/nat_ckpt --ckpt-every 10

On this CPU container the ``smoke`` preset (reduced config) actually trains;
the ``full`` preset builds the exact assigned architecture and is what a TPU
job would launch (same code path the dry-run compiles).  Fault tolerance:
periodic async checkpoints (params, optimizer, data cursor, PRNG, step),
SIGTERM triggers a final save, and restart auto-resumes from the latest
checkpoint — onto whatever mesh the restarted job has (elastic restore).
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.optim import AdamWConfig
from repro.rl import NATGRPOTrainer, NATTrainerConfig, RolloutConfig
from repro.rl.dist_trainer import make_dist_trainer
from repro.rl.env import VOCAB_SIZE as ENV_VOCAB


def build_model_cfg(arch: str, preset: str):
    cfg = get_smoke(arch) if preset == "smoke" else get_config(arch)
    if preset == "smoke":
        # the RL env has its own tiny vocabulary
        cfg = dataclasses.replace(cfg, vocab_size=max(ENV_VOCAB, 32))
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nat-qwen3-8b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--selector", default="rpc",
                    choices=["full", "grpo", "urs", "rpc", "det_trunc", "entropy"])
    ap.add_argument("--min-cut", type=int, default=8)
    ap.add_argument("--urs-p", type=float, default=0.5)
    ap.add_argument("--env", default="mod_arith", choices=["mod_arith", "copy_calc"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--prompts-per-step", type=int, default=8)
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--overprovision", type=float, default=1.25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--layout", default="",
                    choices=["", "padded", "bucketed", "packed"],
                    help="learner batch layout (core/layout.py, DESIGN.md "
                         "§7); default derives from the selector's repack")
    ap.add_argument("--rollout-engine", default="continuous",
                    choices=["continuous", "paged", "legacy"],
                    help="rollout arena: dense slot rows, paged KV pool "
                         "with group prefix sharing (DESIGN.md §8), or "
                         "the legacy fixed-shape scan")
    ap.add_argument("--fleet", type=int, default=0,
                    help="replicated rollout fleet size (DESIGN.md §12): "
                         "carve the device set into a learner slice plus N "
                         "engine replicas with device-to-device weight "
                         "publication; 0 = single in-process engine")
    ap.add_argument("--disagg", default="", choices=["", "prefill,decode"],
                    help="split each fleet slice into a prefill cell and a "
                         "paged decode arena (requires --rollout-engine "
                         "paged; checked against models/capabilities.py at "
                         "config time)")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="bounded staleness for the overlapped pipeline "
                         "(0 = serial; required 0 for bit-exact parity)")
    ap.add_argument("--fleet-elastic", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="supervise the fleet (DESIGN.md §13): heartbeat "
                         "each actor, reclaim a dead/hung replica's claimed "
                         "group for a token-exact re-roll by a survivor, "
                         "and allow add_replica joins mid-run; "
                         "--no-fleet-elastic dies on first replica failure")
    ap.add_argument("--hang-timeout", type=float, default=300.0,
                    help="seconds a claimed group may sit with no heartbeat "
                         "and no engine progress before the supervisor "
                         "condemns the replica and reclaims its group")
    ap.add_argument("--supervise-interval", type=float, default=0.2,
                    help="supervisor monitor poll period in seconds")
    ap.add_argument("--publish-retries", type=int, default=3,
                    help="bounded attempts for weight publication before "
                         "escalating PublicationError (DESIGN.md §13)")
    ap.add_argument("--placement-retries", type=int, default=3,
                    help="bounded rollout attempts under transient "
                         "PagePoolExhausted before escalating")
    ap.add_argument("--eval-prompts", type=int, default=32)
    args = ap.parse_args(argv)

    model_cfg = build_model_cfg(args.arch, args.preset)
    sel_kwargs = ()
    if args.selector == "rpc":
        sel_kwargs = (("min_cut", args.min_cut),)
    elif args.selector == "urs":
        sel_kwargs = (("p", args.urs_p),)
    tcfg = NATTrainerConfig(
        env=args.env,
        selector=args.selector,
        selector_kwargs=sel_kwargs,
        prompts_per_step=args.prompts_per_step,
        rollout=RolloutConfig(max_new_tokens=args.max_new,
                              group_size=args.group_size,
                              overprovision=args.overprovision),
        adamw=AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        layout=args.layout,
        rollout_engine=args.rollout_engine,
        max_staleness=args.max_staleness,
        fleet=args.fleet,
        disagg=args.disagg,
        supervise=args.fleet_elastic,
        hang_timeout=args.hang_timeout,
        supervise_interval=args.supervise_interval,
        publish_retries=args.publish_retries,
        placement_retries=args.placement_retries,
        seed=args.seed,
    )
    # config-time capability check happens inside the dist constructor
    # (models/capabilities.py::check_slice_handoff) — a mixer whose state
    # can't hand off across slices fails HERE, not 50 steps in
    if args.fleet or args.disagg or args.max_staleness:
        trainer = make_dist_trainer(model_cfg, tcfg)
    else:
        trainer = NATGRPOTrainer(model_cfg, tcfg)

    # the trainer's own quiesce-checkpoint (DESIGN.md §6) persists params,
    # optimizer, AND the async cursors (learner version, actor key chain,
    # pipeline step): resume is token-exact for this serial trainer, and a
    # clean group boundary for the max_staleness>0 pipeline
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        trainer.restore_checkpoint(ckpt)
        print(f"resumed from step {trainer.step_count}")

    def on_sigterm(signum, frame):
        print("SIGTERM received: saving final checkpoint", file=sys.stderr)
        if ckpt is not None:
            trainer.save_checkpoint(ckpt, blocking=True)
        sys.exit(0)

    signal.signal(signal.SIGTERM, on_sigterm)

    while trainer.step_count < args.steps:
        m = trainer.train_step()
        s = trainer.step_count
        if args.log_every and s % args.log_every == 0:
            print(f"step {s:4d} reward={m['reward_mean']:.3f} "
                  f"loss={m['loss']:+.4f} sel={m.get('selected_ratio', 1.0):.2f} "
                  f"grad={m['grad_norm']:.2f} t={m['time_total']:.2f}s")
        if ckpt is not None and s % args.ckpt_every == 0:
            trainer.save_checkpoint(ckpt, blocking=False)

    if ckpt is not None:
        ckpt.wait()
        trainer.save_checkpoint(ckpt, blocking=True)
    ev = trainer.evaluate(args.eval_prompts)
    print(f"final eval: accuracy={ev['accuracy']:.3f} "
          f"mean_resp_len={ev['resp_len']:.1f}")
    trainer.close()
    return trainer


if __name__ == "__main__":
    main()
