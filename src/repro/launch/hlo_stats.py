"""Extract roofline terms from compiled XLA artifacts.

* ``cost_stats``       — per-device FLOPs / bytes from ``cost_analysis()``.
* ``collective_bytes`` — per-device collective traffic, parsed from the
  optimized HLO text: for each all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute we take the output shape + replica-group
  size and apply standard ring estimates.
* ``roofline_terms``   — the three §Roofline terms in seconds for TPU v5e
  (197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI per chip).

NOTE (documented bias): XLA cost analysis counts a while-loop body ONCE, so
scanned-over-layers programs under-report.  The dry-run therefore derives
totals from small UNROLLED probe compiles and affine extrapolation (exact
for homogeneous layer stacks); the full scanned compile is still built to
validate sharding and memory.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link (estimate per assignment)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, num_devices: int) -> Dict[str, float]:
    """Per-device bytes moved over the interconnect, ring estimates."""
    out = Counter()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done" in stripped.split("(")[0]:
            continue
        op = None
        for c in _COLL:
            token = " " + c
            if (token + "(" in stripped or token + "-start(" in stripped):
                op = c
                break
        if op is None:
            continue
        head = stripped.split(" " + op)[0]  # "%x = <output shapes>"
        out_bytes = _shape_bytes(head.split("=", 1)[-1])
        n = _group_size(stripped, num_devices)
        if n <= 1:
            continue
        if op == "all-gather":
            moved = out_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            moved = out_bytes * (n - 1)          # input = out * n
        elif op == "all-reduce":
            moved = 2.0 * out_bytes * (n - 1) / n
        elif op == "all-to-all":
            moved = out_bytes * (n - 1) / n
        else:  # collective-permute
            moved = float(out_bytes)
        out[op] += moved
        out["total"] += moved
    return dict(out)


def cost_stats(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5: one dict per computation
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_bytes": float(ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes
                            - ma.alias_size_in_bytes),
    }


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    comp = flops_per_dev / PEAK_FLOPS
    mem = bytes_per_dev / HBM_BW
    coll = coll_bytes_per_dev / ICI_BW
    dominant = max((comp, "compute"), (mem, "memory"), (coll, "collective"))[1]
    total = max(comp, mem, coll)
    return {
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": comp / total if total > 0 else 0.0,
    }
