"""Async serving front-end over the paged rollout engine (DESIGN.md §10).

``AsyncLMServer`` turns the batch-oriented ``PagedRolloutEngine`` into a
request/response server: callers ``submit()`` token prompts and get back a
``TokenStream`` they can async-iterate for incremental output, while one
pump task drives the engine and a deficit-round-robin scheduler arbitrates
admission between tenants.

Three concerns live here and NOT in the engine, on purpose:

* **Admission + fairness.**  Requests queue per tenant; each scheduler
  cycle credits every active tenant ``quantum * weight`` token-credits and
  admits from the head of its queue while credits cover the request's cost
  (prompt tokens + decode budget).  A tenant flooding the server therefore
  cannot starve a light one — admission interleaves proportionally to
  weight, not arrival order.  Deficits reset when a tenant's queue drains,
  so credit cannot be hoarded while idle (classic DRR).
* **Backpressure, two layers.**  The engine-side backlog is capped at
  ``max_backlog`` groups so queued work stays in the server where fairness
  applies; the server-side queue is capped at ``max_queue`` requests, past
  which ``submit`` raises ``ServerSaturated`` — graceful shedding, the
  caller sees an explicit signal while admitted requests keep streaming.
* **Streaming.**  The engine's ``on_token`` deltas land on each request's
  ``TokenStream`` queue; its ``on_finish`` completion resolves the
  stream's result future.  Deltas always precede the completion (engine
  contract), so a consumer that exhausts the iterator has seen every
  token before ``result()`` resolves.

The pump is deliberately simple: one asyncio task alternating
``admit -> engine.drive() -> yield``.  ``drive`` is a blocking jax
dispatch — fine here, because the engine batches all live requests into
that one call; concurrency between *requests* comes from the engine's
continuous batching, and the event loop only needs to interleave
*consumers* between rounds.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from typing import AsyncIterator, Dict, List, Optional

import numpy as np

from repro.rl.engine import Completion, Request


class ServerSaturated(RuntimeError):
    """Both queues are full — the request was shed, try again later.

    ``retry_after_s`` is the server's own estimate of when a slot will
    free up, derived from the recent completion drain rate (see
    ``AsyncLMServer._retry_after``): a saturated caller can sleep that
    long instead of hammering ``submit`` in a tight loop.  Falls back to
    0.1 s when the server has not completed anything recently."""

    def __init__(self, msg: str, retry_after_s: float = 0.1):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Front-end knobs (the engine keeps its own ``PagedEngineConfig``)."""

    max_queue: int = 64       # server-side cap: pending requests before shed
    max_backlog: int = 2      # engine-side cap: unplaced groups pushed ahead
    quantum: int = 64         # DRR token-credits per tenant per cycle
    default_budget: int = 0   # 0 -> the engine rollout config's max_new

    def __post_init__(self):
        if self.quantum < 1:
            raise ValueError("quantum must be >= 1 (DRR cannot progress)")
        if self.max_queue < 1 or self.max_backlog < 1:
            raise ValueError("max_queue and max_backlog must be >= 1")


class TokenStream:
    """One request's live output: async-iterate numpy token deltas, then
    ``await result()`` for the final ``Completion``."""

    _DONE = object()

    def __init__(self, uid: int, tenant: str, loop: asyncio.AbstractEventLoop):
        self.uid = uid
        self.tenant = tenant
        self.submit_time = time.perf_counter()
        self.first_token_time: Optional[float] = None
        self._deltas: asyncio.Queue = asyncio.Queue()
        self._result: asyncio.Future = loop.create_future()

    # -- producer side (server callbacks) ---------------------------------
    def _push(self, toks: np.ndarray) -> None:
        if self.first_token_time is None:
            self.first_token_time = time.perf_counter()
        self._deltas.put_nowait(toks)

    def _finish(self, comp: Completion) -> None:
        # a zero-delta finish still records TTFT at completion time so
        # empty responses don't poison the latency statistics with None
        if self.first_token_time is None:
            self.first_token_time = time.perf_counter()
        self._deltas.put_nowait(self._DONE)
        if not self._result.done():
            self._result.set_result(comp)

    # -- consumer side ----------------------------------------------------
    def __aiter__(self) -> AsyncIterator[np.ndarray]:
        return self

    async def __anext__(self) -> np.ndarray:
        item = await self._deltas.get()
        if item is self._DONE:
            raise StopAsyncIteration
        return item

    async def result(self) -> Completion:
        return await self._result

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


@dataclasses.dataclass
class _Queued:
    request: Request
    stream: TokenStream
    cost: int


class AsyncLMServer:
    """Admission + fairness + streaming over one paged engine session.

    Usage::

        server = AsyncLMServer(engine, params, key, scfg)
        await server.start()
        stream = server.submit(tokens, tenant="alice", max_new=32)
        async for delta in stream: ...
        comp = await stream.result()
        await server.stop()

    ``tenant_weights`` scales each tenant's DRR credit (default 1.0); an
    unknown tenant gets weight 1.0 — tenants are created on first submit.
    """

    def __init__(self, engine, params, key, scfg: ServeConfig = ServeConfig(),
                 *, tenant_weights: Optional[Dict[str, float]] = None):
        self.engine = engine
        self.scfg = scfg
        self._params = params
        self._key = key
        self._weights = dict(tenant_weights or {})
        self._queues: Dict[str, List[_Queued]] = {}
        self._deficit: Dict[str, float] = {}
        self._rr: List[str] = []          # tenant visit order (rotating)
        self._streams: Dict[int, TokenStream] = {}
        self._uid = itertools.count()
        self._pump_task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._stopping = False
        # recent completion timestamps -> drain-rate estimate for the
        # retry_after_s hint carried by ServerSaturated (DESIGN.md §13)
        self._finish_times: List[float] = []
        self.stats = {"submitted": 0, "admitted": 0, "completed": 0,
                      "shed": 0, "tokens_out": 0, "ttft_sum": 0.0,
                      "ttft_max": 0.0, "drive_rounds": 0}

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self.engine.begin(self._params, self._key,
                          on_finish=self._on_finish,
                          on_token=self._on_token)
        self._stopping = False
        self._pump_task = loop.create_task(self._pump())

    async def stop(self) -> None:
        """Stop pumping after in-flight work drains; queued-but-unadmitted
        requests are still admitted first (stop is graceful, not abort)."""
        self._stopping = True
        self._wake.set()
        if self._pump_task is not None:
            await self._pump_task
            self._pump_task = None

    async def drain(self) -> None:
        """Wait until every admitted and queued request has completed."""
        while self._streams or any(self._queues.values()):
            self._wake.set()
            await asyncio.sleep(0)

    # ------------------------------------------------------------- ingress
    def submit(self, tokens, *, tenant: str = "default",
               max_new: int = 0) -> TokenStream:
        """Queue one prompt; returns its ``TokenStream`` or raises
        ``ServerSaturated`` when the server-side queue is full."""
        queued = sum(len(q) for q in self._queues.values())
        if queued >= self.scfg.max_queue:
            self.stats["shed"] += 1
            hint = self._retry_after()
            raise ServerSaturated(
                f"queue full ({queued}/{self.scfg.max_queue} requests); "
                f"retry in ~{hint:.2f}s (completion drain-rate estimate)",
                retry_after_s=hint)
        budget = int(max_new) or self.scfg.default_budget
        uid = next(self._uid)
        req = Request(uid=uid,
                      tokens=np.asarray(tokens, np.int32).reshape(-1),
                      budget=budget)
        stream = TokenStream(uid, tenant, asyncio.get_event_loop())
        cost = len(req.tokens) + (budget or self.engine.rcfg.max_new_tokens)
        if tenant not in self._queues:
            self._queues[tenant] = []
            self._deficit[tenant] = 0.0
            self._rr.append(tenant)
        self._queues[tenant].append(_Queued(req, stream, cost))
        self._streams[uid] = stream
        self.stats["submitted"] += 1
        if self._wake is not None:
            self._wake.set()
        return stream

    async def submit_with_retry(self, tokens, *, tenant: str = "default",
                                max_new: int = 0, attempts: int = 3,
                                max_sleep_s: float = 1.0) -> TokenStream:
        """``submit`` with bounded backoff on ``ServerSaturated``.

        Sleeps ``min(retry_after_s, max_sleep_s)`` between attempts — the
        server's own drain-rate estimate paces the retry instead of a
        blind fixed interval — and re-raises the last ``ServerSaturated``
        once ``attempts`` are exhausted (never an unbounded spin)."""
        if attempts < 1:
            raise ValueError("attempts must be >= 1")
        for attempt in range(attempts):
            try:
                return self.submit(tokens, tenant=tenant, max_new=max_new)
            except ServerSaturated as e:
                if attempt + 1 >= attempts:
                    raise
                await asyncio.sleep(min(e.retry_after_s, max_sleep_s))
        raise AssertionError("unreachable")  # pragma: no cover

    def _retry_after(self) -> float:
        """Seconds until a queue slot likely frees: the mean gap between
        the last few completions.  With fewer than two recent completions
        there is no rate to measure — fall back to 0.1 s."""
        now = time.perf_counter()
        # only completions from the last few seconds say anything about
        # the *current* drain rate
        recent = [t for t in self._finish_times if now - t < 5.0]
        self._finish_times = recent
        if len(recent) < 2:
            return 0.1
        span = recent[-1] - recent[0]
        if span <= 0.0:
            return 0.1
        gap = span / (len(recent) - 1)
        return max(gap, 1e-3)

    # ----------------------------------------------------------- scheduler
    def _admit(self) -> int:
        """One DRR sweep: rotate tenants, credit ``quantum * weight``,
        admit head-of-line requests while credits cover their cost and the
        engine backlog stays under ``max_backlog``.  Returns admissions."""
        n = 0
        active = [t for t in self._rr if self._queues[t]]
        for tenant in active:
            if self.engine.backlog >= self.scfg.max_backlog:
                break
            q = self._queues[tenant]
            self._deficit[tenant] += (
                self.scfg.quantum * self._weights.get(tenant, 1.0))
            while q and self._deficit[tenant] >= q[0].cost:
                if self.engine.backlog >= self.scfg.max_backlog:
                    break
                item = q.pop(0)
                self._deficit[tenant] -= item.cost
                self.engine.submit_group([item.request])
                self.stats["admitted"] += 1
                n += 1
            if not q:
                self._deficit[tenant] = 0.0  # idle tenants hoard nothing
        # rotate so the next sweep starts with a different tenant
        if self._rr:
            self._rr.append(self._rr.pop(0))
        return n

    # -------------------------------------------------------- engine hooks
    def _on_token(self, uid: int, toks: np.ndarray) -> None:
        stream = self._streams.get(uid)
        if stream is not None and len(toks):
            stream._push(toks)
            self.stats["tokens_out"] += int(len(toks))

    def _on_finish(self, comp: Completion):
        stream = self._streams.pop(comp.uid, None)
        if stream is not None:
            stream._finish(comp)
            if stream.ttft is not None:
                self.stats["ttft_sum"] += stream.ttft
                self.stats["ttft_max"] = max(self.stats["ttft_max"],
                                             stream.ttft)
        self.stats["completed"] += 1
        self._finish_times.append(time.perf_counter())
        if len(self._finish_times) > 64:
            del self._finish_times[:-64]
        return None

    # ---------------------------------------------------------------- pump
    async def _pump(self) -> None:
        while True:
            while self._admit():
                pass
            has_queued = any(self._queues.values())
            if self.engine.idle and not has_queued:
                if self._stopping:
                    return
                self._wake.clear()
                await self._wake.wait()
                continue
            if not self.engine.idle:
                self.engine.drive()
                self.stats["drive_rounds"] += 1
            # yield so consumers can drain the deltas this round produced
            await asyncio.sleep(0)

    # ---------------------------------------------------------------- misc
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def mean_ttft(self) -> float:
        done = self.stats["completed"]
        return self.stats["ttft_sum"] / done if done else 0.0
