"""Async serving front-end: DRR admission, streaming, graceful shedding
over the paged rollout engine (DESIGN.md §10)."""
from repro.serve.server import (
    AsyncLMServer,
    ServeConfig,
    ServerSaturated,
    TokenStream,
)

__all__ = ["AsyncLMServer", "ServeConfig", "ServerSaturated", "TokenStream"]
