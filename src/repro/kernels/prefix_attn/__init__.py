from repro.kernels.prefix_attn.ops import (
    attention_bthd,
    packed_attention_bthd,
    packed_flash_attention,
    prefix_flash_attention,
)
from repro.kernels.prefix_attn.ref import attention_ref, packed_attention_ref

__all__ = [
    "attention_bthd",
    "packed_attention_bthd",
    "packed_flash_attention",
    "prefix_flash_attention",
    "attention_ref",
    "packed_attention_ref",
]
