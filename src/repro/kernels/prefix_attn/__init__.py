"""Prefix-aware and packed (segment-id block-sparse) flash attention
(DESIGN.md §4/§7).

Package shape shared with ``kernels/ht_loss`` and ``kernels/paged_attn``
(see docs/kernels.md): ``ref.py`` pure-jnp oracles, ``kernel.py`` Pallas
grids, ``ops.py`` jit-friendly wrappers.  ``prefix_flash_attention``
skips whole key blocks past each row's prefix cut;
``packed_flash_attention`` adds segment-id block sparsity so bin-packed
rows never attend across packed neighbors — per-token logp stays
bitwise identical to the padded grid.
"""
from repro.kernels.prefix_attn.ops import (
    attention_bthd,
    packed_attention_bthd,
    packed_flash_attention,
    prefix_flash_attention,
)
from repro.kernels.prefix_attn.ref import attention_ref, packed_attention_ref

__all__ = [
    "attention_bthd",
    "packed_attention_bthd",
    "packed_flash_attention",
    "prefix_flash_attention",
    "attention_ref",
    "packed_attention_ref",
]
