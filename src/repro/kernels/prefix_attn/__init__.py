from repro.kernels.prefix_attn.ops import attention_bthd, prefix_flash_attention
from repro.kernels.prefix_attn.ref import attention_ref

__all__ = ["attention_bthd", "prefix_flash_attention", "attention_ref"]
