"""jit wrappers + custom_vjp for prefix-aware and packed flash attention.

``prefix_flash_attention(q, k, v, cut_lens, window=0)`` — q (B, H, T, D),
k/v (B, KV, T, D), cut_lens (B,) int32.  Residuals are (q, k, v, O, LSE):
activation memory is O(B·H·T·D), never O(T^2).  GQA backward reduces the
per-query-head dk/dv over groups.

``packed_flash_attention(q, k, v, segment_ids)`` — the packed-layout
variant (core/layout.py): segment_ids (B, T) int32 confine attention to
same-segment tokens and drive the block-sparse skip of cross-segment KV
blocks.  Same residual/backward structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.prefix_attn import kernel as K


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def prefix_flash_attention(q, k, v, cut_lens, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    o, _ = K.fwd_pallas(q, k, v, cut_lens, window=window, bq=bq, bk=bk,
                        interpret=interpret)
    return o


def _fwd(q, k, v, cut_lens, window, bq, bk, interpret):
    o, lse = K.fwd_pallas(q, k, v, cut_lens, window=window, bq=bq, bk=bk,
                          interpret=interpret)
    return o, (q, k, v, o, lse, cut_lens)


def _bwd(window, bq, bk, interpret, res, do):
    q, k, v, o, lse, cut_lens = res
    dq, dk_full, dv_full = K.bwd_pallas(q, k, v, o, lse, do, cut_lens,
                                        window=window, bq=bq, bk=bk,
                                        interpret=interpret)
    kvh = k.shape[1]
    b, h, t, d = q.shape
    g = h // kvh
    dk = dk_full.reshape(b, kvh, g, t, d).sum(axis=2).astype(k.dtype)
    dv = dv_full.reshape(b, kvh, g, t, d).sum(axis=2).astype(v.dtype)
    return dq, dk, dv, None


prefix_flash_attention.defvjp(_fwd, _bwd)


def attention_bthd(q, k, v, cut_lens, *, window: int = 0, bq: int = 128,
                   bk: int = 128, interpret: bool = True):
    """(B, T, H, D)-layout convenience wrapper matching the model's attention
    call sites; transposes around the kernel layout."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = prefix_flash_attention(qt, kt, vt, cut_lens, window, bq, bk, interpret)
    return jnp.swapaxes(o, 1, 2)


# ------------------------------------------------------- packed (segment-id)
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def packed_flash_attention(q, k, v, segment_ids, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    o, _ = K.packed_fwd_pallas(q, k, v, segment_ids, bq=bq, bk=bk,
                               interpret=interpret)
    return o


def _packed_fwd(q, k, v, segment_ids, bq, bk, interpret):
    o, lse = K.packed_fwd_pallas(q, k, v, segment_ids, bq=bq, bk=bk,
                                 interpret=interpret)
    return o, (q, k, v, o, lse, segment_ids)


def _packed_bwd(bq, bk, interpret, res, do):
    q, k, v, o, lse, segment_ids = res
    dq, dk_full, dv_full = K.packed_bwd_pallas(q, k, v, o, lse, do,
                                               segment_ids, bq=bq, bk=bk,
                                               interpret=interpret)
    kvh = k.shape[1]
    b, h, t, d = q.shape
    g = h // kvh
    dk = dk_full.reshape(b, kvh, g, t, d).sum(axis=2).astype(k.dtype)
    dv = dv_full.reshape(b, kvh, g, t, d).sum(axis=2).astype(v.dtype)
    return dq, dk, dv, None


packed_flash_attention.defvjp(_packed_fwd, _packed_bwd)


def packed_attention_bthd(q, k, v, segment_ids, *, bq: int = 128,
                          bk: int = 128, interpret: bool = True):
    """(B, T, H, D)-layout convenience wrapper for the packed variant."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = packed_flash_attention(qt, kt, vt, segment_ids, bq, bk, interpret)
    return jnp.swapaxes(o, 1, 2)
