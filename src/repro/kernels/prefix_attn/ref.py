"""Pure-jnp oracles for prefix-aware and packed (segment-id) attention.

Semantics shared with the kernels:
  * causal: query i attends keys j <= i,
  * window w > 0: additionally j > i - w,
  * cut_lens (B,): positions t >= cut_lens[b] are INVALID — both as queries
    and keys (RPC physical truncation).  Outputs at invalid query rows are 0.
  * segment_ids (B, T) (packed variant): query i additionally attends only
    keys with the SAME segment id — packed neighbors are invisible to each
    other.  Padding slots carry a sentinel id, so they self-attend (their
    diagonal keeps the softmax row non-empty) without touching real tokens.
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(q, k, v, cut_lens, *, window: int = 0):
    """q: (B, H, T, D); k/v: (B, KV, T, D) with H % KV == 0; cut_lens (B,).

    Returns (out (B, H, T, D), logsumexp (B, H, T))."""
    b, h, t, d = q.shape
    kv = k.shape[1]
    g = h // kv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    scale = 1.0 / jnp.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), k.astype(F32)) * scale
    qi = jnp.arange(t)[:, None]
    kj = jnp.arange(t)[None, :]
    mask = kj <= qi
    if window > 0:
        mask &= (qi - kj) < window
    mask = mask[None, None]
    valid_k = (kj[None, None] < cut_lens[:, None, None, None])
    valid_q = (qi[None, None] < cut_lens[:, None, None, None])
    mask = mask & valid_k & valid_q
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(F32))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    row_ok = l > 0
    o = jnp.where(row_ok[..., None], o, 0.0)
    lse = jnp.where(row_ok, m_safe + jnp.log(jnp.maximum(l, 1e-30)), 0.0)
    return o.astype(q.dtype), lse


def packed_attention_ref(q, k, v, segment_ids):
    """Packed-layout causal attention: same-segment visibility only.

    q: (B, H, T, D); k/v: (B, KV, T, D) with H % KV == 0; segment_ids
    (B, T) int32.  Returns (out (B, H, T, D), logsumexp (B, H, T)).  The
    diagonal is always visible (j == i shares i's segment), so every
    softmax row is non-empty — no NaN path even on padding.
    """
    b, h, t, d = q.shape
    kv = k.shape[1]
    g = h // kv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    scale = 1.0 / jnp.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), k.astype(F32)) * scale
    qi = jnp.arange(t)[:, None]
    kj = jnp.arange(t)[None, :]
    mask = (kj <= qi)[None, None]
    mask = mask & (segment_ids[:, None, :, None]
                   == segment_ids[:, None, None, :])
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(F32))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    lse = m_safe + jnp.log(jnp.maximum(l, 1e-30))
    return o.astype(q.dtype), lse
