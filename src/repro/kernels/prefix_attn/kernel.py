"""Pallas TPU kernels: prefix-aware and packed (segment-id) flash attention.

This is the TPU realization of NAT's forward saving (DESIGN.md §3/§7), in
two variants:

* **prefix** — each sequence carries a cut length L_b; query/key blocks
  past the cut frontier are SKIPPED with ``pl.when`` — compute drops from
  O(T^2) to O(L_b^2) per sequence while shapes stay static (the repack
  bucket ladder handles the batch-level savings; this kernel handles the
  per-sequence remainder).
* **packed** — rows hold several sequences back to back with per-token
  segment ids (core/layout.py).  Attention must never cross packed
  neighbors, and the block-skip exploits the same structure: per-row
  segment ids are monotone, so a KV block whose [min, max] segment range
  cannot intersect the query block's is skipped wholesale — block-sparse
  over segment boundaries, elementwise id-equality masking inside blocks.

Layout: q (B, H, T, D), k/v (B, KV, T, D); GQA is handled in the BlockSpec
index map (query head h reads kv head h // (H // KV) — no kv repeat in HBM).

Three kernels per variant (flash-standard decomposition):
  fwd     — grid (B, H, Tq/bq, Tk/bk), online softmax, saves (O, LSE)
  bwd dq  — same grid, accumulates dq over k blocks
  bwd dkv — grid (B, H, Tk/bk, Tq/bq) (k outer), accumulates dk/dv over
            q blocks
cut_lens / per-block segment ranges ride in as scalar-prefetch operands.
All accumulation f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _block_mask(q0, k0, bq, bk, cut, window):
    """(bq, bk) validity mask for global query offset q0, key offset k0."""
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = (kj <= qi) & (kj < cut) & (qi < cut)
    if window > 0:
        m &= (qi - kj) < window
    return m


def _needed(qi, ki, bq, bk, cut, window):
    """Whether key block ki contributes to query block qi (block-level skip)."""
    q0, k0 = qi * bq, ki * bk
    need = (k0 <= q0 + bq - 1) & (k0 < cut) & (q0 < cut)
    if window > 0:
        need &= (k0 + bk - 1) > (q0 - window)
    return need


# -------------------------------------------------------------------- fwd
def _fwd_kernel(cut_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_sc, l_sc, acc_sc, *, bq, bk, nk, window, scale):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    cut = cut_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    @pl.when(_needed(qi, ki, bq, bk, cut, window))
    def _compute():
        q = q_ref[0, 0].astype(F32)                     # (bq, D)
        k = k_ref[0, 0].astype(F32)                     # (bk, D)
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST) * scale
        mask = _block_mask(qi * bq, ki * bk, bq, bk, cut, window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_sc[...]
        ok = l > 0
        lsafe = jnp.where(ok, l, 1.0)
        o_ref[0, 0] = jnp.where(ok[:, None], acc_sc[...] / lsafe[:, None],
                                0.0).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(ok, m_sc[...] + jnp.log(lsafe), 0.0)


def fwd_pallas(q, k, v, cut_lens, *, window: int = 0, bq: int = 128,
               bk: int = 128, interpret: bool = True):
    b, h, t, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    nq, nk = t // bq, t // bk
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_fwd_kernel, bq=bq, bk=bk, nk=nk, window=window,
                             scale=scale)
    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, qi, ki, cut: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, cut: (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, cut: (b_, h_ // g, ki, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, qi, ki, cut: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq), lambda b_, h_, qi, ki, cut: (b_, h_, qi)),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq,), F32),
                pltpu.VMEM((bq,), F32),
                pltpu.VMEM((bq, d), F32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, t), F32),
        ],
        interpret=interpret,
    )(cut_lens, q, k, v)
    return out


# ----------------------------------------------------------------- bwd: dq
def _bwd_dq_kernel(cut_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, acc_sc, *, bq, bk, nk, window, scale):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    cut = cut_ref[b]

    @pl.when(ki == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    @pl.when(_needed(qi, ki, bq, bk, cut, window))
    def _compute():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        do = do_ref[0, 0].astype(F32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST) * scale
        mask = _block_mask(qi * bq, ki * bk, bq, bk, cut, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST)
        ds = p * (dp - delta[:, None]) * scale
        acc_sc[...] += jax.lax.dot(ds, k, precision=jax.lax.Precision.HIGHEST)

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0, 0] = acc_sc[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------- bwd: dkv
def _bwd_dkv_kernel(cut_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_sc, dv_sc, *, bq, bk, nq, window, scale):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    cut = cut_ref[b]

    @pl.when(qi == 0)
    def _init():
        dk_sc[...] = jnp.zeros_like(dk_sc)
        dv_sc[...] = jnp.zeros_like(dv_sc)

    @pl.when(_needed(qi, ki, bq, bk, cut, window))
    def _compute():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        do = do_ref[0, 0].astype(F32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST) * scale
        mask = _block_mask(qi * bq, ki * bk, bq, bk, cut, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)          # (bq, bk)
        dv_sc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                          precision=jax.lax.Precision.HIGHEST)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST)
        ds = p * (dp - delta[:, None]) * scale                       # (bq, bk)
        dk_sc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                          precision=jax.lax.Precision.HIGHEST)

    @pl.when(qi == nq - 1)
    def _fin():
        dk_ref[0, 0] = dk_sc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_sc[...].astype(dv_ref.dtype)


def bwd_pallas(q, k, v, o, lse, do, cut_lens, *, window: int = 0,
               bq: int = 128, bk: int = 128, interpret: bool = True):
    """Returns (dq (B,H,T,D), dk (B,H,T,D), dv (B,H,T,D)) — dk/dv are
    PER-QUERY-HEAD here; ops.py reduces them over GQA groups."""
    b, h, t, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    nq, nk = t // bq, t // bk
    scale = 1.0 / (d ** 0.5)
    delta = jnp.sum(do.astype(F32) * o.astype(F32), axis=-1)  # (B,H,T)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, bq=bq, bk=bk, nk=nk, window=window,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki, c: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, c: (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, c: (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki, c: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq), lambda b_, h_, qi, ki, c: (b_, h_, qi)),
                pl.BlockSpec((1, 1, bq), lambda b_, h_, qi, ki, c: (b_, h_, qi)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, d),
                                   lambda b_, h_, qi, ki, c: (b_, h_, qi, 0)),
            scratch_shapes=[pltpu.VMEM((bq, d), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        interpret=interpret,
    )(cut_lens, q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, bq=bq, bk=bk, nq=nq, window=window,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, nk, nq),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), lambda b_, h_, ki, qi, c: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, ki, qi, c: (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, ki, qi, c: (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bq, d), lambda b_, h_, ki, qi, c: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq), lambda b_, h_, ki, qi, c: (b_, h_, qi)),
                pl.BlockSpec((1, 1, bq), lambda b_, h_, ki, qi, c: (b_, h_, qi)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bk, d), lambda b_, h_, ki, qi, c: (b_, h_, ki, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b_, h_, ki, qi, c: (b_, h_, ki, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((bk, d), F32), pltpu.VMEM((bk, d), F32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        ],
        interpret=interpret,
    )(cut_lens, q, k, v, do, lse, delta)
    return dq, dk, dv


# ====================================================== packed (segment-id)
def _packed_mask(q0, k0, bq, bk, segq, segk):
    """(bq, bk) validity: causal in the packed row AND same segment."""
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return (kj <= qi) & (segq[:, None] == segk[None, :])


def _packed_needed(qi, ki, bq, bk, lo_ref, hi_ref, b):
    """Block-level skip: causal overlap + segment-range intersection.

    ``lo/hi`` hold each block's min/max segment id (monotone per row, so
    min/max = first/last).  Disjoint ranges cannot contain an equal pair;
    overlapping ranges fall through to the elementwise mask.
    """
    causal = ki * bk <= qi * bq + bq - 1
    inter = (lo_ref[b, ki] <= hi_ref[b, qi]) & (lo_ref[b, qi] <= hi_ref[b, ki])
    return causal & inter


# -------------------------------------------------------------- packed fwd
def _packed_fwd_kernel(lo_ref, hi_ref, q_ref, k_ref, v_ref, segq_ref,
                       segk_ref, o_ref, lse_ref, m_sc, l_sc, acc_sc,
                       *, bq, bk, nk, scale):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    @pl.when(_packed_needed(qi, ki, bq, bk, lo_ref, hi_ref, b))
    def _compute():
        q = q_ref[0, 0].astype(F32)                     # (bq, D)
        k = k_ref[0, 0].astype(F32)                     # (bk, D)
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST) * scale
        mask = _packed_mask(qi * bq, ki * bk, bq, bk, segq_ref[0], segk_ref[0])
        s = jnp.where(mask, s, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_sc[...]
        ok = l > 0
        lsafe = jnp.where(ok, l, 1.0)
        o_ref[0, 0] = jnp.where(ok[:, None], acc_sc[...] / lsafe[:, None],
                                0.0).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(ok, m_sc[...] + jnp.log(lsafe), 0.0)


def seg_block_ranges(segment_ids, blk: int):
    """Per-block (min, max) segment-id summaries, each (B, T // blk) int32
    — the scalar-prefetch operands driving the packed block skip."""
    b, t = segment_ids.shape
    s = segment_ids.reshape(b, t // blk, blk)
    return (jnp.min(s, axis=2).astype(jnp.int32),
            jnp.max(s, axis=2).astype(jnp.int32))


def packed_fwd_pallas(q, k, v, segment_ids, *, bq: int = 128, bk: int = 128,
                      interpret: bool = True):
    b, h, t, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    assert bq == bk, "packed variant shares one block-range table: bq == bk"
    nq, nk = t // bq, t // bk
    scale = 1.0 / (d ** 0.5)
    lo, hi = seg_block_ranges(segment_ids, bq)
    kern = functools.partial(_packed_fwd_kernel, bq=bq, bk=bk, nk=nk,
                             scale=scale)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, lo_, hi_:
                             (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, lo_, hi_:
                             (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, bq),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, qi)),
                pl.BlockSpec((1, bk),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, ki)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi)),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq,), F32),
                pltpu.VMEM((bq,), F32),
                pltpu.VMEM((bq, d), F32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, t), F32),
        ],
        interpret=interpret,
    )(lo, hi, q, k, v, segment_ids, segment_ids)
    return out


# ----------------------------------------------------------- packed bwd: dq
def _packed_bwd_dq_kernel(lo_ref, hi_ref, q_ref, k_ref, v_ref, do_ref,
                          lse_ref, delta_ref, segq_ref, segk_ref, dq_ref,
                          acc_sc, *, bq, bk, nk, scale):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    @pl.when(_packed_needed(qi, ki, bq, bk, lo_ref, hi_ref, b))
    def _compute():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        do = do_ref[0, 0].astype(F32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST) * scale
        mask = _packed_mask(qi * bq, ki * bk, bq, bk, segq_ref[0], segk_ref[0])
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST)
        ds = p * (dp - delta[:, None]) * scale
        acc_sc[...] += jax.lax.dot(ds, k, precision=jax.lax.Precision.HIGHEST)

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0, 0] = acc_sc[...].astype(dq_ref.dtype)


# ---------------------------------------------------------- packed bwd: dkv
def _packed_bwd_dkv_kernel(lo_ref, hi_ref, q_ref, k_ref, v_ref, do_ref,
                           lse_ref, delta_ref, segq_ref, segk_ref, dk_ref,
                           dv_ref, dk_sc, dv_sc, *, bq, bk, nq, scale):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_sc[...] = jnp.zeros_like(dk_sc)
        dv_sc[...] = jnp.zeros_like(dv_sc)

    @pl.when(_packed_needed(qi, ki, bq, bk, lo_ref, hi_ref, b))
    def _compute():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        do = do_ref[0, 0].astype(F32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                precision=jax.lax.Precision.HIGHEST) * scale
        mask = _packed_mask(qi * bq, ki * bk, bq, bk, segq_ref[0], segk_ref[0])
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)          # (bq, bk)
        dv_sc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                          precision=jax.lax.Precision.HIGHEST)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST)
        ds = p * (dp - delta[:, None]) * scale                       # (bq, bk)
        dk_sc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                          precision=jax.lax.Precision.HIGHEST)

    @pl.when(qi == nq - 1)
    def _fin():
        dk_ref[0, 0] = dk_sc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_sc[...].astype(dv_ref.dtype)


def packed_bwd_pallas(q, k, v, o, lse, do, segment_ids, *, bq: int = 128,
                      bk: int = 128, interpret: bool = True):
    """Returns (dq (B,H,T,D), dk (B,H,T,D), dv (B,H,T,D)) — dk/dv are
    PER-QUERY-HEAD here; ops.py reduces them over GQA groups."""
    b, h, t, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    assert bq == bk, "packed variant shares one block-range table: bq == bk"
    nq, nk = t // bq, t // bk
    scale = 1.0 / (d ** 0.5)
    lo, hi = seg_block_ranges(segment_ids, bq)
    delta = jnp.sum(do.astype(F32) * o.astype(F32), axis=-1)  # (B,H,T)

    dq = pl.pallas_call(
        functools.partial(_packed_bwd_dq_kernel, bq=bq, bk=bk, nk=nk,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, lo_, hi_:
                             (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, qi, ki, lo_, hi_:
                             (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi)),
                pl.BlockSpec((1, 1, bq),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi)),
                pl.BlockSpec((1, bq),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, qi)),
                pl.BlockSpec((1, bk),
                             lambda b_, h_, qi, ki, lo_, hi_: (b_, ki)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, d),
                lambda b_, h_, qi, ki, lo_, hi_: (b_, h_, qi, 0)),
            scratch_shapes=[pltpu.VMEM((bq, d), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        interpret=interpret,
    )(lo, hi, q, k, v, do, lse, delta, segment_ids, segment_ids)

    dk, dv = pl.pallas_call(
        functools.partial(_packed_bwd_dkv_kernel, bq=bq, bk=bk, nq=nq,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, h, nk, nq),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, ki, qi, lo_, hi_:
                             (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, ki, qi, lo_, hi_:
                             (b_, h_ // g, ki, 0)),
                pl.BlockSpec((1, 1, bq, d),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, h_, qi)),
                pl.BlockSpec((1, 1, bq),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, h_, qi)),
                pl.BlockSpec((1, bq),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, qi)),
                pl.BlockSpec((1, bk),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, ki)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, h_, ki, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda b_, h_, ki, qi, lo_, hi_: (b_, h_, ki, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((bk, d), F32), pltpu.VMEM((bk, d), F32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        ],
        interpret=interpret,
    )(lo, hi, q, k, v, do, lse, delta, segment_ids, segment_ids)
    return dq, dk, dv
