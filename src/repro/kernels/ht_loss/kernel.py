"""Pallas TPU kernels for the fused HT-GRPO loss head.

The learner's memory hot spot is the (N, V) logits tensor (N = B*T tokens,
V up to 262k).  These kernels stream V in VMEM-sized tiles and never
materialize it:

* ``_fwd_kernel``    — logp(target), logsumexp, entropy per token.
* ``_bwd_dh_kernel`` — d(hidden): recomputes softmax tiles from the saved
                       logsumexp (flash-style residual), accumulates
                       dlogits @ W^T across V tiles in VMEM scratch.
* ``_bwd_dw_kernel`` — d(W): grid transposed (V outer, token-block inner) so
                       each dW tile accumulates over token blocks in scratch
                       and is written exactly once.

Grid iteration on TPU is sequential with the LAST axis fastest; scratch
persists across iterations, with @pl.when(first/last) init/finalize — the
same pattern as flash attention.  dtypes: inputs bf16/f32, all accumulation
in f32.  Tile sizes default to (block_n tokens × block_v vocab) with the
full D dimension resident (D ≤ ~8k for the archs that run the RL learner;
the D-tiled extension is a documented TODO).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


# ----------------------------------------------------------------- forward
def _fwd_kernel(h_ref, w_ref, tok_ref, logp_ref, logz_ref, ent_ref,
                m_sc, s_sc, tgt_sc, ed_sc, *, block_v: int, num_v: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        s_sc[...] = jnp.zeros_like(s_sc)
        tgt_sc[...] = jnp.zeros_like(tgt_sc)
        ed_sc[...] = jnp.zeros_like(ed_sc)

    h = h_ref[...].astype(F32)                      # (bn, D)
    w = w_ref[...].astype(F32)                      # (D, bv)
    logits = jax.lax.dot(h, w, precision=jax.lax.Precision.HIGHEST)  # (bn, bv)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    s_sc[...] = s_sc[...] * corr + jnp.sum(p, axis=-1)
    ed_sc[...] = ed_sc[...] * corr + jnp.sum(p * logits, axis=-1)
    m_sc[...] = m_new

    # target logit if it lands in this vocab tile
    tok = tok_ref[...]                              # (bn,) int32 global ids
    local = tok - vi * block_v
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    hit = cols == local[:, None]
    tgt_sc[...] = tgt_sc[...] + jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)

    @pl.when(vi == num_v - 1)
    def _fin():
        logz = m_sc[...] + jnp.log(s_sc[...])
        logz_ref[...] = logz
        logp_ref[...] = tgt_sc[...] - logz
        ent_ref[...] = logz - ed_sc[...] / s_sc[...]


def fwd_pallas(hidden, w, tokens, *, block_n: int = 256, block_v: int = 512,
               interpret: bool = True):
    """hidden: (N, D), w: (D, V), tokens: (N,) -> (logp, logz, ent) f32."""
    n, d = hidden.shape
    v = w.shape[1]
    assert n % block_n == 0 and v % block_v == 0, (n, v, block_n, block_v)
    num_n, num_v = n // block_n, v // block_v
    kern = functools.partial(_fwd_kernel, block_v=block_v, num_v=num_v)
    out_shape = [jax.ShapeDtypeStruct((n,), F32)] * 3
    return pl.pallas_call(
        kern,
        grid=(num_n, num_v),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=[pl.BlockSpec((block_n,), lambda i, j: (i,))] * 3,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block_n,), F32)] * 4,
        interpret=interpret,
    )(hidden, w, tokens)


# ------------------------------------------------------------ backward: dh
def _bwd_dh_kernel(h_ref, w_ref, tok_ref, logz_ref, g_ref, dh_ref, acc_sc,
                   *, block_v: int, num_v: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    h = h_ref[...].astype(F32)
    w = w_ref[...].astype(F32)
    logits = jax.lax.dot(h, w, precision=jax.lax.Precision.HIGHEST)
    p = jnp.exp(logits - logz_ref[...][:, None])     # softmax tile
    tok = tok_ref[...]
    local = tok - vi * block_v
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    onehot = (cols == local[:, None]).astype(F32)
    dlogits = (onehot - p) * g_ref[...][:, None]     # d logp(target)/d logits
    acc_sc[...] += jax.lax.dot(dlogits, w.T, precision=jax.lax.Precision.HIGHEST)

    @pl.when(vi == num_v - 1)
    def _fin():
        dh_ref[...] = acc_sc[...].astype(dh_ref.dtype)


def bwd_dh_pallas(hidden, w, tokens, logz, g, *, block_n: int = 256,
                  block_v: int = 512, interpret: bool = True):
    n, d = hidden.shape
    v = w.shape[1]
    num_n, num_v = n // block_n, v // block_v
    kern = functools.partial(_bwd_dh_kernel, block_v=block_v, num_v=num_v)
    return pl.pallas_call(
        kern,
        grid=(num_n, num_v),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), hidden.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, d), F32)],
        interpret=interpret,
    )(hidden, w, tokens, logz, g)


# ------------------------------------------------------------ backward: dW
def _bwd_dw_kernel(h_ref, w_ref, tok_ref, logz_ref, g_ref, dw_ref, acc_sc,
                   *, block_v: int, num_n: int):
    vi = pl.program_id(0)
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    h = h_ref[...].astype(F32)
    w = w_ref[...].astype(F32)
    logits = jax.lax.dot(h, w, precision=jax.lax.Precision.HIGHEST)
    p = jnp.exp(logits - logz_ref[...][:, None])
    tok = tok_ref[...]
    local = tok - vi * block_v
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    onehot = (cols == local[:, None]).astype(F32)
    dlogits = (onehot - p) * g_ref[...][:, None]
    acc_sc[...] += jax.lax.dot(h.T, dlogits, precision=jax.lax.Precision.HIGHEST)

    @pl.when(ni == num_n - 1)
    def _fin():
        dw_ref[...] = acc_sc[...].astype(dw_ref.dtype)


def bwd_dw_pallas(hidden, w, tokens, logz, g, *, block_n: int = 256,
                  block_v: int = 512, interpret: bool = True):
    n, d = hidden.shape
    v = w.shape[1]
    num_n, num_v = n // block_n, v // block_v
    kern = functools.partial(_bwd_dw_kernel, block_v=block_v, num_n=num_n)
    return pl.pallas_call(
        kern,
        grid=(num_v, num_n),  # V outer so each dW tile finishes before moving on
        in_specs=[
            pl.BlockSpec((block_n, d), lambda j, i: (i, 0)),
            pl.BlockSpec((d, block_v), lambda j, i: (0, j)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((d, block_v), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((d, v), w.dtype),
        scratch_shapes=[pltpu.VMEM((d, block_v), F32)],
        interpret=interpret,
    )(hidden, w, tokens, logz, g)
