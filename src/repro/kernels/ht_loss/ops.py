"""jit-able wrapper for the fused HT head: custom_vjp around the Pallas
kernels, saving only (logz,) per token — flash-style — instead of the
(N, V) logits.

``fused_token_logprobs(hidden, w, tokens)`` is a drop-in for the jnp chunked
path in ``repro.models.layers.chunked_token_logprobs`` (flattened (N, D)
layout; entropy is returned but NOT differentiated — it is a metrics-only
quantity in NAT, so its cotangent is dropped by design).
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp

from repro.kernels.ht_loss import kernel as K

F32 = jnp.float32


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fused_token_logprobs(hidden, w, tokens, block_n: int = 256,
                         block_v: int = 512, interpret: bool = True):
    """hidden: (N, D), w: (D, V), tokens: (N,) -> (logp (N,), entropy (N,)).

    Gradients flow to ``hidden`` and ``w`` through logp only.
    """
    logp, _, ent = K.fwd_pallas(hidden, w, tokens, block_n=block_n,
                                block_v=block_v, interpret=interpret)
    return logp, ent


def _fwd(hidden, w, tokens, block_n, block_v, interpret):
    logp, logz, ent = K.fwd_pallas(hidden, w, tokens, block_n=block_n,
                                   block_v=block_v, interpret=interpret)
    return (logp, ent), (hidden, w, tokens, logz)


def _bwd(block_n, block_v, interpret, res, cts):
    hidden, w, tokens, logz = res
    g_logp, _g_ent = cts  # entropy cotangent intentionally dropped (metrics)
    g = g_logp.astype(F32)
    dh = K.bwd_dh_pallas(hidden, w, tokens, logz, g, block_n=block_n,
                         block_v=block_v, interpret=interpret)
    dw = K.bwd_dw_pallas(hidden, w, tokens, logz, g, block_n=block_n,
                         block_v=block_v, interpret=interpret)
    return dh, dw, None


fused_token_logprobs.defvjp(_fwd, _bwd)


def fused_score_grid(hidden, w, tokens, *, block_n: int = 128,
                     block_v: int = 512, interpret: bool = True):
    """(B, T) grid convenience wrapper: scores tokens[:, 1:] from
    hidden[:, :-1] like ``score_tokens`` and left-pads — returns
    (logp (B, T), entropy (B, T))."""
    b, t = tokens.shape
    h = hidden[:, :-1].reshape(b * (t - 1), -1)
    tg = tokens[:, 1:].reshape(-1)
    n = h.shape[0]
    pad = (-n) % block_n
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        tg = jnp.pad(tg, (0, pad))
    logp, ent = fused_token_logprobs(h, w, tg, block_n, block_v, interpret)
    logp = logp[:n].reshape(b, t - 1)
    ent = ent[:n].reshape(b, t - 1)
    z = jnp.zeros((b, 1), logp.dtype)
    return (jnp.concatenate([z, logp], axis=1),
            jnp.concatenate([z, ent], axis=1))
