"""Pure-jnp oracle for the fused HT-GRPO loss head.

Materializes the full (N, V) logits/softmax — the memory hot spot the Pallas
kernel exists to avoid — and is the ground truth for all kernel tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def logprob_ref(hidden, w, tokens):
    """hidden: (N, D), w: (D, V), tokens: (N,) ->
    (logp (N,), logz (N,), entropy (N,)) in f32."""
    logits = jnp.einsum("nd,dv->nv", hidden, w, preferred_element_type=F32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, tokens[:, None], axis=-1)[:, 0]
    p = jax.nn.softmax(logits, axis=-1)
    ent = logz - jnp.sum(p * logits, axis=-1)
    return tgt - logz, logz, ent


def ht_grpo_loss_ref(hidden, w, tokens, old_logp, adv, ht_w, inv_len,
                     clip_eps: float = 0.2):
    """Full fused objective: chunk-free reference of what kernel+glue compute.

    hidden: (N, D); tokens/old_logp/ht_w/adv/inv_len: (N,).
    Returns scalar loss = -(1/N_seq-ish) handled by caller weights: here we
    return  -sum_n ht_w[n] * inv_len[n] * S_n  with S the clipped surrogate.
    """
    logp, _, _ = logprob_ref(hidden, w, tokens)
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    s = jnp.minimum(ratio * adv, clipped * adv)
    return -jnp.sum(ht_w * inv_len * s)
