"""Fused Horvitz-Thompson GRPO loss kernels (DESIGN.md §2/§4).

Package shape shared with ``kernels/prefix_attn`` and
``kernels/paged_attn`` (see docs/kernels.md): ``ref.py`` pure-jnp
oracles, ``kernel.py`` Pallas grids, ``ops.py`` jit-friendly wrappers.
``fused_token_logprobs`` streams the vocab projection in chunks so the
full ``(B, T, V)`` logits tensor never materializes;
``fused_score_grid`` fuses gather + log-softmax + the HT-weighted
clipped-ratio loss over the score grid, skipping compute past each
row's prefix cut.
"""
from repro.kernels.ht_loss.ops import fused_score_grid, fused_token_logprobs
from repro.kernels.ht_loss.ref import ht_grpo_loss_ref, logprob_ref

__all__ = ["fused_score_grid", "fused_token_logprobs", "ht_grpo_loss_ref",
           "logprob_ref"]
