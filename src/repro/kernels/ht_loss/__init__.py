from repro.kernels.ht_loss.ops import fused_score_grid, fused_token_logprobs
from repro.kernels.ht_loss.ref import ht_grpo_loss_ref, logprob_ref

__all__ = ["fused_score_grid", "fused_token_logprobs", "ht_grpo_loss_ref",
           "logprob_ref"]
