"""Pallas TPU kernel: paged decode attention over a block-table KV pool.

One query token per slot attends to its logical KV sequence, stored as
``(num_pages, page_len)`` pages named by a per-slot block table — the
decode-side twin of the prefix/packed prefill kernels (DESIGN.md §8).
The gather never materializes a dense per-slot KV copy in HBM: the block
table rides in as a scalar-prefetch operand and the page id feeds the
BlockSpec index map directly, so each grid step DMAs exactly one page.

Grid: ``(S, KV, M)`` — slot × kv-head × block-table column.  GQA is
handled by laying queries out as ``(S, KV, G, D)`` (G query heads per kv
head), so one grid step scores all G heads of a kv head against one page
with a single ``(G, page_len)`` matmul.

Skip structure: a block-table entry of ``-1`` (unallocated) skips the
whole page with ``pl.when`` — per-slot cost is O(allocated pages), not
O(M).  Inside a page, per-entry validity comes from the pool's ``pos``
plane (absolute positions, ``-1`` = empty, visible iff ``pos <= q_pos``)
— identical to the dense arena's visibility rule, so the partial
last-prompt-page gap needs no special case.  Online softmax in VMEM
scratch; all accumulation f32.  Decode-only: no backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_sc, l_sc, acc_sc, *, nm, scale):
    s = pl.program_id(0)
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    qp = qpos_ref[s]

    @pl.when((bt_ref[s, mi] >= 0) & (qp >= 0))
    def _compute():
        q = q_ref[0, 0].astype(F32)          # (G, D)
        k = k_ref[0, :, 0].astype(F32)       # (page_len, D)
        v = v_ref[0, :, 0].astype(F32)
        pos = pos_ref[0]                     # (page_len,)
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST) * scale
        valid = (pos >= 0) & (pos <= qp)     # (page_len,)
        sc = jnp.where(valid[None, :], sc, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        p = jnp.where(valid[None, :], p, 0.0)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m_sc[...] = m_new

    @pl.when(mi == nm - 1)
    def _fin():
        l = l_sc[...]
        ok = l > 0
        lsafe = jnp.where(ok, l, 1.0)
        o_ref[0, 0] = jnp.where(ok[:, None], acc_sc[...] / lsafe[:, None],
                                0.0).astype(o_ref.dtype)


def _mla_kernel(bt_ref, qpos_ref, qa_ref, qr_ref, c_ref, kr_ref, pos_ref,
                o_ref, m_sc, l_sc, acc_sc, *, nm, scale):
    s = pl.program_id(0)
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    qp = qpos_ref[s]

    @pl.when((bt_ref[s, mi] >= 0) & (qp >= 0))
    def _compute():
        qa = qa_ref[0].astype(F32)           # (H, R) absorbed queries
        qr = qr_ref[0].astype(F32)           # (H, Dr) rotary queries
        c = c_ref[0].astype(F32)             # (page_len, R) latents
        kr = kr_ref[0].astype(F32)           # (page_len, Dr)
        pos = pos_ref[0]                     # (page_len,)
        sc = (jax.lax.dot_general(qa, c, (((1,), (1,)), ((), ())),
                                  precision=jax.lax.Precision.HIGHEST)
              + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                    precision=jax.lax.Precision.HIGHEST)
              ) * scale
        valid = (pos >= 0) & (pos <= qp)     # (page_len,)
        sc = jnp.where(valid[None, :], sc, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        p = jnp.where(valid[None, :], p, 0.0)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        # the "value" IS the latent page: output stays in latent rank R
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p, c, precision=jax.lax.Precision.HIGHEST)
        m_sc[...] = m_new

    @pl.when(mi == nm - 1)
    def _fin():
        l = l_sc[...]
        ok = l > 0
        lsafe = jnp.where(ok, l, 1.0)
        o_ref[0] = jnp.where(ok[:, None], acc_sc[...] / lsafe[:, None],
                             0.0).astype(o_ref.dtype)


def paged_mla_decode_pallas(q_abs, q_rope, c_pages, kr_pages, pos_pages,
                            block_tables, q_pos, *, scale: float,
                            interpret: bool = True):
    """Paged decode attention over compressed MLA latents (absorbed form).

    q_abs: (S, H, R) absorbed queries (q_nope @ W_uk); q_rope: (S, H, Dr);
    c_pages: (P, page_len, R); kr_pages: (P, page_len, Dr); pos_pages:
    (P, page_len) int32; block_tables: (S, M) int32 (-1 = unallocated);
    q_pos: (S,) int32 (-1 = inactive slot); ``scale`` is the caller's
    1/sqrt(qk_nope + qk_rope) (NOT derivable from R).  Grid (S, M): the
    latent is MQA-shaped — one shared "kv head" — so each grid step scores
    all H heads against one latent page; the softmax output contracts
    against the SAME page (out rank R, W_uv applied by the caller).
    Returns out (S, H, R)."""
    s, h, r = q_abs.shape
    dr = q_rope.shape[-1]
    p, page_len = pos_pages.shape
    m = block_tables.shape[1]
    kern = functools.partial(_mla_kernel, nm=m, scale=scale)

    def page_idx(s_, mi, bt, qp):
        return (jnp.maximum(bt[s_, mi], 0), 0, 0)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(s, m),
            in_specs=[
                pl.BlockSpec((1, h, r), lambda s_, mi, bt, qp: (s_, 0, 0)),
                pl.BlockSpec((1, h, dr), lambda s_, mi, bt, qp: (s_, 0, 0)),
                pl.BlockSpec((1, page_len, r), page_idx),
                pl.BlockSpec((1, page_len, dr), page_idx),
                pl.BlockSpec((1, page_len),
                             lambda s_, mi, bt, qp:
                             (jnp.maximum(bt[s_, mi], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, h, r),
                                   lambda s_, mi, bt, qp: (s_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((h,), F32),
                pltpu.VMEM((h,), F32),
                pltpu.VMEM((h, r), F32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, h, r), q_abs.dtype),
        interpret=interpret,
    )(block_tables, q_pos, q_abs, q_rope, c_pages, kr_pages, pos_pages)
    return out


def paged_decode_pallas(q, k_pages, v_pages, pos_pages, block_tables, q_pos,
                        *, interpret: bool = True):
    """q: (S, KV, G, D); k_pages/v_pages: (P, page_len, KV, D); pos_pages:
    (P, page_len) int32; block_tables: (S, M) int32 (-1 = unallocated);
    q_pos: (S,) int32 (-1 = inactive slot).  Returns out (S, KV, G, D)."""
    s, kvh, g, d = q.shape
    p, page_len = pos_pages.shape
    m = block_tables.shape[1]
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_kernel, nm=m, scale=scale)

    def page_idx(s_, h_, mi, bt, qp):
        return (jnp.maximum(bt[s_, mi], 0), 0, h_, 0)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(s, kvh, m),
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda s_, h_, mi, bt, qp: (s_, h_, 0, 0)),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len),
                             lambda s_, h_, mi, bt, qp:
                             (jnp.maximum(bt[s_, mi], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda s_, h_, mi, bt, qp: (s_, h_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), F32),
                pltpu.VMEM((g,), F32),
                pltpu.VMEM((g, d), F32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, kvh, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, q_pos, q, k_pages, v_pages, pos_pages)
    return out
