"""Pallas TPU kernels: paged attention over a block-table KV pool.

Two families share the pool layout (``k/v_pages (P, page_len, KV, D)``,
``pos_pages (P, page_len)`` absolute positions with ``-1`` = empty,
``block_tables`` rows of page ids with ``-1`` = unallocated; DESIGN.md
§8).  Neither ever materializes a dense per-sequence KV copy in HBM: the
block table rides in as a scalar-prefetch operand and the page id feeds
the BlockSpec index map directly, so each grid step DMAs exactly one
page.

**Decode** (``paged_decode_pallas`` / ``paged_mla_decode_pallas``) — one
query token per slot.  Grid ``(S, KV, M)``: slot × kv-head × block-table
column; GQA lays queries out as ``(S, KV, G, D)`` so one grid step
scores all G heads of a kv head against one page.  A ``-1`` block-table
entry skips the whole page with ``pl.when`` (cost O(allocated pages),
not O(M)); inside a page, key j is visible iff ``0 <= pos_j <= q_pos``
— the dense arena's rule, so the partial last-prompt-page gap needs no
special case.  Decode is never differentiated: no backward.

**Prefill** (``paged_prefill_fwd_pallas`` + the two ``bwd`` kernels,
DESIGN.md §11) — the learner's teacher-forcing forward.  Queries are a
PagedLayout batch ``(R, H, T, D)``: packed rows of per-response
*suffixes* (last prompt token + response hull), each tagged with a
segment id that doubles as the index into ``seg_start`` / the block
table.  Every suffix token attends to (a) its segment's prompt KV read
straight from the rollout pool pages and (b) the packed suffix KV,
causally, under ONE online softmax so the saved ``(O, LSE)`` are global.

  fwd      — grid ``(R, H, T/bq, M + T/bk)``: per query block, M
             block-table steps (pool phase) then T/bk packed-suffix
             steps.  Pool mask: same segment AND ``0 <= pos <
             seg_start[seg]`` (the pool's own copy of the last prompt
             token is excluded — the suffix recomputes it fresh).
             Suffix mask: the packed kernel's causal+segment rule with
             its block-skip tables.
  bwd dq   — grid ``(R, H, T/bq, M)``: the pool-phase dq contribution
             (the suffix contribution comes from prefix_attn's packed
             bwd, fed the fused global (O, LSE)).
  bwd dkv  — grid ``(S, H, M, T/bq)``: per (segment, page), accumulate
             dk/dv over the segment's query blocks; ops.py reduces GQA
             groups and scatter-adds through the block table into a
             pool-shaped gradient (shared prompt pages sum over GRPO
             siblings).

Known limits: ``bq == bk`` and both must divide the PagedLayout
alignment quantum (16 at CPU/interpret smoke scale — raise both with
the layout quantum to 128 on real TPUs); every query block must be
single-segment (+ PAD tail), which PagedLayout guarantees by aligning
segment starts to the quantum; pack ids must equal segment indices in
placement order (the PagedLayout contract).  All accumulation f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prefix_attn import kernel as _PK

F32 = jnp.float32
NEG = -1e30


def _kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_sc, l_sc, acc_sc, *, nm, scale):
    s = pl.program_id(0)
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    qp = qpos_ref[s]

    @pl.when((bt_ref[s, mi] >= 0) & (qp >= 0))
    def _compute():
        q = q_ref[0, 0].astype(F32)          # (G, D)
        k = k_ref[0, :, 0].astype(F32)       # (page_len, D)
        v = v_ref[0, :, 0].astype(F32)
        pos = pos_ref[0]                     # (page_len,)
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST) * scale
        valid = (pos >= 0) & (pos <= qp)     # (page_len,)
        sc = jnp.where(valid[None, :], sc, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        p = jnp.where(valid[None, :], p, 0.0)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m_sc[...] = m_new

    @pl.when(mi == nm - 1)
    def _fin():
        l = l_sc[...]
        ok = l > 0
        lsafe = jnp.where(ok, l, 1.0)
        o_ref[0, 0] = jnp.where(ok[:, None], acc_sc[...] / lsafe[:, None],
                                0.0).astype(o_ref.dtype)


def _mla_kernel(bt_ref, qpos_ref, qa_ref, qr_ref, c_ref, kr_ref, pos_ref,
                o_ref, m_sc, l_sc, acc_sc, *, nm, scale):
    s = pl.program_id(0)
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    qp = qpos_ref[s]

    @pl.when((bt_ref[s, mi] >= 0) & (qp >= 0))
    def _compute():
        qa = qa_ref[0].astype(F32)           # (H, R) absorbed queries
        qr = qr_ref[0].astype(F32)           # (H, Dr) rotary queries
        c = c_ref[0].astype(F32)             # (page_len, R) latents
        kr = kr_ref[0].astype(F32)           # (page_len, Dr)
        pos = pos_ref[0]                     # (page_len,)
        sc = (jax.lax.dot_general(qa, c, (((1,), (1,)), ((), ())),
                                  precision=jax.lax.Precision.HIGHEST)
              + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                    precision=jax.lax.Precision.HIGHEST)
              ) * scale
        valid = (pos >= 0) & (pos <= qp)     # (page_len,)
        sc = jnp.where(valid[None, :], sc, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        p = jnp.where(valid[None, :], p, 0.0)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        # the "value" IS the latent page: output stays in latent rank R
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p, c, precision=jax.lax.Precision.HIGHEST)
        m_sc[...] = m_new

    @pl.when(mi == nm - 1)
    def _fin():
        l = l_sc[...]
        ok = l > 0
        lsafe = jnp.where(ok, l, 1.0)
        o_ref[0] = jnp.where(ok[:, None], acc_sc[...] / lsafe[:, None],
                             0.0).astype(o_ref.dtype)


def paged_mla_decode_pallas(q_abs, q_rope, c_pages, kr_pages, pos_pages,
                            block_tables, q_pos, *, scale: float,
                            interpret: bool = True):
    """Paged decode attention over compressed MLA latents (absorbed form).

    q_abs: (S, H, R) absorbed queries (q_nope @ W_uk); q_rope: (S, H, Dr);
    c_pages: (P, page_len, R); kr_pages: (P, page_len, Dr); pos_pages:
    (P, page_len) int32; block_tables: (S, M) int32 (-1 = unallocated);
    q_pos: (S,) int32 (-1 = inactive slot); ``scale`` is the caller's
    1/sqrt(qk_nope + qk_rope) (NOT derivable from R).  Grid (S, M): the
    latent is MQA-shaped — one shared "kv head" — so each grid step scores
    all H heads against one latent page; the softmax output contracts
    against the SAME page (out rank R, W_uv applied by the caller).
    Returns out (S, H, R)."""
    s, h, r = q_abs.shape
    dr = q_rope.shape[-1]
    p, page_len = pos_pages.shape
    m = block_tables.shape[1]
    kern = functools.partial(_mla_kernel, nm=m, scale=scale)

    def page_idx(s_, mi, bt, qp):
        return (jnp.maximum(bt[s_, mi], 0), 0, 0)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(s, m),
            in_specs=[
                pl.BlockSpec((1, h, r), lambda s_, mi, bt, qp: (s_, 0, 0)),
                pl.BlockSpec((1, h, dr), lambda s_, mi, bt, qp: (s_, 0, 0)),
                pl.BlockSpec((1, page_len, r), page_idx),
                pl.BlockSpec((1, page_len, dr), page_idx),
                pl.BlockSpec((1, page_len),
                             lambda s_, mi, bt, qp:
                             (jnp.maximum(bt[s_, mi], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, h, r),
                                   lambda s_, mi, bt, qp: (s_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((h,), F32),
                pltpu.VMEM((h,), F32),
                pltpu.VMEM((h, r), F32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, h, r), q_abs.dtype),
        interpret=interpret,
    )(block_tables, q_pos, q_abs, q_rope, c_pages, kr_pages, pos_pages)
    return out


def paged_decode_pallas(q, k_pages, v_pages, pos_pages, block_tables, q_pos,
                        *, interpret: bool = True):
    """q: (S, KV, G, D); k_pages/v_pages: (P, page_len, KV, D); pos_pages:
    (P, page_len) int32; block_tables: (S, M) int32 (-1 = unallocated);
    q_pos: (S,) int32 (-1 = inactive slot).  Returns out (S, KV, G, D)."""
    s, kvh, g, d = q.shape
    p, page_len = pos_pages.shape
    m = block_tables.shape[1]
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_kernel, nm=m, scale=scale)

    def page_idx(s_, h_, mi, bt, qp):
        return (jnp.maximum(bt[s_, mi], 0), 0, h_, 0)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(s, kvh, m),
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda s_, h_, mi, bt, qp: (s_, h_, 0, 0)),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len),
                             lambda s_, h_, mi, bt, qp:
                             (jnp.maximum(bt[s_, mi], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda s_, h_, mi, bt, qp: (s_, h_, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), F32),
                pltpu.VMEM((g,), F32),
                pltpu.VMEM((g, d), F32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, kvh, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, q_pos, q, k_pages, v_pages, pos_pages)
    return out


# ================================================ prefill (pool + suffix)
def _qblock_segments(segment_ids, bq: int, s_count: int):
    """Per-query-block segment index ``(R, T // bq)`` int32, ``-1`` for
    blocks holding no live segment.  Relies on the PagedLayout contract:
    every block is single-segment (+ PAD tail), so the first token names
    the block."""
    first = segment_ids[:, ::bq].astype(jnp.int32)
    return jnp.where((first >= 0) & (first < s_count), first, -1)


def _seg_tables(qseg, s_count: int):
    """(seg_row, seg_q0, seg_nq), each (S,) int32 — where segment s lives
    in the query grid: its packed row, first query block, block count.
    Segments absent from the grid get seg_nq == 0 (all steps skipped)."""
    onehot = qseg[:, :, None] == jnp.arange(s_count, dtype=jnp.int32)
    seg_row = jnp.argmax(onehot.any(axis=1), axis=0).astype(jnp.int32)
    seg_q0 = jnp.argmax(onehot.any(axis=0), axis=0).astype(jnp.int32)
    seg_nq = onehot.sum(axis=(0, 1)).astype(jnp.int32)
    return seg_row, seg_q0, seg_nq


def _prefill_fwd_kernel(qseg_ref, sstart_ref, bt_ref, lo_ref, hi_ref,
                        q_ref, k_ref, v_ref, kp_ref, vp_ref, pp_ref,
                        segq_ref, segk_ref, o_ref, lse_ref,
                        m_sc, l_sc, acc_sc, *, bq, bk, nm, nk, scale):
    r = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    seg = qseg_ref[r, qi]

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def _acc(s_mat, mask, v):
        s_mat = jnp.where(mask, s_mat, NEG)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s_mat, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_mat - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        m_sc[...] = m_new

    page_live = bt_ref[jnp.maximum(seg, 0), jnp.minimum(ki, nm - 1)] >= 0

    @pl.when((ki < nm) & (seg >= 0) & page_live)
    def _pool():
        q = q_ref[0, 0].astype(F32)                  # (bq, D)
        k = kp_ref[0, :, 0].astype(F32)              # (page_len, D)
        v = vp_ref[0, :, 0].astype(F32)
        pos = pp_ref[0]                              # (page_len,)
        s_mat = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    precision=jax.lax.Precision.HIGHEST
                                    ) * scale
        # prompt KV only: the pool's own copy of the last prompt token
        # (pos == seg_start - 1 is the newest VISIBLE one; the cut is
        # pos < seg_start) is the newest the suffix may read — the
        # suffix recomputes position seg_start - 1 itself.
        vis = (pos >= 0) & (pos < sstart_ref[jnp.maximum(seg, 0)])
        mask = (segq_ref[0] == seg)[:, None] & vis[None, :]
        _acc(s_mat, mask, v)

    kjc = jnp.maximum(ki - nm, 0)

    @pl.when((ki >= nm)
             & _PK._packed_needed(qi, kjc, bq, bk, lo_ref, hi_ref, r))
    def _suffix():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        s_mat = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    precision=jax.lax.Precision.HIGHEST
                                    ) * scale
        mask = _PK._packed_mask(qi * bq, kjc * bk, bq, bk,
                                segq_ref[0], segk_ref[0])
        _acc(s_mat, mask, v)

    @pl.when(ki == nm + nk - 1)
    def _fin():
        l = l_sc[...]
        ok = l > 0
        lsafe = jnp.where(ok, l, 1.0)
        o_ref[0, 0] = jnp.where(ok[:, None], acc_sc[...] / lsafe[:, None],
                                0.0).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(ok, m_sc[...] + jnp.log(lsafe), 0.0)


def paged_prefill_fwd_pallas(q, k, v, segment_ids, seg_start, block_tables,
                             k_pages, v_pages, pos_pages, *, bq: int = 16,
                             bk: int = 16, interpret: bool = True):
    """Fused pool+suffix prefill forward.

    q (R, H, T, D) / k, v (R, KV, T, D): a PagedLayout batch of response
    suffixes; segment_ids (R, T); seg_start (S,) absolute position of
    each segment's first suffix token; block_tables (S, M); k/v_pages
    (P, page_len, KV, D); pos_pages (P, page_len).  Returns
    (o (R, H, T, D), lse (R, H, T) f32) — LSE is global over pool +
    suffix keys, which is what makes the split backward exact."""
    r, h, t, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    assert bq == bk, "prefill shares one block-range table: bq == bk"
    assert t % bq == 0, f"pack_len {t} must be a multiple of bq {bq}"
    s_count, nm = block_tables.shape
    assert s_count >= 1 and nm >= 1
    page_len = pos_pages.shape[1]
    nq = nk = t // bq
    scale = 1.0 / (d ** 0.5)
    lo, hi = _PK.seg_block_ranges(segment_ids, bq)
    qseg = _qblock_segments(segment_ids, bq, s_count)
    kern = functools.partial(_prefill_fwd_kernel, bq=bq, bk=bk, nm=nm,
                             nk=nk, scale=scale)

    def page_idx(r_, h_, qi, ki, qseg_, ss, bt, lo_, hi_):
        page = bt[jnp.maximum(qseg_[r_, qi], 0), jnp.minimum(ki, nm - 1)]
        return (jnp.maximum(page, 0), 0, h_ // g, 0)

    def pos_idx(r_, h_, qi, ki, qseg_, ss, bt, lo_, hi_):
        page = bt[jnp.maximum(qseg_[r_, qi], 0), jnp.minimum(ki, nm - 1)]
        return (jnp.maximum(page, 0), 0)

    def kv_idx(r_, h_, qi, ki, qseg_, ss, bt, lo_, hi_):
        return (r_, h_ // g, jnp.maximum(ki - nm, 0), 0)

    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(r, h, nq, nm + nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda r_, h_, qi, ki, *_: (r_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bk, d), kv_idx),
                pl.BlockSpec((1, 1, bk, d), kv_idx),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len), pos_idx),
                pl.BlockSpec((1, bq),
                             lambda r_, h_, qi, ki, *_: (r_, qi)),
                pl.BlockSpec((1, bk),
                             lambda r_, h_, qi, ki, *_:
                             (r_, jnp.maximum(ki - nm, 0))),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda r_, h_, qi, ki, *_: (r_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq),
                             lambda r_, h_, qi, ki, *_: (r_, h_, qi)),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq,), F32),
                pltpu.VMEM((bq,), F32),
                pltpu.VMEM((bq, d), F32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((r, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((r, h, t), F32),
        ],
        interpret=interpret,
    )(qseg, seg_start, block_tables, lo, hi,
      q, k, v, k_pages, v_pages, pos_pages, segment_ids, segment_ids)
    return out


# ------------------------------------------------- prefill bwd: dq (pool)
def _prefill_dq_pool_kernel(qseg_ref, sstart_ref, bt_ref, q_ref, kp_ref,
                            vp_ref, pp_ref, do_ref, lse_ref, delta_ref,
                            segq_ref, dq_ref, acc_sc, *, nm, scale):
    r = pl.program_id(0)
    qi = pl.program_id(2)
    mi = pl.program_id(3)
    seg = qseg_ref[r, qi]

    @pl.when(mi == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    @pl.when((seg >= 0) & (bt_ref[jnp.maximum(seg, 0), mi] >= 0))
    def _compute():
        q = q_ref[0, 0].astype(F32)                  # (bq, D)
        k = kp_ref[0, :, 0].astype(F32)              # (page_len, D)
        v = vp_ref[0, :, 0].astype(F32)
        pos = pp_ref[0]
        do = do_ref[0, 0].astype(F32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s_mat = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    precision=jax.lax.Precision.HIGHEST
                                    ) * scale
        vis = (pos >= 0) & (pos < sstart_ref[jnp.maximum(seg, 0)])
        mask = (segq_ref[0] == seg)[:, None] & vis[None, :]
        p = jnp.where(mask, jnp.exp(s_mat - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST)
        ds = p * (dp - delta[:, None]) * scale
        acc_sc[...] += jax.lax.dot(ds, k,
                                   precision=jax.lax.Precision.HIGHEST)

    @pl.when(mi == nm - 1)
    def _fin():
        dq_ref[0, 0] = acc_sc[...]


def paged_prefill_bwd_dq_pallas(q, o, lse, do, segment_ids, seg_start,
                                block_tables, k_pages, v_pages, pos_pages,
                                *, bq: int = 16, interpret: bool = True):
    """Pool-phase dq contribution (f32, same shape as q).  The suffix
    contribution comes from prefix_attn's packed bwd run on the fused
    global (o, lse); with a global LSE and delta the two partitions'
    per-key ds are each exact, so the sum is the exact dq."""
    r, h, t, d = q.shape
    kvh = k_pages.shape[2]
    g = h // kvh
    s_count, nm = block_tables.shape
    page_len = pos_pages.shape[1]
    nq = t // bq
    scale = 1.0 / (d ** 0.5)
    qseg = _qblock_segments(segment_ids, bq, s_count)
    delta = jnp.sum(do.astype(F32) * o.astype(F32), axis=-1)  # (R, H, T)
    kern = functools.partial(_prefill_dq_pool_kernel, nm=nm, scale=scale)

    def page_idx(r_, h_, qi, mi, qseg_, ss, bt):
        return (jnp.maximum(bt[jnp.maximum(qseg_[r_, qi], 0), mi], 0),
                0, h_ // g, 0)

    def pos_idx(r_, h_, qi, mi, qseg_, ss, bt):
        return (jnp.maximum(bt[jnp.maximum(qseg_[r_, qi], 0), mi], 0), 0)

    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(r, h, nq, nm),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda r_, h_, qi, mi, *_: (r_, h_, qi, 0)),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len), pos_idx),
                pl.BlockSpec((1, 1, bq, d),
                             lambda r_, h_, qi, mi, *_: (r_, h_, qi, 0)),
                pl.BlockSpec((1, 1, bq),
                             lambda r_, h_, qi, mi, *_: (r_, h_, qi)),
                pl.BlockSpec((1, 1, bq),
                             lambda r_, h_, qi, mi, *_: (r_, h_, qi)),
                pl.BlockSpec((1, bq),
                             lambda r_, h_, qi, mi, *_: (r_, qi)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, d),
                lambda r_, h_, qi, mi, *_: (r_, h_, qi, 0)),
            scratch_shapes=[pltpu.VMEM((bq, d), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((r, h, t, d), F32),
        interpret=interpret,
    )(qseg, seg_start, block_tables,
      q, k_pages, v_pages, pos_pages, do, lse, delta, segment_ids)


# ------------------------------------------------ prefill bwd: dkv (pool)
def _prefill_dkv_pool_kernel(srow_ref, sq0_ref, snq_ref, sstart_ref, bt_ref,
                             q_ref, kp_ref, vp_ref, pp_ref, do_ref, lse_ref,
                             delta_ref, segq_ref, dk_ref, dv_ref,
                             dk_sc, dv_sc, *, nq, scale):
    s = pl.program_id(0)
    mi = pl.program_id(2)
    qj = pl.program_id(3)

    @pl.when(qj == 0)
    def _init():
        dk_sc[...] = jnp.zeros_like(dk_sc)
        dv_sc[...] = jnp.zeros_like(dv_sc)

    @pl.when((qj < snq_ref[s]) & (bt_ref[s, mi] >= 0))
    def _compute():
        q = q_ref[0, 0].astype(F32)                  # (bq, D)
        k = kp_ref[0, :, 0].astype(F32)              # (page_len, D)
        v = vp_ref[0, :, 0].astype(F32)
        pos = pp_ref[0]
        do = do_ref[0, 0].astype(F32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s_mat = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    precision=jax.lax.Precision.HIGHEST
                                    ) * scale
        vis = (pos >= 0) & (pos < sstart_ref[s])
        mask = (segq_ref[0] == s)[:, None] & vis[None, :]
        p = jnp.where(mask, jnp.exp(s_mat - lse[:, None]), 0.0)  # (bq, pl)
        dv_sc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                          precision=jax.lax.Precision.HIGHEST)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 precision=jax.lax.Precision.HIGHEST)
        ds = p * (dp - delta[:, None]) * scale
        dk_sc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                          precision=jax.lax.Precision.HIGHEST)

    @pl.when(qj == nq - 1)
    def _fin():
        dk_ref[0, 0, 0] = dk_sc[...]
        dv_ref[0, 0, 0] = dv_sc[...]


def paged_prefill_bwd_dkv_pallas(q, o, lse, do, segment_ids, seg_start,
                                 block_tables, k_pages, v_pages, pos_pages,
                                 *, bq: int = 16, interpret: bool = True):
    """Per-(segment, page) pool dk/dv blocks, each PER QUERY HEAD:
    returns (dk, dv), both (S, M, H, page_len, D) f32.  ops.py reduces
    the GQA groups and scatter-adds through the block table into the
    pool-shaped gradient (shared prompt pages sum over GRPO siblings).

    The query grid is walked per segment via scalar tables (packed row,
    first block, block count) derived from segment_ids; the grid's q
    axis is the STATIC upper bound T // bq and steps past a segment's
    block count are skipped."""
    r, h, t, d = q.shape
    kvh = k_pages.shape[2]
    g = h // kvh
    s_count, nm = block_tables.shape
    page_len = pos_pages.shape[1]
    nq = t // bq
    scale = 1.0 / (d ** 0.5)
    qseg = _qblock_segments(segment_ids, bq, s_count)
    srow, sq0, snq = _seg_tables(qseg, s_count)
    delta = jnp.sum(do.astype(F32) * o.astype(F32), axis=-1)  # (R, H, T)
    kern = functools.partial(_prefill_dkv_pool_kernel, nq=nq, scale=scale)

    def qblk(s_, qj, sq0_, snq_):
        return sq0_[s_] + jnp.minimum(qj, jnp.maximum(snq_[s_] - 1, 0))

    def q_idx(s_, h_, mi, qj, srow_, sq0_, snq_, ss, bt):
        return (srow_[s_], h_, qblk(s_, qj, sq0_, snq_), 0)

    def qv_idx(s_, h_, mi, qj, srow_, sq0_, snq_, ss, bt):
        return (srow_[s_], h_, qblk(s_, qj, sq0_, snq_))

    def seg_idx(s_, h_, mi, qj, srow_, sq0_, snq_, ss, bt):
        return (srow_[s_], qblk(s_, qj, sq0_, snq_))

    def page_idx(s_, h_, mi, qj, srow_, sq0_, snq_, ss, bt):
        return (jnp.maximum(bt[s_, mi], 0), 0, h_ // g, 0)

    def pos_idx(s_, h_, mi, qj, srow_, sq0_, snq_, ss, bt):
        return (jnp.maximum(bt[s_, mi], 0), 0)

    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(s_count, h, nm, nq),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_idx),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len, 1, d), page_idx),
                pl.BlockSpec((1, page_len), pos_idx),
                pl.BlockSpec((1, 1, bq, d), q_idx),
                pl.BlockSpec((1, 1, bq), qv_idx),
                pl.BlockSpec((1, 1, bq), qv_idx),
                pl.BlockSpec((1, bq), seg_idx),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, page_len, d),
                             lambda s_, h_, mi, qj, *_: (s_, mi, h_, 0, 0)),
                pl.BlockSpec((1, 1, 1, page_len, d),
                             lambda s_, h_, mi, qj, *_: (s_, mi, h_, 0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((page_len, d), F32),
                            pltpu.VMEM((page_len, d), F32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((s_count, nm, h, page_len, d), F32),
            jax.ShapeDtypeStruct((s_count, nm, h, page_len, d), F32),
        ],
        interpret=interpret,
    )(srow, sq0, snq, seg_start, block_tables,
      q, k_pages, v_pages, pos_pages, do, lse, delta, segment_ids)
