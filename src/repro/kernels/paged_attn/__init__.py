"""Paged attention kernels over a block-table KV pool (DESIGN.md §8/§11).

Package shape shared with ``kernels/ht_loss`` and ``kernels/prefix_attn``
(see docs/kernels.md): ``ref.py`` pure-jnp oracles, ``kernel.py`` Pallas
grids, ``ops.py`` jit-friendly wrappers (the prefill one carries the
custom_vjp).  Decode scores one token per slot against its block-table
pages; prefill scores a PagedLayout suffix batch against pool pages plus
packed suffix KV under one online softmax, with an exact backward that
scatter-adds pool gradients through the block table.
"""
from repro.kernels.paged_attn.kernel import (
    paged_decode_pallas, paged_mla_decode_pallas, paged_prefill_fwd_pallas,
    paged_prefill_bwd_dq_pallas, paged_prefill_bwd_dkv_pallas,
)
from repro.kernels.paged_attn.ops import (
    paged_attention, paged_mla_attention, paged_prefill_attention,
    paged_prefill_attention_bthd,
)
from repro.kernels.paged_attn.ref import (
    paged_attention_ref, paged_mla_attention_ref, paged_prefill_attention_ref,
)

__all__ = [
    "paged_attention",
    "paged_attention_ref",
    "paged_decode_pallas",
    "paged_mla_attention",
    "paged_mla_attention_ref",
    "paged_mla_decode_pallas",
    "paged_prefill_attention",
    "paged_prefill_attention_bthd",
    "paged_prefill_attention_ref",
    "paged_prefill_bwd_dkv_pallas",
    "paged_prefill_bwd_dq_pallas",
    "paged_prefill_fwd_pallas",
]
