from repro.kernels.paged_attn.kernel import (
    paged_decode_pallas, paged_mla_decode_pallas,
)
from repro.kernels.paged_attn.ops import paged_attention, paged_mla_attention
from repro.kernels.paged_attn.ref import (
    paged_attention_ref, paged_mla_attention_ref,
)

__all__ = [
    "paged_attention",
    "paged_attention_ref",
    "paged_decode_pallas",
    "paged_mla_attention",
    "paged_mla_attention_ref",
    "paged_mla_decode_pallas",
]
