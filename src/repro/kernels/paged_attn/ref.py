"""Pure-jnp oracles for paged attention (decode and prefill).

Semantics shared with the kernels (and with ``models/attention.py``'s
paged paths):
  * the KV store is a pool of ``(num_pages, page_len)`` pages per layer;
    a slot's logical KV sequence is the concatenation of the pages named
    by its ``block_tables`` row, in row order,
  * ``block_tables`` entries of ``-1`` are unallocated: every position of
    such a page is invisible to the slot,
  * per-entry validity comes from the pool's ``pos`` plane (absolute
    token positions, ``-1`` = empty): key j is visible to the slot's
    query iff ``0 <= pos_j <= q_pos`` — the same visibility rule the
    dense slot arena uses, which makes the partial-last-prompt-page gap
    (decode tokens always start on a fresh page) just more invisible
    entries, never special-cased,
  * slots with ``q_pos < 0`` are inactive and output exactly 0.
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def paged_attention_ref(q, k_pages, v_pages, pos_pages, block_tables, q_pos):
    """q: (S, KV, G, D) — G query heads per kv head (GQA grouping);
    k_pages/v_pages: (P, page_len, KV, D); pos_pages: (P, page_len) int32;
    block_tables: (S, M) int32 page ids (-1 = unallocated); q_pos: (S,)
    int32 absolute query positions (-1 = inactive slot).

    Returns out (S, KV, G, D)."""
    s, kv, g, d = q.shape
    p, pl = pos_pages.shape
    bt = jnp.maximum(block_tables, 0)
    kg = k_pages[bt]                      # (S, M, pl, KV, D)
    vg = v_pages[bt]
    posg = jnp.where(block_tables[..., None] >= 0, pos_pages[bt], -1)
    m = bt.shape[1]
    kg = kg.reshape(s, m * pl, kv, d)
    vg = vg.reshape(s, m * pl, kv, d)
    posg = posg.reshape(s, m * pl)

    scale = 1.0 / jnp.sqrt(d)
    sc = jnp.einsum("skgd,slkd->skgl", q.astype(F32), kg.astype(F32)) * scale
    valid = (posg >= 0) & (posg <= q_pos[:, None]) & (q_pos[:, None] >= 0)
    sc = jnp.where(valid[:, None, None, :], sc, -jnp.inf)
    mx = jnp.max(sc, axis=-1)
    mx_safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
    pr = jnp.exp(sc - mx_safe[..., None])
    pr = jnp.where(valid[:, None, None, :], pr, 0.0)
    l = jnp.sum(pr, axis=-1)
    o = jnp.einsum("skgl,slkd->skgd", pr, vg.astype(F32))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.where((l > 0)[..., None], o, 0.0)
    return o.astype(q.dtype)


def paged_mla_attention_ref(q_abs, q_rope, c_pages, kr_pages, pos_pages,
                            block_tables, q_pos, *, scale):
    """MLA oracle: q_abs (S, H, R) absorbed queries, q_rope (S, H, Dr);
    c_pages (P, page_len, R) latents, kr_pages (P, page_len, Dr); same
    block-table / ``pos`` visibility rules as ``paged_attention_ref``; the
    value operand is the latent page itself.  Returns out (S, H, R)."""
    s, h, r = q_abs.shape
    bt = jnp.maximum(block_tables, 0)
    cg = c_pages[bt]                      # (S, M, pl, R)
    krg = kr_pages[bt]
    posg = jnp.where(block_tables[..., None] >= 0, pos_pages[bt], -1)
    m, pl = bt.shape[1], pos_pages.shape[1]
    cg = cg.reshape(s, m * pl, r)
    krg = krg.reshape(s, m * pl, krg.shape[-1])
    posg = posg.reshape(s, m * pl)

    sc = (jnp.einsum("shr,slr->shl", q_abs.astype(F32), cg.astype(F32))
          + jnp.einsum("shk,slk->shl", q_rope.astype(F32),
                       krg.astype(F32))) * scale
    valid = (posg >= 0) & (posg <= q_pos[:, None]) & (q_pos[:, None] >= 0)
    sc = jnp.where(valid[:, None, :], sc, -jnp.inf)
    mx = jnp.max(sc, axis=-1)
    mx_safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
    pr = jnp.exp(sc - mx_safe[..., None])
    pr = jnp.where(valid[:, None, :], pr, 0.0)
    l = jnp.sum(pr, axis=-1)
    o = jnp.einsum("shl,slr->shr", pr, cg.astype(F32))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.where((l > 0)[..., None], o, 0.0)
    return o.astype(q_abs.dtype)


def paged_prefill_attention_ref(q, k, v, segment_ids, seg_start,
                                block_tables, k_pages, v_pages, pos_pages):
    """Oracle for the fused pool+suffix prefill kernel.

    Mirrors the KERNEL's decomposition (f32 upcast, -inf masking with an
    isfinite guard, explicit max-subtract) and its masks exactly:
      * pool keys: same segment AND ``0 <= pos < seg_start[seg]`` (the
        pool's duplicate of the last prompt token is excluded — the
        suffix recomputes that position),
      * suffix keys: causal in the packed row AND equal segment ids
        (PAD tokens match PAD tokens, as in the packed kernel; their
        output is garbage-but-deterministic and never read).

    q (R, H, T, D); k/v (R, KV, T, D); segment_ids (R, T); seg_start
    (S,); block_tables (S, M); k/v_pages (P, page_len, KV, D);
    pos_pages (P, page_len).  Returns (o (R, H, T, D), lse (R, H, T))."""
    r, h, t, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    s_count, m = block_tables.shape
    plen = pos_pages.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(F32)

    seg = segment_ids.astype(jnp.int32)
    segv = (seg >= 0) & (seg < s_count)
    segc = jnp.where(segv, seg, 0)

    bt = jnp.maximum(block_tables, 0)
    kpool = k_pages[bt].reshape(s_count, m * plen, kvh, d)   # (S, L, KV, D)
    vpool = v_pages[bt].reshape(s_count, m * plen, kvh, d)
    ppool = jnp.where(block_tables[..., None] >= 0,
                      pos_pages[bt], -1).reshape(s_count, m * plen)

    kp = kpool[segc]                                         # (R, T, L, KV, D)
    vp = vpool[segc]
    posp = ppool[segc]                                       # (R, T, L)

    q4 = q.reshape(r, kvh, g, t, d).astype(F32)
    sc_pool = jnp.einsum("rkgtd,rtlkd->rkgtl", q4,
                         kp.astype(F32)) * scale
    sc_sfx = jnp.einsum("rkgtd,rksd->rkgts", q4,
                        k.astype(F32)) * scale

    m_pool = (segv[:, :, None] & (posp >= 0)
              & (posp < seg_start[segc][:, :, None]))        # (R, T, L)
    ti = jnp.arange(t)
    m_sfx = ((ti[None, :, None] >= ti[None, None, :])
             & (seg[:, :, None] == seg[:, None, :]))         # (R, T, T)

    sc = jnp.concatenate([sc_pool, sc_sfx], axis=-1)
    mask = jnp.concatenate([m_pool, m_sfx], axis=-1)[:, None, None]
    sc = jnp.where(mask, sc, -jnp.inf)
    mx = jnp.max(sc, axis=-1)
    mx_safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
    pr = jnp.exp(sc - mx_safe[..., None])
    pr = jnp.where(mask, pr, 0.0)
    l = jnp.sum(pr, axis=-1)
    o = (jnp.einsum("rkgtl,rtlkd->rkgtd", pr[..., :m * plen],
                    vp.astype(F32))
         + jnp.einsum("rkgts,rksd->rkgtd", pr[..., m * plen:],
                      v.astype(F32)))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.where((l > 0)[..., None], o, 0.0)
    lse = jnp.where(l > 0, mx_safe + jnp.log(jnp.maximum(l, 1e-30)), 0.0)
    return (o.reshape(r, h, t, d).astype(q.dtype),
            lse.reshape(r, h, t).astype(F32))
