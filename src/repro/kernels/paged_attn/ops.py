"""jit-friendly wrappers for paged attention (decode and prefill).

``paged_attention(q, k_pages, v_pages, pos_pages, block_tables, q_pos)``
takes q in the model's flat-head decode layout ``(S, H, D)`` and handles
the GQA regrouping around the kernel's ``(S, KV, G, D)`` layout: query
head ``h`` reads kv head ``h // (H // KV)`` — the same mapping
``repeat_kv`` realizes on the dense path, without the kv repeat in HBM.
Decode (one token per slot) is never differentiated, so the decode
wrappers carry no custom_vjp.

``paged_prefill_attention`` is the learner's teacher-forcing forward
(DESIGN.md §11) and DOES carry a custom_vjp.  The backward splits by key
partition and stays exact because the forward's (O, LSE) are global over
pool + suffix keys:
  * suffix dq/dk/dv — prefix_attn's packed backward, fed the fused
    (O, LSE),
  * pool dq — the dq-pool kernel, summed into the suffix dq,
  * pool dk/dv — the dkv-pool kernel's per-(segment, page) blocks,
    GQA-reduced and scatter-added through the block table into a
    pool-shaped gradient (GRPO siblings sharing a prompt page sum).
The learner wraps the pool in ``stop_gradient`` (the pool belongs to the
rollout policy), so XLA drops the pool-gradient computation there; the
path exists so the kernel-vs-ref grad parity tests can pin it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.prefix_attn import kernel as _PFX
from repro.kernels.paged_attn import kernel as K

F32 = jnp.float32


def paged_attention(q, k_pages, v_pages, pos_pages, block_tables, q_pos,
                    *, interpret: bool = True):
    """q: (S, H, D) flat query heads; k_pages/v_pages: (P, page_len, KV, D);
    pos_pages: (P, page_len); block_tables: (S, M); q_pos: (S,).
    Returns out (S, H, D)."""
    s, h, d = q.shape
    kvh = k_pages.shape[2]
    g = h // kvh
    o = K.paged_decode_pallas(
        q.reshape(s, kvh, g, d), k_pages, v_pages, pos_pages, block_tables,
        q_pos, interpret=interpret)
    return o.reshape(s, h, d)


def paged_mla_attention(q_abs, q_rope, c_pages, kr_pages, pos_pages,
                        block_tables, q_pos, *, scale: float,
                        interpret: bool = True):
    """MLA variant: the latent pool is MQA-shaped (no kv-head axis, no GQA
    regrouping) and the value operand IS the latent page, so the kernel's
    output stays in latent rank R — the caller applies W_uv / W_o.
    q_abs: (S, H, R); q_rope: (S, H, Dr); c_pages: (P, page_len, R);
    kr_pages: (P, page_len, Dr).  Returns out (S, H, R)."""
    return K.paged_mla_decode_pallas(
        q_abs, q_rope, c_pages, kr_pages, pos_pages, block_tables, q_pos,
        scale=scale, interpret=interpret)


# ------------------------------------------------------- prefill (custom vjp)
@functools.partial(jax.custom_vjp, nondiff_argnums=(9, 10, 11))
def paged_prefill_attention(q, k, v, segment_ids, seg_start, block_tables,
                            k_pages, v_pages, pos_pages, bq=16, bk=16,
                            interpret=True):
    """Fused pool+suffix prefill attention with an exact custom vjp.

    q (R, H, T, D) / k, v (R, KV, T, D): PagedLayout suffix batch;
    segment_ids (R, T); seg_start (S,); block_tables (S, M);
    k/v_pages (P, page_len, KV, D); pos_pages (P, page_len).
    Returns o (R, H, T, D).  Gradients flow to q, k, v AND to the pool
    pages (scatter-added through the block table)."""
    o, _ = K.paged_prefill_fwd_pallas(
        q, k, v, segment_ids, seg_start, block_tables,
        k_pages, v_pages, pos_pages, bq=bq, bk=bk, interpret=interpret)
    return o


def _prefill_fwd(q, k, v, segment_ids, seg_start, block_tables,
                 k_pages, v_pages, pos_pages, bq, bk, interpret):
    o, lse = K.paged_prefill_fwd_pallas(
        q, k, v, segment_ids, seg_start, block_tables,
        k_pages, v_pages, pos_pages, bq=bq, bk=bk, interpret=interpret)
    return o, (q, k, v, o, lse, segment_ids, seg_start, block_tables,
               k_pages, v_pages, pos_pages)


def _prefill_bwd(bq, bk, interpret, res, do):
    (q, k, v, o, lse, segment_ids, seg_start, block_tables,
     k_pages, v_pages, pos_pages) = res
    b, h, t, d = q.shape
    kvh = k.shape[1]
    g = h // kvh

    # suffix partition: the packed backward is exact here because the
    # (o, lse, delta) it consumes are GLOBAL over pool + suffix keys
    dq_sfx, dk_full, dv_full = _PFX.packed_bwd_pallas(
        q, k, v, o, lse, do, segment_ids, bq=bq, bk=bk, interpret=interpret)
    dk = dk_full.reshape(b, kvh, g, t, d).sum(axis=2).astype(k.dtype)
    dv = dv_full.reshape(b, kvh, g, t, d).sum(axis=2).astype(v.dtype)

    # pool partition: dq adds in; dk/dv scatter through the block table
    dq_pool = K.paged_prefill_bwd_dq_pallas(
        q, o, lse, do, segment_ids, seg_start, block_tables,
        k_pages, v_pages, pos_pages, bq=bq, interpret=interpret)
    dq = (dq_sfx.astype(F32) + dq_pool).astype(q.dtype)

    dk_pg, dv_pg = K.paged_prefill_bwd_dkv_pallas(
        q, o, lse, do, segment_ids, seg_start, block_tables,
        k_pages, v_pages, pos_pages, bq=bq, interpret=interpret)
    s_count, nm = block_tables.shape
    plen = pos_pages.shape[1]

    def to_pool(dpg):
        # (S, M, H, pl, d) -> per-kv-head (S, M, pl, KV, d) -> pool scatter
        contrib = jnp.moveaxis(
            dpg.reshape(s_count, nm, kvh, g, plen, d).sum(axis=3), 2, 3)
        valid = block_tables >= 0
        contrib = jnp.where(valid[..., None, None, None], contrib, 0.0)
        return jnp.zeros(k_pages.shape, F32).at[
            jnp.maximum(block_tables, 0).reshape(-1)
        ].add(contrib.reshape(-1, plen, kvh, d))

    dk_pool = to_pool(dk_pg).astype(k_pages.dtype)
    dv_pool = to_pool(dv_pg).astype(v_pages.dtype)
    return dq, dk, dv, None, None, None, dk_pool, dv_pool, None


paged_prefill_attention.defvjp(_prefill_fwd, _prefill_bwd)


def paged_prefill_attention_bthd(q, k, v, segment_ids, seg_start,
                                 block_tables, k_pages, v_pages, pos_pages,
                                 *, bq: int = 16, bk: int = 16,
                                 interpret: bool = True):
    """Convenience wrapper taking the model layout q (R, T, H, D) /
    k, v (R, T, KV, D); transposes around the kernel layout (the
    transposes sit outside the custom_vjp and differentiate fine)."""
    o = paged_prefill_attention(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        segment_ids, seg_start, block_tables, k_pages, v_pages, pos_pages,
        bq, bk, interpret)
    return o.swapaxes(1, 2)
