"""jit-friendly wrappers for paged decode attention.

``paged_attention(q, k_pages, v_pages, pos_pages, block_tables, q_pos)``
takes q in the model's flat-head decode layout ``(S, H, D)`` and handles
the GQA regrouping around the kernel's ``(S, KV, G, D)`` layout: query
head ``h`` reads kv head ``h // (H // KV)`` — the same mapping
``repeat_kv`` realizes on the dense path, without the kv repeat in HBM.

Decode-only (one token per slot, no backward), so there is no custom_vjp
here — the rollout engine never differentiates through decode.
"""
from __future__ import annotations

from repro.kernels.paged_attn import kernel as K


def paged_attention(q, k_pages, v_pages, pos_pages, block_tables, q_pos,
                    *, interpret: bool = True):
    """q: (S, H, D) flat query heads; k_pages/v_pages: (P, page_len, KV, D);
    pos_pages: (P, page_len); block_tables: (S, M); q_pos: (S,).
    Returns out (S, H, D)."""
    s, h, d = q.shape
    kvh = k_pages.shape[2]
    g = h // kvh
    o = K.paged_decode_pallas(
        q.reshape(s, kvh, g, d), k_pages, v_pages, pos_pages, block_tables,
        q_pos, interpret=interpret)
    return o.reshape(s, h, d)


def paged_mla_attention(q_abs, q_rope, c_pages, kr_pages, pos_pages,
                        block_tables, q_pos, *, scale: float,
                        interpret: bool = True):
    """MLA variant: the latent pool is MQA-shaped (no kv-head axis, no GQA
    regrouping) and the value operand IS the latent page, so the kernel's
    output stays in latent rank R — the caller applies W_uv / W_o.
    q_abs: (S, H, R); q_rope: (S, H, Dr); c_pages: (P, page_len, R);
    kr_pages: (P, page_len, Dr).  Returns out (S, H, R)."""
    return K.paged_mla_decode_pallas(
        q_abs, q_rope, c_pages, kr_pages, pos_pages, block_tables, q_pos,
        scale=scale, interpret=interpret)
