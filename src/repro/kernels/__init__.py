"""Pallas TPU kernels for the learner's two compute hot spots:

* ``prefix_attn`` — prefix-aware causal flash attention (RPC's forward
  truncation realized at block level),
* ``ht_loss`` — fused vocab-tiled HT-GRPO logprob head (never materializes
  the (N, V) softmax).

Both ship kernel.py (pallas_call + BlockSpec), ops.py (jit + custom_vjp) and
ref.py (pure-jnp oracle); validated on CPU in interpret mode, targeted at
TPU v5e VMEM/MXU tile sizes.
"""
from repro.kernels import ht_loss, prefix_attn

__all__ = ["ht_loss", "prefix_attn"]
