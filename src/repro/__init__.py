"""repro: NAT (Not All Tokens are Needed) token-efficient RL framework in JAX.

Layers (import order is strictly downward — see DESIGN.md §1):
  repro.core        — NAT selectors + HT-weighted GRPO loss + physical repack
  repro.dist        — logical-axis sharding rules (FSDP/TP/EP/SP, DESIGN.md §5)
  repro.models      — composable decoder model zoo (11 assigned archs)
  repro.optim       — AdamW + schedules, int8 moments, param-aligned sharding
  repro.rl          — colocated rollout engine, verifiable envs, NAT-GRPO trainer
  repro.data        — synthetic prompt pipeline
  repro.checkpoint  — fault-tolerant sharded checkpointing, elastic restore
  repro.kernels     — Pallas TPU kernels (prefix-aware flash attn, fused HT loss)
  repro.configs     — architecture configs + smoke variants + shape grids
  repro.launch      — mesh construction / dry-run / training entry points
"""

__version__ = "1.0.0"
