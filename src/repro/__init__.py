"""repro: NAT (Not All Tokens are Needed) token-efficient RL framework in JAX.

Layers:
  repro.core        — NAT selectors + HT-weighted GRPO (the paper)
  repro.models      — composable decoder model zoo (10 assigned archs)
  repro.rl          — rollout engine, verifiable envs, NAT-GRPO trainer
  repro.data        — synthetic prompt pipeline
  repro.optim       — AdamW + schedules, sharded states
  repro.dist        — logical-axis sharding rules (FSDP/TP/EP/SP)
  repro.checkpoint  — fault-tolerant sharded checkpointing
  repro.kernels     — Pallas TPU kernels (prefix-aware flash attn, fused HT loss)
  repro.configs     — architecture configs
  repro.launch      — mesh / dry-run / training entry points
"""

__version__ = "1.0.0"
