"""Assigned input shapes (same four for every LM-family architecture).

``train_*`` lowers ``train_step`` (NAT-GRPO learner fwd+bwd+optimizer).
``prefill_*`` lowers the prefill forward (builds the decode cache).
``decode_*`` / ``long_*`` lower ``serve_step`` — ONE new token against a KV
cache of the given sequence length.  ``long_500k`` runs only for archs whose
``supports_long_context`` resolves True (sub-quadratic / mostly-local).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shapes_for(cfg) -> list:
    """The shape cells this architecture runs (long_500k gated)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out
