"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144.  5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

62 layers = (local x5, global) x10 + (local x2).  Mostly-local, so it runs
the long_500k decode shape: the 10 global layers carry full-length caches
(sequence-sharded over the mesh); the 52 local layers use window-1024 rings.
"""
from repro.models.config import ModelConfig

ARCH_ID = "gemma3-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        blocks=(
            (("local", "local", "local", "local", "local", "attn"), 10),
            (("local", "local"), 1),
        ),
        window=1024,
        mlp_kind="geglu",
        rope_theta=1_000_000.0,
        emb_scale_by_dim=True,
        long_context_ok=True,  # mostly-local; global layers seq-shard their cache
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=251,
        blocks=((("local", "local", "attn"), 1), (("local", "local"), 1)),
        window=8,
        mlp_kind="geglu",
        emb_scale_by_dim=True,
        seq_parallel=False,
    )
