"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072.  128k context window, explicit head_dim=128.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.models.config import ModelConfig, dense_blocks

ARCH_ID = "mistral-nemo-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        blocks=dense_blocks(40),
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
        long_context_ok=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=251,
        blocks=dense_blocks(3),
        mlp_kind="swiglu",
        seq_parallel=False,
    )
