"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256.  Cross-attn image layers every 5th layer
(4 self + 1 cross) × 20.  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, 1601, d_model); the cross-attention layers
project them to K/V in-backbone.
"""
from repro.models.config import ModelConfig

ARCH_ID = "llama-3.2-vision-90b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        blocks=((("attn", "attn", "attn", "attn", "xattn"), 20),),  # 100 layers
        mlp_kind="swiglu",
        rope_theta=500_000.0,
        num_image_tokens=1601,
        long_context_ok=False,  # full-span self-attention -> skip long_500k
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=251,
        blocks=((("attn", "attn", "attn", "attn", "xattn"), 2),),
        mlp_kind="swiglu",
        num_image_tokens=7,
        seq_parallel=False,
    )
