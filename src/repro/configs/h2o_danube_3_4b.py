"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000.  llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]

All layers use SWA (window 4096), so the arch is sub-quadratic and runs the
long_500k decode shape (ring-buffer KV caches of window size)."""
from repro.models.config import ModelConfig

ARCH_ID = "h2o-danube-3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        blocks=((("local",), 24),),
        window=4096,
        mlp_kind="swiglu",
        rope_theta=10_000.0,
        long_context_ok=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=251,
        blocks=((("local",), 3),),
        window=8,
        mlp_kind="swiglu",
        seq_parallel=False,
    )
