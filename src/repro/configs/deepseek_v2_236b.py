"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400.  MLA (kv_lora=512), 2 shared + 160 routed experts top-6;
first layer dense (d_ff 12288).  [arXiv:2405.04434; hf]

The MLA decode cache stores only (c_kv 512 + k_rope 64) per token — the
paper's ~24x KV reduction — and decodes in the absorbed form."""
from repro.models.config import MLAConfig, MoEConfig, ModelConfig

ARCH_ID = "deepseek-v2-236b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,   # MLA: per-head K/V decompressed from the latent
        head_dim=128,
        d_ff=12288,       # the single dense layer's FFN
        vocab_size=102400,
        blocks=((("mla:dense",), 1), (("mla:moe",), 59)),
        mlp_kind="swiglu",
        rope_theta=10_000.0,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(num_experts=160, top_k=6, num_shared=2, d_ff_expert=1536,
                      capacity_factor=1.25),
        long_context_ok=False,  # MLA is latent but still full-span
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=192,
        vocab_size=251,
        blocks=((("mla:dense",), 1), (("mla:moe",), 2)),
        mlp_kind="swiglu",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=48),
        seq_parallel=False,
    )
