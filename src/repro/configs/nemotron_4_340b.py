"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.  GQA + squared-ReLU MLP.  [arXiv:2402.16819; unverified]"""
from repro.models.config import ModelConfig, dense_blocks

ARCH_ID = "nemotron-4-340b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        blocks=dense_blocks(96),
        mlp_kind="relu2",
        rope_theta=10_000.0,
        long_context_ok=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=384,
        vocab_size=251,
        blocks=dense_blocks(3),
        mlp_kind="relu2",
        seq_parallel=False,
    )
