"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128.  SSD (state-space duality).  [arXiv:2405.21060; unverified]

Attention-free: NAT's RPC truncation composes with the linear-time scan
(forward savings are linear in the cut ratio); decode carries an O(1)
recurrent state, so long_500k is natural."""
from repro.models.config import ModelConfig, SSMConfig

ARCH_ID = "mamba2-130m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=768,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        blocks=((("ssm",), 24),),
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      chunk=128, n_groups=1),
        long_context_ok=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=251,
        blocks=((("ssm",), 3),),
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      chunk=16, n_groups=1),
        seq_parallel=False,
    )
