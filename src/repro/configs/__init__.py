"""Architecture config registry: the 10 assigned architectures plus the
paper's own Qwen3-8B subject model.  ``get_config(arch_id)`` /
``get_smoke(arch_id)`` resolve by the public arch id (``--arch`` flag)."""
from repro.configs import (
    deepseek_v2_236b,
    gemma3_27b,
    h2o_danube_3_4b,
    llama_3_2_vision_90b,
    mamba2_130m,
    mistral_nemo_12b,
    musicgen_large,
    nat_qwen3_8b,
    nemotron_4_340b,
    qwen3_moe_235b_a22b,
    recurrentgemma_9b,
)
from repro.configs.shapes import SHAPES, ShapeSpec, shapes_for

_MODULES = [
    llama_3_2_vision_90b,
    nemotron_4_340b,
    h2o_danube_3_4b,
    mistral_nemo_12b,
    gemma3_27b,
    recurrentgemma_9b,
    deepseek_v2_236b,
    qwen3_moe_235b_a22b,
    mamba2_130m,
    musicgen_large,
    nat_qwen3_8b,
]

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ASSIGNED_ARCHS = [m.ARCH_ID for m in _MODULES[:10]]  # the 10-arch pool
ALL_ARCHS = list(REGISTRY)


def get_config(arch_id: str):
    try:
        return REGISTRY[arch_id].config()
    except KeyError as e:
        raise ValueError(f"unknown arch {arch_id!r}; known: {ALL_ARCHS}") from e


def get_smoke(arch_id: str):
    try:
        return REGISTRY[arch_id].smoke()
    except KeyError as e:
        raise ValueError(f"unknown arch {arch_id!r}; known: {ALL_ARCHS}") from e


__all__ = [
    "SHAPES", "ShapeSpec", "shapes_for", "REGISTRY", "ASSIGNED_ARCHS",
    "ALL_ARCHS", "get_config", "get_smoke",
]
