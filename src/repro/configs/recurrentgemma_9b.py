"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000.  RG-LRU + local attention, 2:1 pattern.
[arXiv:2402.19427; unverified]

38 layers = (rec, rec, local) x12 + (rec, rec).  Attention layers are MQA
with window 2048.  Hybrid/linear-time -> runs long_500k."""
from repro.models.config import ModelConfig, RGLRUConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        blocks=((("rec", "rec", "local"), 12), (("rec", "rec"), 1)),
        window=2048,
        mlp_kind="geglu",
        rope_theta=10_000.0,
        emb_scale_by_dim=True,
        rglru=RGLRUConfig(lru_width=4096, conv_width=4),
        long_context_ok=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=192,
        vocab_size=251,
        blocks=((("rec", "rec", "local"), 1), (("rec", "rec"), 1)),
        window=8,
        mlp_kind="geglu",
        emb_scale_by_dim=True,
        rglru=RGLRUConfig(lru_width=64, conv_width=4),
        seq_parallel=False,
    )
