"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(expert) vocab=151936.  128 experts, top-8, no shared experts.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import MoEConfig, ModelConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=12288,       # unused (every MLP is routed); kept for reference
        vocab_size=151936,
        blocks=((("attn:moe",), 94),),
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=128, top_k=8, num_shared=0, d_ff_expert=1536,
                      capacity_factor=1.25),
        long_context_ok=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=251,
        blocks=((("attn:moe",), 3),),
        mlp_kind="swiglu",
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=0, d_ff_expert=48),
        seq_parallel=False,
    )
