"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048 per codebook.  Decoder-only over EnCodec tokens, 4 codebooks with
the delay pattern applied upstream.  [arXiv:2306.05284; hf]

Backbone-only per the assignment: the EnCodec frontend is a stub — inputs
are (B, T, 4) codebook-token frames; the model sums 4 codebook embeddings
per frame and emits 4 output heads.  RPC cutoffs operate on FRAME positions,
so all 4 codebooks of a frame share the mask (delay pattern stays coherent).
"""
from repro.models.config import ModelConfig, dense_blocks

ARCH_ID = "musicgen-large"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        blocks=dense_blocks(48),
        mlp_kind="geglu",
        rope_theta=10_000.0,
        num_codebooks=4,
        long_context_ok=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=192,
        vocab_size=31,
        blocks=dense_blocks(3),
        mlp_kind="geglu",
        num_codebooks=4,
        seq_parallel=False,
    )
