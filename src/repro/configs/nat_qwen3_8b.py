"""nat-qwen3-8b — the paper's own subject model (Qwen3-8B): 36L d_model=4096
32H (GQA kv=8) d_ff=12288 vocab=151936.  This is the config the NAT paper
trains with GRPO/URS/RPC on DAPO-Math-17K; we use it for the paper-faithful
reproduction runs and as the 11th dry-run architecture."""
from repro.models.config import ModelConfig, dense_blocks

ARCH_ID = "nat-qwen3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        blocks=dense_blocks(36),
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
        long_context_ok=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=251,
        blocks=dense_blocks(3),
        mlp_kind="swiglu",
        seq_parallel=False,
    )
