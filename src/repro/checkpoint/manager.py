"""Fault-tolerant sharded checkpointing (no external deps).

* Per-leaf .npy files saved from addressable shards + a JSON manifest
  (paths, shapes, dtypes, shard offsets, step, user metadata).
* **Atomic**: writes go to ``<dir>/.tmp-<step>`` and are renamed to
  ``<dir>/step_<step>`` only after the manifest is fsynced — a killed job
  never leaves a half-written checkpoint that ``latest_step`` would find.
* **Async**: ``save(..., blocking=False)`` snapshots to host (device_get)
  synchronously, then writes on a background thread; ``wait()`` joins.
* **Keep-last-k** garbage collection.
* **Elastic restore**: ``restore`` takes a *target* abstract tree plus
  optional NamedShardings — arrays are assembled from whatever shard layout
  was saved and re-placed onto the new mesh (different device count or
  topology than the writer's): a 512-chip job can resume on 256.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"

# numpy can't round-trip ml_dtypes through .npy reliably: store raw bits
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8, "float16": None}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    v = _VIEW.get(str(arr.dtype))
    return arr.view(v) if v is not None else arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if _VIEW.get(dtype_name) is not None:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def _host_shards(leaf) -> list:
    """[(offset tuple, np array)] — deduped addressable shards."""
    if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
        seen, out = set(), []
        for s in leaf.addressable_shards:
            idx = s.index if isinstance(s.index, tuple) else (s.index,)
            off = tuple((sl.start or 0) if isinstance(sl, slice) else 0
                        for sl in idx)
            off = off + (0,) * (leaf.ndim - len(off))
            if off not in seen:
                seen.add(off)
                out.append((off, np.asarray(s.data)))
        if not out:  # fully replicated single-shard fallback
            out = [((0,) * leaf.ndim, np.asarray(leaf))]
        return out
    arr = np.asarray(leaf)
    return [((0,) * arr.ndim, arr)]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        flat, _ = _flatten(tree)
        host = [(k, tuple(np.shape(l)),
                 str(l.dtype if hasattr(l, "dtype")
                     else np.asarray(l).dtype),
                 _host_shards(l)) for k, l in flat]

        def write():
            try:
                tmp = os.path.join(self.dir, f".tmp-{step}")
                final = os.path.join(self.dir, f"step_{step:09d}")
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(tmp)
                manifest = {"step": step, "extra": extra or {}, "leaves": {}}
                for i, (key, shape, dtype, shards) in enumerate(host):
                    entries = []
                    for j, (offset, data) in enumerate(shards):
                        fn = f"leaf_{i:05d}_{j:05d}.npy"
                        np.save(os.path.join(tmp, fn), _to_storable(data))
                        entries.append({"file": fn, "offset": list(offset)})
                    manifest["leaves"][key] = {
                        "shape": list(shape), "dtype": dtype, "shards": entries}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                shutil.rmtree(final, ignore_errors=True)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # pragma: no cover
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        if not self.keep_last:
            return
        for s in self.all_steps()[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list:
        return sorted(int(n.split("_")[1]) for n in os.listdir(self.dir)
                      if n.startswith("step_"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> tuple:
        """Load ``step`` into the structure of ``target``; optionally place
        each leaf with the given NamedSharding (elastic resume).
        Returns (tree, extra_metadata)."""
        import jax.numpy as jnp

        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_t, treedef = _flatten(target)
        flat_s = dict(_flatten(shardings)[0]) if shardings is not None else {}
        out = []
        for key, _ref in flat_t:
            meta = manifest["leaves"][key]
            shape = tuple(meta["shape"])
            first = np.load(os.path.join(path, meta["shards"][0]["file"]))
            full = np.zeros(shape, first.dtype)
            for sh in meta["shards"]:
                data = np.load(os.path.join(path, sh["file"]))
                if data.ndim == 0:
                    full = data
                    continue
                idx = tuple(slice(o, o + s) for o, s in zip(sh["offset"], data.shape))
                full[idx] = data
            full = _from_storable(np.asarray(full), meta["dtype"])
            sh = flat_s.get(key)
            out.append(jax.device_put(full, sh) if sh is not None
                       else jnp.asarray(full))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
