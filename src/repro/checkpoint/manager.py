"""Fault-tolerant sharded checkpointing (no external deps).

* Per-leaf .npy files saved from addressable shards + a JSON manifest
  (paths, shapes, dtypes, shard offsets, step, user metadata).
* **Atomic + crash-safe**: writes go to ``<dir>/.tmp-<step>`` and are
  renamed to ``<dir>/step_<step>`` only after every leaf file and the
  manifest are fsynced (then the directory itself, so the rename is
  durable) — a killed job never leaves a half-written checkpoint that
  ``latest_step`` would find.  Belt-and-braces for torn state that
  slipped through anyway (power loss mid-fsync, a truncating copy):
  ``latest_step`` *validates* the newest checkpoint — manifest parses,
  every shard file present with a readable npy header and the manifest's
  shape — and falls back to the previous valid step with a loud warning
  instead of crashing the resume (DESIGN.md §13).
* **Async**: ``save(..., blocking=False)`` snapshots to host (device_get)
  synchronously, then writes on a background thread; ``wait()`` joins.
* **Keep-last-k** garbage collection.
* **Elastic restore**: ``restore`` takes a *target* abstract tree plus
  optional NamedShardings — arrays are assembled from whatever shard layout
  was saved and re-placed onto the new mesh (different device count or
  topology than the writer's): a 512-chip job can resume on 256.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"

# numpy can't round-trip ml_dtypes through .npy reliably: store raw bits
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8, "float16": None}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    v = _VIEW.get(str(arr.dtype))
    return arr.view(v) if v is not None else arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if _VIEW.get(dtype_name) is not None:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def _host_shards(leaf) -> list:
    """[(offset tuple, np array)] — deduped addressable shards."""
    if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
        seen, out = set(), []
        for s in leaf.addressable_shards:
            idx = s.index if isinstance(s.index, tuple) else (s.index,)
            off = tuple((sl.start or 0) if isinstance(sl, slice) else 0
                        for sl in idx)
            off = off + (0,) * (leaf.ndim - len(off))
            if off not in seen:
                seen.add(off)
                out.append((off, np.asarray(s.data)))
        if not out:  # fully replicated single-shard fallback
            out = [((0,) * leaf.ndim, np.asarray(leaf))]
        return out
    arr = np.asarray(leaf)
    return [((0,) * arr.ndim, arr)]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        flat, _ = _flatten(tree)
        host = [(k, tuple(np.shape(l)),
                 str(l.dtype if hasattr(l, "dtype")
                     else np.asarray(l).dtype),
                 _host_shards(l)) for k, l in flat]

        def write():
            try:
                tmp = os.path.join(self.dir, f".tmp-{step}")
                final = os.path.join(self.dir, f"step_{step:09d}")
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(tmp)
                manifest = {"step": step, "extra": extra or {}, "leaves": {}}
                for i, (key, shape, dtype, shards) in enumerate(host):
                    entries = []
                    for j, (offset, data) in enumerate(shards):
                        fn = f"leaf_{i:05d}_{j:05d}.npy"
                        # write through an explicit handle so the data
                        # hits disk before the rename publishes it —
                        # np.save alone leaves it in the page cache
                        with open(os.path.join(tmp, fn), "wb") as lf:
                            np.save(lf, _to_storable(data))
                            lf.flush()
                            os.fsync(lf.fileno())
                        entries.append({"file": fn, "offset": list(offset)})
                    manifest["leaves"][key] = {
                        "shape": list(shape), "dtype": dtype, "shards": entries}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                shutil.rmtree(final, ignore_errors=True)
                os.rename(tmp, final)
                self._fsync_dir(self.dir)  # make the rename itself durable
                self._gc()
            except BaseException as e:  # pragma: no cover
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        if not self.keep_last:
            return
        for s in self.all_steps()[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    @staticmethod
    def _fsync_dir(path: str) -> None:
        """Best-effort directory fsync (no-op where unsupported)."""
        try:
            fd = os.open(path, os.O_RDONLY)
        except OSError:  # pragma: no cover - exotic filesystems
            return
        try:
            os.fsync(fd)
        except OSError:  # pragma: no cover
            pass
        finally:
            os.close(fd)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list:
        return sorted(int(n.split("_")[1]) for n in os.listdir(self.dir)
                      if n.startswith("step_"))

    def is_valid(self, step: int) -> bool:
        """Cheap integrity check: manifest parses and every shard file has
        a readable npy header whose shape matches the manifest.  Headers
        only — a torn/truncated file fails the header read or the size
        check without loading gigabytes."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            for key, meta in manifest["leaves"].items():
                for sh in meta["shards"]:
                    fp = os.path.join(path, sh["file"])
                    arr = np.load(fp, mmap_mode="r")
                    if (arr.ndim > 0
                            and arr.size * arr.dtype.itemsize
                            + arr.offset > os.path.getsize(fp)):
                        return False  # truncated payload behind the header
            return True
        except Exception:
            return False

    def latest_step(self) -> Optional[int]:
        """Newest *valid* step: a torn/corrupt newest checkpoint (crash
        mid-write on a non-atomic filesystem, truncation in transit) is
        skipped with a loud warning and the previous valid one wins."""
        for s in reversed(self.all_steps()):
            if self.is_valid(s):
                return s
            warnings.warn(
                f"checkpoint step_{s:09d} in {self.dir!r} is torn or "
                "corrupt (unreadable manifest or truncated shard) — "
                "skipping it and falling back to the previous valid step",
                RuntimeWarning, stacklevel=2)
        return None

    def restore(self, step: int, target: Any, shardings: Any = None) -> tuple:
        """Load ``step`` into the structure of ``target``; optionally place
        each leaf with the given NamedSharding (elastic resume).
        Returns (tree, extra_metadata)."""
        import jax.numpy as jnp

        path = os.path.join(self.dir, f"step_{step:09d}")
        if not self.is_valid(step):
            raise ValueError(
                f"checkpoint step_{step:09d} in {self.dir!r} is torn or "
                "corrupt; restore from latest_step() (which skips invalid "
                "checkpoints) or an earlier step")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_t, treedef = _flatten(target)
        flat_s = dict(_flatten(shardings)[0]) if shardings is not None else {}
        out = []
        for key, _ref in flat_t:
            meta = manifest["leaves"][key]
            shape = tuple(meta["shape"])
            first = np.load(os.path.join(path, meta["shards"][0]["file"]))
            full = np.zeros(shape, first.dtype)
            for sh in meta["shards"]:
                data = np.load(os.path.join(path, sh["file"]))
                if data.ndim == 0:
                    full = data
                    continue
                idx = tuple(slice(o, o + s) for o, s in zip(sh["offset"], data.shape))
                full[idx] = data
            full = _from_storable(np.asarray(full), meta["dtype"])
            sh = flat_s.get(key)
            out.append(jax.device_put(full, sh) if sh is not None
                       else jnp.asarray(full))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
