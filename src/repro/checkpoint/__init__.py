"""Fault-tolerant checkpointing: atomic, async, keep-last-k, elastic restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
