"""Composable decoder model zoo covering all 10 assigned architectures."""
from repro.models.config import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    RGLRUConfig,
    SSMConfig,
    dense_blocks,
)
from repro.models.model import (
    cache_axes,
    cache_decl,
    decode_step,
    forward_hidden,
    full_logits,
    invalidate_cache_rows,
    invalidate_pages,
    merge_cache,
    model_decl,
    paged_cache_decl,
    paged_prefill,
    prefill,
    score_tokens,
)
from repro.models.params import (
    ParamDecl,
    abstract_params,
    count_params,
    init_params,
    param_specs,
)

__all__ = [
    "MLAConfig", "MoEConfig", "ModelConfig", "RGLRUConfig", "SSMConfig",
    "dense_blocks", "cache_axes", "cache_decl", "decode_step",
    "forward_hidden", "full_logits", "invalidate_cache_rows",
    "invalidate_pages", "merge_cache", "model_decl", "paged_cache_decl",
    "paged_prefill", "prefill", "score_tokens",
    "ParamDecl", "abstract_params", "count_params", "init_params",
    "param_specs",
]
