"""Parameter declaration trees.

Model code builds a tree of ``ParamDecl`` (shape + logical axes + init) once
from the config; three materializers derive everything else from it:

* ``init_params``     — random concrete arrays (for real training)
* ``abstract_params`` — ShapeDtypeStructs (for the dry-run; no allocation)
* ``param_specs``     — logical-axis tuples (for sharding resolution)

Keeping shapes and shardings in one declaration removes the usual drift
between init code and sharding tables.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: tuple
    axes: tuple  # logical axis names (or None), len == len(shape)
    init: str = "normal"  # normal | zeros | ones | value
    scale: Optional[float] = None  # stddev; default 1/sqrt(fan_in)
    dtype: Any = jnp.bfloat16
    value: Optional[float] = None  # for init == "value"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def stack_decls(tree, repeat: int):
    """Add a leading stacked-layer dim to every decl in the tree."""
    return jax.tree.map(
        lambda d: ParamDecl(
            shape=(repeat,) + d.shape,
            axes=("layers",) + d.axes,
            init=d.init,
            scale=d.scale,
            dtype=d.dtype,
            value=d.value,
        ),
        tree,
        is_leaf=is_decl,
    )


def _fan_in(shape: tuple) -> int:
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    # all dims but the last are treated as inputs (matches our einsum layouts)
    return max(int(np.prod(shape[:-1])), 1)


def _init_one(key, d: ParamDecl, stacked: bool):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "value":
        return jnp.full(d.shape, d.value, d.dtype)
    shape = d.shape
    fan_shape = shape[1:] if (stacked and d.axes and d.axes[0] == "layers") else shape
    std = d.scale if d.scale is not None else 1.0 / math.sqrt(_fan_in(fan_shape))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(d.dtype)


def init_params(key, decl_tree):
    leaves, treedef = jax.tree.flatten(decl_tree, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, d, stacked=True) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(decl_tree):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decl_tree, is_leaf=is_decl
    )


def param_specs(decl_tree):
    """Tree of logical-axes tuples parallel to the params tree."""
    return jax.tree.map(lambda d: d.axes, decl_tree, is_leaf=is_decl)


def count_params(decl_tree) -> int:
    leaves = jax.tree.leaves(decl_tree, is_leaf=is_decl)
    return sum(int(np.prod(d.shape)) for d in leaves)
