"""Model assembly: declaration tree, full-sequence forward (train / scoring),
prefill, and single-token decode — all built from the block zoo and scanned
over stacked layer groups so HLO size stays O(#groups), not O(#layers).

Public surface:
    model_decl(cfg)                        -> ParamDecl tree
    forward_hidden(params, cfg, tokens, …) -> (hidden, caches|None, aux)
    score_tokens(params, cfg, tokens, …)   -> per-token logprobs (B, T)
    prefill(params, cfg, tokens, …)        -> (last_logits, decode_cache)
    decode_step(params, cfg, tokens, cache, pos) -> (logits, new_cache)
    cache_decl(cfg, batch, cache_len)      -> abstract cache tree
    cache_axes(cfg)                        -> logical-axes tree for sharding
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules, DEFAULT_RULES, shard_constraint
from repro.models import blocks as B
from repro.models import capabilities as caps
from repro.models.config import ModelConfig
from repro.models.layers import (
    chunked_token_logprobs,
    embed_apply,
    embed_decl,
    head_decl,
    head_weight,
    logits_apply,
    rmsnorm,
    rmsnorm_decl,
)
from repro.models.params import stack_decls

Array = jax.Array


# -------------------------------------------------------------- declaration
def model_decl(cfg: ModelConfig) -> dict:
    d = {
        "embed": embed_decl(cfg.vocab_size, cfg.d_model, cfg.num_codebooks),
        "final_norm": rmsnorm_decl(cfg.d_model),
        "head": head_decl(cfg.vocab_size, cfg.d_model, cfg.num_codebooks,
                          cfg.tie_embeddings),
    }
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        layer = {f"l{j}": B.block_decl(cfg, kind) for j, kind in enumerate(pattern)}
        d[f"group{gi}"] = stack_decls(layer, repeat)
    return d


def _make_shard(cfg: ModelConfig, mesh, rules):
    if mesh is None:
        return None
    if not cfg.seq_parallel:  # keep batch/vocab constraints, drop seq-parallel
        rules = rules.override(act_seq=None)
    return partial(shard_constraint, mesh=mesh, rules=rules)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


# -------------------------------------------------------------- forward
def forward_hidden(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    *,
    positions: Optional[Array] = None,
    lengths: Optional[Array] = None,
    segment_ids: Optional[Array] = None,
    image_embeds: Optional[Array] = None,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    collect_cache: bool = False,
    prefix_kv: Optional[dict] = None,
    paged_prefix: Optional[dict] = None,
    page_tables: Optional[dict] = None,
    paged_impl: str = "ref",
):
    """tokens: (B, T) int32 (or (B, T, K) codebook grid).

    ``segment_ids`` (B, T) selects the packed batch layout (core/layout.py):
    each row holds several sequences back to back, attention never crosses
    segment boundaries, and ``positions`` carries each token's ORIGINAL
    position (rope + window distances stay exact).

    ``prefix_kv`` enables partial-prefix prefill resume (radix prefix
    cache, DESIGN.md §10): a tree mirroring the cache layout —
    ``prefix_kv[f"group{gi}"][f"l{j}"] = {"k"/"v": (repeat, B, Sp, KV, D),
    "pos": (repeat, B, Sp)}`` — holding already-computed (roped) K/V for a
    cached prompt prefix; ``tokens`` then carries only the suffix and
    ``positions`` its absolute offsets.  Every layer must have an entry
    (the capability table restricts this path to pure global-attention
    stacks).

    ``paged_prefix`` + ``page_tables`` select zero-re-prefill scoring from
    the rollout KV pool (DESIGN.md §11): a tree of the same shape holding
    each layer's pool pages ``{"k"/"v": (repeat, P, page_len, KV, D),
    "pos": (repeat, P, page_len)}``, with ``page_tables`` =
    ``{"block_tables": (S, M), "seg_start": (S,)}`` shared by all layers.
    ``tokens`` is then a PagedLayout batch of response suffixes
    (``segment_ids`` required, ids = segment indices); mutually exclusive
    with ``prefix_kv``; gated to pure global-attention stacks by
    ``capabilities.check_paged_score``.

    Returns (hidden (B, T, D) after final norm, caches or None, aux scalar).
    Caches (when collected) are per-group dicts of stacked prefill entries.
    """
    assert prefix_kv is None or paged_prefix is None, \
        "prefix_kv and paged_prefix are mutually exclusive"
    if paged_prefix is not None:
        assert page_tables is not None and segment_ids is not None
        caps.check_paged_score(cfg)
    shard = _make_shard(cfg, mesh, rules)
    bsz, t = tokens.shape[:2]
    scale = math.sqrt(cfg.d_model) if cfg.emb_scale_by_dim else None
    x = embed_apply(params["embed"], tokens, scale=scale, shard=shard)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (bsz, t))
    if shard is not None:
        x = shard(x, ("batch", "act_seq", None))

    caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        gp = params[f"group{gi}"]
        pfx_g = None if prefix_kv is None else prefix_kv[f"group{gi}"]
        pgd_g = None if paged_prefix is None else paged_prefix[f"group{gi}"]
        # extra per-layer tree scanned alongside the params (at most one of
        # prefix_kv / paged_prefix is set); page_tables stays a closure —
        # block tables are shared by every layer, not per-layer state
        ext_g = pfx_g if pfx_g is not None else pgd_g

        def body(carry, xs, _pattern=pattern):
            xx = carry
            layer_p, ext_l = xs if ext_g is not None else (xs, None)
            pfx_l = ext_l if prefix_kv is not None else None
            pgd_l = ext_l if paged_prefix is not None else None
            entries = {}
            aux = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(_pattern):
                xx, ce, a = B.block_apply(
                    cfg, kind, layer_p[f"l{j}"], xx,
                    positions=positions, lengths=lengths,
                    image_embeds=image_embeds,
                    collect_cache=collect_cache, shard=shard,
                    segment_ids=segment_ids,
                    prefix_kv=None if pfx_l is None else pfx_l[f"l{j}"],
                    paged_prefix=None if pgd_l is None else pgd_l[f"l{j}"],
                    page_tables=page_tables, paged_impl=paged_impl)
                if collect_cache:
                    entries[f"l{j}"] = ce
                aux = aux + a
            return xx, (entries, aux)

        body = _remat(cfg, body)
        if cfg.scan_layers and repeat > 1:
            xs = gp if ext_g is None else (gp, ext_g)
            x, (entries, aux) = jax.lax.scan(body, x, xs)
            aux = jnp.sum(aux)
        else:
            entries_list, aux = [], jnp.zeros((), jnp.float32)
            for r in range(repeat):
                lp = jax.tree.map(lambda a: a[r], gp)
                xs = lp if ext_g is None else (
                    lp, jax.tree.map(lambda a: a[r], ext_g))
                x, (e, a) = body(x, xs)
                entries_list.append(e)
                aux = aux + a
            entries = (jax.tree.map(lambda *xs: jnp.stack(xs), *entries_list)
                       if collect_cache else {})
        if collect_cache:
            caches[f"group{gi}"] = entries
        aux_total = aux_total + aux

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if shard is not None:
        x = shard(x, ("batch", "act_seq", None))
    return x, (caches if collect_cache else None), aux_total


# -------------------------------------------------------------- scoring
def score_tokens(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    *,
    lengths: Optional[Array] = None,
    positions: Optional[Array] = None,
    segment_ids: Optional[Array] = None,
    image_embeds: Optional[Array] = None,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
    with_entropy: bool = False,
    vocab_chunks: int = 8,
    paged_prefix: Optional[dict] = None,
    page_tables: Optional[dict] = None,
    paged_impl: str = "ref",
):
    """Per-token logprobs on the (B, T) grid.

    logp[:, t] = log pi(tokens[:, t] | tokens[:, <t]); logp[:, 0] = 0.
    Uses the chunked head — the (B, T, V) softmax is never materialized
    (pure-jnp analogue of the fused Pallas HT head).

    Packed layout (``segment_ids`` + ``positions``, core/layout.py): the
    conditioning prefix is each token's own segment, and the logp at every
    segment START is zeroed — its left neighbor in the packed row belongs
    to a different sequence, exactly as ``logp[:, 0]`` has no predecessor
    on the padded grid.

    Paged layout (``paged_prefix`` + ``page_tables``, DESIGN.md §11): each
    packed segment is [last prompt token, response...] and the prompt KV
    comes from the rollout pool — zero re-prefill.  The segment-start rule
    above zeroes the last prompt token's slot, and the response's first
    token gets its true logp because its predecessor (the last prompt
    token) IS in the batch, attending over the pooled prompt.
    """
    hidden, _, aux = forward_hidden(
        params, cfg, tokens, positions=positions, lengths=lengths,
        segment_ids=segment_ids, image_embeds=image_embeds,
        mesh=mesh, rules=rules, paged_prefix=paged_prefix,
        page_tables=page_tables, paged_impl=paged_impl)
    shard = _make_shard(cfg, mesh, rules)
    w = head_weight(params.get("head", {}), params["embed"], cfg.tie_embeddings)
    h = hidden[:, :-1]
    tgt = tokens[:, 1:]
    bsz = tokens.shape[0]
    if cfg.num_codebooks:
        # sum logp over codebooks of each frame: (B, T-1, K)
        outs = [chunked_token_logprobs(
            w[k], h, tgt[..., k], softcap=cfg.logits_softcap,
            num_chunks=vocab_chunks, with_entropy=with_entropy, shard=shard)
            for k in range(cfg.num_codebooks)]
        if with_entropy:
            logp = sum(o[0] for o in outs)
            ent = sum(o[1] for o in outs)
        else:
            logp = sum(outs)
            ent = None
    else:
        out = chunked_token_logprobs(
            w, h, tgt, softcap=cfg.logits_softcap,
            num_chunks=vocab_chunks, with_entropy=with_entropy, shard=shard)
        logp, ent = out if with_entropy else (out, None)
    if segment_ids is not None:
        # a segment's first token has no in-segment predecessor: its shifted
        # hidden state belongs to the previous packed segment — zero it
        same_seg = segment_ids[:, 1:] == segment_ids[:, :-1]
        logp = jnp.where(same_seg, logp, 0.0)
        if with_entropy:
            ent = jnp.where(same_seg, ent, 0.0)
    pad = jnp.zeros((bsz, 1), logp.dtype)
    logp = jnp.concatenate([pad, logp], axis=1)
    if with_entropy:
        ent = jnp.concatenate([pad, ent], axis=1)
        return logp, ent, aux
    return logp, aux


def full_logits(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    *,
    lengths: Optional[Array] = None,
    image_embeds: Optional[Array] = None,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
):
    """(B, T, V) logits — small-model tests and decode sampling only."""
    hidden, _, _ = forward_hidden(params, cfg, tokens, lengths=lengths,
                                  image_embeds=image_embeds, mesh=mesh, rules=rules)
    w = head_weight(params.get("head", {}), params["embed"], cfg.tie_embeddings)
    return logits_apply(w, hidden, cfg.logits_softcap)


# -------------------------------------------------------------- prefill
def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    *,
    cache_len: int,
    prefill_len: Optional[Array] = None,
    image_embeds: Optional[Array] = None,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
):
    """Run the prompt through the model, build the decode cache.

    Returns (last_logits (B, V) [or (B, K, V)], cache).
    """
    bsz, t = tokens.shape[:2]
    if prefill_len is None:
        prefill_len = jnp.full((bsz,), t, jnp.int32)
    hidden, raw, _ = forward_hidden(
        params, cfg, tokens, lengths=prefill_len, image_embeds=image_embeds,
        mesh=mesh, rules=rules, collect_cache=True)

    cache = {}
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        entries = raw[f"group{gi}"]
        out = {}
        for j, kind in enumerate(pattern):
            conv = partial(B.block_cache_from_prefill, cfg, kind,
                           cache_len=cache_len, prefill_len=prefill_len)
            out[f"l{j}"] = jax.vmap(lambda e, _c=conv: _c(e))(entries[f"l{j}"])
        cache[f"group{gi}"] = out

    w = head_weight(params.get("head", {}), params["embed"], cfg.tie_embeddings)
    idx = jnp.maximum(prefill_len - 1, 0)
    last_h = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)  # (B,1,D)
    logits = logits_apply(w, last_h, cfg.logits_softcap)[:, 0]
    return logits, cache


def paged_prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    *,
    cache_len: int,
    prefill_len: Optional[Array] = None,
    prefix_kv: Optional[dict] = None,
    prefix_len: Optional[Array] = None,
    mesh=None,
    rules: ShardingRules = DEFAULT_RULES,
):
    """Prompt prefill for the paged engine: raw per-token state instead of
    dense rows.

    ``prefix_kv`` + ``prefix_len`` (B,) switch on partial-prefix resume
    (radix prefix cache): ``tokens`` holds only the uncached suffix
    (``prefill_len`` counts suffix tokens), ``prefix_kv`` carries the
    cached pages' K/V gathered per layer (see ``forward_hidden``), and
    positions are offset by ``prefix_len`` so rope and causal masking see
    absolute coordinates.  Returned raw K/V covers the suffix only — the
    engine scatters it into fresh pages after the cached ones.

    Same forward as ``prefill``, but pool-resident layers (capability
    table ``shared_prefix_ok``: attn, mla) come back raw — global
    attention as roped projections ``{"k"/"v": (repeat, B, T, KV, D)}``,
    MLA as compressed latents ``{"c_kv": (repeat, B, T, R), "k_rope":
    (repeat, B, T, Dr)}`` — the engine scatters them straight into pool
    pages, shared by every slot of a GRPO group — while every other mixer
    is converted to its normal per-slot decode entry (the engine
    broadcasts those to the group's slots; they are O(window) or O(1),
    not worth paging).

    Returns (last_logits (B, V), cache_tree).
    """
    caps.check_paged(cfg)
    bsz, t = tokens.shape[:2]
    if prefill_len is None:
        prefill_len = jnp.full((bsz,), t, jnp.int32)
    positions = None
    if prefix_len is not None:
        positions = (jnp.asarray(prefix_len).reshape(-1, 1)
                     + jnp.arange(t)[None, :]).astype(jnp.int32)
    hidden, raw, _ = forward_hidden(
        params, cfg, tokens, positions=positions, lengths=prefill_len,
        mesh=mesh, rules=rules, collect_cache=True, prefix_kv=prefix_kv)

    cache = {}
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        entries = raw[f"group{gi}"]
        out = {}
        for j, kind in enumerate(pattern):
            mixer = cfg.mixer_of(kind)
            if mixer == "attn":
                out[f"l{j}"] = {"k": entries[f"l{j}"]["k"],
                                "v": entries[f"l{j}"]["v"]}
            elif mixer == "mla":
                out[f"l{j}"] = {"c_kv": entries[f"l{j}"]["c_kv"],
                                "k_rope": entries[f"l{j}"]["k_rope"]}
            else:
                conv = partial(B.block_cache_from_prefill, cfg, kind,
                               cache_len=cache_len, prefill_len=prefill_len)
                out[f"l{j}"] = jax.vmap(lambda e, _c=conv: _c(e))(
                    entries[f"l{j}"])
        cache[f"group{gi}"] = out

    w = head_weight(params.get("head", {}), params["embed"], cfg.tie_embeddings)
    idx = jnp.maximum(prefill_len - 1, 0)
    last_h = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
    logits = logits_apply(w, last_h, cfg.logits_softcap)[:, 0]
    return logits, cache


# -------------------------------------------------------------- decode
def decode_step(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    cache: dict,
    pos: Array,
    *,
    block_tables: Optional[Array] = None,
    write_page: Optional[Array] = None,
    write_off: Optional[Array] = None,
    attn_impl: str = "ref",
):
    """One decode step.  tokens: (B,) int32 (or (B, K)); pos: (B,) int32
    absolute position of the NEW token.  Returns (logits (B, V) | (B, K, V),
    new_cache).

    With ``block_tables`` (B, M) + ``write_page``/``write_off`` (B,), the
    global-attention layers of ``cache`` are paged KV pools (DESIGN.md §8):
    each layer writes the new token at its pool's
    ``[write_page, write_off]`` cell (``write_page == num_pages`` drops the
    write) and attends through the shared block table.  Non-attention
    layers keep per-slot state either way.
    """
    if cfg.num_codebooks:
        tok = tokens[:, None, :]  # (B, 1, K)
    else:
        tok = tokens[:, None]     # (B, 1)
    scale = math.sqrt(cfg.d_model) if cfg.emb_scale_by_dim else None
    x = embed_apply(params["embed"], tok, scale=scale)
    paged = (None if block_tables is None else
             {"block_tables": block_tables, "write_page": write_page,
              "write_off": write_off})

    new_cache = {}
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        gp = params[f"group{gi}"]
        cg = cache[f"group{gi}"]

        def body(carry, xs, _pattern=pattern):
            xx = carry
            layer_p, cache_l = xs
            entries = {}
            for j, kind in enumerate(_pattern):
                xx, nc = B.block_decode(cfg, kind, layer_p[f"l{j}"], xx,
                                        cache_l[f"l{j}"], pos,
                                        paged=paged, attn_impl=attn_impl)
                entries[f"l{j}"] = nc
            return xx, entries

        if cfg.scan_layers and repeat > 1:
            x, nc = jax.lax.scan(body, x, (gp, cg))
        else:
            ncs = []
            for r in range(repeat):
                lp = jax.tree.map(lambda a: a[r], gp)
                cl = jax.tree.map(lambda a: a[r], cg)
                x, e = body(x, (lp, cl))
                ncs.append(e)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        new_cache[f"group{gi}"] = nc

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    w = head_weight(params.get("head", {}), params["embed"], cfg.tie_embeddings)
    logits = logits_apply(w, x, cfg.logits_softcap)[:, 0]
    return logits, new_cache


# -------------------------------------------------------------- cache ops
def _row_mask(mask: Array, leaf_ndim: int) -> Array:
    """Broadcast a (B,) row mask onto a stacked cache leaf (repeat, B, ...)."""
    return mask.reshape((1, -1) + (1,) * (leaf_ndim - 2))


def merge_cache(new_cache, old_cache, row_mask: Array):
    """Row-wise select between two decode-cache trees of identical layout.

    ``row_mask`` (B,) bool: True rows take ``new_cache``.  Every cache leaf
    is (repeat, batch, ...) (see ``cache_decl``), so the mask broadcasts on
    dim 1.  New leaves are cast to the old leaf's dtype — the arena's
    storage dtype (e.g. a bf16 KV arena) wins over the prefill compute
    dtype.  Reference semantics for slot refill in the continuous-batching
    engine: a retired slot's rows are replaced wholesale by a fresh prefill,
    so no state of the previous occupant can leak.  (The engine itself uses
    an equivalent narrow-lane scatter — prefill width R < S — for cost;
    tests/test_engine.py pins this full-width form.)
    """
    return jax.tree.map(
        lambda n, o: jnp.where(_row_mask(row_mask, n.ndim), n.astype(o.dtype), o),
        new_cache, old_cache)


def invalidate_cache_rows(cache, row_mask: Array):
    """Erase the selected batch rows of a decode cache.

    K/V planes and recurrent states go to zero; ``pos`` planes go to -1, the
    "empty" marker decode attention's visibility mask respects — an
    invalidated attention row attends to nothing even before it is
    re-prefilled.
    """
    def inv(path, leaf):
        is_pos = any(getattr(k, "key", None) == "pos" for k in path)
        fill = jnp.asarray(-1 if is_pos else 0, leaf.dtype)
        return jnp.where(_row_mask(row_mask, leaf.ndim), fill, leaf)

    return jax.tree_util.tree_map_with_path(inv, cache)


def invalidate_pages(cfg: ModelConfig, cache: dict, page_mask: Array) -> dict:
    """Poison the masked pages of every paged-attention pool in ``cache``.

    ``page_mask`` (num_pages,) bool: those pages' ``pos`` planes go to
    ``-1`` — invisible to every block table until rewritten.  The paged
    analogue of ``invalidate_cache_rows``: the engine applies it to pages
    returned to the free list (refcount hit zero) before they can be
    reallocated, so a recycled page can never leak its previous
    occupant's positions as valid entries.  K/V (or latent) bytes are left
    in place: an entry with ``pos = -1`` is unreachable.  Per-slot entries
    of non-pool mixers are untouched.
    """
    out = {}
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        grp = dict(cache[f"group{gi}"])
        for j, kind in enumerate(pattern):
            if caps.pool_resident(cfg.mixer_of(kind)):
                entry = dict(grp[f"l{j}"])
                # leaves are stacked (repeat, num_pages, page_len)
                entry["pos"] = jnp.where(page_mask[None, :, None], -1,
                                         entry["pos"])
                grp[f"l{j}"] = entry
        out[f"group{gi}"] = grp
    return out


# -------------------------------------------------------------- cache decl
def cache_decl(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    out = {}
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        layer = {}
        for j, kind in enumerate(pattern):
            entry = B.block_cache_decl(cfg, kind, batch, cache_len)
            layer[f"l{j}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((repeat,) + s.shape, s.dtype), entry)
        out[f"group{gi}"] = layer
    return out


def paged_cache_decl(cfg: ModelConfig, num_slots: int, cache_len: int, *,
                     num_pages: int, page_len: int) -> dict:
    """Abstract cache for the paged engine: global-attention layers become
    shared ``(num_pages, page_len)`` pools; every other mixer keeps its
    per-slot entry (rings are window-bounded, ssm/rec are O(1))."""
    out = {}
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        layer = {}
        for j, kind in enumerate(pattern):
            entry = B.block_cache_decl(cfg, kind, num_slots, cache_len,
                                       paged=(num_pages, page_len))
            layer[f"l{j}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((repeat,) + s.shape, s.dtype), entry)
        out[f"group{gi}"] = layer
    return out


def cache_axes(cfg: ModelConfig) -> dict:
    out = {}
    for gi, (pattern, repeat) in enumerate(cfg.blocks):
        layer = {}
        for j, kind in enumerate(pattern):
            ax = B.block_cache_axes(cfg, kind)
            layer[f"l{j}"] = jax.tree.map(
                lambda a: ("layers",) + a, ax,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x))
        out[f"group{gi}"] = layer
    return out
