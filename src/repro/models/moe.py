"""Mixture-of-Experts FFN: top-k routing, optional shared experts, capacity
dropping, expert parallelism over the "model" mesh axis.

TPU adaptation: dispatch is sort/gather-based (no one-hot dispatch einsum),
so HLO FLOPs reflect real expert compute instead of a quadratic-in-capacity
masking matmul.  A leading *group* dimension (the data-parallel batch shard)
is kept through dispatch so expert compute shards over BOTH the data axis
(groups) and the model axis (experts) — verified against the SPMD partitioner
during bring-up.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import mlp_apply, mlp_decl
from repro.models.params import ParamDecl

Array = jax.Array
F32 = jnp.float32


def moe_decl(d_model: int, m: MoEConfig):
    e, f = m.num_experts, m.d_ff_expert
    d = {
        "router": ParamDecl((d_model, e), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": ParamDecl((e, d_model, f), ("experts", "embed", "expert_mlp")),
        "w_up": ParamDecl((e, d_model, f), ("experts", "embed", "expert_mlp")),
        "w_down": ParamDecl((e, f, d_model), ("experts", "expert_mlp", "embed")),
    }
    if m.num_shared:
        d["shared"] = mlp_decl(d_model, m.num_shared * f, "swiglu")
    return d


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_apply(p, x: Array, m: MoEConfig, *, shard=None) -> tuple:
    """x: (B, T, D) -> (out (B, T, D), aux_metrics dict).

    Internally reshapes to (G, N, D) groups where G is the batch dim (sharded
    over data) so expert compute keeps both parallel axes.
    """
    b, t, dm = x.shape
    g, n = b, t
    e, k, cap = m.num_experts, m.top_k, _capacity(t, m)
    xg = x  # (G, N, D)

    logits = jnp.einsum("gnd,de->gne", xg.astype(F32), p["router"])
    gate_w, idx = jax.lax.top_k(logits, k)                    # (G, N, K)
    gate_w = jax.nn.softmax(gate_w, axis=-1).astype(x.dtype)

    def dispatch(xr, idxr, gwr):
        flat_e = idxr.reshape(-1)                             # (N*K,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        start = jnp.searchsorted(sorted_e, jnp.arange(e))
        rank_sorted = jnp.arange(n * k, dtype=jnp.int32) - start[sorted_e]
        ranks = jnp.zeros((n * k,), jnp.int32).at[order].set(rank_sorted)
        slot = flat_e * cap + jnp.minimum(ranks, cap - 1)
        keep = (ranks < cap).astype(xr.dtype)                 # dropped tokens
        xk = jnp.repeat(xr, k, axis=0) * keep[:, None]
        buf = jnp.zeros((e * cap, dm), xr.dtype).at[slot].add(xk)
        return buf.reshape(e, cap, dm), slot, gwr.reshape(-1) * keep, keep

    buf, slot, comb_w, keep = jax.vmap(dispatch)(xg, idx, gate_w)  # (G,E,C,D)
    if shard is not None:
        buf = shard(buf, ("batch", "experts", None, None))

    h_gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    h_up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    if shard is not None:
        h = shard(h, ("batch", "experts", None, None))
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    if shard is not None:
        y = shard(y, ("batch", None, None, None))
    y = y.reshape(g, e * cap, dm)

    out = jnp.take_along_axis(y, slot[..., None], axis=1) * comb_w[..., None]
    out = out.reshape(g, n, k, dm).sum(axis=2)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xg, "swiglu")

    # router aux: load-balance loss (Switch-style) + drop fraction
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, N, E)
    density = jnp.mean(probs, axis=(0, 1))
    onehot_top1 = jax.nn.one_hot(idx[..., 0], e, dtype=F32)
    frac_tokens = jnp.mean(onehot_top1, axis=(0, 1))
    aux_loss = m.router_aux_weight * e * jnp.sum(density * frac_tokens)
    metrics = {
        "moe_aux_loss": aux_loss,
        "moe_drop_frac": 1.0 - jnp.mean(keep),
    }
    return out, metrics


def moe_decode_apply(p, x: Array, m: MoEConfig) -> Array:
    """Single-token decode: fold the whole batch into ONE dispatch group so
    capacity padding stays ~capacity_factor instead of blowing up from the
    per-group capacity floor.  Expert compute shards over the model axis;
    the token all-gather this implies is ~1 MB at decode batch sizes."""
    b, t, dm = x.shape
    xg = x.reshape(1, b * t, dm)
    out, _ = moe_apply(p, xg, m)
    return out.reshape(b, t, dm)
