"""Mamba-2 SSD (state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6):
quadratic attention-like compute *within* chunks plus a linear scan of
inter-chunk states — O(T·Q) FLOPs for chunk length Q, TPU-friendly (all
einsums, one short lax.scan over chunks).

Decode is the exact SSM recurrence on a (B, H, P, N) state.

NAT note: a prefix is a valid computation for any left-to-right SSM, so RPC
physical truncation composes directly — savings are linear in the cut ratio
(the forward was never quadratic), as recorded in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import SSMConfig
from repro.models.params import ParamDecl

Array = jax.Array
F32 = jnp.float32


def ssm_decl(d_model: int, s: SSMConfig):
    d_inner = s.expand * d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    return {
        # in_proj emits [z (gate), x, B, C, dt]
        "w_in": ParamDecl(
            (d_model, 2 * d_inner + 2 * s.n_groups * s.state_dim + n_heads),
            ("embed", "mlp")),
        "conv_w": ParamDecl((s.conv_width, conv_dim), ("conv", "mlp"), scale=0.5),
        "conv_b": ParamDecl((conv_dim,), ("mlp",), init="zeros"),
        "a_log": ParamDecl((n_heads,), ("ssm_heads",), init="value", value=0.0,
                           dtype=jnp.float32),
        "dt_bias": ParamDecl((n_heads,), ("ssm_heads",), init="zeros",
                             dtype=jnp.float32),
        "d_skip": ParamDecl((n_heads,), ("ssm_heads",), init="ones",
                            dtype=jnp.float32),
        "norm_w": ParamDecl((d_inner,), ("mlp",), init="zeros"),
        "w_out": ParamDecl((d_inner, d_model), ("mlp", "embed")),
    }


def _split_proj(cfg: SSMConfig, d_model: int, zxbcdt: Array):
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    gn = cfg.n_groups * cfg.state_dim
    z, xin, bc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * gn],
                               axis=-1)
    b, c = jnp.split(bc, 2, axis=-1)
    return z, xin, b, c, dt, d_inner, n_heads


def _gated_norm(w: Array, x: Array, z: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(F32))).astype(x.dtype)


def ssm_apply(p, x: Array, cfg: SSMConfig, *, lengths=None,
              return_state: bool = False, segment_ids=None):
    """Full-sequence SSD.  x: (B, T, D) -> (B, T, D).

    ``lengths`` (B,) marks valid prefixes: padded positions become identity
    transitions (decay 1, zero input) so the final state equals the state at
    position lengths-1 — required for variable-length prefill and for the
    internal pad-to-chunk-multiple.

    ``segment_ids`` (B, T) activates packed-segment state resets
    (capability table ``state_reset='zero'``): the conv taps, intra-chunk
    decay, inter-chunk carry, and carried-state readout are all masked to
    same-segment pairs, so every token's output depends only on its own
    segment — exactly the math of scoring each segment from a zero state.
    (Exact, not bitwise: chunk boundaries fall at different offsets than in
    the padded grid, so f32 cumsums reassociate — see DESIGN.md §9.)
    """
    bsz, t_orig, d_model = x.shape
    q = min(cfg.chunk, t_orig)
    seg = None if segment_ids is None else segment_ids.astype(jnp.int32)
    if t_orig % q:
        pad = q - t_orig % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        if lengths is None:
            lengths = jnp.full((bsz,), t_orig, jnp.int32)
        if seg is not None:
            # tail gets its own segment id: never interacts with real tokens
            seg = jnp.pad(seg, ((0, 0), (0, pad)), constant_values=-1)
    bsz, t, d_model = x.shape
    nc = t // q
    zxbcdt = jnp.einsum("btd,de->bte", x, p["w_in"])
    z, xin, bmat, cmat, dt, d_inner, n_heads = _split_proj(cfg, d_model, zxbcdt)
    valid = (None if lengths is None
             else (jnp.arange(t)[None, :] < lengths[:, None]))  # (B, T)

    # causal depthwise conv over [x, B, C]
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], seg)
    xin, bmat, cmat = jnp.split(conv, [d_inner, d_inner + cfg.n_groups * cfg.state_dim],
                                axis=-1)

    h, pdim, n = n_heads, cfg.head_dim, cfg.state_dim
    g = cfg.n_groups
    rep = h // g
    xh = xin.reshape(bsz, t, h, pdim)
    # expand B/C groups to heads once: (B, T, H, N)
    bh = jnp.repeat(bmat.reshape(bsz, t, g, n), rep, axis=2)
    ch = jnp.repeat(cmat.reshape(bsz, t, g, n), rep, axis=2)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])             # (B, T, H)
    if valid is not None:
        dt = dt * valid[:, :, None]  # identity transition on padding
    a = -jnp.exp(p["a_log"])                                        # (H,)
    da = dt * a                                                     # (B, T, H) <= 0

    # --- chunked SSD ---
    dac = da.reshape(bsz, nc, q, h)
    cum = jnp.cumsum(dac, axis=2)                                   # within-chunk
    seg_total = cum[:, :, -1]                                       # (B, nc, H)
    if seg is not None:
        seg_q = seg.reshape(bsz, nc, q)
        seg_first = seg_q[:, :, :1]                                 # (B, nc, 1)
        seg_last = seg_q[:, :, -1:]
        # chunk flags: does a packed-segment boundary cross this chunk, and
        # does the carry entering it belong to a different segment?
        broken = seg_first[:, :, 0] != seg_last[:, :, 0]            # (B, nc)
        reset = (jnp.concatenate([seg_first[:, :1, 0],
                                  seg_last[:, :-1, 0]], axis=1)
                 != seg_first[:, :, 0])                             # (B, nc)

    bq = bh.reshape(bsz, nc, q, h, n).astype(F32)
    cq = ch.reshape(bsz, nc, q, h, n).astype(F32)
    xq = xh.reshape(bsz, nc, q, h, pdim).astype(F32)
    dtq = dt.reshape(bsz, nc, q, h)

    # intra-chunk (quadratic in q): L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]              # (B,nc,q,q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    if seg is not None:
        same = seg_q[:, :, :, None] == seg_q[:, :, None, :]         # (B,nc,q,q)
        decay = jnp.where(same[..., None], decay, 0.0)
    cb = jnp.einsum("bnihs,bnjhs->bnijh", cq, bq)                   # (B,nc,q,q,H)
    att = cb * decay * dtq[:, :, None, :, :]                        # weight by dt_j
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att, xq)

    # inter-chunk: states carried by a scan
    # chunk state contribution: S_n = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    w_state = jnp.exp(seg_total[:, :, None, :] - cum) * dtq         # (B,nc,q,H)
    if seg is not None:
        # only the chunk's suffix run (same segment as its last token) may
        # feed the carried state
        w_state = w_state * (seg_q == seg_last)[..., None]
    bx = jnp.einsum("bnjh,bnjhs,bnjhp->bnhps", w_state, bq, xq)     # (B,nc,H,P,N)

    init = jnp.zeros((bsz, h, pdim, n), F32)
    if seg is None:

        def scan_fn(state, inp):
            bx_n, seg_n = inp                                       # (B,H,P,N),(B,H)
            new = state * jnp.exp(seg_n)[:, :, None, None] + bx_n
            return new, state                                       # emit PREVIOUS

        final_state, prev_states = jax.lax.scan(
            scan_fn, init,
            (jnp.moveaxis(bx, 1, 0), jnp.moveaxis(seg_total, 1, 0)))
    else:

        def scan_fn(state, inp):
            bx_n, seg_n, reset_n, broken_n = inp
            # carry from a different segment never enters; a chunk whose
            # suffix run started inside it emits only its own bx
            state_in = jnp.where(reset_n[:, None, None, None], 0.0, state)
            new = jnp.where(broken_n[:, None, None, None], bx_n,
                            state_in * jnp.exp(seg_n)[:, :, None, None] + bx_n)
            return new, state_in                                    # emit PREVIOUS

        final_state, prev_states = jax.lax.scan(
            scan_fn, init,
            (jnp.moveaxis(bx, 1, 0), jnp.moveaxis(seg_total, 1, 0),
             jnp.moveaxis(reset, 1, 0), jnp.moveaxis(broken, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                   # (B,nc,H,P,N)

    # contribution of carried state to each position: C_i exp(cum_i) S_prev
    y_inter = jnp.einsum("bnihs,bnhps,bnih->bnihp", cq, prev_states, jnp.exp(cum))
    if seg is not None:
        # the carry only reaches the chunk's prefix run (same segment as
        # its first token)
        y_inter = y_inter * (seg_q == seg_first)[..., None, None]
    y = (y_intra + y_inter).reshape(bsz, t, h, pdim)
    y = y + xh.astype(F32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, t, d_inner)
    y = _gated_norm(p["norm_w"], y.astype(x.dtype), z)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])[:, :t_orig]
    if return_state:
        conv_tail = conv_tail_at(conv_in, p["conv_w"].shape[0], lengths)
        return out, {"state": final_state.astype(jnp.float32), "conv": conv_tail}
    return out, None


def _causal_conv(x: Array, w: Array, b: Array, seg=None) -> Array:
    """Depthwise causal conv, width K.  x: (B, T, C), w: (K, C).

    ``seg`` (B, T) masks taps that would read across a packed-segment
    boundary — bitwise-identical to the zero left-padding each segment sees
    at the start of a padded row."""
    k = w.shape[0]
    t = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    if seg is None:
        out = sum(xp[:, i:i + t] * w[i][None, None, :] for i in range(k))
    else:
        sp = jnp.pad(seg, ((0, 0), (k - 1, 0)), constant_values=-2)
        out = sum(
            jnp.where((sp[:, i:i + t] == seg)[:, :, None], xp[:, i:i + t], 0)
            * w[i][None, None, :] for i in range(k))
    return jax.nn.silu((out + b[None, None, :]).astype(F32)).astype(x.dtype)


def conv_tail_at(x: Array, k: int, lengths=None) -> Array:
    """Last K-1 raw conv inputs *per row* (the decode-time conv state).
    With ``lengths`` the tail ends at position lengths-1; out-of-range
    entries (length < K-1) are zero."""
    b, t, c = x.shape
    if lengths is None:
        return x[:, -(k - 1):, :].astype(jnp.float32)
    idx = lengths[:, None] - (k - 1) + jnp.arange(k - 1)[None, :]   # (B, K-1)
    ok = idx >= 0
    g = jnp.take_along_axis(x, jnp.maximum(idx, 0)[:, :, None], axis=1)
    return jnp.where(ok[:, :, None], g, 0).astype(jnp.float32)


def ssm_decode(p, x: Array, cache: dict, cfg: SSMConfig):
    """Exact single-step recurrence.  x: (B, 1, D).
    cache: {"state": (B,H,P,N) f32, "conv": (B, K-1, conv_dim) f32}."""
    bsz, _, d_model = x.shape
    zxbcdt = jnp.einsum("btd,de->bte", x, p["w_in"])
    z, xin, bmat, cmat, dt, d_inner, n_heads = _split_proj(cfg, d_model, zxbcdt)

    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)           # (B,1,C)
    k = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"].astype(conv_in.dtype), conv_in], axis=1)
    w = p["conv_w"]
    conv = sum(hist[:, i:i + 1] * w[i][None, None, :] for i in range(k))
    conv = jax.nn.silu((conv + p["conv_b"][None, None, :]).astype(F32)).astype(x.dtype)
    new_conv = hist[:, 1:, :].astype(jnp.float32)

    xin, bmat, cmat = jnp.split(conv, [d_inner, d_inner + cfg.n_groups * cfg.state_dim],
                                axis=-1)
    h, pdim, n = n_heads, cfg.head_dim, cfg.state_dim
    rep = h // cfg.n_groups
    xh = xin.reshape(bsz, h, pdim)
    bh = jnp.repeat(bmat.reshape(bsz, cfg.n_groups, n), rep, axis=1)  # (B, H, N)
    ch = jnp.repeat(cmat.reshape(bsz, cfg.n_groups, n), rep, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(F32) + p["dt_bias"])       # (B, H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                         # (B, H)

    state = cache["state"]
    new_state = (state * decay[:, :, None, None]
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt, bh.astype(F32), xh.astype(F32)))
    y = jnp.einsum("bhn,bhpn->bhp", ch.astype(F32), new_state)
    y = y + xh.astype(F32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_inner)
    y = _gated_norm(p["norm_w"], y.astype(x.dtype), z)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return out, {"state": new_state, "conv": new_conv}


def ssm_cache_decl(batch: int, d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    h = d_inner // cfg.head_dim
    conv_dim = d_inner + 2 * cfg.n_groups * cfg.state_dim
    return {
        "state": jax.ShapeDtypeStruct((batch, h, cfg.head_dim, cfg.state_dim),
                                      jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, conv_dim),
                                     jnp.float32),
    }


def ssm_cache_axes():
    return {"state": ("batch", "ssm_heads", None, "ssm_state"),
            "conv": ("batch", "conv", "mlp")}
