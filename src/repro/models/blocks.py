"""Block-level glue: declaration / train-apply / decode-apply / cache layout
for every mixer kind, dispatched by the block-kind strings in
``ModelConfig.blocks``.

A block = pre-norm mixer + residual, then (unless the kind's MLP is "none")
pre-norm MLP/MoE + residual.  All functions are shape-polymorphic and pure,
so the model can lax.scan over stacked layer parameters.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import capabilities as caps
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    bf16_grad, mlp_apply, mlp_decl, rmsnorm, rmsnorm_decl,
)

Array = jax.Array


# ------------------------------------------------------------- declarations
def block_decl(cfg: ModelConfig, kind: str) -> dict:
    mixer = cfg.mixer_of(kind)
    mlp = cfg.mlp_of(kind)
    d = {"ln1": rmsnorm_decl(cfg.d_model)}
    if mixer in ("attn", "local"):
        d["mixer"] = attn.attn_decl(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim)
    elif mixer == "xattn":
        d["mixer"] = attn.xattn_decl(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim)
    elif mixer == "mla":
        d["mixer"] = mla_mod.mla_decl(cfg.d_model, cfg.n_heads, cfg.mla)
    elif mixer == "ssm":
        d["mixer"] = ssm_mod.ssm_decl(cfg.d_model, cfg.ssm)
    elif mixer == "rec":
        d["mixer"] = rg_mod.rglru_decl(cfg.d_model, cfg.rglru)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if mlp != "none":
        d["ln2"] = rmsnorm_decl(cfg.d_model)
        if mlp == "moe":
            d["mlp"] = moe_mod.moe_decl(cfg.d_model, cfg.moe)
        else:
            d["mlp"] = mlp_decl(cfg.d_model, cfg.d_ff, mlp)
    return d


def _window_of(cfg: ModelConfig, mixer: str) -> int:
    return cfg.window if mixer == "local" else 0


# ---------------------------------------------------------------- training
def block_apply(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x: Array,
    *,
    positions: Array,
    lengths: Optional[Array],
    image_embeds: Optional[Array],
    collect_cache: bool,
    shard=None,
    segment_ids: Optional[Array] = None,
    prefix_kv: Optional[dict] = None,
    paged_prefix: Optional[dict] = None,
    page_tables: Optional[dict] = None,
    paged_impl: str = "ref",
):
    """Full-sequence application.  Returns (x, cache_entry_or_None, aux).

    ``segment_ids`` (B, T) selects the packed batch layout.  Isolation per
    mixer kind follows the capability table (models/capabilities.py):
    attention kinds mask visibility on segment equality; ssm/rec zero their
    recurrent state and conv taps at segment starts; cross-attention rejects
    packing (its image K-V is shared across the whole row).

    ``prefix_kv`` is this layer's cached-prefix K/V for partial-prefix
    prefill resume (radix prefix cache) — only the global-attention mixer
    supports it; the capability table gates configs before we get here.

    ``paged_prefix`` (this layer's rollout pool {"k"/"v"/"pos"}) +
    ``page_tables`` ({"block_tables" (S, M), "seg_start" (S,)}) select the
    zero-re-prefill scoring path (DESIGN.md §11): the row holds response
    suffixes and prompt KV is read straight from the pool pages.  Also
    gated to the global-attention mixer by the capability table
    (``check_paged_score``); ``paged_impl`` picks the jnp gather ref or
    the Pallas prefill kernel.
    """
    mixer = cfg.mixer_of(kind)
    mlp = cfg.mlp_of(kind)
    if segment_ids is not None:
        caps.require_packed_mixer(mixer)
    if prefix_kv is not None and mixer != "attn":
        raise caps.CapabilityError(
            f"partial-prefix prefill resume requires the 'attn' mixer "
            f"(full-KV pool pages); got {mixer!r}")
    if paged_prefix is not None and mixer != "attn":
        raise caps.CapabilityError(
            f"paged scoring requires the 'attn' mixer "
            f"(full-KV pool pages); got {mixer!r}")
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    cache_entry = None
    if mixer == "attn" and paged_prefix is not None:
        out, (k, v) = attn.paged_score_attention(
            p["mixer"], h, positions, rope_theta=cfg.rope_theta,
            segment_ids=segment_ids, pool=paged_prefix,
            block_tables=page_tables["block_tables"],
            seg_start=page_tables["seg_start"], impl=paged_impl)
        if collect_cache:
            cache_entry = {"k": k, "v": v}
    elif mixer in ("attn", "local"):
        out, (k, v) = attn.self_attention(
            p["mixer"], h, positions, window=_window_of(cfg, mixer),
            rope_theta=cfg.rope_theta, lengths=lengths,
            segment_ids=segment_ids, prefix=prefix_kv)
        if collect_cache:
            cache_entry = {"k": k, "v": v}
    elif mixer == "xattn":
        ikv = attn.image_kv_from_embeds(p["mixer"], image_embeds)
        out = attn.cross_attention(p["mixer"], h, ikv)
        if collect_cache:
            cache_entry = {"ik": ikv[0], "iv": ikv[1]}
    elif mixer == "mla":
        out, (c_kv, k_rope) = mla_mod.mla_attention(
            p["mixer"], h, positions, cfg.mla, norm_eps=cfg.norm_eps,
            lengths=lengths, segment_ids=segment_ids)
        if collect_cache:
            cache_entry = {"c_kv": c_kv, "k_rope": k_rope}
    elif mixer == "ssm":
        out, st = ssm_mod.ssm_apply(p["mixer"], h, cfg.ssm, lengths=lengths,
                                    return_state=collect_cache,
                                    segment_ids=segment_ids)
        cache_entry = st
    elif mixer == "rec":
        out, st = rg_mod.rglru_apply(p["mixer"], h, cfg.rglru, lengths=lengths,
                                     return_state=collect_cache,
                                     segment_ids=segment_ids)
        cache_entry = st
    else:
        raise ValueError(mixer)
    x = x + out
    if mlp != "none":
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if mlp == "moe":
            out2, metrics = moe_mod.moe_apply(p["mlp"], h2, cfg.moe, shard=shard)
            aux = aux + metrics["moe_aux_loss"]
        else:
            out2 = mlp_apply(p["mlp"], h2, mlp)
        x = x + out2
    # cotangents crossing block boundaries travel in bf16 (see bf16_grad);
    # ensures all backward psums of the residual stream are half-width
    x = bf16_grad(x)
    if shard is not None:
        x = shard(x, ("batch", "act_seq", None))
    return x, cache_entry, aux


# ------------------------------------------------------------------ decode
def block_decode(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x: Array,
    cache: dict,
    pos: Array,
    *,
    paged: Optional[dict] = None,
    attn_impl: str = "ref",
):
    """One-token decode.  x: (B, 1, D).  Returns (x, new_cache).

    ``paged`` (arrays: ``block_tables`` (S, M), ``write_page`` /
    ``write_off`` (S,)) switches pool-resident mixers (capability table:
    ``shared_prefix_ok``) to the paged pool — global attention pages full
    KV, MLA pages its compressed latents; other mixers keep their per-slot
    state — local rings are already window-bounded and ssm/rec states are
    O(1), so only O(T) per-token state is worth paging.
    """
    mixer = cfg.mixer_of(kind)
    mlp = cfg.mlp_of(kind)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mixer == "attn" and paged is not None:
        out, new_cache = attn.paged_decode_attention(
            p["mixer"], h, cache, pos, paged["block_tables"],
            paged["write_page"], paged["write_off"],
            rope_theta=cfg.rope_theta, impl=attn_impl)
    elif mixer == "mla" and paged is not None:
        out, new_cache = mla_mod.mla_paged_decode(
            p["mixer"], h, cache, pos, paged["block_tables"],
            paged["write_page"], paged["write_off"], cfg.mla,
            norm_eps=cfg.norm_eps, impl=attn_impl)
    elif mixer in ("attn", "local"):
        out, new_cache = attn.decode_attention(
            p["mixer"], h, cache, pos, window=_window_of(cfg, mixer),
            rope_theta=cfg.rope_theta)
    elif mixer == "xattn":
        out = attn.cross_attention(p["mixer"], h, (cache["ik"], cache["iv"]))
        new_cache = cache
    elif mixer == "mla":
        out, new_cache = mla_mod.mla_decode(p["mixer"], h, cache, pos, cfg.mla,
                                            norm_eps=cfg.norm_eps)
    elif mixer == "ssm":
        out, new_cache = ssm_mod.ssm_decode(p["mixer"], h, cache, cfg.ssm)
    elif mixer == "rec":
        out, new_cache = rg_mod.rglru_decode(p["mixer"], h, cache, cfg.rglru)
    else:
        raise ValueError(mixer)
    x = x + out
    if mlp != "none":
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if mlp == "moe":
            out2 = moe_mod.moe_decode_apply(p["mlp"], h2, cfg.moe)
        else:
            out2 = mlp_apply(p["mlp"], h2, mlp)
        x = x + out2
    return x, new_cache


# ----------------------------------------------------------- cache layouts
def block_cache_decl(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     paged: Optional[tuple] = None):
    """Abstract decode-cache entry for one layer of this kind (or None).

    ``paged = (num_pages, page_len)`` declares pool-resident layers
    (capability table: attn full KV, MLA compressed latents) as shared
    pools instead of per-slot rows; every other mixer keeps its per-slot
    layout (see ``block_decode``).
    """
    mixer = cfg.mixer_of(kind)
    if mixer == "attn":
        if paged is not None:
            num_pages, page_len = paged
            return attn.paged_attn_cache_decl(num_pages, page_len,
                                              cfg.n_kv_heads, cfg.head_dim)
        return attn.attn_cache_decl(batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    if mixer == "local":
        return attn.attn_cache_decl(batch, min(cache_len, cfg.window),
                                    cfg.n_kv_heads, cfg.head_dim)
    if mixer == "xattn":
        n = cfg.num_image_tokens
        sds = jax.ShapeDtypeStruct
        return {"ik": sds((batch, n, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                "iv": sds((batch, n, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)}
    if mixer == "mla":
        if paged is not None:
            num_pages, page_len = paged
            return mla_mod.mla_paged_cache_decl(num_pages, page_len, cfg.mla)
        return mla_mod.mla_cache_decl(batch, cache_len, cfg.mla)
    if mixer == "ssm":
        return ssm_mod.ssm_cache_decl(batch, cfg.d_model, cfg.ssm)
    if mixer == "rec":
        return rg_mod.rglru_cache_decl(batch, cfg.d_model, cfg.rglru)
    raise ValueError(mixer)


def block_cache_axes(cfg: ModelConfig, kind: str):
    mixer = cfg.mixer_of(kind)
    if mixer in ("attn", "local"):
        return attn.attn_cache_axes()
    if mixer == "xattn":
        return {"ik": ("batch", "image_tokens", "kv_heads", "head_dim"),
                "iv": ("batch", "image_tokens", "kv_heads", "head_dim")}
    if mixer == "mla":
        return mla_mod.mla_cache_axes()
    if mixer == "ssm":
        return ssm_mod.ssm_cache_axes()
    if mixer == "rec":
        return rg_mod.rglru_cache_axes()
    raise ValueError(mixer)


def block_cache_from_prefill(cfg: ModelConfig, kind: str, entry, cache_len: int,
                             prefill_len):
    """Convert a prefill cache entry into the decode cache layout."""
    mixer = cfg.mixer_of(kind)
    if mixer in ("attn", "local"):
        s_len = cache_len if mixer == "attn" else min(cache_len, cfg.window)
        return attn.cache_from_prefill(entry["k"], entry["v"], s_len,
                                       prefill_len, _window_of(cfg, mixer))
    if mixer == "xattn":
        return entry
    if mixer == "mla":
        return mla_mod.mla_cache_from_prefill(entry["c_kv"], entry["k_rope"],
                                              cache_len, prefill_len)
    if mixer in ("ssm", "rec"):
        return entry
    raise ValueError(mixer)
