"""Multi-head Latent Attention (DeepSeek-V2).

Keys/values are compressed into a per-token latent ``c_kv`` of rank
``kv_lora_rank`` plus a single shared rotary key ``k_rope``; the cache stores
only (c_kv, k_rope) — the paper's ~1/24 KV-cache reduction.

Train/prefill uses the decompressed (matmul-friendly) form.  Decode uses the
*absorbed* form: W_uk is folded into the query and W_uv into the output
projection, so attention contracts directly against the cached latents and
never materializes per-head K/V for the whole history.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MLAConfig
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_decl
from repro.models.params import ParamDecl

Array = jax.Array
F32 = jnp.float32
NEG_INF = -2.0 ** 30


def mla_decl(d_model: int, n_heads: int, m: MLAConfig):
    qk = m.qk_nope_dim + m.qk_rope_dim
    d = {
        "w_dkv": ParamDecl((d_model, m.kv_lora_rank), ("embed", "kv_lora")),
        "w_kr": ParamDecl((d_model, m.qk_rope_dim), ("embed", "head_dim")),
        "kv_norm": rmsnorm_decl(m.kv_lora_rank),
        "w_uk": ParamDecl((m.kv_lora_rank, n_heads, m.qk_nope_dim),
                          ("kv_lora", "heads", "head_dim")),
        "w_uv": ParamDecl((m.kv_lora_rank, n_heads, m.v_head_dim),
                          ("kv_lora", "heads", "head_dim")),
        "wo": ParamDecl((n_heads, m.v_head_dim, d_model),
                        ("heads", "head_dim", "embed")),
    }
    if m.q_lora_rank:
        d["w_dq"] = ParamDecl((d_model, m.q_lora_rank), ("embed", "q_lora"))
        d["q_norm"] = rmsnorm_decl(m.q_lora_rank)
        d["w_uq"] = ParamDecl((m.q_lora_rank, n_heads, qk),
                              ("q_lora", "heads", "head_dim"))
    else:
        d["wq"] = ParamDecl((d_model, n_heads, qk), ("embed", "heads", "head_dim"))
    return d


def _queries(p, x: Array, m: MLAConfig, norm_eps: float):
    if "w_dq" in p:
        cq = jnp.einsum("btd,dr->btr", x, p["w_dq"])
        cq = rmsnorm(p["q_norm"], cq, norm_eps)
        q = jnp.einsum("btr,rhk->bthk", cq, p["w_uq"])
    else:
        q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    return jnp.split(q, [m.qk_nope_dim], axis=-1)  # q_nope, q_rope


def _latents(p, x: Array, m: MLAConfig, norm_eps: float, positions: Array):
    c_kv = jnp.einsum("btd,dr->btr", x, p["w_dkv"])
    c_kv = rmsnorm(p["kv_norm"], c_kv, norm_eps)
    k_rope = jnp.einsum("btd,dk->btk", x, p["w_kr"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, 10_000.0)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(
    p, x: Array, positions: Array, m: MLAConfig, *,
    norm_eps: float, lengths=None, segment_ids=None,
) -> tuple:
    """Full-sequence MLA (train/prefill), decompressed form.

    ``segment_ids`` (B, T) switches to the packed layout: attention is
    confined to same-segment tokens (``lengths`` is then ignored).
    Returns (out, (c_kv, k_rope)) — the latter is the decode cache content.
    """
    b, t, _ = x.shape
    q_nope, q_rope = _queries(p, x, m, norm_eps)
    q_rope = apply_rope(q_rope, positions, 10_000.0)
    c_kv, k_rope = _latents(p, x, m, norm_eps, positions)

    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"])
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"])

    scale = 1.0 / jnp.sqrt(m.qk_nope_dim + m.qk_rope_dim).astype(F32)
    s = jnp.einsum("bthk,bshk->bhts", q_nope, k_nope, preferred_element_type=F32)
    s += jnp.einsum("bthk,bsk->bhts", q_rope, k_rope, preferred_element_type=F32)
    s *= scale
    if segment_ids is not None:
        from repro.models.attention import segment_mask

        mask = segment_mask(segment_ids, positions)
    else:
        mask = jnp.tril(jnp.ones((t, t), bool))[None, None]
        if lengths is not None:
            mask = mask & (jnp.arange(t)[None, None, None, :]
                           < lengths[:, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    pa = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhts,bshk->bthk", pa.astype(v.dtype), v)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    return out, (c_kv, k_rope)


def mla_decode(p, x: Array, cache: dict, pos: Array, m: MLAConfig, *, norm_eps: float):
    """One-token decode in the absorbed form.

    cache: {"c_kv": (B, S, R), "k_rope": (B, S, Dr), "pos": (B, S)}.
    Scores: q_nope @ W_uk absorbed -> contract against latents directly:
        s = (q_nope W_uk) . c_kv + q_rope . k_rope
        o = (softmax(s) @ c_kv) W_uv
    """
    from repro.models.attention import _norm_pos

    b = x.shape[0]
    s_len = cache["c_kv"].shape[1]
    q_nope, q_rope = _queries(p, x, m, norm_eps)      # (B, 1, H, *)
    posb = _norm_pos(pos, b)
    q_rope = apply_rope(q_rope, posb, 10_000.0)
    c_new, kr_new = _latents(p, x, m, norm_eps, posb)  # (B, 1, R), (B, 1, Dr)

    slot = (posb[:, 0] % s_len).astype(jnp.int32)
    bi = jnp.arange(b)
    c_kv = cache["c_kv"].at[bi, slot].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[bi, slot].set(
        kr_new[:, 0].astype(cache["k_rope"].dtype))
    cpos = cache["pos"].at[bi, slot].set(posb[:, 0].astype(jnp.int32))

    q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, p["w_uk"])  # absorbed query
    scale = 1.0 / jnp.sqrt(m.qk_nope_dim + m.qk_rope_dim).astype(F32)
    s = jnp.einsum("bthr,bsr->bhts", q_abs, c_kv.astype(q_abs.dtype),
                   preferred_element_type=F32)
    s += jnp.einsum("bthk,bsk->bhts", q_rope, k_rope.astype(q_rope.dtype),
                    preferred_element_type=F32)
    s *= scale
    valid = (cpos >= 0) & (cpos <= posb[:, :1])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pa = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhts,bsr->bthr", pa, c_kv.astype(pa.dtype))  # (B,1,H,R)
    o = jnp.einsum("bthr,rhk->bthk", o_lat.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope, "pos": cpos}


def mla_cache_decl(batch: int, s_len: int, m: MLAConfig, dtype=jnp.bfloat16):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, s_len, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, s_len, m.qk_rope_dim), dtype),
        "pos": jax.ShapeDtypeStruct((batch, s_len), jnp.int32),
    }


def mla_cache_axes():
    return {
        "c_kv": ("batch", "kv_seq", "kv_lora"),
        "k_rope": ("batch", "kv_seq", "head_dim"),
        "pos": ("batch", "kv_seq"),
    }


def mla_paged_cache_decl(num_pages: int, page_len: int, m: MLAConfig,
                         dtype=jnp.bfloat16):
    """Abstract paged latent pool for one MLA layer.

    Same shared-pool convention as ``attention.paged_attn_cache_decl`` —
    no batch axis, per-slot structure lives in the engine's block tables,
    ``pos`` is per-entry absolute position with ``-1`` = empty — but the
    per-token payload is the compressed latent (rank R + the shared rotary
    key), so the page stride is R + Dr instead of 2 * KV * D."""
    return {
        "c_kv": jax.ShapeDtypeStruct((num_pages, page_len, m.kv_lora_rank),
                                     dtype),
        "k_rope": jax.ShapeDtypeStruct((num_pages, page_len, m.qk_rope_dim),
                                       dtype),
        "pos": jax.ShapeDtypeStruct((num_pages, page_len), jnp.int32),
    }


def mla_paged_cache_axes():
    return {
        "c_kv": ("kv_pages", None, "kv_lora"),
        "k_rope": ("kv_pages", None, "head_dim"),
        "pos": ("kv_pages", None),
    }


def mla_paged_cache_update(pool: dict, c_new: Array, kr_new: Array, pos: Array,
                           write_page: Array, write_off: Array) -> dict:
    """Write one token's latents per slot into its private decode page
    (``write_page == num_pages`` is the drop sentinel, as for attention)."""
    new_c = pool["c_kv"].at[write_page, write_off].set(
        c_new[:, 0].astype(pool["c_kv"].dtype), mode="drop")
    new_kr = pool["k_rope"].at[write_page, write_off].set(
        kr_new[:, 0].astype(pool["k_rope"].dtype), mode="drop")
    new_pos = pool["pos"].at[write_page, write_off].set(
        pos[:, 0].astype(jnp.int32), mode="drop")
    return {"c_kv": new_c, "k_rope": new_kr, "pos": new_pos}


def mla_gather_pages(pool: dict, block_tables: Array):
    """Materialize each slot's logical latent sequence through its block
    table (jnp reference realization; the Pallas kernel reads pages through
    the same table without the dense copy)."""
    s, m_ = block_tables.shape
    bt = jnp.maximum(block_tables, 0)
    cg = pool["c_kv"][bt]                    # (S, M, page_len, R)
    krg = pool["k_rope"][bt]
    posg = jnp.where(block_tables[..., None] >= 0, pool["pos"][bt], -1)
    pl_ = posg.shape[-1]
    return (cg.reshape(s, m_ * pl_, cg.shape[-1]),
            krg.reshape(s, m_ * pl_, krg.shape[-1]),
            posg.reshape(s, m_ * pl_))


def mla_paged_decode(
    p,
    x: Array,
    pool: dict,
    pos: Array,
    block_tables: Array,
    write_page: Array,
    write_off: Array,
    m: MLAConfig,
    *,
    norm_eps: float,
    impl: str = "ref",
):
    """One-token absorbed-form decode against the paged latent pool.
    x: (S, 1, D).  Returns (out (S, 1, D), new_pool).

    Same math as ``mla_decode`` — absorbed query contracts against cached
    latents, softmax output contracts against the SAME latents — with the
    page gather in place of the per-slot ring.  ``impl="kernel"`` routes
    the contraction through the Pallas paged MLA kernel; ``"ref"`` mirrors
    ``mla_decode``'s exact op sequence (dense-parity numerics), while
    ``kernels/paged_attn/ref.py`` mirrors the kernel's decomposition as
    its oracle — the same two-references split as paged attention.
    """
    from repro.models.attention import _norm_pos

    b = x.shape[0]
    q_nope, q_rope = _queries(p, x, m, norm_eps)       # (S, 1, H, *)
    posb = _norm_pos(pos, b)
    q_rope = apply_rope(q_rope, posb, 10_000.0)
    c_new, kr_new = _latents(p, x, m, norm_eps, posb)  # (S, 1, R), (S, 1, Dr)

    new_pool = mla_paged_cache_update(pool, c_new, kr_new, posb,
                                      write_page, write_off)
    q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, p["w_uk"])
    # python float: jit-safe (the kernel takes it as a static operand)
    scale = 1.0 / float(np.sqrt(m.qk_nope_dim + m.qk_rope_dim))

    if impl == "kernel":
        from repro.kernels.paged_attn import paged_mla_attention

        o_lat = paged_mla_attention(
            q_abs[:, 0], q_rope[:, 0], new_pool["c_kv"], new_pool["k_rope"],
            new_pool["pos"], block_tables, posb[:, 0],
            scale=scale)[:, None]
    else:
        cg, krg, posg = mla_gather_pages(new_pool, block_tables)
        s = jnp.einsum("bthr,bsr->bhts", q_abs, cg.astype(q_abs.dtype),
                       preferred_element_type=F32)
        s += jnp.einsum("bthk,bsk->bhts", q_rope, krg.astype(q_rope.dtype),
                        preferred_element_type=F32)
        s *= scale
        valid = (posg >= 0) & (posg <= posb)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        pa = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", pa, cg.astype(pa.dtype))
    o = jnp.einsum("bthr,rhk->bthk", o_lat.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    return out, new_pool


def mla_cache_from_prefill(c_kv: Array, k_rope: Array, s_len: int, prefill_len) -> dict:
    b, t, _ = c_kv.shape
    pad = s_len - t
    ckv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
    kr = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    pos = jnp.broadcast_to(jnp.arange(s_len)[None], (b, s_len)).astype(jnp.int32)
    valid = pos < jnp.asarray(prefill_len).reshape(-1, 1)
    return {"c_kv": ckv, "k_rope": kr, "pos": jnp.where(valid, pos, -1)}
