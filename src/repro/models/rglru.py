"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (the paper's "recurrent block"):
    x -> [W_x -> causal conv1d -> RG-LRU]  ⊙  gelu(W_gate x) -> W_out

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)          (input gate)
    log a_t = -c * softplus(Λ) * r_t      (Λ learnable, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

Training uses an associative scan over T (log-depth on TPU); decode is the
exact one-step recurrence.  A prefix is always a valid computation, so RPC
physical truncation composes (NAT applicability, DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import RGLRUConfig
from repro.models.params import ParamDecl

Array = jax.Array
F32 = jnp.float32


def rglru_decl(d_model: int, cfg: RGLRUConfig):
    w = cfg.lru_width or d_model
    return {
        "w_x": ParamDecl((d_model, w), ("embed", "lru_width")),
        "w_gate": ParamDecl((d_model, w), ("embed", "lru_width")),
        "conv_w": ParamDecl((cfg.conv_width, w), ("conv", "lru_width"), scale=0.5),
        "conv_b": ParamDecl((w,), ("lru_width",), init="zeros"),
        "w_a": ParamDecl((w, w), ("lru_width", "lru_width")),
        "b_a": ParamDecl((w,), ("lru_width",), init="zeros", dtype=jnp.float32),
        "w_i": ParamDecl((w, w), ("lru_width", "lru_width")),
        "b_i": ParamDecl((w,), ("lru_width",), init="zeros", dtype=jnp.float32),
        # Λ init so that a ≈ 0.9..0.999 at r=1 (softplus(Λ) ~ U[...])
        "lam": ParamDecl((w,), ("lru_width",), init="value", value=-1.0,
                         dtype=jnp.float32),
        "w_out": ParamDecl((w, d_model), ("lru_width", "embed")),
    }


_CHUNK = 256


def _linear_scan(a: Array, b: Array) -> Array:
    """h_t = a_t h_{t-1} + b_t over axis 1, two-level:

    associative scan WITHIN chunks of 256 + a lax.scan carrying state
    ACROSS chunks.  Equivalent math, but HLO stays O(log chunk) + one loop
    body instead of O(log T) full-width stages — compiles ~10x faster at
    T=4k..512k and keeps intermediates chunk-sized (the same trick as the
    SSD chunking in ssm.py)."""
    bsz, t, w = a.shape
    q = min(_CHUNK, t)
    pad = (-t) % q
    if pad:  # identity transitions on the tail
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // q
    ac = a.reshape(bsz, nc, q, w)
    bc = b.reshape(bsz, nc, q, w)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    # within-chunk prefix: h_local[i] assuming zero carry, plus the prefix
    # products needed to apply the carry
    a_pref, h_local = jax.lax.associative_scan(combine, (ac, bc), axis=2)

    def step(carry, xs):
        a_p, h_l = xs                       # (B, q, W)
        h = h_l + a_p * carry[:, None, :]
        return h[:, -1], h

    _, h = jax.lax.scan(step, jnp.zeros((bsz, w), b.dtype),
                        (jnp.moveaxis(a_pref, 1, 0), jnp.moveaxis(h_local, 1, 0)))
    h = jnp.moveaxis(h, 0, 1).reshape(bsz, nc * q, w)
    return h[:, :t]


def _conv1d(x: Array, w: Array, b: Array, seg=None) -> Array:
    """``seg`` (B, T) masks taps that would read across a packed-segment
    boundary — identical to the zero left-padding a padded-row start sees."""
    k = w.shape[0]
    t = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    if seg is None:
        out = sum(xp[:, i:i + t] * w[i][None, None, :] for i in range(k))
    else:
        sp = jnp.pad(seg, ((0, 0), (k - 1, 0)), constant_values=-2)
        out = sum(
            jnp.where((sp[:, i:i + t] == seg)[:, :, None], xp[:, i:i + t], 0)
            * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _gates(p, xb: Array, cfg: RGLRUConfig):
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xb, p["w_a"]).astype(F32)
                       + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xb, p["w_i"]).astype(F32)
                       + p["b_i"])
    log_a = -cfg.c_exponent * jax.nn.softplus(p["lam"]) * r       # (B, T, W) <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    return a, beta * i


def rglru_apply(p, x: Array, cfg: RGLRUConfig, *, lengths=None,
                return_state: bool = False, segment_ids=None):
    """Full-sequence recurrent block.  x: (B, T, D).

    ``lengths`` (B,) marks valid prefixes: padded positions become identity
    transitions (a=1, input 0) so the final recurrent state equals the state
    at position lengths-1.

    ``segment_ids`` (B, T) activates packed-segment state resets
    (capability table ``state_reset='zero'``): conv taps never read across
    a boundary, and a_t = 0 at every segment start, so h_start = b_start —
    exactly the padded-row recurrence from a zero state.  (Exact, not
    bitwise: the two-level scan reassociates f32 sums at packed offsets —
    DESIGN.md §9.)"""
    t = x.shape[1]
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_gate"]).astype(F32))
    xb_raw = jnp.einsum("btd,dw->btw", x, p["w_x"])
    xb = _conv1d(xb_raw, p["conv_w"], p["conv_b"], segment_ids)
    a, scale_in = _gates(p, xb, cfg)
    bterm = scale_in * xb.astype(F32)
    if lengths is not None:
        valid = (jnp.arange(t)[None, :] < lengths[:, None])[:, :, None]
        a = jnp.where(valid, a, 1.0)
        bterm = jnp.where(valid, bterm, 0.0)
    if segment_ids is not None:
        start = jnp.concatenate(
            [jnp.ones_like(segment_ids[:, :1], bool),
             segment_ids[:, 1:] != segment_ids[:, :-1]], axis=1)
        a = jnp.where(start[:, :, None], 0.0, a)

    h = _linear_scan(a, bterm)
    y = (h * gate).astype(x.dtype)
    out = jnp.einsum("btw,wd->btd", y, p["w_out"])
    if return_state:
        from repro.models.ssm import conv_tail_at

        return out, {"h": h[:, -1].astype(F32),
                     "conv": conv_tail_at(xb_raw, p["conv_w"].shape[0], lengths)}
    return out, None


def rglru_decode(p, x: Array, cache: dict, cfg: RGLRUConfig):
    """One-step recurrence.  x: (B, 1, D).
    cache: {"h": (B, W) f32, "conv": (B, K-1, W) f32}."""
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_gate"]).astype(F32))
    xb_new = jnp.einsum("btd,dw->btw", x, p["w_x"])                 # (B,1,W)
    k = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"].astype(xb_new.dtype), xb_new], axis=1)
    w = p["conv_w"]
    xb = sum(hist[:, i:i + 1] * w[i][None, None, :] for i in range(k))
    xb = xb + p["conv_b"][None, None, :]
    new_conv = hist[:, 1:, :].astype(F32)

    a, scale_in = _gates(p, xb, cfg)                                # (B,1,W)
    h = a[:, 0] * cache["h"] + (scale_in * xb.astype(F32))[:, 0]
    y = (h[:, None, :] * gate).astype(x.dtype)
    out = jnp.einsum("btw,wd->btd", y, p["w_out"])
    return out, {"h": h, "conv": new_conv}


def rglru_cache_decl(batch: int, d_model: int, cfg: RGLRUConfig):
    w = cfg.lru_width or d_model
    return {
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), jnp.float32),
    }


def rglru_cache_axes():
    return {"h": ("batch", "lru_width"), "conv": ("batch", "conv", "lru_width")}
