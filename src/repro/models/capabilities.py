"""Per-mixer capability table: which batch layouts and rollout engines each
mixer kind legally supports, and how it isolates packed segments.

Before this table existed, "which config runs which fast path" lived in
scattered ``raise`` guards (``models/blocks.py``, ``rl/engine.py``,
``models/model.py``) and silent fallbacks (``rl/async_trainer.py`` would
happily run a packed-capable config on the padded grid).  Every consumer now
asks one table, every rejection names its row, and
``tests/test_config_matrix.py`` sweeps configs x layouts x engines to pin
that each config exercises the fastest path its rows permit (DESIGN.md §9).

Row semantics:

* ``packed_ok``       — the mixer can run ``PackedLayout`` rows: per-token
  outputs depend only on same-segment tokens.  Attention kinds mask on
  segment equality (bitwise vs the padded grid); recurrent kinds zero their
  state at segment starts (exact in math, ULP-level reassociation vs the
  padded grid — see ``state_reset``).
* ``paged_ok``        — the mixer runs under ``PagedRolloutEngine``: either
  pool-resident (per-token KV pages named by block tables) or per-slot
  (O(1)/window-bounded state widened to the slot axis).
* ``shared_prefix_ok``— per-token state lives in the shared page pool, so a
  group's prompt pages can be refcount-shared across siblings and parked
  siblings can resume on freed slots.  Per-slot-state mixers place groups
  atomically instead.
* ``state_reset``     — packed-segment isolation mechanism: ``"mask"``
  (stateless across tokens; visibility masked on segment equality),
  ``"zero"`` (recurrent state + conv taps zeroed at segment boundaries), or
  ``"unsupported"``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.config import ModelConfig


class CapabilityError(ValueError):
    """A config asked for a layout/engine its capability row forbids."""


@dataclasses.dataclass(frozen=True)
class MixerCapability:
    kind: str
    packed_ok: bool
    paged_ok: bool
    shared_prefix_ok: bool
    state_reset: str          # "mask" | "zero" | "unsupported"
    notes: str


CAPABILITIES = {
    "attn": MixerCapability(
        "attn", packed_ok=True, paged_ok=True, shared_prefix_ok=True,
        state_reset="mask",
        notes="global KV pages in the shared pool; bitwise packed parity"),
    "local": MixerCapability(
        "local", packed_ok=True, paged_ok=True, shared_prefix_ok=False,
        state_reset="mask",
        notes="window ring stays per-slot (already O(window)); packed mask "
              "windows on ORIGINAL positions"),
    "mla": MixerCapability(
        "mla", packed_ok=True, paged_ok=True, shared_prefix_ok=True,
        state_reset="mask",
        notes="compressed latent (c_kv, k_rope) pages in the shared pool "
              "(smaller page stride than full KV)"),
    "ssm": MixerCapability(
        "ssm", packed_ok=True, paged_ok=True, shared_prefix_ok=False,
        state_reset="zero",
        notes="SSD state + conv taps zeroed at segment starts; per-slot "
              "O(1) state in the paged engine"),
    "rec": MixerCapability(
        "rec", packed_ok=True, paged_ok=True, shared_prefix_ok=False,
        state_reset="zero",
        notes="RG-LRU a_t=0 at segment starts (+ conv tap masking); "
              "per-slot O(1) state in the paged engine"),
    "xattn": MixerCapability(
        "xattn", packed_ok=False, paged_ok=False, shared_prefix_ok=False,
        state_reset="unsupported",
        notes="image K/V is shared across ALL tokens of a row; packing "
              "would cross-attend packed neighbors to the wrong image, and "
              "no rollout engine provides image embeddings"),
}

_LAYOUT_ORDER = ("packed", "bucketed", "padded")       # fastest first
_ENGINE_ORDER = ("paged", "continuous", "legacy")      # fastest first


def capability(kind: str) -> MixerCapability:
    try:
        return CAPABILITIES[kind]
    except KeyError as e:
        raise CapabilityError(
            f"unknown mixer kind {kind!r}; capability table rows: "
            f"{sorted(CAPABILITIES)}") from e


def describe_row(kind: str) -> str:
    c = capability(kind)
    return (f"capability row {kind!r}: packed_ok={c.packed_ok} "
            f"paged_ok={c.paged_ok} shared_prefix_ok={c.shared_prefix_ok} "
            f"state_reset={c.state_reset!r} ({c.notes})")


def require_packed_mixer(kind: str) -> None:
    """Raise unless this mixer kind supports packed (segment-id) rows."""
    if not capability(kind).packed_ok:
        raise CapabilityError(
            f"packed layout (segment_ids) is not supported for {kind!r} "
            f"mixers — {describe_row(kind)}")


def config_mixers(cfg: ModelConfig) -> Tuple[str, ...]:
    """Ordered unique mixer kinds a config's block patterns use."""
    seen: list = []
    for pattern, _repeat in cfg.blocks:
        for kind in pattern:
            m = cfg.mixer_of(kind)
            if m not in seen:
                seen.append(m)
    return tuple(seen)


def _packed_blocker(cfg: ModelConfig) -> Optional[str]:
    if cfg.num_codebooks:
        return (f"num_codebooks={cfg.num_codebooks}: packed logp parity is "
                "only defined for single-plane token grids")
    for m in config_mixers(cfg):
        if not capability(m).packed_ok:
            return describe_row(m)
    return None


def _paged_blocker(cfg: ModelConfig) -> Optional[str]:
    if cfg.num_codebooks:
        return (f"num_codebooks={cfg.num_codebooks}: the slot arena serves "
                "single-plane token streams")
    for m in config_mixers(cfg):
        if not capability(m).paged_ok:
            return describe_row(m)
    return None


def check_packed(cfg: ModelConfig) -> None:
    """Config-time gate for ``layout='packed'`` — raises at construction,
    not deep inside the learner's first jitted step."""
    why = _packed_blocker(cfg)
    if why is not None:
        raise CapabilityError(f"layout 'packed' is illegal for this config "
                              f"— {why}")


def check_paged(cfg: ModelConfig) -> None:
    """Config-time gate for ``PagedRolloutEngine``."""
    why = _paged_blocker(cfg)
    if why is not None:
        raise CapabilityError(f"the paged rollout engine is illegal for "
                              f"this config — {why}")


def check_engine(cfg: ModelConfig, engine: str) -> None:
    """Config-time gate for any rollout engine by name."""
    why = _engine_blocker(cfg, engine)
    if why is not None:
        raise CapabilityError(f"rollout engine {engine!r} is illegal for "
                              f"this config — {why}")


def prefix_cache_ok(cfg: ModelConfig) -> bool:
    """True when the cross-request radix prefix cache (DESIGN.md §10) can
    serve this config: every mixer must be global attention, whose pool
    pages hold the complete per-token state (post-rope K/V) needed to
    resume a prefill mid-prompt.  Window rings would need cross-splice
    window bookkeeping, MLA latents a latent-resume prefill, and ssm/rec
    carry O(1) state that cannot be re-entered at a page boundary."""
    return all(m == "attn" for m in config_mixers(cfg))


def check_prefix_cache(cfg: ModelConfig) -> None:
    """Config-time gate for ``PagedEngineConfig(prefix_cache=True)``."""
    if prefix_cache_ok(cfg):
        return
    bad = next(m for m in config_mixers(cfg) if m != "attn")
    raise CapabilityError(
        "the radix prefix cache requires a pure global-attention stack "
        f"(full-KV pool pages support partial-prefix prefill resume) — "
        f"{describe_row(bad)}")


def paged_score_ok(cfg: ModelConfig) -> bool:
    """True when the learner can teacher-force directly from the rollout
    engine's paged KV pool (zero re-prefill scoring, DESIGN.md §11): every
    mixer must be global attention, whose pool pages hold the complete
    per-token state (post-rope K/V) the paged prefill kernel consumes.
    Window rings and ssm/rec states are per-slot (gone once the slot is
    recycled) and MLA latents would need a latent-score kernel."""
    return not cfg.num_codebooks and all(
        m == "attn" for m in config_mixers(cfg))


def check_paged_score(cfg: ModelConfig) -> None:
    """Config-time gate for learner page-backed scoring
    (``score_tokens(paged_prefix=...)`` / ``make_train_step(paged=True)``)."""
    if paged_score_ok(cfg):
        return
    if cfg.num_codebooks:
        raise CapabilityError(
            "paged scoring is illegal for this config — num_codebooks="
            f"{cfg.num_codebooks}: the paged pool serves single-plane "
            "token streams")
    bad = next(m for m in config_mixers(cfg) if m != "attn")
    raise CapabilityError(
        "zero re-prefill (paged) scoring requires a pure global-attention "
        f"stack (full-KV pool pages feed the paged prefill kernel) — "
        f"{describe_row(bad)}")


def slice_handoff_ok(cfg: ModelConfig) -> bool:
    """True when a group's prefill state can hand off across mesh slices
    (prefill cells on one slice, paged decode on another — DESIGN.md §12):
    the prefill output shipped device-to-device must be the COMPLETE prompt
    state, i.e. every mixer pool-resident (attn full KV, mla latents) so
    the handoff is (prompt logits, page payloads) and nothing else.
    Per-slot sequence state (local rings, ssm/rec) lives outside the page
    pool and would be stranded on the prefill slice."""
    return not cfg.num_codebooks and pure_pool_prefix(cfg)


def check_slice_handoff(cfg: ModelConfig) -> None:
    """Config-time gate for prefill/decode disaggregation
    (``--disagg prefill,decode`` / ``DisaggPagedRolloutEngine``)."""
    if slice_handoff_ok(cfg):
        return
    if cfg.num_codebooks:
        raise CapabilityError(
            "prefill/decode disaggregation is illegal for this config — "
            f"num_codebooks={cfg.num_codebooks}: the paged pool serves "
            "single-plane token streams")
    bad = next(m for m in config_mixers(cfg) if not pool_resident(m))
    raise CapabilityError(
        "prefill/decode disaggregation requires every mixer's prompt state "
        "to be pool-resident (the cross-slice handoff ships page payloads "
        f"+ prompt logits, nothing per-slot) — {describe_row(bad)}")


def pool_resident(kind: str) -> bool:
    """True when this mixer's per-token state lives in the shared page pool
    (so group prefix pages can be refcount-shared / parked siblings can
    resume on freed slots)."""
    return capability(kind).shared_prefix_ok


def pure_pool_prefix(cfg: ModelConfig) -> bool:
    """All mixers pool-resident -> groups need not be placed atomically."""
    return all(pool_resident(m) for m in config_mixers(cfg))


def _engine_blocker(cfg: ModelConfig, engine: str) -> Optional[str]:
    if engine == "legacy":
        if any(m == "xattn" for m in config_mixers(cfg)):
            return ("no rollout path provides image embeddings — "
                    + describe_row("xattn"))
        return None
    if engine == "continuous":
        if cfg.num_codebooks:
            return (f"num_codebooks={cfg.num_codebooks}: the slot arena "
                    "serves single-plane token streams")
        if any(m == "xattn" for m in config_mixers(cfg)):
            return ("no rollout path provides image embeddings — "
                    + describe_row("xattn"))
        return None
    if engine == "paged":
        if any(m == "xattn" for m in config_mixers(cfg)):
            return ("no rollout path provides image embeddings — "
                    + describe_row("xattn"))
        return _paged_blocker(cfg)
    raise CapabilityError(f"unknown engine {engine!r}; expected one of "
                          f"{_ENGINE_ORDER}")


def legal_layouts(cfg: ModelConfig) -> Tuple[str, ...]:
    return tuple(n for n in _LAYOUT_ORDER
                 if n != "packed" or _packed_blocker(cfg) is None)


def legal_engines(cfg: ModelConfig) -> Tuple[str, ...]:
    return tuple(n for n in _ENGINE_ORDER
                 if _engine_blocker(cfg, n) is None)


def fastest_layout(cfg: ModelConfig) -> str:
    return legal_layouts(cfg)[0]


def fastest_engine(cfg: ModelConfig) -> Optional[str]:
    """Fastest legal rollout engine, or None when no engine serves the
    config (vision: nothing feeds image embeddings to a rollout)."""
    legal = legal_engines(cfg)
    return legal[0] if legal else None


def coverage_cells(archs=None):
    """All legal (config, layout, engine) cells plus each config's fastest
    pair — the coverage surface ``tests/test_config_matrix.py`` exercises
    and ``benchmarks/check_gates.py`` gates (the count may never shrink)."""
    from repro.configs import ALL_ARCHS, get_config

    cells = []
    for arch in (archs if archs is not None else ALL_ARCHS):
        cfg = get_config(arch)
        for layout in legal_layouts(cfg):
            for engine in legal_engines(cfg) or (None,):
                cells.append((arch, layout, engine))
    return cells


def render_matrix(archs=None) -> str:
    """Markdown matrix of config -> (mixers, fastest layout, fastest
    engine) — the rendered table DESIGN.md §9 embeds."""
    from repro.configs import ALL_ARCHS, get_config

    rows = ["| config | mixers | fastest layout | fastest engine |",
            "|---|---|---|---|"]
    for arch in (archs if archs is not None else ALL_ARCHS):
        cfg = get_config(arch)
        rows.append(
            f"| {arch} | {'+'.join(config_mixers(cfg))} "
            f"| {fastest_layout(cfg)} | {fastest_engine(cfg) or '—'} |")
    return "\n".join(rows)
