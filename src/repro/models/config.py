"""ModelConfig: one composable description covering all 10 assigned
architecture families (dense / GQA / SWA / local:global / cross-attn VLM /
MLA / MoE / SSD / RG-LRU / codebook-audio)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# A block kind is "mixer" or "mixer:mlp_override".
#   mixers: attn (full causal), local (sliding window), xattn (cross-attn to
#           image embeds), mla (latent attention), ssm (mamba-2 SSD),
#           rec (RG-LRU)
#   mlp override: "moe" routes this layer's MLP through experts; "dense"
#           forces the dense MLP; "none" removes the MLP (mamba blocks).
BlockGroups = Tuple[Tuple[Tuple[str, ...], int], ...]  # ((pattern, repeat), ...)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    num_shared: int = 0          # shared (always-on) experts, DeepSeek-style
    d_ff_expert: int = 1024
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536      # 0 = full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64           # P; n_heads = d_inner / head_dim
    expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128             # SSD chunk length
    n_groups: int = 1            # B/C groups


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0           # 0 -> d_model
    conv_width: int = 4
    c_exponent: float = 8.0      # a_t = exp(c * softplus(Lambda) * r_t) decay


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    blocks: BlockGroups
    mlp_kind: str = "swiglu"          # swiglu | relu2 | geglu
    window: int = 4096                # for "local" mixers
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logits_softcap: float = 0.0       # gemma-style tanh soft cap (0 = off)
    emb_scale_by_dim: bool = False    # gemma multiplies embeds by sqrt(d)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # modality frontend stubs
    num_image_tokens: int = 0         # vlm: length of precomputed patch embeds
    num_codebooks: int = 0            # audio: EnCodec codebooks (0 = text LM)
    # numerics / training
    remat_policy: str = "full"        # none | full | dots
    scan_layers: bool = True
    # Shard residual seq dim over "model" (Megatron SP).  Default OFF: §Perf
    # measured 2.7x collective inflation (per-layer activation all-gathers +
    # grad psums over both axes) while full remat already bounds activation
    # memory — SP pays off only when remat is off and memory binds.
    seq_parallel: bool = False
    # long_500k eligibility override (None -> derived: no full-span attention;
    # mostly-local archs like gemma3 set True explicitly per DESIGN.md)
    long_context_ok: Optional[bool] = None

    @property
    def n_layers(self) -> int:
        return sum(len(p) * r for p, r in self.blocks)

    def mixer_of(self, kind: str) -> str:
        return kind.split(":")[0]

    def mlp_of(self, kind: str) -> str:
        parts = kind.split(":")
        if len(parts) > 1:
            # ":dense" forces the config's dense MLP kind; ":moe"/":none" literal
            return self.mlp_kind if parts[1] == "dense" else parts[1]
        if self.mixer_of(kind) == "ssm":
            return "none"             # mamba blocks are mixer-only
        return "moe" if self.moe is not None and self._default_moe else self.mlp_kind

    @property
    def _default_moe(self) -> bool:
        # if a config has MoE and never says ":moe"/":dense" explicitly,
        # every MLP is routed (qwen3-moe style)
        return not any(":" in k for p, _ in self.blocks for k in p)

    @property
    def is_recurrent_only(self) -> bool:
        mixers = {self.mixer_of(k) for p, _ in self.blocks for k in p}
        return mixers <= {"ssm", "rec"}

    @property
    def has_full_attention(self) -> bool:
        return any(self.mixer_of(k) in ("attn", "xattn", "mla")
                   for p, _ in self.blocks for k in p)

    @property
    def supports_long_context(self) -> bool:
        """Eligible for the long_500k decode shape.  Default: no full-span
        self-attention mixers (xattn spans only the fixed image tokens, so it
        does not disqualify).  Configs may override via ``long_context_ok``."""
        if self.long_context_ok is not None:
            return self.long_context_ok
        return not any(self.mixer_of(k) in ("attn", "mla")
                       for p, _ in self.blocks for k in p)


def dense_blocks(n_layers: int, mixer: str = "attn") -> BlockGroups:
    return (((mixer,), n_layers),)
