"""Shared primitive layers: RMSNorm, RoPE, dense MLP variants, embeddings,
and the chunked logprob head (never materializes the (B, T, V) softmax)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamDecl

Array = jax.Array
F32 = jnp.float32


# ------------------------------------------------------- gradient barrier
@jax.custom_vjp
def bf16_grad(x: Array) -> Array:
    """Identity forward; cotangent is rounded through bf16 on the way back.

    Gradient compression for the cross-device psums of activation
    gradients: rmsnorm/softmax compute in f32, and their transposes upcast
    the whole residual cotangent to f32 — which doubles every backward
    all-reduce.  Placing this barrier at block boundaries keeps the maths
    fp32 inside the block but ships bf16 across devices (§Perf)."""
    return x


def _bg_fwd(x):
    return x, None


def _bg_bwd(_, ct):
    return (ct.astype(jnp.bfloat16).astype(ct.dtype),)


bf16_grad.defvjp(_bg_fwd, _bg_bwd)


# ----------------------------------------------------------------- RMSNorm
def rmsnorm_decl(dim: int):
    return {"scale": ParamDecl((dim,), ("embed",), init="zeros")}


def rmsnorm(p, x: Array, eps: float) -> Array:
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    # (1 + scale): zero-init keeps init statistics; gemma/llama convention
    return (y * (1.0 + p["scale"].astype(F32))).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> Array:
    d2 = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(d2, dtype=F32) / d2))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, D) with D even; positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(F32) * freqs     # (..., T, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- MLP zoo
def mlp_decl(d_model: int, d_ff: int, kind: str):
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDecl((d_model, d_ff), ("embed", "mlp")),
            "w_up": ParamDecl((d_model, d_ff), ("embed", "mlp")),
            "w_down": ParamDecl((d_ff, d_model), ("mlp", "embed")),
        }
    if kind == "relu2":  # nemotron squared-ReLU, no gate
        return {
            "w_up": ParamDecl((d_model, d_ff), ("embed", "mlp")),
            "w_down": ParamDecl((d_ff, d_model), ("mlp", "embed")),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_apply(p, x: Array, kind: str) -> Array:
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w_gate"]))
        h = h * jnp.einsum("...d,df->...f", x, p["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_gate"]))
        h = h * jnp.einsum("...d,df->...f", x, p["w_up"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", x, p["w_up"])))
    else:
        raise ValueError(kind)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ------------------------------------------------------------- Embeddings
def embed_decl(vocab: int, d_model: int, num_codebooks: int = 0):
    if num_codebooks:
        return {"table": ParamDecl((num_codebooks, vocab, d_model),
                                   ("codebooks", "vocab", "embed"), scale=1.0)}
    return {"table": ParamDecl((vocab, d_model), ("vocab", "embed"), scale=1.0)}


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _sharded_gather(table: Array, tokens: Array, shard) -> Array:
    return jnp.take(table, tokens, axis=0)


def _sg_fwd(table, tokens, shard):
    return _sharded_gather(table, tokens, shard), (tokens, table)


def _sg_bwd(shard, res, dx):
    """Embedding-gradient scatter, SPMD-efficient (§Perf, two iterations):

    1. Constraining the accumulator keeps XLA from replicating the scatter
       (35.2 -> 0.56 GiB/device temp on the nemotron head).
    2. Sharding the EMBED dim over every mesh axis ("opt_blocks") while the
       vocab dim stays local makes the scatter row-local: the only traffic
       is an all-to-all of dx plus the final reshard of the table gradient
       (4.8 GB -> ~0.1 GB/device per microbatch measured on nemotron-340b).
    """
    tokens, table = res
    dt = jnp.zeros(table.shape, jnp.float32)
    upd = dx.reshape(-1, table.shape[-1]).astype(jnp.float32)
    if shard is not None:
        # vocab rows stay LOCAL (unsharded); embed columns split over every
        # mesh axis -> the scatter needs no cross-device routing of rows
        dt = shard(dt, (None, "opt_blocks"))
        upd = shard(upd, (None, "opt_blocks"))
    dt = dt.at[tokens.reshape(-1)].add(upd)
    if shard is not None:
        dt = shard(dt, ("vocab", "embed"))
    return dt.astype(table.dtype), None


_sharded_gather.defvjp(_sg_fwd, _sg_bwd)


def embed_apply(p, tokens: Array, *, scale: Optional[float] = None,
                shard=None) -> Array:
    """tokens: (B, T) int32 -> (B, T, D); or (B, T, K) for codebook models
    (embeds are summed over codebooks, MusicGen-style)."""
    table = p["table"]
    if tokens.ndim == 3:  # (B, T, K)
        k = table.shape[0]
        embs = [_sharded_gather(table[i], tokens[..., i], shard)
                for i in range(k)]
        x = sum(embs)
    else:
        x = _sharded_gather(table, tokens, shard)
    if scale is not None:
        x = (x.astype(F32) * scale).astype(x.dtype)
    return x


def head_decl(vocab: int, d_model: int, num_codebooks: int = 0, tied: bool = False):
    if tied:
        return {}
    if num_codebooks:
        return {"w": ParamDecl((num_codebooks, d_model, vocab),
                               ("codebooks", "embed", "vocab"))}
    return {"w": ParamDecl((d_model, vocab), ("embed", "vocab"))}


def head_weight(head_p, embed_p, tied: bool) -> Array:
    if tied:
        t = embed_p["table"]
        return jnp.swapaxes(t, -1, -2)  # (V, D) -> (D, V) (or (K,V,D)->(K,D,V))
    return head_p["w"]


def logits_apply(w: Array, x: Array, softcap: float = 0.0) -> Array:
    """x: (B, T, D) -> (B, T, V) (or (B, T, K, V) for codebook heads)."""
    if w.ndim == 3:  # (K, D, V)
        out = jnp.einsum("btd,kdv->btkv", x, w,
                         preferred_element_type=F32)
    else:
        out = jnp.einsum("btd,dv->btv", x, w, preferred_element_type=F32)
    if softcap:
        out = jnp.tanh(out / softcap) * softcap
    return out


# ------------------------------------------------- Chunked logprob scoring
def chunked_token_logprobs(
    w: Array,
    x: Array,
    tokens: Array,
    *,
    softcap: float = 0.0,
    num_chunks: int = 8,
    with_entropy: bool = False,
    shard=None,
):
    """log pi(token) (+ optional entropy) without materializing (B, T, V).

    Scans over vocab chunks keeping running (max, sumexp, dot) statistics —
    the pure-jnp analogue of the fused Pallas HT-loss head (kernels/ht_loss).
    The sharding constraint on the reshaped W keeps the dW accumulator
    vocab-sharded through the scan transpose (17.6 -> 1.1 GiB/device on the
    nemotron head, EXPERIMENTS.md §Perf).

    w: (D, V); x: (B, T, D); tokens: (B, T) -> logp (B, T) float32.
    """
    v = w.shape[-1]
    assert v % num_chunks == 0, (v, num_chunks)
    cs = v // num_chunks
    wc = w.reshape(w.shape[0], num_chunks, cs)        # (D, C, cs)
    if shard is not None:
        # Megatron-style vocab-parallel head: gather activations over the
        # seq-parallel axis ONCE (bf16, small), keep W chunks vocab-sharded
        # with full D, and leave logits vocab-sharded — the per-token stats
        # then need only tiny all-reduces.  Without these constraints the
        # partitioner all-gathered fp32 hidden over the whole mesh
        # (4.8 GB/microbatch on nemotron-340b — EXPERIMENTS.md §Perf).
        wc = shard(wc, (None, None, "vocab"))
        x = shard(x, ("batch", None, None))

    def chunk(carry, ci):
        m, s, tl, ent_dot = carry
        logits = jnp.einsum("btd,dv->btv", x, wc[:, ci], preferred_element_type=F32)
        if shard is not None:
            logits = shard(logits, ("batch", None, "vocab"))
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        cmax = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, cmax)
        correction = jnp.exp(m - new_m)
        s = s * correction + jnp.sum(jnp.exp(logits - new_m[..., None]), axis=-1)
        if with_entropy:
            ent_dot = ent_dot * correction + jnp.sum(
                jnp.exp(logits - new_m[..., None]) * logits, axis=-1)
        # target logit if it falls in this chunk
        local = tokens - ci * cs
        in_chunk = (local >= 0) & (local < cs)
        idx = jnp.clip(local, 0, cs - 1)
        got = jnp.take_along_axis(logits, idx[..., None], axis=-1)[..., 0]
        tl = jnp.where(in_chunk, got, tl)
        return (new_m, s, tl, ent_dot), ()

    b, t = tokens.shape
    init = (jnp.full((b, t), -jnp.inf, F32), jnp.zeros((b, t), F32),
            jnp.zeros((b, t), F32), jnp.zeros((b, t), F32))
    (m, s, tl, ent_dot), _ = jax.lax.scan(
        jax.checkpoint(chunk), init, jnp.arange(num_chunks))
    logz = m + jnp.log(s)
    logp = tl - logz
    if with_entropy:
        entropy = logz - ent_dot / s
        return logp, entropy
    return logp
